"""Per-op collective/traffic breakdown of a dry-run cell (the 'profile' of
the CPU-only perf loop). Usage:

  PYTHONPATH=src python -m benchmarks.collective_breakdown \
      --arch gemma3_27b --shape train_4k [--opt k=v,...] [--top 15] [--kind coll|mem]
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
from collections import defaultdict  # noqa: E402

import jax  # noqa: E402

from repro import perf_flags  # noqa: E402
from repro.configs import SHAPES, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (  # noqa: E402
    build_decode_step,
    build_prefill_step,
    build_train_step,
)
from repro.models import build_model  # noqa: E402
from repro.roofline import hlo_cost as H  # noqa: E402
from repro.sharding.specs import make_topology, use_topology  # noqa: E402


def lower_cell(arch: str, shape_name: str):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh()
    topo = make_topology(mesh)
    api = build_model(cfg)
    with use_topology(topo):
        if shape.kind == "train":
            step, shapes, _ = build_train_step(api, topo, shape)
            return step.lower(*shapes[:3]).compile(), topo
        if shape.kind == "prefill":
            step, shapes, _ = build_prefill_step(api, topo, shape)
            return step.lower(*shapes).compile(), topo
        step, (ps, bs), _ = build_decode_step(api, topo, shape)
        return step.lower(ps, bs["token"], bs["cache"], bs["cache_len"]).compile(), topo


def breakdown(compiled, topo, kind: str, top: int):
    comps, entry = H.parse_module(compiled.as_text())
    agg = defaultdict(lambda: [0.0, 0.0])  # key -> [bytes, count]

    def walk(comp, mult):
        for op in comp.ops:
            if op.kind == "while":
                body = H._called(op.attrs, "body")
                cond = H._called(op.attrs, "condition")
                trip = H._trip_count(comps[cond], comps) if cond in comps else 1
                if body in comps:
                    walk(comps[body], mult * trip)
                continue
            if op.kind in ("fusion", "call"):
                callee = H._called(op.attrs, "calls") or H._called(op.attrs, "to_apply")
                if callee and callee in comps:
                    walk_fused(comps[callee], mult)
            if kind == "coll":
                continue
            b = H._traffic_bytes(op, comp, comps)
            if b > 0:
                key = (op.kind, op.result_type[:44], "")
                agg[key][0] += b * mult
                agg[key][1] += mult

    def walk_fused(comp, mult):
        for op in comp.ops:
            is_coll = None
            for c in H._COLLECTIVES:
                if op.kind == c or op.kind == c + "-start":
                    is_coll = c
            if is_coll:
                nbytes = H._collective_payload_bytes(op, comp, comps)
                g = H._group_size(op.attrs, topo.model_size)
                meta = ""
                if "metadata" in op.attrs:
                    i = op.attrs.find("op_name=")
                    meta = op.attrs[i + 9 : i + 69] if i >= 0 else ""
                key = (is_coll, op.result_type[:44], meta)
                frac = (g - 1) / g if g > 1 else 0
                wire = 2 * nbytes * frac if is_coll == "all-reduce" else (
                    nbytes if is_coll == "collective-permute" else nbytes * frac
                )
                agg[key][0] += wire * mult
                agg[key][1] += mult
            if op.kind in ("fusion", "call"):
                callee = H._called(op.attrs, "calls") or H._called(op.attrs, "to_apply")
                if callee and callee in comps:
                    walk_fused(comps[callee], mult)

    if kind == "coll":
        # collectives appear at computation scope too
        def walk_coll(comp, mult):
            for op in comp.ops:
                if op.kind == "while":
                    body = H._called(op.attrs, "body")
                    cond = H._called(op.attrs, "condition")
                    trip = H._trip_count(comps[cond], comps) if cond in comps else 1
                    if body in comps:
                        walk_coll(comps[body], mult * trip)
                    continue
                walk_fused_one(op, comp, mult)

        def walk_fused_one(op, comp, mult):
            is_coll = None
            for c in H._COLLECTIVES:
                if op.kind == c or op.kind == c + "-start":
                    is_coll = c
            if is_coll:
                nbytes = H._collective_payload_bytes(op, comp, comps)
                g = H._group_size(op.attrs, topo.model_size)
                import re as _re
                m = _re.search(r'op_name="([^"]{0,80})', op.attrs)
                meta = m.group(1) if m else ""
                key = (is_coll, op.result_type[:44], f"{meta} [{nbytes/1e6:.0f}MB sem]")
                frac = (g - 1) / g if g > 1 else 0
                wire = 2 * nbytes * frac if is_coll == "all-reduce" else (
                    nbytes if is_coll == "collective-permute" else nbytes * frac
                )
                agg[key][0] += wire * mult
                agg[key][1] += mult
                return
            if op.kind in ("fusion", "call"):
                callee = H._called(op.attrs, "calls") or H._called(op.attrs, "to_apply")
                if callee and callee in comps:
                    for o2 in comps[callee].ops:
                        walk_fused_one(o2, comps[callee], mult)

        walk_coll(comps[entry], 1.0)
    else:
        walk(comps[entry], 1.0)

    total = sum(v[0] for v in agg.values())
    print(f"total {kind} bytes/device: {total:.3e}")
    for key, (b, n) in sorted(agg.items(), key=lambda kv: -kv[1][0])[:top]:
        k, rt, meta = key
        print(f"{b:10.3e}  x{n:6.0f}  {k:20s} {rt:44s} {meta}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--opt", default="")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--kind", default="coll", choices=["coll", "mem"])
    args = ap.parse_args()
    perf_flags.parse_opt_string(args.opt)
    compiled, topo = lower_cell(args.arch, args.shape)
    breakdown(compiled, topo, args.kind, args.top)


if __name__ == "__main__":
    main()
