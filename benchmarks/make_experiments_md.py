"""Assemble EXPERIMENTS.md: narrative + auto-generated tables from artifacts.

    PYTHONPATH=src python -m benchmarks.make_experiments_md > EXPERIMENTS.md
"""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.report import (
    ART,
    chunking_table,
    dryrun_table,
    fit_report,
    fmt_s,
    load,
    per_round_table,
    roofline_table,
)

REPO = Path(__file__).resolve().parents[1]


def obs_overhead_section() -> str:
    """Flight-recorder overhead table from BENCH_obs.json; a missing or
    unreadable artifact degrades to a regeneration hint, never a crash
    (EXPERIMENTS.md must build on a fresh checkout)."""
    path = REPO / "benchmarks" / "BENCH_obs.json"
    try:
        report = json.loads(path.read_text())
        d = report["dispatch"]
        r = report["record"]
        rows = [
            "| metric | value |",
            "|---|---|",
            f"| dispatch, recorder on | {d['on_us_per_dispatch']:.1f} us |",
            f"| dispatch, recorder off | {d['off_us_per_dispatch']:.1f} us |",
            f"| measured overhead (best of {d.get('trials', 1)} trials) "
            f"| {d['overhead_frac']*100:.2f}% |",
            f"| derived overhead (events/dispatch x record cost) "
            f"| {d.get('derived_frac', 0.0)*100:.2f}% |",
            f"| raw `record()` cost | {r['per_call_ns']:.0f} ns |",
            f"| events per dispatch | {d.get('events_per_dispatch', 0):.1f} |",
        ]
        return "\n".join(rows)
    except (OSError, ValueError, KeyError, TypeError):
        return (
            "*(no `benchmarks/BENCH_obs.json` artifact — regenerate with "
            "`python -m benchmarks.obs_overhead`)*"
        )


def headline_mfu() -> str:
    """Best roofline fractions achieved (optimized artifacts)."""
    rows = []
    for p in sorted(ART.glob("*__single__opt.json")):
        r = json.loads(p.read_text())
        ro = r["roofline"]
        tb = max(ro["t_compute_s"], ro["t_memory_s"], ro["t_collective_s"])
        if tb <= 0 or r["kind"] == "decode":
            continue
        mfu = r["model_flops_total"] / (tb * ro["n_chips"] * 197e12)
        rows.append((mfu, r["arch"], r["shape"], ro["bottleneck"], tb))
    rows.sort(reverse=True)
    out = ["| rank | arch | shape | MFU@bound | bottleneck |", "|---|---|---|---|---|"]
    for i, (mfu, a, sh, b, tb) in enumerate(rows[:8], 1):
        out.append(f"| {i} | {a} | {sh} | {mfu*100:.1f}% | {b} |")
    return "\n".join(out)


def opt_vs_baseline_table() -> str:
    """Optimized-flag sweep vs baseline, per cell (single pod)."""
    base = {(r["arch"], r["shape"]): r for r in load("single")}
    rows = [
        "| arch | shape | t_bound base | t_bound opt | speedup | bottleneck base -> opt |",
        "|---|---|---|---|---|---|",
    ]
    for p in sorted(ART.glob("*__single__opt.json")):
        r = json.loads(p.read_text())
        key = (r["arch"], r["shape"])
        if key not in base:
            continue
        b = base[key]["roofline"]
        o = r["roofline"]
        tb = max(b["t_compute_s"], b["t_memory_s"], b["t_collective_s"])
        to = max(o["t_compute_s"], o["t_memory_s"], o["t_collective_s"])
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(tb)} | {fmt_s(to)} "
            f"| {tb/to:.2f}x | {b['bottleneck']} -> {o['bottleneck']} |"
        )
    return "\n".join(rows)


def perf_iteration_table(arch: str, shape: str, iters: list) -> str:
    rows = [
        "| iteration | flags | t_compute | t_memory | t_collective | bottleneck |",
        "|---|---|---|---|---|---|",
    ]
    base = ART / f"{arch}__{shape}__single.json"
    series = [("baseline (paper-faithful)", base)]
    for tag, label in iters:
        series.append((label, ART / f"{arch}__{shape}__single__{tag}.json"))
    for label, p in series:
        if not p.exists():
            rows.append(f"| {label} | (missing) | - | - | - | - |")
            continue
        r = json.loads(p.read_text())
        ro = r["roofline"]
        flags = r.get("opt", {})
        on = ",".join(
            f"{k}={v}" for k, v in flags.items()
            if v not in (False, "none", "binomial_tree", 0, 1024)
        ) or "-"
        rows.append(
            f"| {label} | {on} | {fmt_s(ro['t_compute_s'])} "
            f"| {fmt_s(ro['t_memory_s'])} | {fmt_s(ro['t_collective_s'])} "
            f"| {ro['bottleneck']} |"
        )
    return "\n".join(rows)


HEADER = """# EXPERIMENTS — Offloading MPI_Scan (NetFPGA) on a TPU v5e production mesh

All numbers in this file are generated from artifacts
(`benchmarks/artifacts/dryrun/*.json`, written by `repro.launch.dryrun`) or by
`python -m benchmarks.run`; regenerate with
`python -m benchmarks.make_experiments_md`. Hardware constants: TPU v5e,
197 TFLOP/s bf16/chip, 819 GB/s HBM, 50 GB/s/link ICI; single pod = 16x16 =
256 chips, multi-pod = 2x16x16 = 512.

## Paper reproduction (Figs. 4-7)

`python -m benchmarks.run` reproduces the paper's comparison on a simulated
8-rank communicator (mapping in DESIGN.md section 2). Summary of the measured
CSV (full output in bench_output.txt):

* **Offload gap** (Fig. 4/5 analogue): the host-driven schedule ("software
  MPI": one dispatch + sync per hop) costs 450-12000us per scan at 4B-1KB
  payloads; the fused one-program schedule ("offloaded") costs 7-50us —
  a 30-300x gap. This is the paper's architectural point isolated: who
  drives the schedule.
* **Software ordering matches the paper**: among software algorithms,
  sequential is fastest (no synchronization structure, fewest dispatches),
  and the synchronizing algorithms (recursive doubling, binomial) are 3-20x
  worse — the paper's Fig. 4 finding. The paper's nuance that SW-sequential
  beats offloaded on *average* latency (ranks returning early) is not
  reproducible in SPMD timing (all ranks share one program) and is noted as
  a divergence.
* **In-network latency** (Fig. 6/7 analogue): measured fused-program times
  plus the alpha-beta-gamma ICI model at production scale; the selector's
  algo_type crossovers (paper: "runtime makes an intelligent selection")
  appear in the `selector` CSV rows: log-depth algorithms win everywhere at
  p>=16, `binomial_tree` is preferred only off-auto (its 2logp steps but
  sparse per-step traffic), and `sequential` is auto-excluded at p>8 as the
  paper's own conclusion dictates.
"""


def main() -> None:
    print(HEADER)
    print("\n## Dry-run (single pod, 16x16 = 256 chips)\n")
    print(dryrun_table("single"))
    print("\n### Memory fit (16GB HBM/chip)\n")
    print(fit_report("single"))
    print("\n## Dry-run (multi-pod, 2x16x16 = 512 chips)\n")
    print(dryrun_table("multi"))
    print(
        "\nCell accounting: the assignment's 10 archs x 4 shapes = 40 cells; "
        "long_500k is defined only for sub-quadratic families, so 33 cells "
        "are applicable (run above, BOTH meshes, zero failures) and 7 are "
        "documented skips (long_500k for the seven pure full-attention "
        "archs: deepseek-moe, olmoe, whisper, smollm, granite, qwen2.5, "
        "qwen2-vl) per DESIGN.md section 7. gemma3-27b runs long_500k via its 5:1 "
        "sliding-window pattern; mamba2/jamba via SSM state."
    )
    print("\n## Roofline (single pod, baseline = paper-faithful config)\n")
    print(roofline_table("single"))
    print("""
Reading guide: terms are seconds/step from the trip-count-aware HLO cost
model (`repro/roofline/hlo_cost.py`; `cost_analysis()` counts loop bodies
once and is recorded in artifacts as `raw_cost_analysis` for reference).
`useful/HLO` = MODEL_FLOPS / compiled FLOPs (remat + replication waste
shows up here); `MFU@bound` = MODEL_FLOPS / (t_bound x chips x peak).
Decode rows are inherently memory-bound (one token against a large cache);
their MFU is expected to be ~0 and the memory term is the figure of merit.
""")
    print("\n## Perf — headline roofline fractions (optimized, train/prefill cells)\n")
    print(headline_mfu())
    print("""
MFU@bound = MODEL_FLOPS / (dominant-roofline-term x chips x peak): the
fraction of peak the step would reach IF it exactly hit its own roofline
bound — the score of how close the compiled program's work/traffic ratio is
to ideal for its bottleneck. Decode cells are excluded (memory-bound by
construction; their figure of merit is the memory term, see Roofline table).
""")
    print("\n## Perf — hillclimb logs (3 cells) and optimized-vs-baseline\n")
    print("### Cell A: qwen2.5-14b x prefill_32k (worst roofline fraction)\n")
    print(perf_iteration_table("qwen25_14b", "prefill_32k", [
        ("opt_seqshard", "i1: seq_shard_attn"),
        ("opt_i2", "i2: + attn_probs_bf16"),
        ("opt_i3", "i3: + attn_kv_block=4096"),
    ]))
    print("""
* i1 hypothesis: 40 heads don't divide the 16-way axis, so baseline
  replicates ALL attention compute per device (useful/HLO 0.05). Sharding
  flash q-blocks over the model axis predicts ~16x less attention work.
  CONFIRMED: t_memory 138.8s -> 15.3s (9.1x), t_compute 13.3s -> 3.1s (4.3x).
* i2 hypothesis: bf16 probs for the PV matmul cut score-tensor traffic.
  CONFIRMED (small): t_memory -3%.
* i3 hypothesis: 4x larger KV blocks amortize the (m,l,o) rescale traffic.
  CONFIRMED (small): t_memory -2.5%. Stopping: two consecutive <5% changes.
""")
    print("### Cell B: gemma3-27b x train_4k (most collective-bound)\n")
    print(perf_iteration_table("gemma3_27b", "train_4k", [
        ("opt_i1", "i1: remat save_block_outputs"),
        ("opt_i3", "i2: + explicit_tp (shard_map psums)"),
    ]))
    print("""
* i1 hypothesis: default remat re-runs forward TP all-reduces during
  backward; naming the post-collective block outputs in the checkpoint
  policy removes them. CONFIRMED: collective wire bytes 1.337e12 ->
  1.173e12 (-12.3%).
* i2 hypothesis: explicit shard_map psums with bf16 payloads halve the
  remaining AR bytes. REFUTED ON THIS METER: the CPU backend's
  float-normalization widens every reduction to f32 and folds the bf16
  casts away, so the HLO (and the meter) cannot express bf16 wires; on a
  real TPU both baseline and explicit-TP ARs ride the dot's native bf16
  output, so the honest claim is parity, not a win. The explicit-TP path is
  kept (collective placement under our control, verified numerically
  identical) and the lesson is recorded: payload-dtype optimizations must
  be validated on hardware whose HLO can express them.
* Remaining gap analysis: 62 layers x ~4 unavoidable dgrad/fwd ARs of the
  (16,4096,5376) residual; the next lever is architectural (parallel
  attention+MLP blocks share one psum) which would break paper-faithful
  config reproduction, so it is documented, not applied.
""")
    print("### Cell C: mamba2-130m x train_4k (paper-representative: the scan collective)\n")
    print(perf_iteration_table("mamba2_130m", "train_4k", [
        ("opt_i1", "i1: scan_algorithm=hillis_steele"),
        ("opt_i2", "i2: + bf16 scan payload"),
        ("opt_i3", "i3: sklansky (multicast) instead"),
        ("opt_i5", "i4: ssm_chunk 256->128"),
        ("opt_i6", "i5: ssm_chunk 256->64"),
    ]))
    print("""
* i1 hypothesis: the paper-faithful binomial tree costs 2log2(p) steps with
  masked combines; Hillis-Steele needs log2(p) send-only steps, halving
  collective-permutes and removing the (value,valid) masking traffic.
  CONFIRMED: collective wire bytes 8.8GB -> 6.1GB (-31%), t_memory -6%
  (the masking selects it removes are small next to the SSD math). An
  earlier 2.6x memory claim from a pre-final meter version was an
  apples-to-oranges comparison and is corrected here — the meter and all
  artifacts in this file are one version.
* i2 hypothesis: bf16 (decay,state) payloads halve permute bytes. REFUTED ON
  THIS METER (same CPU float-normalization artifact as Cell B i2);
  analytically ~2x on the CP term on TPU, recorded as expected-not-measured.
* i3 hypothesis: Sklansky (the paper's Fig. 3 multicast) should match
  Hillis-Steele latency with fewer messages. REFUTED, instructive: JAX's
  ppermute forbids one-to-many sources, so the multicast decomposes into
  fanout unicasts — measured 2.64x MORE wire bytes (16.1GB vs 6.1GB) and the
  cell flips to collective-bound. The paper's NIC multicast does not
  transfer to the ppermute lowering; with native ICI multicast it would
  (DESIGN.md hardware-adaptation notes).
* i4/i5 hypothesis: smaller SSD chunks shrink the (Q x Q) score tensors.
  REFUTED: inter-chunk state tensors grow faster than score tensors shrink
  (t_memory +10% / +33%); the config default Q=256 is on the knee.
  Stopping: three consecutive non-improvements.
""")
    print("### Cell D (extra budget): deepseek-moe-16b x train_4k (MoE, collective-bound)\n")
    print(perf_iteration_table("deepseek_moe_16b", "train_4k", [
        ("opt", "i1: global production flags"),
        ("opt_i2", "i2: attn_seq_over_tp (replicated projections)"),
    ]))
    print("""
* Profile finding: for this fine-grained MoE (d=2048), the TP-attention dx
  all-reduces are 43% of collective bytes — MORE than the EP all-to-alls
  (19%). The MoE machinery is cheap; the dense attention plumbing is not.
* i1 (remat policy et al.): CONFIRMED, collective 4.10s -> 3.46s (1.19x).
* i2 hypothesis: replicate the attention projections and shard flash
  query-blocks instead — no contraction over a sharded dim means NO dx
  psum at all. REFUTED: collective 3.46s -> 6.67s (1.9x worse). The dx AR
  carries ONE (B,S,d) tensor but the replacement needs K AND V gathered
  (2x the payload) plus the remat re-gather — the napkin missed that
  attention has two activation streams to move but only one gradient
  stream to reduce. TP attention stays optimal even at small d_model.
""")
    print("### Optimized flags vs baseline — every cell (single pod)\n")
    print(
        "Production flags: seq_shard_attn, attn_probs_bf16, remat "
        "save_block_outputs, explicit_tp, scan hillis_steele — selected "
        "PER-ARCH: granite-20b drops explicit_tp (see note below).\n"
    )
    print(opt_vs_baseline_table())
    print("""
Per-arch flag finding (measured): `explicit_tp` REGRESSES MQA/low-KV archs
(granite kv=1: collective 0.83e12 -> 1.30e12, 1.57x worse) because the
replicated-KV branch's backward inside shard_map pays a boundary psum of the
x-cotangent every layer, where the auto-partitioned baseline recomputes that
branch redundantly-but-locally. Rule shipped in the config guidance: enable
explicit_tp only when num_kv_heads divides the model axis. granite's row
above uses its per-arch flags (remat-only: collective -19%, bottleneck flips
collective->memory).
""")
    print("\n## Per-(round, chunk) latency attribution (offload observability)\n")
    print(per_round_table())
    print("""
Each row re-lowers one planned collective through the traced eager sim
interpreter (`lower_sim(plan, traced=True)` under `repro.obs.tracing`):
every communication round emits a span whose duration is that round's
real host dispatch cost, so the table names the single round where the
host-side constant concentrates — per (coll, mesh, raw|fused|chunked) —
instead of one opaque wall-clock number. Chunked variants run C=4
pipelined streaming, so their spans carry (chunk, chunk_round) pipeline
coordinates and the top-round column names the exact pipeline cell; the
chunked host total exceeding the fused one at these benchmark payloads is
expected (C x rounds dispatches, each cheap) — chunking is priced to
engage only past the payload threshold, see the chunked-streaming section
below. Regenerate the underlying section with
`python -m benchmarks.fusion_speedup --per-round --report-json`; full
host+device Perfetto timelines come from
`python -m repro.launch.offload_runtime --trace OUT.json`.
""")
    print("\n## Chunked streaming (pipelined payload chunks)\n")
    print(chunking_table())
    print("""
The tuned-schedule column is what `make_descriptor(optimize="auto",
chunks="auto")` resolved through the measured tuning table for that grid
point — the benchmark asserts the resolved descriptor's (optimized,
chunks) matches the measured winner and that its engine dispatch is
bitwise-equal to the raw lowering. At benchmark payloads (1KB) the winner
is always C=1: pipelining splits the per-round payload B into B/C at the
cost of C-1 extra pipeline-fill rounds, `(R + C - 1) x (alpha + B x
beta / C)`, which only pays off once B x beta dominates alpha. The
chunking-check row measures the crossover point (1MiB on a 2x8 mesh,
interleaved min-of-samples timing) where the best C > 1 wins wall-clock
while staying bitwise-identical — the paper's per-round constant beaten
by streaming, not by removing rounds.
""")
    print("\n## Health monitoring & flight-recorder overhead\n")
    print(obs_overhead_section())
    print("""
The flight recorder (`repro/obs/events.py`) keeps the last 4096
structured events (dispatches, cache misses, deadline misses, remeshes,
straggler flags) in an always-on ring; the table above is the price of
"always-on", measured by `python -m benchmarks.obs_overhead` as
recorder-on vs recorder-off on the cached smoke dispatch path and gated
at 2% by `benchmarks.check_regression`. The health stack on top
(`repro/obs/health.py`) evaluates burn-rate SLOs over the service/engine
telemetry and attributes slow rounds to a named (axis, src, dst) link;
`python -m repro.testing.health_check 2 2` proves a planted 10 ms link
delay is attributed to exactly that link while every result stays
bitwise-identical — see the README's Observability section for the event
schema and endpoints.
""")
    print("""
## Multi-pod note

The 2x16x16 dry-run shards batch over ('pod','data'): per-device argument
and temp bytes halve vs single-pod (tables above), collective schedules gain
the cross-pod gradient all-reduce on the 'pod' axis, and every cell still
compiles — the 'pod' axis is load-bearing. At 1000+ nodes the pod axis is
where int8+error-feedback gradient compression (optim/compression.py,
convergence-parity tested) and the elastic re-mesh path (runtime/fault.py,
recovery-tested) engage.
""")


if __name__ == "__main__":
    main()
