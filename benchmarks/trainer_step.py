"""Trainer-step offload-vs-raw benchmark + the offloaded-training smoke gate.

Drives ``repro.testing.train_offload_check`` in a subprocess (the multi-device
CPU mesh must be fixed before jax import) and re-emits its CSV rows:

  trainer_step,<mode>,<ms_per_step>          -- raw_lax vs offload_engine
  trainer_offload,step,<n>,misses,...        -- per-step dispatch telemetry
  trainer_offload_summary,bitwise_equal,...  -- the CI assertions

The subprocess itself *asserts* (exit status + ALL-OK marker) that the
engine-dispatched step is bitwise equal to the raw shard_map baseline, that
the step-2 dispatch hits the compiled-plan cache, and that recovery adopts
``plan_remesh``'s topology — so a regression fails the benchmark run, not
just a grep.
"""

from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path
from typing import List

REPO = Path(__file__).resolve().parent.parent


def _run_check(args: List[str], timeout: int = 900) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.testing.train_offload_check", *args],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
        cwd=str(REPO),
    )
    if proc.returncode != 0 or "ALL-OK" not in proc.stdout:
        raise RuntimeError(
            f"train_offload_check failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout[-4000:]}\n"
            f"--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return proc.stdout


def _rows(stdout: str) -> List[str]:
    return [
        line
        for line in stdout.splitlines()
        if line.startswith("trainer_step,")
        or line.startswith("trainer_offload")
    ]


def smoke() -> List[str]:
    """CI gate: 2-step trainer on a 2x2 CPU mesh, engine vs raw, bitwise."""
    return _rows(_run_check(["2", "2", "--steps", "2"]))


def run(steps: int = 2, bench_iters: int = 5) -> List[str]:
    """Full report: adds the per-step wall-clock comparison."""
    return _rows(
        _run_check(
            ["2", "2", "--steps", str(steps), "--bench-iters",
             str(bench_iters)]
        )
    )
