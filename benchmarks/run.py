"""Benchmark harness entrypoint: one function per paper table/figure.

  fig4/fig5 (scan_latency)      -- host-visible SW vs offloaded scan latency
  fig6/fig7 (offloaded_latency) -- in-network latency per algorithm + the
                                   derived ICI model + selector crossovers
  tuned_vs_static               -- autotuner crossover report + engine smoke
                                   + planned-collective sections: tuned vs
                                   fixed axis splits and the 3D planner
                                   cache-hit proof
  trainer_step                  -- trainer-step offload-vs-raw comparison on
                                   a 2x2 CPU mesh (subprocess): per-step
                                   wall-clock + bitwise/cache-hit assertions
  service_throughput            -- multi-tenant broker requests/sec and
                                   p50/p99 latency vs client count, with
                                   coalescing on/off
  fusion_speedup                -- tuned schedule grid (fused x chunked
                                   streaming) vs raw planned collectives:
                                   communication rounds + measured us +
                                   bitwise proof + chunking check +
                                   profiler-sourced device latency
  roofline (report)             -- dry-run derived roofline tables

Prints ``name,...,derived`` CSV sections. Run:
  PYTHONPATH=src python -m benchmarks.run [--quick | --smoke]
                                          [--report-json [PATH]]

``--smoke`` runs only the offload-engine smoke (budgeted tuning grid +
descriptor-cache proof + one 3D planned collective end-to-end with an
asserted schedule-cache hit rate + a 2-step offloaded trainer on a 2x2 mesh
asserted bitwise against the raw shard_map baseline + the service broker's
coalesce/bitwise proof + the plan-optimizer's fused-vs-unfused rounds/
bitwise/device-latency proof) — the CI regression gate for the offload
subsystem.

``--report-json`` writes the service-throughput stats to a JSON artifact
(default ``BENCH_service.json`` next to this file) and the fusion stats to
``BENCH_fusion.json`` for the perf trajectory.
"""

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import (  # noqa: E402
    fusion_speedup,
    offloaded_latency,
    report,
    scan_latency,
    service_throughput,
    trainer_step,
    tuned_vs_static,
)

DEFAULT_REPORT_PATH = Path(__file__).resolve().parent / "BENCH_service.json"


def _write_report(path: Path, stats, mode: str) -> None:
    payload = {
        "benchmark": "service_throughput",
        "mode": mode,
        "columns": "one dict per (clients, coalesce) configuration",
        "stats": stats,
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# service throughput stats written to {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer timing iters")
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="offload-engine smoke benchmark only (~10 s)",
    )
    ap.add_argument(
        "--report-json",
        nargs="?",
        const=str(DEFAULT_REPORT_PATH),
        default=None,
        metavar="PATH",
        help="write service-throughput stats to a JSON artifact "
        f"(default {DEFAULT_REPORT_PATH.name})",
    )
    args = ap.parse_args()
    iters = 8 if args.quick else 30
    service_stats: list = []

    if args.smoke:
        print(
            "# === Offload engine smoke: tuned-vs-static + planned-3D "
            "cache proof ==="
        )
        for row in tuned_vs_static.smoke():
            print(row)
        print()
        print(
            "# === Offloaded trainer smoke: 2-step DP trainer on a 2x2 "
            "mesh, engine vs raw (bitwise) ==="
        )
        for row in trainer_step.smoke():
            print(row)
        print()
        print(
            "# === Service smoke: multi-tenant broker, coalesced vs "
            "direct (bitwise) ==="
        )
        print(
            "service_throughput,clients,coalesce,requests,reqs_per_s,"
            "p50_us,p99_us,mean_us,coalesce_factor"
        )
        for row in service_throughput.smoke(stats_out=service_stats):
            print(row)
        print()
        print(
            "# === Fusion smoke: tuned schedule grid vs raw "
            "(rounds + bitwise + chunked streaming + device latency) ==="
        )
        print(
            "fusion_speedup,coll,sizes,msg_bytes,raw_rounds,fused_rounds,"
            "raw_us,fused_us,speedup,bitwise,tuned_opt,tuned_chunks"
        )
        fusion_stats: list = []
        for row in fusion_speedup.smoke(stats_out=fusion_stats):
            print(row)
        if args.report_json:
            _write_report(Path(args.report_json), service_stats, "smoke")
            fusion_speedup.write_report(
                fusion_speedup.DEFAULT_REPORT_PATH, fusion_stats, "smoke"
            )
        return

    print("# === Paper Fig. 4/5: host-visible scan latency (8 ranks) ===")
    print("figure,algo,variant,msg_bytes,us_per_call")
    for row in scan_latency.run(iters=iters):
        print(row)
    for row in scan_latency.run_min(iters=iters):
        print(row)

    print()
    print("# === Paper Fig. 6/7: offloaded in-network latency ===")
    print("figure,algo,metric,msg_bytes,value_us")
    for row in offloaded_latency.run():
        print(row)
    for row in offloaded_latency.selector_crossover():
        print(row)

    print()
    print("# === Tuned-vs-static selection crossovers (autotuner) ===")
    print(
        "section,coll,p,msg_bytes,static_algo,tuned_algo,"
        "static_meas_us,tuned_meas_us,changed"
    )
    for row in tuned_vs_static.run(
        iters=max(3, iters // 6), time_budget_s=120.0
    ):
        print(row)
    for row in tuned_vs_static.engine_smoke():
        print(row)

    print()
    print("# === Planned collectives: tuned vs fixed axis split + 3D ===")
    print(
        "section,coll,sizes,msg_bytes,fixed_order,fixed_us,tuned_order,"
        "tuned_us,speedup"
    )
    for row in tuned_vs_static.split_report(
        topologies=((2, 4), (4, 2), (2, 8), (2, 2, 2), (2, 2, 4)),
        payloads=(1024, 65536),
        colls=("scan", "allreduce"),
        iters=max(3, iters // 6),
        time_budget_s=120.0,
    ):
        print(row)
    for row in tuned_vs_static.planned_smoke():
        print(row)

    print()
    print("# === Trainer step: offload-engine vs raw collectives ===")
    print("trainer_step,mode,ms_per_step")
    try:
        for row in trainer_step.run(bench_iters=3 if args.quick else 5):
            print(row)
    except Exception as e:  # subprocess needs a CPU with >= 4 threads
        print(f"(trainer-step comparison unavailable: {e})")

    print()
    print("# === Service throughput: multi-tenant broker, coalesce on/off ===")
    print(
        "service_throughput,clients,coalesce,requests,reqs_per_s,"
        "p50_us,p99_us,mean_us,coalesce_factor"
    )
    for row in service_throughput.run(
        client_counts=(1, 2, 4) if args.quick else (1, 2, 4, 8),
        n_requests=8 if args.quick else 32,
        stats_out=service_stats,
    ):
        print(row)
    if args.report_json:
        _write_report(Path(args.report_json), service_stats, "full")

    print()
    print("# === Fusion speedup: tuned schedule grid vs raw ===")
    print(
        "fusion_speedup,coll,sizes,msg_bytes,raw_rounds,fused_rounds,"
        "raw_us,fused_us,speedup,bitwise,tuned_opt,tuned_chunks"
    )
    fusion_stats: list = []
    for row in fusion_speedup.run(
        iters=3 if args.quick else 5, stats_out=fusion_stats
    ):
        print(row)
    if args.report_json:
        fusion_speedup.write_report(
            fusion_speedup.DEFAULT_REPORT_PATH, fusion_stats, "full"
        )

    print()
    print("# === Roofline tables (from dry-run artifacts) ===")
    try:
        report.main()
    except Exception as e:  # artifacts may be absent on a fresh clone
        print(f"(roofline artifacts missing: {e}; run repro.launch.dryrun --all)")


if __name__ == "__main__":
    main()
