"""Fused vs unfused planned collectives: communication rounds + measured µs.

For each (coll, mesh shape, payload) grid point the same plan is lowered
twice — raw (``build_plan``) and through the plan-optimizer pass pipeline
(``optimize_plan``: SCAN+TOTAL fusion, dead-phase elimination, permute
threading) — and the benchmark reports the round counts
(``plan_comm_rounds``), the measured sim-backend wall latency of each form,
and a **bitwise** comparison of their outputs (integer payloads, so any
combine association must produce identical bits). A second section runs
optimized descriptors through ``OffloadEngine.profile_offload`` so the
reported latency includes a measured (profiler-sourced) per-schedule device
time from ``EngineTelemetry.snapshot()`` — not just the cost model.

CSV sections:
  fusion_speedup,coll,sizes,msg_bytes,raw_rounds,fused_rounds,raw_us,fused_us,speedup,bitwise
  fusion_device,coll,sizes,device_us,wall_us,source,events
  fusion_summary,bitwise_equal,B,rounds_reduced,R,device_latency,D,mean_speedup,S

``--report-json`` (default ``benchmarks/BENCH_fusion.json``) writes the
grid + device timings + summary for the perf trajectory; ``scripts/ci.sh``
gates on the summary row: the fused plan must never regress the unfused
bitwise check, and SCAN/EXSCAN must need fewer rounds on every benched
multi-axis mesh.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.offload import (
    OffloadEngine,
    build_plan,
    lower_sim,
    optimize_plan,
    plan_comm_rounds,
)

DEFAULT_REPORT_PATH = Path(__file__).resolve().parent / "BENCH_fusion.json"

#: only multi-axis meshes where fusion provably drops rounds for SCAN and
#: EXSCAN both. An inclusive-scan fusion on a p-rank axis goes from
#: 2*log2(p) rounds to log2(p)+1, so a fused pair on a p=2 axis is a tie,
#: not a win — (4, 2) or (2, 2) SCAN keeps its round count (still bitwise,
#: never worse); EXSCAN always wins because its unfused form pays the
#: structural-shift round on top.
DEFAULT_TOPOLOGIES: Tuple[Tuple[int, ...], ...] = (
    (2, 4), (4, 4), (2, 2, 2), (2, 2, 4),
)
DEFAULT_PAYLOADS: Tuple[int, ...] = (1024, 65536)
DEFAULT_COLLS: Tuple[str, ...] = ("scan", "exscan")


def _time_fn(fn, arg, iters: int) -> float:
    out = fn(arg)
    jax.tree.map(lambda a: a.block_until_ready(), out)  # warm the jit
    times = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        out = fn(arg)
        jax.tree.map(lambda a: a.block_until_ready(), out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def run(
    *,
    topologies: Sequence[Tuple[int, ...]] = DEFAULT_TOPOLOGIES,
    payloads: Sequence[int] = DEFAULT_PAYLOADS,
    colls: Sequence[str] = DEFAULT_COLLS,
    iters: int = 5,
    profile_axes: Tuple[int, ...] = (2, 2, 2),
    stats_out: Optional[list] = None,
) -> List[str]:
    rows: List[str] = []
    grid: List[Dict] = []
    all_bitwise = True
    all_reduced = True
    speedups: List[float] = []
    for sizes in topologies:
        sizes = tuple(int(s) for s in sizes)
        p = int(np.prod(sizes))
        for payload in payloads:
            n = max(1, payload // 4)
            rng = np.random.default_rng(p * 31 + payload)
            x = jnp.asarray(
                rng.integers(-6, 7, size=(p, n)).astype(np.float32)
            )
            for coll in colls:
                raw = build_plan(
                    coll, sizes, "sum", payload,
                    order=tuple(range(len(sizes))),
                )
                opt = optimize_plan(raw)
                rr, fr = plan_comm_rounds(raw), plan_comm_rounds(opt)
                fn_raw = jax.jit(lower_sim(raw))
                fn_opt = jax.jit(lower_sim(opt))
                bitwise = bool(
                    np.array_equal(
                        np.asarray(fn_opt(x)), np.asarray(fn_raw(x))
                    )
                )
                t_raw = _time_fn(fn_raw, x, iters)
                t_opt = _time_fn(fn_opt, x, iters)
                speedup = t_raw / t_opt if t_opt > 0 else 0.0
                all_bitwise &= bitwise
                all_reduced &= fr < rr
                speedups.append(speedup)
                shape = "x".join(map(str, sizes))
                rows.append(
                    f"fusion_speedup,{coll},{shape},{payload},{rr},{fr},"
                    f"{t_raw*1e6:.1f},{t_opt*1e6:.1f},{speedup:.3f},"
                    f"{int(bitwise)}"
                )
                grid.append(
                    {
                        "coll": coll,
                        "sizes": list(sizes),
                        "payload_bytes": payload,
                        "raw_rounds": rr,
                        "fused_rounds": fr,
                        "raw_us": t_raw * 1e6,
                        "fused_us": t_opt * 1e6,
                        "speedup": speedup,
                        "bitwise": bitwise,
                    }
                )

    # profiler-sourced per-schedule device latency through the engine
    eng = OffloadEngine()
    device: Dict[str, Dict] = {}
    p = int(np.prod(profile_axes))
    rng = np.random.default_rng(0)
    xp = jnp.asarray(rng.integers(-5, 6, size=(p, 64)).astype(np.float32))
    for coll in colls:
        desc = eng.make_descriptor(
            coll, axes=profile_axes, payload_bytes=64 * 4, op="sum",
            optimize=True,
        )
        t = eng.profile_offload(desc, xp)
        shape = "x".join(map(str, profile_axes))
        rows.append(
            f"fusion_device,{coll},{shape},{t.device_us:.1f},"
            f"{t.wall_us:.1f},{t.source},{t.events}"
        )
        device[coll] = {
            "sizes": list(profile_axes),
            "device_us": t.device_us,
            "wall_us": t.wall_us,
            "source": t.source,
            "events": t.events,
        }
    snap = eng.telemetry.snapshot()
    # the gate demands genuinely trace-derived numbers: a wall-clock
    # fallback (e.g. the profiler's chrome export disappearing in a jax
    # upgrade) must fail CI, not silently impersonate a device measurement
    has_device = all(
        snap["device_latency_by_coll_us"].get(c, 0.0) > 0
        and snap["latency_source_by_coll"].get(c) == "profiler"
        for c in colls
    )
    mean_speedup = (
        float(np.mean(speedups)) if speedups else 0.0
    )
    rows.append(
        f"fusion_summary,bitwise_equal,{int(all_bitwise)},"
        f"rounds_reduced,{int(all_reduced)},"
        f"device_latency,{int(has_device)},mean_speedup,{mean_speedup:.3f}"
    )
    if stats_out is not None:
        stats_out.append(
            {
                "grid": grid,
                "device_latency": device,
                "telemetry": {
                    "device_latency_by_coll_us": snap[
                        "device_latency_by_coll_us"
                    ],
                    "latency_source_by_coll": snap[
                        "latency_source_by_coll"
                    ],
                },
                "summary": {
                    "bitwise_equal": all_bitwise,
                    "rounds_reduced": all_reduced,
                    "device_latency": has_device,
                    "mean_speedup": mean_speedup,
                },
            }
        )
    return rows


def smoke(stats_out: Optional[list] = None) -> List[str]:
    """The CI entry: reduced grid, same gates."""
    return run(
        topologies=((2, 4), (2, 2, 2)),
        payloads=(1024,),
        colls=("scan", "exscan"),
        iters=2,
        stats_out=stats_out,
    )


def write_report(path: Path, stats: list, mode: str) -> None:
    payload = {
        "benchmark": "fusion_speedup",
        "mode": mode,
        "columns": "rounds + measured us per (coll, sizes, payload); "
        "device latency is profiler-sourced where source == 'profiler'",
        **(stats[0] if stats else {}),
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# fusion speedup stats written to {path}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="fewer iters")
    ap.add_argument(
        "--report-json",
        nargs="?",
        const=str(DEFAULT_REPORT_PATH),
        default=None,
        metavar="PATH",
        help=f"write stats to a JSON artifact (default "
        f"{DEFAULT_REPORT_PATH.name})",
    )
    args = ap.parse_args()
    stats: list = []
    print(
        "fusion_speedup,coll,sizes,msg_bytes,raw_rounds,fused_rounds,"
        "raw_us,fused_us,speedup,bitwise"
    )
    for row in run(iters=3 if args.quick else 5, stats_out=stats):
        print(row)
    if args.report_json:
        write_report(Path(args.report_json), stats, "full")


if __name__ == "__main__":
    main()
