"""Tuned vs raw planned collectives: rounds, measured µs, chunked streaming.

For each (coll, mesh shape, payload) grid point the same plan is lowered
across the full schedule grid — (raw, pass-optimized) x chunk count — and
every variant is measured with the amortized timer
(``time_planned_collective`` with an inner ``fori_loop``, so the
per-dispatch floor does not drown the schedule) and recorded into an
in-process :class:`~repro.offload.tuning_cache.TuningCache` via
``record_schedule``, exactly the way ``tune_schedule`` writes the
persisted table. The reported ``fused_us`` is the **measured winner** of
that grid (``TuningCache.schedule_winner``), and the winner is then
*exercised* end-to-end: ``make_descriptor(optimize="auto",
chunks="auto")`` against the activated cache must resolve to the measured
winner, and the engine dispatch of that descriptor must be **bitwise**
equal to the raw lowering (integer payloads, so any combine association
must produce identical bits). Every (form, chunk) variant is also
bitwise-checked against raw — the chunked pipeline is a pure reordering.

A second section runs optimized descriptors through
``OffloadEngine.profile_offload`` so the reported latency includes a
measured (profiler-sourced) per-schedule device time from
``EngineTelemetry.snapshot()`` — not just the cost model.

A third section answers the ROADMAP wall-clock question *where does the
per-round constant live*: each plan is re-lowered through the **traced
eager interpreter** (``lower_sim(plan, traced=True)`` under a collecting
:mod:`repro.obs.tracing` tracer), whose backend blocks after every
``permute`` — so each ``round`` span's duration is one round's real host
dispatch cost. Chunked variants carry the pipeline coordinates on every
span, so the breakdown attributes cost per (round, chunk) cell.

A fourth section is the **chunking check**: at a payload past the
pipelining threshold (default 1 MiB on a (2, 8) mesh) the best chunked
schedule must be bitwise-identical to C=1 *and* beat it on wall-clock.

CSV sections:
  fusion_speedup,coll,sizes,msg_bytes,raw_rounds,fused_rounds,raw_us,fused_us,speedup,bitwise,tuned_opt,tuned_chunks
  fusion_device,coll,sizes,device_us,wall_us,source,events
  fusion_per_round,coll,sizes,msg_bytes,variant,phase,round,dur_us
  fusion_per_round_chunk,coll,sizes,msg_bytes,phase,round,chunk,chunk_round,dur_us
  fusion_per_round_top,coll,sizes,variant,phase,round,dur_us,total,T
  chunking_check,coll,sizes,msg_bytes,c1_us,U,best_chunks,C,best_us,V,bitwise,B,win,W
  fusion_summary,bitwise_equal,B,rounds_reduced,R,device_latency,D,mean_speedup,S,chunked_win,W

``--report-json`` (default ``benchmarks/BENCH_fusion.json``) writes the
grid + device timings + per-round attribution + chunking check + summary
for the perf trajectory; ``--per-round`` runs only the span-derived
attribution and merges it into the existing report. ``scripts/ci.sh``
gates on the summary row (the tuned plan must never regress the raw
bitwise check, SCAN/EXSCAN must need fewer rounds on every benched
multi-axis mesh) and on the ``chunking_check`` row (bitwise + wall-clock
win at the chunked grid point).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.offload import (
    OffloadEngine,
    TuningCache,
    amortize_inner,
    build_plan,
    deactivate,
    lower_sim,
    optimize_plan,
    plan_comm_rounds,
    time_planned_collective,
)

DEFAULT_REPORT_PATH = Path(__file__).resolve().parent / "BENCH_fusion.json"

#: only multi-axis meshes where fusion provably drops rounds for SCAN and
#: EXSCAN both. An inclusive-scan fusion on a p-rank axis goes from
#: 2*log2(p) rounds to log2(p)+1, so a fused pair on a p=2 axis is a tie,
#: not a win — (4, 2) or (2, 2) SCAN keeps its round count (still bitwise,
#: never worse); EXSCAN always wins because its unfused form pays the
#: structural-shift round on top.
DEFAULT_TOPOLOGIES: Tuple[Tuple[int, ...], ...] = (
    (2, 4), (4, 4), (2, 2, 2), (2, 2, 4),
)
DEFAULT_PAYLOADS: Tuple[int, ...] = (1024, 65536)
DEFAULT_COLLS: Tuple[str, ...] = ("scan", "exscan")
#: chunk counts the grid measures per (raw, optimized) form
DEFAULT_CHUNK_GRID: Tuple[int, ...] = (1, 2, 4, 8)
SMOKE_CHUNK_GRID: Tuple[int, ...] = (1, 2, 4)

#: the chunking-check point: past the pipelining threshold, where the
#: serialized link term dominates the extra pipeline-fill alphas
CHUNK_CHECK_SIZES: Tuple[int, ...] = (2, 8)
CHUNK_CHECK_PAYLOAD: int = 1 << 20
CHUNK_CHECK_CHUNKS: Tuple[int, ...] = (2, 4)


def _grid_payload(p: int, payload: int) -> jnp.ndarray:
    n = max(1, payload // 4)
    rng = np.random.default_rng(p * 31 + payload)
    # integer-valued floats: bitwise comparison must not trip over the
    # -0.0 / rounding hazards of real-valued sums
    return jnp.asarray(rng.integers(-6, 7, size=(p, n)).astype(np.float32))


def _variant_plans(raw, opt, chunk_grid: Sequence[int]):
    """Every (optimized, chunks) schedule variant of one plan, C=1 first."""
    for optimized, plan in ((False, raw), (True, opt)):
        for c in chunk_grid:
            yield optimized, c, (
                plan if c == 1 else dataclasses.replace(plan, chunking=c)
            )


def _per_round_profile(plan, x, iters: int) -> List[Dict]:
    """Median per-round host latency from the traced eager interpreter.

    ``lower_sim(plan, traced=True)`` runs under a private collecting
    tracer, so every backend ``permute`` emits a ``round`` span whose
    duration (the backend blocks on the permuted result) is that round's
    host dispatch cost. Chunked plans label each span with its pipeline
    coordinates (``chunk``, ``chunk_round``), which propagate into the
    returned dicts. One warmup run keeps primitive compilation out of the
    samples; the reported number is the per-round median over ``iters``
    runs.
    """
    from repro.obs import tracing as obs_tracing

    fn = lower_sim(plan, traced=True)
    samples: Dict[Tuple, List[float]] = {}
    order: List[Tuple] = []
    with obs_tracing.tracing(obs_tracing.Tracer()) as tracer:
        fn(x)  # warmup
        for _ in range(max(1, iters)):
            tracer.clear()
            fn(x)
            for s in tracer.spans():
                if s.cat != "round":
                    continue
                key = (
                    str(s.args.get("phase")),
                    int(s.args.get("round", 0)),
                    int(s.args.get("chunk", -1)),
                    int(s.args.get("chunk_round", -1)),
                )
                if key not in samples:
                    samples[key] = []
                    order.append(key)
                samples[key].append(s.dur_us)
    rounds: List[Dict] = []
    for key in order:
        durs = sorted(samples[key])
        phase, rnd, chunk, chunk_round = key
        entry = {"phase": phase, "round": rnd, "dur_us": durs[len(durs) // 2]}
        if chunk >= 0:
            entry["chunk"] = chunk
            entry["chunk_round"] = chunk_round
        rounds.append(entry)
    return rounds


def per_round(
    *,
    topologies: Sequence[Tuple[int, ...]] = DEFAULT_TOPOLOGIES,
    payloads: Sequence[int] = (1024,),
    colls: Sequence[str] = DEFAULT_COLLS,
    iters: int = 5,
    chunked_c: int = 4,
    stats_out: Optional[list] = None,
) -> List[str]:
    """Span-derived per-round latency attribution: raw, fused, chunked.

    Only the first payload is profiled: the per-round host constant this
    section attributes is dispatch overhead, not bandwidth, so it is flat
    in payload at benchmark sizes (the grid section covers payload
    scaling). The ``chunked`` variant is the fused plan at C=chunked_c;
    its rounds carry (chunk, chunk_round) pipeline coordinates.
    """
    rows: List[str] = []
    entries: List[Dict] = []
    payload = int(payloads[0])
    for sizes in topologies:
        sizes = tuple(int(s) for s in sizes)
        p = int(np.prod(sizes))
        x = _grid_payload(p, payload)
        shape = "x".join(map(str, sizes))
        for coll in colls:
            raw = build_plan(
                coll, sizes, "sum", payload,
                order=tuple(range(len(sizes))),
            )
            fused = optimize_plan(raw)
            chunked = dataclasses.replace(fused, chunking=int(chunked_c))
            for variant, plan in (
                ("raw", raw), ("fused", fused), ("chunked", chunked)
            ):
                rounds = _per_round_profile(plan, x, iters)
                total = sum(r["dur_us"] for r in rounds)
                top = (
                    max(rounds, key=lambda r: r["dur_us"]) if rounds else None
                )
                for r in rounds:
                    if "chunk" in r:
                        rows.append(
                            f"fusion_per_round_chunk,{coll},{shape},"
                            f"{payload},{r['phase']},{r['round']},"
                            f"{r['chunk']},{r['chunk_round']},"
                            f"{r['dur_us']:.1f}"
                        )
                    else:
                        rows.append(
                            f"fusion_per_round,{coll},{shape},{payload},"
                            f"{variant},{r['phase']},{r['round']},"
                            f"{r['dur_us']:.1f}"
                        )
                if top is not None:
                    rows.append(
                        f"fusion_per_round_top,{coll},{shape},{variant},"
                        f"{top['phase']},{top['round']},{top['dur_us']:.1f},"
                        f"total,{total:.1f}"
                    )
                entries.append(
                    {
                        "coll": coll,
                        "sizes": list(sizes),
                        "payload_bytes": payload,
                        "variant": variant,
                        "chunks": int(chunked_c) if variant == "chunked"
                        else 1,
                        "rounds": rounds,
                        "total_us": total,
                        "top_round": top,
                    }
                )
    if stats_out is not None:
        stats_out.append(entries)
    return rows


def chunking_check(
    *,
    sizes: Tuple[int, ...] = CHUNK_CHECK_SIZES,
    payload: int = CHUNK_CHECK_PAYLOAD,
    chunks: Sequence[int] = CHUNK_CHECK_CHUNKS,
    coll: str = "scan",
    iters: int = 5,
    stats_out: Optional[list] = None,
) -> List[str]:
    """Bitwise + wall-clock proof that chunked streaming engages and wins.

    At the check point the payload is big enough that pipelining the
    chunks across the doubling rounds beats paying the full serialized
    payload per round: the best C > 1 schedule must measure faster than
    C=1 on the same raw plan, and every chunked lowering must be
    bitwise-identical to the unchunked one.

    Timing is interleaved: one amortized sample per variant per sweep, the
    per-variant minimum over all sweeps taken as the score. Sequential
    per-variant blocks are vulnerable to machine-load drift (whichever
    variant runs during a slow window loses regardless of merit); the
    round-robin minimum cancels the drift and keeps this CI gate stable.
    """
    sizes = tuple(int(s) for s in sizes)
    order = tuple(range(len(sizes)))
    p = int(np.prod(sizes))
    shape = "x".join(map(str, sizes))
    inner = amortize_inner(payload)
    raw = build_plan(coll, sizes, "sum", payload, order=order)
    x = _grid_payload(p, payload)

    def _sampler(plan):
        run = lower_sim(plan)
        fn = jax.jit(
            lambda t: jax.lax.fori_loop(0, inner, lambda _i, a: run(a), t)
        )
        jax.tree.map(lambda a: a.block_until_ready(), fn(x))  # warm the jit

        def sample() -> float:
            t0 = time.perf_counter()
            jax.tree.map(lambda a: a.block_until_ready(), fn(x))
            return (time.perf_counter() - t0) / inner

        return sample

    grid = [1] + [int(c) for c in chunks]
    samplers = {
        c: _sampler(
            raw if c == 1 else dataclasses.replace(raw, chunking=c)
        )
        for c in grid
    }
    best: Dict[int, float] = {c: float("inf") for c in grid}
    for _ in range(max(int(iters), 5)):
        for c, sample in samplers.items():
            best[c] = min(best[c], sample())
    t1 = best[1]
    best_c, best_t = min(best.items(), key=lambda kv: (kv[1], kv[0]))
    timings = {c: t * 1e6 for c, t in best.items()}
    y1 = np.asarray(jax.jit(lower_sim(raw))(x))
    bitwise = all(
        np.array_equal(
            np.asarray(
                jax.jit(
                    lower_sim(dataclasses.replace(raw, chunking=int(c)))
                )(x)
            ),
            y1,
        )
        for c in chunks
    )
    win = best_c > 1 and best_t < t1
    rows = [
        f"chunking_check,{coll},{shape},{payload},c1_us,{t1 * 1e6:.1f},"
        f"best_chunks,{best_c},best_us,{best_t * 1e6:.1f},"
        f"bitwise,{int(bitwise)},win,{int(win)}"
    ]
    if stats_out is not None:
        stats_out.append(
            {
                "coll": coll,
                "sizes": list(sizes),
                "payload_bytes": payload,
                "timings_us": timings,
                "c1_us": t1 * 1e6,
                "best_chunks": best_c,
                "best_us": best_t * 1e6,
                "bitwise": bitwise,
                "win": win,
            }
        )
    return rows


def run(
    *,
    topologies: Sequence[Tuple[int, ...]] = DEFAULT_TOPOLOGIES,
    payloads: Sequence[int] = DEFAULT_PAYLOADS,
    colls: Sequence[str] = DEFAULT_COLLS,
    chunk_grid: Sequence[int] = DEFAULT_CHUNK_GRID,
    iters: int = 5,
    profile_axes: Tuple[int, ...] = (2, 2, 2),
    stats_out: Optional[list] = None,
) -> List[str]:
    rows: List[str] = []
    grid: List[Dict] = []
    all_bitwise = True
    all_reduced = True
    speedups: List[float] = []
    cache = TuningCache()
    eng = OffloadEngine()
    for sizes in topologies:
        sizes = tuple(int(s) for s in sizes)
        p = int(np.prod(sizes))
        order = tuple(range(len(sizes)))
        for payload in payloads:
            x = _grid_payload(p, payload)
            inner = amortize_inner(payload)
            for coll in colls:
                raw = build_plan(coll, sizes, "sum", payload, order=order)
                opt = optimize_plan(raw)
                rr, fr = plan_comm_rounds(raw), plan_comm_rounds(opt)
                y_raw = np.asarray(jax.jit(lower_sim(raw))(x))
                # every (form, chunk) variant is a bitwise-identical
                # reordering of the raw schedule; measure each one the way
                # tune_schedule would and record it into the cache
                bitwise = True
                t_raw = None
                for optimized, c, plan in _variant_plans(
                    raw, opt, chunk_grid
                ):
                    if not (optimized is False and c == 1):
                        bitwise &= bool(
                            np.array_equal(
                                np.asarray(jax.jit(lower_sim(plan))(x)),
                                y_raw,
                            )
                        )
                    t = time_planned_collective(
                        coll, sizes, order, payload, iters=iters,
                        optimized=optimized, chunking=c, inner=inner,
                    )
                    cache.record_schedule(
                        coll, sizes, optimized, c, payload, t
                    )
                    if optimized is False and c == 1:
                        t_raw = t
                winner = cache.schedule_winner(coll, sizes, payload)
                w_opt, w_c = winner if winner is not None else (False, 1)
                t_best = min(
                    m.seconds
                    for m in cache.fusion_measurements
                    if m.coll == coll and m.sizes == sizes
                    and m.payload_bytes == payload
                )
                # exercise the winner end-to-end: make_descriptor must
                # resolve it from the activated cache, and the engine
                # dispatch of that descriptor must match raw bitwise
                cache.activate()
                try:
                    desc = eng.make_descriptor(
                        coll, axes=sizes, payload_bytes=payload, op="sum",
                        split=order,
                    )
                finally:
                    deactivate()
                resolved = (desc.optimized, desc.chunks) == (w_opt, w_c)
                bitwise &= bool(
                    np.array_equal(np.asarray(eng.offload(desc, x)), y_raw)
                )
                speedup = t_raw / t_best if t_best > 0 else 0.0
                all_bitwise &= bitwise and resolved
                all_reduced &= fr < rr
                speedups.append(speedup)
                shape = "x".join(map(str, sizes))
                rows.append(
                    f"fusion_speedup,{coll},{shape},{payload},{rr},{fr},"
                    f"{t_raw * 1e6:.1f},{t_best * 1e6:.1f},{speedup:.3f},"
                    f"{int(bitwise)},{int(w_opt)},{w_c}"
                )
                grid.append(
                    {
                        "coll": coll,
                        "sizes": list(sizes),
                        "payload_bytes": payload,
                        "raw_rounds": rr,
                        "fused_rounds": fr,
                        "raw_us": t_raw * 1e6,
                        "fused_us": t_best * 1e6,
                        "speedup": speedup,
                        "bitwise": bitwise,
                        "tuned_optimized": w_opt,
                        "tuned_chunks": w_c,
                        "winner_resolved": resolved,
                    }
                )

    # profiler-sourced per-schedule device latency through the engine
    device: Dict[str, Dict] = {}
    p = int(np.prod(profile_axes))
    rng = np.random.default_rng(0)
    xp = jnp.asarray(rng.integers(-5, 6, size=(p, 64)).astype(np.float32))
    for coll in colls:
        desc = eng.make_descriptor(
            coll, axes=profile_axes, payload_bytes=64 * 4, op="sum",
            optimize=True, chunks=1,
        )
        t = eng.profile_offload(desc, xp)
        shape = "x".join(map(str, profile_axes))
        rows.append(
            f"fusion_device,{coll},{shape},{t.device_us:.1f},"
            f"{t.wall_us:.1f},{t.source},{t.events}"
        )
        device[coll] = {
            "sizes": list(profile_axes),
            "device_us": t.device_us,
            "wall_us": t.wall_us,
            "source": t.source,
            "events": t.events,
        }
    snap = eng.telemetry.snapshot()
    # the gate demands genuinely trace-derived numbers: a wall-clock
    # fallback (e.g. the profiler's chrome export disappearing in a jax
    # upgrade) must fail CI, not silently impersonate a device measurement
    has_device = all(
        snap["device_latency_by_coll_us"].get(c, 0.0) > 0
        and snap["latency_source_by_coll"].get(c) == "profiler"
        for c in colls
    )
    mean_speedup = (
        float(np.mean(speedups)) if speedups else 0.0
    )

    # span-derived per-round attribution (raw/fused/chunked, traced)
    per_round_stats: list = []
    rows.extend(
        per_round(
            topologies=topologies,
            payloads=payloads,
            colls=colls,
            iters=iters,
            stats_out=per_round_stats,
        )
    )

    # chunked streaming must engage and win past the payload threshold
    chunk_stats: list = []
    rows.extend(chunking_check(iters=iters, stats_out=chunk_stats))
    chunk_entry = chunk_stats[0] if chunk_stats else {}
    chunked_win = bool(
        chunk_entry.get("win") and chunk_entry.get("bitwise")
    )

    rows.append(
        f"fusion_summary,bitwise_equal,{int(all_bitwise)},"
        f"rounds_reduced,{int(all_reduced)},"
        f"device_latency,{int(has_device)},mean_speedup,{mean_speedup:.3f},"
        f"chunked_win,{int(chunked_win)}"
    )
    if stats_out is not None:
        stats_out.append(
            {
                "grid": grid,
                "device_latency": device,
                "per_round": per_round_stats[0] if per_round_stats else [],
                "chunking_check": chunk_entry,
                "telemetry": {
                    "device_latency_by_coll_us": snap[
                        "device_latency_by_coll_us"
                    ],
                    "latency_source_by_coll": snap[
                        "latency_source_by_coll"
                    ],
                },
                "summary": {
                    "bitwise_equal": all_bitwise,
                    "rounds_reduced": all_reduced,
                    "device_latency": has_device,
                    "mean_speedup": mean_speedup,
                    "chunked_win": chunked_win,
                },
            }
        )
    return rows


def smoke(stats_out: Optional[list] = None) -> List[str]:
    """The CI entry: reduced grid, same gates."""
    return run(
        topologies=((2, 4), (2, 2, 2)),
        payloads=(1024,),
        colls=("scan", "exscan"),
        chunk_grid=SMOKE_CHUNK_GRID,
        iters=3,
        stats_out=stats_out,
    )


def write_report(path: Path, stats: list, mode: str) -> None:
    payload = {
        "benchmark": "fusion_speedup",
        "mode": mode,
        "columns": "rounds + measured us per (coll, sizes, payload); "
        "fused_us is the measured winner of the (raw, optimized) x chunks "
        "schedule grid (amortized timer); device latency is "
        "profiler-sourced where source == 'profiler'; per_round is the "
        "span-derived host cost of each communication round (traced eager "
        "interpreter, median us; chunked rounds carry (chunk, chunk_round) "
        "pipeline coordinates); chunking_check proves the chunked "
        "schedule wins wall-clock past the payload threshold",
        **(stats[0] if stats else {}),
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# fusion speedup stats written to {path}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="fewer iters")
    ap.add_argument(
        "--per-round",
        action="store_true",
        help="only the span-derived per-round attribution (traced eager "
        "interpreter); with --report-json, merges a 'per_round' section "
        "into the existing artifact instead of rewriting it",
    )
    ap.add_argument(
        "--report-json",
        nargs="?",
        const=str(DEFAULT_REPORT_PATH),
        default=None,
        metavar="PATH",
        help=f"write stats to a JSON artifact (default "
        f"{DEFAULT_REPORT_PATH.name})",
    )
    args = ap.parse_args()
    iters = 3 if args.quick else 5
    if args.per_round:
        print(
            "fusion_per_round,coll,sizes,msg_bytes,variant,phase,round,"
            "dur_us"
        )
        pr_stats: list = []
        for row in per_round(iters=iters, stats_out=pr_stats):
            print(row)
        if args.report_json:
            path = Path(args.report_json)
            payload = (
                json.loads(path.read_text())
                if path.exists()
                else {"benchmark": "fusion_speedup"}
            )
            payload["per_round"] = pr_stats[0] if pr_stats else []
            path.write_text(json.dumps(payload, indent=2) + "\n")
            print(f"# per-round attribution merged into {path}")
        return
    stats: list = []
    print(
        "fusion_speedup,coll,sizes,msg_bytes,raw_rounds,fused_rounds,"
        "raw_us,fused_us,speedup,bitwise,tuned_opt,tuned_chunks"
    )
    for row in run(iters=iters, stats_out=stats):
        print(row)
    if args.report_json:
        write_report(Path(args.report_json), stats, "full")


if __name__ == "__main__":
    main()
