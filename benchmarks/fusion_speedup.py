"""Fused vs unfused planned collectives: communication rounds + measured µs.

For each (coll, mesh shape, payload) grid point the same plan is lowered
twice — raw (``build_plan``) and through the plan-optimizer pass pipeline
(``optimize_plan``: SCAN+TOTAL fusion, dead-phase elimination, permute
threading) — and the benchmark reports the round counts
(``plan_comm_rounds``), the measured sim-backend wall latency of each form,
and a **bitwise** comparison of their outputs (integer payloads, so any
combine association must produce identical bits). A second section runs
optimized descriptors through ``OffloadEngine.profile_offload`` so the
reported latency includes a measured (profiler-sourced) per-schedule device
time from ``EngineTelemetry.snapshot()`` — not just the cost model.

A third section answers the ROADMAP wall-clock question *where does the
per-round constant live*: each plan is re-lowered through the **traced
eager interpreter** (``lower_sim(plan, traced=True)`` under a collecting
:mod:`repro.obs.tracing` tracer), whose backend blocks after every
``permute`` — so each ``round`` span's duration is one round's real host
dispatch cost. The breakdown ranks rounds per (coll, mesh, raw|fused) and
names the top-cost round, turning the wall-clock mystery into an ordered
list.

CSV sections:
  fusion_speedup,coll,sizes,msg_bytes,raw_rounds,fused_rounds,raw_us,fused_us,speedup,bitwise
  fusion_device,coll,sizes,device_us,wall_us,source,events
  fusion_per_round,coll,sizes,msg_bytes,variant,phase,round,dur_us
  fusion_per_round_top,coll,sizes,variant,phase,round,dur_us,total,T
  fusion_summary,bitwise_equal,B,rounds_reduced,R,device_latency,D,mean_speedup,S

``--report-json`` (default ``benchmarks/BENCH_fusion.json``) writes the
grid + device timings + per-round attribution + summary for the perf
trajectory; ``--per-round`` runs only the span-derived attribution and
merges it into the existing report. ``scripts/ci.sh`` gates on the summary
row: the fused plan must never regress the unfused bitwise check, and
SCAN/EXSCAN must need fewer rounds on every benched multi-axis mesh.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.offload import (
    OffloadEngine,
    build_plan,
    lower_sim,
    optimize_plan,
    plan_comm_rounds,
)

DEFAULT_REPORT_PATH = Path(__file__).resolve().parent / "BENCH_fusion.json"

#: only multi-axis meshes where fusion provably drops rounds for SCAN and
#: EXSCAN both. An inclusive-scan fusion on a p-rank axis goes from
#: 2*log2(p) rounds to log2(p)+1, so a fused pair on a p=2 axis is a tie,
#: not a win — (4, 2) or (2, 2) SCAN keeps its round count (still bitwise,
#: never worse); EXSCAN always wins because its unfused form pays the
#: structural-shift round on top.
DEFAULT_TOPOLOGIES: Tuple[Tuple[int, ...], ...] = (
    (2, 4), (4, 4), (2, 2, 2), (2, 2, 4),
)
DEFAULT_PAYLOADS: Tuple[int, ...] = (1024, 65536)
DEFAULT_COLLS: Tuple[str, ...] = ("scan", "exscan")


def _time_fn(fn, arg, iters: int) -> float:
    out = fn(arg)
    jax.tree.map(lambda a: a.block_until_ready(), out)  # warm the jit
    times = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        out = fn(arg)
        jax.tree.map(lambda a: a.block_until_ready(), out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _per_round_profile(plan, x, iters: int) -> List[Dict]:
    """Median per-round host latency from the traced eager interpreter.

    ``lower_sim(plan, traced=True)`` runs under a private collecting
    tracer, so every backend ``permute`` emits a ``round`` span whose
    duration (the backend blocks on the permuted result) is that round's
    host dispatch cost. One warmup run keeps primitive compilation out of
    the samples; the reported number is the per-round median over
    ``iters`` runs.
    """
    from repro.obs import tracing as obs_tracing

    fn = lower_sim(plan, traced=True)
    samples: Dict[Tuple[str, int], List[float]] = {}
    order: List[Tuple[str, int]] = []
    with obs_tracing.tracing(obs_tracing.Tracer()) as tracer:
        fn(x)  # warmup
        for _ in range(max(1, iters)):
            tracer.clear()
            fn(x)
            for s in tracer.spans():
                if s.cat != "round":
                    continue
                key = (str(s.args.get("phase")), int(s.args.get("round", 0)))
                if key not in samples:
                    samples[key] = []
                    order.append(key)
                samples[key].append(s.dur_us)
    rounds: List[Dict] = []
    for phase, rnd in order:
        durs = sorted(samples[(phase, rnd)])
        rounds.append(
            {"phase": phase, "round": rnd, "dur_us": durs[len(durs) // 2]}
        )
    return rounds


def per_round(
    *,
    topologies: Sequence[Tuple[int, ...]] = DEFAULT_TOPOLOGIES,
    payloads: Sequence[int] = (1024,),
    colls: Sequence[str] = DEFAULT_COLLS,
    iters: int = 5,
    stats_out: Optional[list] = None,
) -> List[str]:
    """Span-derived per-round latency attribution, raw vs fused.

    Only the first payload is profiled: the per-round host constant this
    section attributes is dispatch overhead, not bandwidth, so it is flat
    in payload at benchmark sizes (the grid section covers payload
    scaling).
    """
    rows: List[str] = []
    entries: List[Dict] = []
    payload = int(payloads[0])
    for sizes in topologies:
        sizes = tuple(int(s) for s in sizes)
        p = int(np.prod(sizes))
        n = max(1, payload // 4)
        rng = np.random.default_rng(p * 31 + payload)
        x = jnp.asarray(
            rng.integers(-6, 7, size=(p, n)).astype(np.float32)
        )
        shape = "x".join(map(str, sizes))
        for coll in colls:
            raw = build_plan(
                coll, sizes, "sum", payload,
                order=tuple(range(len(sizes))),
            )
            for variant, plan in (("raw", raw), ("fused", optimize_plan(raw))):
                rounds = _per_round_profile(plan, x, iters)
                total = sum(r["dur_us"] for r in rounds)
                top = (
                    max(rounds, key=lambda r: r["dur_us"]) if rounds else None
                )
                for r in rounds:
                    rows.append(
                        f"fusion_per_round,{coll},{shape},{payload},"
                        f"{variant},{r['phase']},{r['round']},"
                        f"{r['dur_us']:.1f}"
                    )
                if top is not None:
                    rows.append(
                        f"fusion_per_round_top,{coll},{shape},{variant},"
                        f"{top['phase']},{top['round']},{top['dur_us']:.1f},"
                        f"total,{total:.1f}"
                    )
                entries.append(
                    {
                        "coll": coll,
                        "sizes": list(sizes),
                        "payload_bytes": payload,
                        "variant": variant,
                        "rounds": rounds,
                        "total_us": total,
                        "top_round": top,
                    }
                )
    if stats_out is not None:
        stats_out.append(entries)
    return rows


def run(
    *,
    topologies: Sequence[Tuple[int, ...]] = DEFAULT_TOPOLOGIES,
    payloads: Sequence[int] = DEFAULT_PAYLOADS,
    colls: Sequence[str] = DEFAULT_COLLS,
    iters: int = 5,
    profile_axes: Tuple[int, ...] = (2, 2, 2),
    stats_out: Optional[list] = None,
) -> List[str]:
    rows: List[str] = []
    grid: List[Dict] = []
    all_bitwise = True
    all_reduced = True
    speedups: List[float] = []
    for sizes in topologies:
        sizes = tuple(int(s) for s in sizes)
        p = int(np.prod(sizes))
        for payload in payloads:
            n = max(1, payload // 4)
            rng = np.random.default_rng(p * 31 + payload)
            x = jnp.asarray(
                rng.integers(-6, 7, size=(p, n)).astype(np.float32)
            )
            for coll in colls:
                raw = build_plan(
                    coll, sizes, "sum", payload,
                    order=tuple(range(len(sizes))),
                )
                opt = optimize_plan(raw)
                rr, fr = plan_comm_rounds(raw), plan_comm_rounds(opt)
                fn_raw = jax.jit(lower_sim(raw))
                fn_opt = jax.jit(lower_sim(opt))
                bitwise = bool(
                    np.array_equal(
                        np.asarray(fn_opt(x)), np.asarray(fn_raw(x))
                    )
                )
                t_raw = _time_fn(fn_raw, x, iters)
                t_opt = _time_fn(fn_opt, x, iters)
                speedup = t_raw / t_opt if t_opt > 0 else 0.0
                all_bitwise &= bitwise
                all_reduced &= fr < rr
                speedups.append(speedup)
                shape = "x".join(map(str, sizes))
                rows.append(
                    f"fusion_speedup,{coll},{shape},{payload},{rr},{fr},"
                    f"{t_raw*1e6:.1f},{t_opt*1e6:.1f},{speedup:.3f},"
                    f"{int(bitwise)}"
                )
                grid.append(
                    {
                        "coll": coll,
                        "sizes": list(sizes),
                        "payload_bytes": payload,
                        "raw_rounds": rr,
                        "fused_rounds": fr,
                        "raw_us": t_raw * 1e6,
                        "fused_us": t_opt * 1e6,
                        "speedup": speedup,
                        "bitwise": bitwise,
                    }
                )

    # profiler-sourced per-schedule device latency through the engine
    eng = OffloadEngine()
    device: Dict[str, Dict] = {}
    p = int(np.prod(profile_axes))
    rng = np.random.default_rng(0)
    xp = jnp.asarray(rng.integers(-5, 6, size=(p, 64)).astype(np.float32))
    for coll in colls:
        desc = eng.make_descriptor(
            coll, axes=profile_axes, payload_bytes=64 * 4, op="sum",
            optimize=True,
        )
        t = eng.profile_offload(desc, xp)
        shape = "x".join(map(str, profile_axes))
        rows.append(
            f"fusion_device,{coll},{shape},{t.device_us:.1f},"
            f"{t.wall_us:.1f},{t.source},{t.events}"
        )
        device[coll] = {
            "sizes": list(profile_axes),
            "device_us": t.device_us,
            "wall_us": t.wall_us,
            "source": t.source,
            "events": t.events,
        }
    snap = eng.telemetry.snapshot()
    # the gate demands genuinely trace-derived numbers: a wall-clock
    # fallback (e.g. the profiler's chrome export disappearing in a jax
    # upgrade) must fail CI, not silently impersonate a device measurement
    has_device = all(
        snap["device_latency_by_coll_us"].get(c, 0.0) > 0
        and snap["latency_source_by_coll"].get(c) == "profiler"
        for c in colls
    )
    mean_speedup = (
        float(np.mean(speedups)) if speedups else 0.0
    )

    # span-derived per-round attribution (raw vs fused, traced interpreter)
    per_round_stats: list = []
    rows.extend(
        per_round(
            topologies=topologies,
            payloads=payloads,
            colls=colls,
            iters=iters,
            stats_out=per_round_stats,
        )
    )

    rows.append(
        f"fusion_summary,bitwise_equal,{int(all_bitwise)},"
        f"rounds_reduced,{int(all_reduced)},"
        f"device_latency,{int(has_device)},mean_speedup,{mean_speedup:.3f}"
    )
    if stats_out is not None:
        stats_out.append(
            {
                "grid": grid,
                "device_latency": device,
                "per_round": per_round_stats[0] if per_round_stats else [],
                "telemetry": {
                    "device_latency_by_coll_us": snap[
                        "device_latency_by_coll_us"
                    ],
                    "latency_source_by_coll": snap[
                        "latency_source_by_coll"
                    ],
                },
                "summary": {
                    "bitwise_equal": all_bitwise,
                    "rounds_reduced": all_reduced,
                    "device_latency": has_device,
                    "mean_speedup": mean_speedup,
                },
            }
        )
    return rows


def smoke(stats_out: Optional[list] = None) -> List[str]:
    """The CI entry: reduced grid, same gates."""
    return run(
        topologies=((2, 4), (2, 2, 2)),
        payloads=(1024,),
        colls=("scan", "exscan"),
        iters=2,
        stats_out=stats_out,
    )


def write_report(path: Path, stats: list, mode: str) -> None:
    payload = {
        "benchmark": "fusion_speedup",
        "mode": mode,
        "columns": "rounds + measured us per (coll, sizes, payload); "
        "device latency is profiler-sourced where source == 'profiler'; "
        "per_round is the span-derived host cost of each communication "
        "round (traced eager interpreter, median us)",
        **(stats[0] if stats else {}),
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# fusion speedup stats written to {path}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="fewer iters")
    ap.add_argument(
        "--per-round",
        action="store_true",
        help="only the span-derived per-round attribution (traced eager "
        "interpreter); with --report-json, merges a 'per_round' section "
        "into the existing artifact instead of rewriting it",
    )
    ap.add_argument(
        "--report-json",
        nargs="?",
        const=str(DEFAULT_REPORT_PATH),
        default=None,
        metavar="PATH",
        help=f"write stats to a JSON artifact (default "
        f"{DEFAULT_REPORT_PATH.name})",
    )
    args = ap.parse_args()
    iters = 3 if args.quick else 5
    if args.per_round:
        print(
            "fusion_per_round,coll,sizes,msg_bytes,variant,phase,round,"
            "dur_us"
        )
        pr_stats: list = []
        for row in per_round(iters=iters, stats_out=pr_stats):
            print(row)
        if args.report_json:
            path = Path(args.report_json)
            payload = (
                json.loads(path.read_text())
                if path.exists()
                else {"benchmark": "fusion_speedup"}
            )
            payload["per_round"] = pr_stats[0] if pr_stats else []
            path.write_text(json.dumps(payload, indent=2) + "\n")
            print(f"# per-round attribution merged into {path}")
        return
    stats: list = []
    print(
        "fusion_speedup,coll,sizes,msg_bytes,raw_rounds,fused_rounds,"
        "raw_us,fused_us,speedup,bitwise"
    )
    for row in run(iters=iters, stats_out=stats):
        print(row)
    if args.report_json:
        write_report(Path(args.report_json), stats, "full")


if __name__ == "__main__":
    main()
