"""Reliability overhead benchmark: reliable dispatch vs raw dispatch.

The reliability stack (:mod:`repro.offload.reliability` + the broker's
checksum/bisection plumbing) claims its happy path is nearly free: two
payload checksums (submit + pre-dispatch verify), one breaker check, one
retry-loop entry, and the group-bisection wrapper. This benchmark is
that claim's receipt, measured two ways because the true cost (tens of
µs against a multi-ms dispatch) sits close to the wall-clock noise
floor of a shared CI box:

  * **A/B dispatch timing** — one broker, one engine, one schedule
    cache; the *same* submit/drain loop runs with (a) the reliability
    layer installed (``_dispatcher`` + policy) and (b) both detached —
    so the delta isolates exactly what reliability adds to the steady
    cached path. Modes alternate rep by rep in *both* orders (a fixed
    on-then-off order lets per-pair transition cost masquerade as
    reliability cost), each trial reports the median-of-reps delta, and
    ``overhead_frac`` is the **best of ``TRIALS`` independent trials**:
    a genuinely expensive layer shows up in every trial, a noise spike
    in one.
  * **Derived overhead** — the two per-dispatch checksums and the
    dispatcher's pure bookkeeping (retry entry + breaker + ladder cache,
    measured against a stub engine so no actual dispatch is timed) are
    microbenchmarked, and ``derived_frac = (2 x checksum + bookkeeping)
    / dispatch`` gives the statistically-powerful bound: a checksum that
    got 10x slower moves it 10x, no matter how noisy the box.

The payload is deliberately large (8 MiB): the reliability cost is a
*flat* ~90 µs per dispatch — the checksum is O(16 KiB) per leaf by
design (tiered sampling — see ``reliability._fold_bytes``) and runs
cold-cache after each multi-MiB dispatch — so the "< 2% of the cached
dispatch path" contract is a statement about the large-payload
streaming regime the paper targets (the break-even is ~3 MiB;
sub-MiB payloads pay proportionally more, which the report makes
visible rather than hiding). Large payloads are also where the
historical regression lived: a reference cycle in the bisection driver
stalled multi-MiB buffers until gc and slowed the same jitted
executable ~25%.

Writes ``benchmarks/BENCH_reliability.json``;
``benchmarks.check_regression --reliability`` gates *both* fractions
(default ceiling 2%).

CSV section:
  reliability_overhead,batch,reps,on_us,off_us,overhead_frac,derived_frac,checksum_us
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np
import jax.numpy as jnp

from repro.offload import OffloadEngine
from repro.offload import reliability as rel
from repro.service import DescriptorBroker

#: the smoke dispatch path: an 8 MiB payload (~28 ms cached dispatch)
#: puts the flat ~90 µs reliability cost at ~0.3% — a wide margin under
#: the 2% gate, so box noise can't flake CI — and is exactly the regime
#: where buffer-lifetime bugs scale up
AXES = (2, 4)
N = 262144    # payload columns (x int32 x prod(AXES) rows = 8 MiB)
BATCH = 8     # dispatches per timed sample (dispatch is ~28 ms here)
REPS = 12     # alternating samples per mode per trial; median is used
TRIALS = 4    # independent trials; the best (lowest) delta is reported
CHECKSUM_CALLS = 200
BOOKKEEPING_CALLS = 2000


def _median(xs: List[float]) -> float:
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


def _payload(nrows: int) -> jnp.ndarray:
    rng = np.random.default_rng(7)
    return jnp.asarray(
        rng.integers(0, 1 << 20, size=(nrows, N), dtype=np.int32)
    )


def measure_dispatch(
    *, batch: int = BATCH, reps: int = REPS, trials: int = TRIALS
) -> Dict[str, float]:
    """Per-request broker latency with the reliability layer vs without.

    Same broker both ways — only ``_dispatcher`` and the policy are
    swapped, so schedule caches, queues, and telemetry are shared and
    the delta is the reliability layer alone.
    """
    broker = DescriptorBroker(reliability=rel.ReliabilityPolicy())
    eng = broker.engine
    desc = eng.make_descriptor(
        "scan", axes=AXES, payload_bytes=N * 4, op="sum", optimize=True,
    )
    x = _payload(int(np.prod(AXES)))
    client = broker.client("bench")
    dispatcher, policy = broker._dispatcher, broker.reliability
    modes = {"on": (dispatcher, policy), "off": (None, None)}

    def sample(mode: str) -> float:
        broker._dispatcher, broker.reliability = modes[mode]
        try:
            t0 = time.perf_counter()
            for _ in range(batch):
                t = client.submit(desc, x)
                broker.drain()
            t.result(timeout=120.0)
            return (time.perf_counter() - t0) / batch * 1e6
        finally:
            broker._dispatcher, broker.reliability = dispatcher, policy

    for mode in ("on", "off"):  # warm: compile + schedule cache
        sample(mode)
    trial_rows: List[Dict[str, float]] = []
    for _ in range(trials):
        samples: Dict[str, List[float]] = {"on": [], "off": []}
        for rep in range(reps):
            # alternate which mode goes first so per-pair transition
            # cost (allocator state, frequency ramp) cancels out
            order = ("on", "off") if rep % 2 == 0 else ("off", "on")
            for mode in order:
                samples[mode].append(sample(mode))
        on_us = _median(samples["on"])
        off_us = _median(samples["off"])
        trial_rows.append(
            {
                "on_us": on_us,
                "off_us": off_us,
                "overhead_frac": (
                    (on_us - off_us) / off_us if off_us > 0 else 0.0
                ),
            }
        )
    best = min(trial_rows, key=lambda r: r["overhead_frac"])
    counts = dict(dispatcher.counts)
    return {
        "batch": batch,
        "reps": reps,
        "trials": trials,
        "payload_bytes": int(np.prod(AXES)) * N * 4,
        "on_us_per_dispatch": best["on_us"],
        "off_us_per_dispatch": best["off_us"],
        "overhead_frac": best["overhead_frac"],
        "trial_overheads": [r["overhead_frac"] for r in trial_rows],
        "retries": counts["retries"],
        "degrades": counts["degrades"],
    }


def measure_checksum(calls: int = CHECKSUM_CALLS) -> Dict[str, float]:
    """Raw per-call ``payload_checksum`` cost on the benchmark payload.

    Measured **cold-cache** (a 32 MiB sweep between calls): in the
    broker the submit-side checksum always runs right after a dispatch
    streamed multi-MiB buffers through the cache, so the warm tight-loop
    figure (~4x lower) would understate the real in-situ cost and let
    the derived bound pass a checksum the A/B would fail.
    """
    x = _payload(int(np.prod(AXES)))
    evict = np.zeros(32 * 1024 * 1024 // 8, np.int64)
    rel.payload_checksum(x)  # warm the structure-digest cache
    ts: List[float] = []
    for _ in range(calls):
        evict[:] += 1
        t0 = time.perf_counter()
        rel.payload_checksum(x)
        ts.append(time.perf_counter() - t0)
    return {"calls": calls, "per_call_us": _median(ts) * 1e6}


class _StubEngine:
    """Returns the payload untouched: times the dispatcher's bookkeeping
    (descriptor resolve, ladder cache, breaker, retry entry) with zero
    actual dispatch cost inside."""

    def __init__(self, engine: OffloadEngine):
        self._engine = engine

    def _as_descriptor(self, d):
        return self._engine._as_descriptor(d)

    def offload(self, d, x, axis_name=None, mesh=None):
        return x


def measure_bookkeeping(
    calls: int = BOOKKEEPING_CALLS,
) -> Dict[str, float]:
    """Pure per-dispatch cost of the ReliableDispatcher machinery."""
    eng = OffloadEngine()
    desc = eng.make_descriptor(
        "scan", axes=AXES, payload_bytes=N * 4, op="sum", optimize=True,
    )
    stub = _StubEngine(eng)
    dispatcher = rel.ReliableDispatcher.from_policy(
        stub, rel.ReliabilityPolicy()
    )
    x = jnp.zeros((1,), jnp.int32)  # payload size is irrelevant here
    for _ in range(10):
        dispatcher.offload(desc, x)
    t0 = time.perf_counter()
    for _ in range(calls):
        dispatcher.offload(desc, x)
    dt = time.perf_counter() - t0
    return {"calls": calls, "per_call_us": dt / calls * 1e6}


def derived_frac(
    dispatch: Dict[str, float],
    checksum: Dict[str, float],
    bookkeeping: Dict[str, float],
) -> float:
    """Analytic overhead bound: two checksums + bookkeeping / dispatch
    time. Immune to wall-clock noise — the gate with statistical power."""
    dispatch_us = dispatch["off_us_per_dispatch"]
    if dispatch_us <= 0:
        return 0.0
    return (
        2.0 * checksum["per_call_us"] + bookkeeping["per_call_us"]
    ) / dispatch_us


def smoke(*, stats_out: Optional[Dict] = None) -> List[str]:
    """CI entry: one measurement, one greppable row."""
    dispatch = measure_dispatch()
    checksum = measure_checksum()
    bookkeeping = measure_bookkeeping()
    derived = derived_frac(dispatch, checksum, bookkeeping)
    dispatch["derived_frac"] = derived
    if stats_out is not None:
        stats_out["dispatch"] = dispatch
        stats_out["checksum"] = checksum
        stats_out["bookkeeping"] = bookkeeping
    return [
        f"reliability_overhead,{dispatch['batch']},{dispatch['reps']},"
        f"{dispatch['on_us_per_dispatch']:.1f},"
        f"{dispatch['off_us_per_dispatch']:.1f},"
        f"{dispatch['overhead_frac']:.4f},{derived:.4f},"
        f"{checksum['per_call_us']:.1f}"
    ]


def write_report(path: "str | Path", stats: Dict) -> Path:
    path = Path(path)
    report = {
        "benchmark": "reliability_overhead",
        "mode": "smoke",
        "columns": (
            "dispatch: reliability-on vs reliability-off per-request "
            "broker latency (best-of-trials median delta + derived "
            "analytic fraction); checksum: raw payload_checksum cost on "
            "the 4 MiB benchmark payload; bookkeeping: ReliableDispatcher "
            "machinery against a stub engine"
        ),
        **stats,
    }
    path.write_text(json.dumps(report, indent=1) + "\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out", default="benchmarks/BENCH_reliability.json",
        help="report path (default benchmarks/BENCH_reliability.json)",
    )
    ap.add_argument("--batch", type=int, default=BATCH)
    ap.add_argument("--reps", type=int, default=REPS)
    args = ap.parse_args()
    stats: Dict = {}
    stats["dispatch"] = measure_dispatch(batch=args.batch, reps=args.reps)
    stats["checksum"] = measure_checksum()
    stats["bookkeeping"] = measure_bookkeeping()
    d = stats["dispatch"]
    d["derived_frac"] = derived_frac(
        d, stats["checksum"], stats["bookkeeping"]
    )
    print(
        "reliability_overhead,batch,reps,on_us,off_us,overhead_frac,"
        "derived_frac,checksum_us"
    )
    print(
        f"reliability_overhead,{d['batch']},{d['reps']},"
        f"{d['on_us_per_dispatch']:.1f},{d['off_us_per_dispatch']:.1f},"
        f"{d['overhead_frac']:.4f},{d['derived_frac']:.4f},"
        f"{stats['checksum']['per_call_us']:.1f}"
    )
    out = write_report(args.out, stats)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
