"""Flight-recorder overhead benchmark: recorder-on vs recorder-off dispatch.

The flight recorder (:mod:`repro.obs.events`) claims to be cheap enough to
stay on always. This benchmark is that claim's receipt, measured two ways
because the true cost (~1 µs/dispatch) sits *below* the wall-clock noise
floor of a shared CI box (~5 µs on a ~0.5 ms dispatch):

  * **A/B dispatch timing** — the same cached sim-mode dispatch loop runs
    with (a) the real ring recorder installed and (b) a null recorder
    whose ``record()`` does nothing — same code path, same
    ``set_recorder`` indirection, so the delta isolates exactly the ring
    append the "on" configuration pays. Modes alternate rep by rep in
    *both* orders (a fixed on-then-off order lets per-pair transition
    cost masquerade as recorder cost — measured at +3% before the fix),
    each trial reports the median-of-reps delta, and ``overhead_frac``
    is the **best of ``TRIALS`` independent trials**: a genuinely
    expensive recorder shows up in every trial, a noise spike in one.
  * **Derived overhead** — raw ``record()`` calls are microbenchmarked
    (that effect is thousands of σ, not a coin flip), and
    ``derived_frac = events_per_dispatch x record_ns / dispatch_ns``
    gives the statistically-powerful bound: a record() that got 10x
    slower moves it 10x, no matter how noisy the box.

Writes ``benchmarks/BENCH_obs.json``; ``benchmarks.check_regression``
gates *both* fractions (default ceiling 2%).

CSV section:
  obs_overhead,batch,reps,on_us,off_us,overhead_frac,derived_frac,record_ns
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np
import jax.numpy as jnp

from repro.obs import events as obs_events
from repro.offload import OffloadEngine

#: the smoke dispatch path: large enough (~0.5 ms cached dispatch) that
#: the ~1 µs/event ring append is a fraction-of-a-percent signal, not a
#: coin flip against scheduler noise on a tiny 50 µs dispatch
AXES = (2, 4)
N = 16384     # payload columns
BATCH = 50    # dispatches per timed sample
REPS = 12     # alternating samples per mode per trial; median is used
TRIALS = 3    # independent trials; the best (lowest) delta is reported
RECORD_CALLS = 20_000


class _NullRecorder(obs_events.FlightRecorder):
    """Recorder-off mode: the same object shape, a no-op hot path."""

    def record(self, kind, **fields):  # noqa: D102 - interface override
        return None


def _median(xs: List[float]) -> float:
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


def measure_dispatch(
    *, batch: int = BATCH, reps: int = REPS, trials: int = TRIALS
) -> Dict[str, float]:
    """Per-dispatch latency with the ring recorder vs a null recorder."""
    eng = OffloadEngine()
    desc = eng.make_descriptor(
        "scan", axes=AXES, payload_bytes=N * 4, op="sum", optimize=True,
    )
    rng = np.random.default_rng(3)
    x = jnp.asarray(
        rng.standard_normal((int(np.prod(AXES)), N)).astype(np.float32)
    )
    # warm: compile + schedule cache, so every timed dispatch is the
    # steady-state cached path the recorder instruments
    for _ in range(5):
        eng.offload(desc, x).block_until_ready()

    recorders = {
        "on": obs_events.FlightRecorder(),
        "off": _NullRecorder(),
    }
    trial_rows: List[Dict[str, float]] = []
    prev = obs_events.get_recorder()
    try:
        for _ in range(trials):
            samples: Dict[str, List[float]] = {"on": [], "off": []}
            for rep in range(reps):
                # alternate which mode goes first so per-pair transition
                # cost (cache state, frequency ramp) cancels out instead
                # of always landing on one mode
                order = ("on", "off") if rep % 2 == 0 else ("off", "on")
                for mode in order:
                    obs_events.set_recorder(recorders[mode])
                    t0 = time.perf_counter()
                    for _ in range(batch):
                        eng.offload(desc, x).block_until_ready()
                    dt = time.perf_counter() - t0
                    samples[mode].append(dt / batch * 1e6)
            on_us = _median(samples["on"])
            off_us = _median(samples["off"])
            trial_rows.append(
                {
                    "on_us": on_us,
                    "off_us": off_us,
                    "overhead_frac": (
                        (on_us - off_us) / off_us if off_us > 0 else 0.0
                    ),
                }
            )
    finally:
        obs_events.set_recorder(prev)
    best = min(trial_rows, key=lambda r: r["overhead_frac"])
    events_per_dispatch = len(recorders["on"]) / (batch * reps * trials)
    return {
        "batch": batch,
        "reps": reps,
        "trials": trials,
        "on_us_per_dispatch": best["on_us"],
        "off_us_per_dispatch": best["off_us"],
        "overhead_frac": best["overhead_frac"],
        "trial_overheads": [r["overhead_frac"] for r in trial_rows],
        "events_per_dispatch": events_per_dispatch,
        "events_recorded": len(recorders["on"]),
    }


def measure_record(calls: int = RECORD_CALLS) -> Dict[str, float]:
    """Raw per-``record()`` cost in ns (ring at steady capacity)."""
    rec = obs_events.FlightRecorder()
    for i in range(rec.capacity):  # fill: steady state evicts every append
        rec.record("warm", i=i)
    t0 = time.perf_counter()
    for i in range(calls):
        rec.record("bench", coll="SCAN", cache="hit", latency_us=1.0)
    dt = time.perf_counter() - t0
    return {"calls": calls, "per_call_ns": dt / calls * 1e9}


def derived_frac(dispatch: Dict[str, float], rec: Dict[str, float]) -> float:
    """Analytic overhead bound: per-event cost x event rate / dispatch
    time. Immune to wall-clock noise — the gate with statistical power."""
    dispatch_ns = dispatch["off_us_per_dispatch"] * 1e3
    if dispatch_ns <= 0:
        return 0.0
    return (
        dispatch["events_per_dispatch"] * rec["per_call_ns"] / dispatch_ns
    )


def smoke(*, stats_out: Optional[Dict] = None) -> List[str]:
    """CI entry: one measurement, one greppable row."""
    dispatch = measure_dispatch()
    rec = measure_record()
    derived = derived_frac(dispatch, rec)
    dispatch["derived_frac"] = derived
    if stats_out is not None:
        stats_out["dispatch"] = dispatch
        stats_out["record"] = rec
    return [
        f"obs_overhead,{dispatch['batch']},{dispatch['reps']},"
        f"{dispatch['on_us_per_dispatch']:.1f},"
        f"{dispatch['off_us_per_dispatch']:.1f},"
        f"{dispatch['overhead_frac']:.4f},{derived:.4f},"
        f"{rec['per_call_ns']:.0f}"
    ]


def write_report(path: "str | Path", stats: Dict) -> Path:
    path = Path(path)
    report = {
        "benchmark": "obs_overhead",
        "mode": "smoke",
        "columns": (
            "dispatch: recorder-on vs recorder-off per-dispatch latency "
            "(best-of-trials median delta + derived analytic fraction); "
            "record: raw per-event cost"
        ),
        **stats,
    }
    path.write_text(json.dumps(report, indent=1) + "\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out", default="benchmarks/BENCH_obs.json",
        help="report path (default benchmarks/BENCH_obs.json)",
    )
    ap.add_argument("--batch", type=int, default=BATCH)
    ap.add_argument("--reps", type=int, default=REPS)
    args = ap.parse_args()
    stats: Dict = {}
    stats["dispatch"] = measure_dispatch(batch=args.batch, reps=args.reps)
    stats["record"] = measure_record()
    d, r = stats["dispatch"], stats["record"]
    d["derived_frac"] = derived_frac(d, r)
    print(
        "obs_overhead,batch,reps,on_us,off_us,overhead_frac,"
        "derived_frac,record_ns"
    )
    print(
        f"obs_overhead,{d['batch']},{d['reps']},"
        f"{d['on_us_per_dispatch']:.1f},{d['off_us_per_dispatch']:.1f},"
        f"{d['overhead_frac']:.4f},{d['derived_frac']:.4f},"
        f"{r['per_call_ns']:.0f}"
    )
    out = write_report(args.out, stats)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
