"""Service throughput benchmark: requests/sec and latency percentiles vs.
client count, with coalescing on and off.

Each configuration runs C client threads, each streaming R identical-shape
SCAN requests through one started :class:`DescriptorBroker` (sim-mode
engine). "Coalescing off" pins ``max_coalesce=1`` — every request is its
own engine dispatch — so the on/off delta isolates what request fusion
buys. Latencies are measured client-side (submit -> result, the
host-visible number), p50/p99 from the exact sample set; the broker's
per-tenant histograms are telemetry, not the benchmark's ruler.

``smoke()`` is the CI entry: a single coalesced configuration that asserts
the fused results are bitwise equal to direct engine dispatch and that the
coalesce factor exceeds 1, emitting a greppable summary row.

CSV section:
  service_throughput,clients,coalesce,requests,reqs_per_s,p50_us,p99_us,
      mean_us,coalesce_factor
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence

import numpy as np
import jax.numpy as jnp

from repro.offload import OffloadEngine
from repro.service import DescriptorBroker

N = 256  # payload columns per request
P = 8    # ranks per collective


def _run_config(
    n_clients: int,
    n_requests: int,
    *,
    coalesce: bool,
    flush_interval_s: float = 0.002,
    payload_cols: int = N,
) -> Dict[str, float]:
    broker = DescriptorBroker(
        OffloadEngine(),
        flush_interval_s=flush_interval_s,
        max_coalesce=64 if coalesce else 1,
    )
    desc = broker.make_descriptor(
        "SCAN", p=P, payload_bytes=payload_cols * 4, op="sum"
    ).encode()
    rng = np.random.default_rng(0)
    x = jnp.asarray(
        rng.normal(size=(P, payload_cols)).astype(np.float32)
    )
    # warm every fused shape the run can produce (single + pow2 widths up
    # to the client count) so compile time doesn't skew the percentiles;
    # the broker is drained unstarted so each warm group's width is exact
    width = 1
    while width <= (1 << max(0, n_clients - 1).bit_length()):
        tmp = [broker.client(f"warm{width}_{i}") for i in range(width)]
        tickets = [t.submit(desc, x) for t in tmp]
        broker.drain()
        for t, c in zip(tickets, tmp):
            t.result(60)
            c.close()
        if not coalesce:
            break  # every dispatch is width 1 anyway
        width *= 2
    broker.start()

    clients = [broker.client(f"c{i}") for i in range(n_clients)]
    barrier = threading.Barrier(n_clients)
    latencies: List[List[float]] = [[] for _ in range(n_clients)]
    errors: List[BaseException] = []

    def work(ci: int) -> None:
        try:
            barrier.wait()
            for _ in range(n_requests):
                t0 = time.perf_counter()
                clients[ci].offload(desc, x, timeout=60)
                latencies[ci].append(time.perf_counter() - t0)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [
        threading.Thread(target=work, args=(i,)) for i in range(n_clients)
    ]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    broker.stop()
    if errors:
        raise errors[0]
    flat = np.asarray([s for per in latencies for s in per])
    total = n_clients * n_requests
    return {
        "clients": n_clients,
        "coalesce": int(coalesce),
        "requests": total,
        "reqs_per_s": total / wall,
        "p50_us": float(np.percentile(flat, 50) * 1e6),
        "p99_us": float(np.percentile(flat, 99) * 1e6),
        "mean_us": float(flat.mean() * 1e6),
        "coalesce_factor": broker.telemetry.coalesce_factor,
    }


def _row(s: Dict[str, float]) -> str:
    return (
        f"service_throughput,{s['clients']},"
        f"{'on' if s['coalesce'] else 'off'},{s['requests']},"
        f"{s['reqs_per_s']:.0f},{s['p50_us']:.0f},{s['p99_us']:.0f},"
        f"{s['mean_us']:.0f},{s['coalesce_factor']:.2f}"
    )


def run(
    *,
    client_counts: Sequence[int] = (1, 2, 4, 8),
    n_requests: int = 32,
    stats_out: Optional[List[Dict[str, float]]] = None,
) -> List[str]:
    """One row per (client count, coalescing on/off)."""
    rows: List[str] = []
    for c in client_counts:
        for coalesce in (False, True):
            s = _run_config(c, n_requests, coalesce=coalesce)
            if stats_out is not None:
                stats_out.append(s)
            rows.append(_row(s))
    return rows


def smoke(
    n_clients: int = 4,
    n_requests: int = 8,
    stats_out: Optional[List[Dict[str, float]]] = None,
) -> List[str]:
    """CI entry: coalesced service dispatch must be bitwise equal to direct
    engine dispatch, with a coalesce factor > 1."""
    rows: List[str] = []
    # bitwise proof: distinct per-tenant payloads through one fused dispatch
    broker = DescriptorBroker(OffloadEngine())
    direct = OffloadEngine()
    desc = broker.make_descriptor("SCAN", p=P, payload_bytes=N * 4, op="sum")
    rng = np.random.default_rng(7)
    xs = [
        jnp.asarray(rng.integers(-4, 5, size=(P, N)).astype(np.float32))
        for _ in range(n_clients)
    ]
    tickets = [
        broker.client(f"s{i}").submit(desc.encode(), xs[i])
        for i in range(n_clients)
    ]
    broker.drain()
    bitwise = all(
        np.array_equal(
            np.asarray(t.result(30)), np.asarray(direct.offload(desc, x))
        )
        for t, x in zip(tickets, xs)
    )
    factor = broker.telemetry.coalesce_factor
    assert bitwise, "coalesced dispatch diverged from direct dispatch"
    assert factor > 1.0, f"no coalescing happened (factor={factor})"

    # one small threaded throughput config, coalescing on vs off
    for coalesce in (False, True):
        s = _run_config(
            n_clients, n_requests, coalesce=coalesce,
            flush_interval_s=0.01, payload_cols=64,
        )
        if stats_out is not None:
            stats_out.append(s)
        rows.append(_row(s))
    rows.append(
        f"service_smoke_summary,bitwise_equal,{int(bitwise)},"
        f"coalesce_gt1,{int(factor > 1.0)},coalesce_factor,{factor:.2f}"
    )
    return rows
