"""Paper Figs. 4-5 analogue: host-visible MPI_Scan latency, software vs
offloaded, per algorithm x message size, 8 ranks.

Mapping (DESIGN.md section 2):
  software ("SW_")   = host-orchestrated schedule: one dispatch + host sync
                       per hop (core.host_scan) — the MPI-over-Ethernet role.
  offloaded ("NF_")  = whole schedule fused into ONE compiled program
                       (core.sim_scan under jit) — the NIC-offload role: one
                       descriptor in, one result out.

Ranks are simulated as the leading axis on one device, so the deltas isolate
exactly what the paper isolates: who drives the schedule. Message sizes match
the paper's sweep (4B..1KB of int/float payload per rank).

Emits CSV rows: figure,algo,variant,msg_bytes,us_per_call
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sim_scan, time_host_scan, time_offloaded_scan

P = 8  # paper: 8 NetFPGA nodes
ALGOS = ["sequential", "recursive_doubling", "binomial_tree", "sklansky"]
MSG_BYTES = [4, 16, 64, 256, 1024]


def _payload(msg_bytes: int) -> jax.Array:
    n = max(1, msg_bytes // 4)
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.normal(size=(P, n)).astype(np.float32))


def run(iters: int = 30) -> List[str]:
    rows = []
    for msg in MSG_BYTES:
        x = _payload(msg)
        for algo in ALGOS:
            t_sw = time_host_scan(x, "sum", P, algorithm=algo, iters=iters)
            t_nf = time_offloaded_scan(x, "sum", P, algorithm=algo, iters=iters)
            rows.append(f"fig4_avg_latency,SW_{algo},software,{msg},{t_sw*1e6:.2f}")
            rows.append(f"fig4_avg_latency,NF_{algo},offloaded,{msg},{t_nf*1e6:.2f}")
    return rows


def run_min(iters: int = 30) -> List[str]:
    """Fig. 5: minimum observed latency (best case over iterations)."""
    rows = []
    for msg in MSG_BYTES:
        x = _payload(msg)
        for algo in ALGOS:
            best_sw = float("inf")
            best_nf = float("inf")
            from repro.core import host_scan
            fused = jax.jit(
                lambda s, a=algo: sim_scan(s, "sum", P, algorithm=a)
            )
            fused(x).block_until_ready()
            host_scan(x, "sum", P, algorithm=algo)  # warm
            for _ in range(iters):
                t0 = time.perf_counter()
                host_scan(x, "sum", P, algorithm=algo)
                best_sw = min(best_sw, time.perf_counter() - t0)
                t0 = time.perf_counter()
                fused(x).block_until_ready()
                best_nf = min(best_nf, time.perf_counter() - t0)
            rows.append(f"fig5_min_latency,SW_{algo},software,{msg},{best_sw*1e6:.2f}")
            rows.append(f"fig5_min_latency,NF_{algo},offloaded,{msg},{best_nf*1e6:.2f}")
    return rows


def main() -> None:
    print("figure,algo,variant,msg_bytes,us_per_call")
    for row in run() + run_min():
        print(row)


if __name__ == "__main__":
    main()
