"""Roofline report generator: dry-run artifacts -> markdown tables.

Reads benchmarks/artifacts/dryrun/*.json (written by repro.launch.dryrun) and
emits the EXPERIMENTS.md section bodies. Never hand-type a roofline number:
this script is the single source of truth.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

ART = Path(__file__).resolve().parent / "artifacts" / "dryrun"
BENCH_FUSION = Path(__file__).resolve().parent / "BENCH_fusion.json"
HBM_PER_CHIP = 16e9  # v5e


def load(mesh: str) -> List[Dict]:
    recs = []
    for p in sorted(ART.glob(f"*__{mesh}.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def fmt_b(x) -> str:
    if x is None:
        return "-"
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.2f}{unit}"
    return f"{x:.0f}B"


_NO_DRYRUN = (
    "(no dry-run artifacts under benchmarks/artifacts/dryrun; run "
    "`python -m repro.launch.dryrun --all` to populate this table)"
)


def roofline_table(mesh: str = "single") -> str:
    recs = load(mesh)
    if not recs:
        return _NO_DRYRUN
    rows = [
        "| arch | shape | t_compute | t_memory | t_collective | bottleneck "
        "| useful/HLO | MFU@bound |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        ro = r["roofline"]
        tb = max(ro["t_compute_s"], ro["t_memory_s"], ro["t_collective_s"])
        mfu = (
            r["model_flops_total"] / (tb * ro["n_chips"] * 197e12)
            if tb > 0 else 0.0
        )
        ratio = r.get("useful_flops_ratio")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(ro['t_compute_s'])} "
            f"| {fmt_s(ro['t_memory_s'])} | {fmt_s(ro['t_collective_s'])} "
            f"| {ro['bottleneck']} | {ratio:.2f} | {mfu*100:.1f}% |"
            if ratio is not None else
            f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - |"
        )
    return "\n".join(rows)


def dryrun_table(mesh: str) -> str:
    recs = load(mesh)
    if not recs:
        return _NO_DRYRUN
    rows = [
        "| arch | shape | chips | compile | args/dev | temps/dev | "
        "collectives (AR/AG/RS/A2A/CP) | coll wire bytes |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        m = r["memory"]
        c = r["collectives"]["counts"]
        n = r["n_chips"]
        args = m["argument_size_bytes"]
        temps = m["temp_size_bytes"]
        counts = (
            f"{c.get('all-reduce',0):.0f}/{c.get('all-gather',0):.0f}/"
            f"{c.get('reduce-scatter',0):.0f}/{c.get('all-to-all',0):.0f}/"
            f"{c.get('collective-permute',0):.0f}"
        )
        rows.append(
            f"| {r['arch']} | {r['shape']} | {n} | {r['compile_s']}s "
            f"| {fmt_b(args/n if args else None)} | {fmt_b(temps/n if temps else None)} "
            f"| {counts} | {fmt_b(r['roofline']['collective_bytes_per_device'])} |"
        )
    return "\n".join(rows)


def fit_report(mesh: str = "single") -> str:
    """Per-device memory fit check vs 16GB HBM."""
    lines = []
    for r in load(mesh):
        m = r["memory"]
        n = r["n_chips"]
        total = (m["argument_size_bytes"] or 0) / n + (m["temp_size_bytes"] or 0) / n
        flag = "OK" if total < HBM_PER_CHIP else "OVER"
        if flag == "OVER":
            lines.append(
                f"  - {r['arch']} x {r['shape']}: {fmt_b(total)}/chip {flag}"
            )
    return "\n".join(lines) if lines else "  - all cells fit in 16GB/chip"


def per_round_table() -> str:
    """Span-derived per-round attribution table from BENCH_fusion.json.

    Each row is one (coll, mesh, raw|fused|chunked) traced lowering: how
    many communication rounds the eager interpreter dispatched, the summed
    host cost, and which single round dominates — the ranked answer to
    the ROADMAP wall-clock question of where the per-round constant
    lives. Chunked variants attribute cost per (round, chunk) pipeline
    cell, so the top-round column names the exact pipeline slot.
    """
    if not BENCH_FUSION.exists():
        return (
            "(no BENCH_fusion.json; run `python -m benchmarks.run "
            "--smoke --report-json`)"
        )
    rep = json.loads(BENCH_FUSION.read_text())
    entries = rep.get("per_round", [])
    if not entries:
        return (
            "(BENCH_fusion.json has no per_round section; run "
            "`python -m benchmarks.fusion_speedup --per-round "
            "--report-json`)"
        )
    rows = [
        "| coll | mesh | variant | chunks | rounds | host total "
        "| top round | top phase | top cost | share |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for e in entries:
        top = e.get("top_round") or {}
        total = e.get("total_us", 0.0)
        share = top.get("dur_us", 0.0) / total if total else 0.0
        top_round = top.get("round", "-")
        if "chunk" in top:
            # pipeline cell: slot index plus its (chunk, per-chunk round)
            top_round = (
                f"{top_round} (c{top['chunk']} r{top.get('chunk_round', 0)})"
            )
        rows.append(
            f"| {e['coll']} | {'x'.join(map(str, e['sizes']))} "
            f"| {e['variant']} | {e.get('chunks', 1)} "
            f"| {len(e.get('rounds', []))} "
            f"| {fmt_s(total * 1e-6)} | {top_round} "
            f"| {top.get('phase', '-')} "
            f"| {fmt_s(top.get('dur_us', 0.0) * 1e-6)} "
            f"| {share * 100:.0f}% |"
        )
    return "\n".join(rows)


def chunking_table() -> str:
    """Chunked-streaming evidence from BENCH_fusion.json: the tuned
    schedule winner per grid point and the chunking-check wall-clock
    proof at the payload past the pipelining threshold."""
    if not BENCH_FUSION.exists():
        return (
            "(no BENCH_fusion.json; run `python -m benchmarks.run "
            "--smoke --report-json`)"
        )
    rep = json.loads(BENCH_FUSION.read_text())
    rows = [
        "| coll | mesh | payload | tuned schedule | speedup vs raw "
        "| bitwise |",
        "|---|---|---|---|---|---|",
    ]
    for r in rep.get("grid", []):
        sched = (
            f"{'fused' if r.get('tuned_optimized') else 'raw'}"
            f", C={r.get('tuned_chunks', 1)}"
        )
        rows.append(
            f"| {r['coll']} | {'x'.join(map(str, r['sizes']))} "
            f"| {fmt_b(r['payload_bytes'])} | {sched} "
            f"| {r.get('speedup', 0.0):.2f}x "
            f"| {'yes' if r.get('bitwise') else 'NO'} |"
        )
    cc = rep.get("chunking_check") or {}
    if cc:
        timings = cc.get("timings_us", {})
        cells = ", ".join(
            f"C={c}: {float(t) / 1e3:.1f}ms"
            for c, t in sorted(timings.items(), key=lambda kv: int(kv[0]))
        )
        gain = (
            cc.get("c1_us", 0.0) / cc.get("best_us", 1.0)
            if cc.get("best_us") else 0.0
        )
        rows.append("")
        rows.append(
            f"Chunking check — {cc.get('coll', '?')} on "
            f"{'x'.join(map(str, cc.get('sizes', [])))} at "
            f"{fmt_b(cc.get('payload_bytes', 0))}: {cells}. Best C="
            f"{cc.get('best_chunks', 1)} beats unchunked by {gain:.2f}x "
            f"(bitwise {'holds' if cc.get('bitwise') else 'FAILS'}, "
            f"win={'yes' if cc.get('win') else 'no'})."
        )
    return "\n".join(rows)


def main() -> None:
    print("## Dry-run (single pod, 16x16)\n")
    print(dryrun_table("single"))
    print("\n## Dry-run (multi-pod, 2x16x16)\n")
    print(dryrun_table("multi"))
    print("\n## Roofline (single pod)\n")
    print(roofline_table("single"))
    print("\n## Memory fit\n")
    print(fit_report("single"))
    print("\n## Per-round latency attribution (traced sim interpreter)\n")
    print(per_round_table())


if __name__ == "__main__":
    main()
