"""Benchmark regression gate: fresh BENCH artifacts vs committed baselines.

``scripts/ci.sh`` snapshots the committed ``BENCH_fusion.json`` /
``BENCH_service.json`` *before* ``benchmarks.run --smoke`` rewrites them,
then runs this check on the (baseline, fresh) pairs. Three failure modes:

  1. **Lost rows** — a (coll, sizes, payload) fusion grid point or a
     (clients, coalesce) service configuration present in the baseline is
     missing from the fresh report. A benchmark silently shrinking its
     grid would otherwise look like a pass.
  2. **Lost proofs** — a fusion row whose ``bitwise`` flag was true goes
     false, rounds increase on a previously-reduced row, a grid point
     whose tuned schedule previously beat raw (speedup clearly > 1, past
     a noise guard) regresses to a loss (< 1), the chunking check stops
     winning (or loses its bitwise proof), or a coalescing service
     configuration stops coalescing (factor drops to <= 1).
  3. **Latency drift** — a measured latency grows by more than
     ``--max-drift`` (default 2.0x) over the baseline. Timing in CI is
     noisy, so the bar is deliberately loose: 2x is a real regression,
     not jitter. Improvements never fail.
  4. **Observability overhead** — ``BENCH_obs.json`` (``--baseline-obs``
     / ``--obs``, from ``benchmarks.obs_overhead``) must keep the
     flight-recorder dispatch overhead — both the measured A/B delta and
     the derived per-event fraction — at or under ``--max-obs-overhead``
     (default 2%). "Always-on" telemetry earns that adjective here.
  5. **Reliability overhead** — ``BENCH_reliability.json``
     (``--baseline-reliability`` / ``--reliability``, from
     ``benchmarks.reliability_overhead``) must keep the reliable-dispatch
     happy path — two payload checksums + retry/breaker bookkeeping —
     at or under ``--max-reliability-overhead`` (default 2%) of the
     cached dispatch path, both as the measured A/B delta and as the
     derived cost fraction. The run must also stay retry-free: a retry
     during the benchmark means the happy path wasn't the thing measured.

Missing, non-JSON, or truncated reports (a row dropped mid-object, a
section replaced by the wrong type) fail the gate with a message naming
the offending file — never a KeyError traceback, which would read as the
*checker* being broken rather than the baseline.

Prints one ``regression_check,...`` CSV row per comparison and ``ALL-OK``
iff everything passed (exit code 1 otherwise), matching the repo's other
check modules so ``scripts/ci.sh`` can grep it.

Usage:
  python -m benchmarks.check_regression \
      --baseline-fusion OLD_fusion.json --fusion benchmarks/BENCH_fusion.json \
      --baseline-service OLD_service.json --service benchmarks/BENCH_service.json
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple

_FAILED = False


def _fail(msg: str) -> None:
    global _FAILED
    _FAILED = True
    print(f"REGRESSION: {msg}")


def _load(path: Optional[str]) -> Optional[Dict]:
    if not path:
        return None
    p = Path(path)
    if not p.exists():
        _fail(f"report {p} does not exist")
        return None
    try:
        data = json.loads(p.read_text())
    except ValueError as e:
        _fail(f"report {p} is not valid JSON (truncated?): {e}")
        return None
    if not isinstance(data, dict):
        _fail(
            f"report {p} is malformed: expected a JSON object at top "
            f"level, got {type(data).__name__}"
        )
        return None
    return data


def _valid_rows(
    report: Dict, section: str, required: Tuple[str, ...], name: str
) -> List[Dict]:
    """The well-formed rows of ``report[section]``; every malformed or
    truncated row fails the check with a message naming the file, instead
    of surfacing as a KeyError traceback."""
    rows = report.get(section, [])
    if not isinstance(rows, list):
        _fail(
            f"report {name} is malformed: section {section!r} should be "
            f"a list, got {type(rows).__name__}"
        )
        return []
    out: List[Dict] = []
    for i, r in enumerate(rows):
        if not isinstance(r, dict):
            _fail(
                f"report {name} is truncated: {section}[{i}] is not an "
                f"object"
            )
            continue
        missing = [k for k in required if k not in r]
        if missing:
            _fail(
                f"report {name} is truncated: {section}[{i}] is missing "
                f"{', '.join(missing)}"
            )
            continue
        out.append(r)
    return out


def _drift_ok(base_us: float, new_us: float, max_drift: float) -> bool:
    if base_us <= 0.0:
        return True  # no baseline signal to drift from
    return new_us <= base_us * max_drift


#: baseline speedups at or below this are treated as measurement-noise
#: ties, not wins — only clearly-winning baselines arm the speedup floor
SPEEDUP_NOISE_GUARD = 1.05


def _row_speedup(r: Dict) -> float:
    if "speedup" in r:
        return float(r["speedup"])
    fused = float(r.get("fused_us", 0.0))
    raw = float(r.get("raw_us", 0.0))
    return raw / fused if fused > 0 else 0.0


_FUSION_ROW_KEYS = ("coll", "sizes", "payload_bytes")
_SERVICE_ROW_KEYS = ("clients", "coalesce")


def check_fusion(
    base: Dict,
    new: Dict,
    max_drift: float,
    *,
    require_per_round: bool,
    base_name: str = "baseline fusion",
    new_name: str = "fresh fusion",
) -> None:
    by_key: Dict[Tuple, Dict] = {
        (r["coll"], tuple(r["sizes"]), r["payload_bytes"]): r
        for r in _valid_rows(new, "grid", _FUSION_ROW_KEYS, new_name)
    }
    for r in _valid_rows(base, "grid", _FUSION_ROW_KEYS, base_name):
        key = (r["coll"], tuple(r["sizes"]), r["payload_bytes"])
        label = f"{key[0]},{'x'.join(map(str, key[1]))},{key[2]}"
        nr = by_key.get(key)
        if nr is None:
            _fail(f"fusion grid row lost: {label}")
            continue
        if r.get("bitwise") and not nr.get("bitwise"):
            _fail(f"fusion bitwise proof lost: {label}")
        if nr.get("fused_rounds", 0) > r.get("fused_rounds", 0):
            _fail(
                f"fusion rounds regressed: {label} "
                f"{r.get('fused_rounds', 0)} -> {nr.get('fused_rounds', 0)}"
            )
        ok = _drift_ok(r.get("fused_us", 0.0), nr.get("fused_us", 0.0),
                       max_drift)
        if not ok:
            _fail(
                f"fusion latency drift > {max_drift}x: {label} "
                f"{r['fused_us']:.1f}us -> {nr['fused_us']:.1f}us"
            )
        base_speedup, new_speedup = _row_speedup(r), _row_speedup(nr)
        floor_ok = not (
            base_speedup > SPEEDUP_NOISE_GUARD and new_speedup < 1.0
        )
        if not floor_ok:
            _fail(
                f"fusion speedup floor lost: {label} tuned schedule beat "
                f"raw at {base_speedup:.3f}x in the baseline but now "
                f"loses ({new_speedup:.3f}x)"
            )
        print(
            f"regression_check,fusion,{label},"
            f"bitwise,{int(bool(nr.get('bitwise')))},"
            f"fused_us,{nr.get('fused_us', 0.0):.1f},"
            f"baseline_us,{r.get('fused_us', 0.0):.1f},"
            f"speedup,{new_speedup:.3f},baseline_speedup,"
            f"{base_speedup:.3f},ok,{int(ok and floor_ok)}"
        )
    bc = base.get("chunking_check") or {}
    nc = new.get("chunking_check") or {}
    if bc:
        if not nc:
            _fail("chunking check section lost")
        else:
            if bc.get("bitwise") and not nc.get("bitwise"):
                _fail("chunking check bitwise proof lost")
            if bc.get("win") and not nc.get("win"):
                _fail(
                    "chunking check stopped winning: best chunked "
                    f"schedule was {bc.get('c1_us', 0.0):.0f}us -> "
                    f"{bc.get('best_us', 0.0):.0f}us in the baseline, now "
                    f"{nc.get('c1_us', 0.0):.0f}us -> "
                    f"{nc.get('best_us', 0.0):.0f}us"
                )
            print(
                f"regression_check,chunking,"
                f"{'x'.join(map(str, nc.get('sizes', [])))},"
                f"{nc.get('payload_bytes', 0)},"
                f"bitwise,{int(bool(nc.get('bitwise')))},"
                f"win,{int(bool(nc.get('win')))},"
                f"best_chunks,{nc.get('best_chunks', 1)}"
            )
    for coll, d in base.get("device_latency", {}).items():
        nd = new.get("device_latency", {}).get(coll)
        if nd is None:
            _fail(f"fusion device-latency row lost: {coll}")
        elif d.get("source") == "profiler" and nd.get("source") != "profiler":
            _fail(
                f"fusion device latency degraded to wall clock: {coll} "
                f"(was profiler-sourced)"
            )
    if require_per_round and not new.get("per_round"):
        _fail("fusion report has no per_round attribution section")


def check_service(
    base: Dict,
    new: Dict,
    max_drift: float,
    *,
    base_name: str = "baseline service",
    new_name: str = "fresh service",
) -> None:
    by_key: Dict[Tuple, Dict] = {
        (r["clients"], r["coalesce"]): r
        for r in _valid_rows(new, "stats", _SERVICE_ROW_KEYS, new_name)
    }
    for r in _valid_rows(base, "stats", _SERVICE_ROW_KEYS, base_name):
        key = (r["clients"], r["coalesce"])
        label = f"clients={key[0]},coalesce={key[1]}"
        nr = by_key.get(key)
        if nr is None:
            _fail(f"service configuration lost: {label}")
            continue
        if (
            r["coalesce"]
            and r.get("coalesce_factor", 0.0) > 1.0
            and nr.get("coalesce_factor", 0.0) <= 1.0
        ):
            _fail(
                f"service stopped coalescing: {label} factor "
                f"{r['coalesce_factor']:.2f} -> "
                f"{nr.get('coalesce_factor', 0.0):.2f}"
            )
        ok = _drift_ok(r.get("p50_us", 0.0), nr.get("p50_us", 0.0), max_drift)
        if not ok:
            _fail(
                f"service p50 drift > {max_drift}x: {label} "
                f"{r['p50_us']:.1f}us -> {nr['p50_us']:.1f}us"
            )
        print(
            f"regression_check,service,{label},"
            f"coalesce_factor,{nr.get('coalesce_factor', 0.0):.2f},"
            f"p50_us,{nr.get('p50_us', 0.0):.1f},"
            f"baseline_us,{r.get('p50_us', 0.0):.1f},ok,{int(ok)}"
        )


def check_obs(
    base: Dict,
    new: Dict,
    max_overhead: float,
    *,
    base_name: str = "baseline obs",
    new_name: str = "fresh obs",
) -> None:
    """Flight-recorder overhead gate (see ``benchmarks.obs_overhead``).

    Both overhead figures must stay at or under ``max_overhead``: the
    measured A/B delta (best-of-trials — catches systemic slowdowns) and
    the derived analytic fraction (per-event cost x event rate — catches
    a ``record()`` regression regardless of wall-clock noise). A section
    present in the baseline but gone from the fresh report fails, same
    as a lost benchmark grid row.
    """
    for section in ("dispatch", "record"):
        if section in base and section not in new:
            _fail(f"obs report lost its {section!r} section ({new_name})")
    d = new.get("dispatch")
    if not isinstance(d, dict):
        if "dispatch" not in base:
            _fail(f"obs report {new_name} has no dispatch section")
        return
    measured = float(d.get("overhead_frac", 0.0))
    derived = float(d.get("derived_frac", 0.0))
    ok = True
    if measured > max_overhead:
        ok = False
        _fail(
            f"flight-recorder dispatch overhead {measured:.4f} exceeds "
            f"{max_overhead} (recorder-on vs recorder-off)"
        )
    if derived > max_overhead:
        ok = False
        _fail(
            f"flight-recorder derived overhead {derived:.4f} exceeds "
            f"{max_overhead} (per-event cost x event rate)"
        )
    rec = new.get("record") or {}
    print(
        f"regression_check,obs,dispatch,"
        f"overhead_frac,{measured:.4f},derived_frac,{derived:.4f},"
        f"record_ns,{float(rec.get('per_call_ns', 0.0)):.0f},"
        f"max,{max_overhead},ok,{int(ok)}"
    )


def check_reliability(
    base: Dict,
    new: Dict,
    max_overhead: float,
    *,
    base_name: str = "baseline reliability",
    new_name: str = "fresh reliability",
) -> None:
    """Reliable-dispatch overhead gate (see
    ``benchmarks.reliability_overhead``).

    Both overhead figures must stay at or under ``max_overhead``: the
    measured A/B delta (reliability-on vs reliability-off through the
    same broker, best-of-trials — catches systemic slowdowns like the
    buffer-retention cycle this gate was built after) and the derived
    analytic fraction (2 x cold-cache checksum + dispatcher bookkeeping
    over the dispatch time — catches a checksum regression regardless of
    wall-clock noise). A benchmark run that took retries or degrades
    fails too: it measured the recovery path, not the happy path.
    """
    for section in ("dispatch", "checksum", "bookkeeping"):
        if section in base and section not in new:
            _fail(
                f"reliability report lost its {section!r} section "
                f"({new_name})"
            )
    d = new.get("dispatch")
    if not isinstance(d, dict):
        if "dispatch" not in base:
            _fail(f"reliability report {new_name} has no dispatch section")
        return
    measured = float(d.get("overhead_frac", 0.0))
    derived = float(d.get("derived_frac", 0.0))
    ok = True
    if measured > max_overhead:
        ok = False
        _fail(
            f"reliable-dispatch overhead {measured:.4f} exceeds "
            f"{max_overhead} (reliability-on vs reliability-off)"
        )
    if derived > max_overhead:
        ok = False
        _fail(
            f"reliable-dispatch derived overhead {derived:.4f} exceeds "
            f"{max_overhead} (2 x checksum + bookkeeping / dispatch)"
        )
    if d.get("retries", 0) or d.get("degrades", 0):
        ok = False
        _fail(
            f"reliability benchmark was not a happy-path run: "
            f"{d.get('retries', 0)} retries, {d.get('degrades', 0)} "
            f"degrades during the A/B measurement"
        )
    chk = new.get("checksum") or {}
    print(
        f"regression_check,reliability,dispatch,"
        f"overhead_frac,{measured:.4f},derived_frac,{derived:.4f},"
        f"checksum_us,{float(chk.get('per_call_us', 0.0)):.1f},"
        f"max,{max_overhead},ok,{int(ok)}"
    )


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-fusion", help="committed BENCH_fusion.json")
    ap.add_argument("--fusion", help="freshly written BENCH_fusion.json")
    ap.add_argument("--baseline-service", help="committed BENCH_service.json")
    ap.add_argument("--service", help="freshly written BENCH_service.json")
    ap.add_argument("--baseline-obs", help="committed BENCH_obs.json")
    ap.add_argument("--obs", help="freshly written BENCH_obs.json")
    ap.add_argument(
        "--max-obs-overhead", type=float, default=0.02,
        help="fail when flight-recorder overhead exceeds this fraction "
        "(default 0.02)",
    )
    ap.add_argument(
        "--baseline-reliability", help="committed BENCH_reliability.json"
    )
    ap.add_argument(
        "--reliability", help="freshly written BENCH_reliability.json"
    )
    ap.add_argument(
        "--max-reliability-overhead", type=float, default=0.02,
        help="fail when the reliable-dispatch happy path exceeds this "
        "fraction of the raw dispatch path (default 0.02)",
    )
    ap.add_argument(
        "--max-drift", type=float, default=2.0,
        help="fail when a latency grows past this factor (default 2.0)",
    )
    ap.add_argument(
        "--require-per-round", action="store_true",
        help="fail when the fresh fusion report lacks a per_round section",
    )
    args = ap.parse_args(argv)
    if not (args.baseline_fusion or args.baseline_service
            or args.baseline_obs or args.baseline_reliability):
        ap.error(
            "nothing to check; pass --baseline-fusion/--baseline-service/"
            "--baseline-obs/--baseline-reliability"
        )
    if args.baseline_fusion:
        base = _load(args.baseline_fusion)
        new_path = args.fusion or args.baseline_fusion
        new = _load(new_path)
        if base is not None and new is not None:
            check_fusion(
                base, new, args.max_drift,
                require_per_round=args.require_per_round,
                base_name=args.baseline_fusion, new_name=new_path,
            )
    if args.baseline_service:
        base = _load(args.baseline_service)
        new_path = args.service or args.baseline_service
        new = _load(new_path)
        if base is not None and new is not None:
            check_service(
                base, new, args.max_drift,
                base_name=args.baseline_service, new_name=new_path,
            )
    if args.baseline_obs:
        base = _load(args.baseline_obs)
        new_path = args.obs or args.baseline_obs
        new = _load(new_path)
        if base is not None and new is not None:
            check_obs(
                base, new, args.max_obs_overhead,
                base_name=args.baseline_obs, new_name=new_path,
            )
    if args.baseline_reliability:
        base = _load(args.baseline_reliability)
        new_path = args.reliability or args.baseline_reliability
        new = _load(new_path)
        if base is not None and new is not None:
            check_reliability(
                base, new, args.max_reliability_overhead,
                base_name=args.baseline_reliability, new_name=new_path,
            )
    print(
        f"check_regression_summary,ok,{int(not _FAILED)},"
        f"max_drift,{args.max_drift}"
    )
    if _FAILED:
        return 1
    print("ALL-OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
