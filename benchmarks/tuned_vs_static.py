"""Tuned-vs-static selection crossover report + offload-engine smoke.

The static selector prices schedules with TPU v5e ICI constants; the autotuner
re-fits the model from latencies measured on the backend actually running.
This benchmark runs a budgeted tuning pass, then emits one CSV row per grid
point comparing the two selections (and the measured latency of each choice),
plus an engine-dispatch section proving the descriptor cache: five CollTypes
through ``OffloadEngine.offload`` twice each, hit/miss telemetry printed.

Two planner sections ride along: ``planned_split`` measures every logical
axis order of each mesh shape and compares the tuned split (the measured
winner the planner would adopt) against the fixed physical (outer, inner)
order — by construction the tuned split is never slower than the fixed one
on the sim backend; ``planned_smoke`` drives one 3D planned collective
end-to-end through the engine twice per CollType and *asserts* the repeat
dispatch hits the schedule cache (CI gate).

CSV sections:
  tuned_vs_static,coll,p,msg_bytes,static_algo,tuned_algo,static_meas_us,tuned_meas_us,changed
  planned_split,coll,sizes,msg_bytes,fixed_order,fixed_us,tuned_order,tuned_us,speedup
  engine_smoke,coll,dispatch,cache,latency_us
  planned_smoke,coll,dispatch,cache,latency_us
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core import SUM, CollType, select_algorithm
from repro.core.selector import get_active_tuning, set_active_tuning
from repro.offload import OffloadEngine, TuningCache, autotune, tune_splits

SMOKE_PS = (2, 4, 8)
SMOKE_PAYLOADS = (1024, 65536)
FULL_PS = (2, 4, 8, 16)
FULL_PAYLOADS = (1024, 65536, 1 << 20)


def _measured(
    cache: TuningCache, coll: str, p: int, msg: int, algo: str
) -> Optional[float]:
    best: Dict[Tuple[str, str, int, int], float] = {}
    for m in cache.measurements:
        key = (m.coll, m.algo, m.p, m.payload_bytes)
        if key not in best or m.seconds < best[key]:
            best[key] = m.seconds
    return best.get((coll, algo, p, msg))


def run(
    *,
    ps=FULL_PS,
    payloads=FULL_PAYLOADS,
    iters: int = 5,
    time_budget_s: Optional[float] = None,
) -> List[str]:
    """Tune over the grid, then compare selections point by point."""
    rows: List[str] = []
    prior = get_active_tuning()
    cache = autotune(
        ps=ps, payloads=payloads, iters=iters, time_budget_s=time_budget_s
    )
    changed = 0
    try:
        for coll in ("scan", "exscan"):
            for p in ps:
                for msg in payloads:
                    set_active_tuning(None)
                    static = select_algorithm(p, msg, SUM, coll=coll)
                    cache.activate()
                    tuned = select_algorithm(p, msg, SUM, coll=coll)
                    s_us = _measured(cache, coll, p, msg, static)
                    t_us = _measured(cache, coll, p, msg, tuned)
                    diff = tuned != static
                    changed += int(diff)
                    rows.append(
                        f"tuned_vs_static,{coll},{p},{msg},{static},{tuned},"
                        f"{'' if s_us is None else f'{s_us*1e6:.1f}'},"
                        f"{'' if t_us is None else f'{t_us*1e6:.1f}'},"
                        f"{int(diff)}"
                    )
    finally:
        set_active_tuning(prior)
    fitted = cache.fitted_model()
    if fitted is not None:
        rows.append(
            f"fitted_model,alpha_s,{fitted.alpha:.3e},beta_s_per_byte,"
            f"{fitted.beta:.3e},gamma_s,{fitted.gamma:.3e}"
        )
    rows.append(f"tuned_vs_static_summary,changed_points,{changed}")
    return rows


def engine_smoke(p: int = 8, n: int = 64) -> List[str]:
    """All five CollTypes through the descriptor path, twice: the second
    dispatch of each must be a schedule-cache hit."""
    rows: List[str] = []
    eng = OffloadEngine()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(p, n)).astype(np.float32))
    for coll in CollType:
        desc = eng.make_descriptor(
            coll.name, p=p, payload_bytes=n * 4, op="sum"
        )
        for dispatch in ("miss", "hit"):
            before = eng.telemetry.hits
            eng.offload(desc.encode(), x)
            cache = "hit" if eng.telemetry.hits > before else "miss"
            rows.append(
                f"engine_smoke,{coll.name.lower()},{dispatch},{cache},"
                f"{eng.telemetry.last_latency_s*1e6:.1f}"
            )
    snap = eng.telemetry.snapshot()
    rows.append(
        f"engine_smoke_summary,hits,{snap['hits']},misses,{snap['misses']},"
        f"hit_rate,{snap['hit_rate']:.2f}"
    )
    return rows


def split_report(
    *,
    topologies: Sequence[Tuple[int, ...]] = ((2, 4), (4, 2), (2, 2, 2)),
    payloads: Sequence[int] = (1024, 65536),
    colls: Sequence[str] = ("scan", "allreduce"),
    iters: int = 3,
    time_budget_s: Optional[float] = None,
) -> List[str]:
    """Tuned-vs-fixed axis split: one row per (coll, mesh shape, payload).

    The tuned order is the measured winner ``tune_splits`` records (what
    ``plan_axis_order`` adopts once the table is active), so
    ``tuned_us <= fixed_us`` holds by construction wherever the fixed order
    was measured at all.
    """
    rows: List[str] = []
    cache = tune_splits(
        topologies=topologies,
        payloads=payloads,
        colls=colls,
        iters=iters,
        time_budget_s=time_budget_s,
    )
    measured: Dict[Tuple[str, Tuple[int, ...], int, Tuple[int, ...]], float] = {}
    for m in cache.split_measurements:
        key = (m.coll, m.sizes, m.payload_bytes, m.order)
        if key not in measured or m.seconds < measured[key]:
            measured[key] = m.seconds
    never_slower = True
    for sizes in topologies:
        sizes = tuple(sizes)
        fixed = tuple(range(len(sizes)))
        for payload in payloads:
            for coll in colls:
                tuned = cache.split_winner(coll, sizes, payload)
                if tuned is None:
                    continue  # budget cut this shape
                f_us = measured.get((coll, sizes, payload, fixed))
                t_us = measured.get((coll, sizes, payload, tuned))
                if f_us is None or t_us is None:
                    continue
                never_slower &= t_us <= f_us
                shape = "x".join(map(str, sizes))
                rows.append(
                    f"planned_split,{coll},{shape},{payload},"
                    f"{''.join(map(str, fixed))},{f_us*1e6:.1f},"
                    f"{''.join(map(str, tuned))},{t_us*1e6:.1f},"
                    f"{f_us/t_us:.3f}"
                )
    rows.append(
        f"planned_split_summary,tuned_never_slower,{int(never_slower)}"
    )
    return rows


def planned_smoke(axes: Tuple[int, ...] = (2, 2, 2), n: int = 64) -> List[str]:
    """One 3D planned collective per CollType through the descriptor path,
    twice each; asserts the repeat dispatch hits the plan cache and that the
    telemetry exposes cache_size + per-coll latency (the CI regression
    gate for the planner subsystem)."""
    rows: List[str] = []
    eng = OffloadEngine()
    p = int(np.prod(axes))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(p, n)).astype(np.float32))
    for coll in CollType:
        desc = eng.make_descriptor(
            coll.name, axes=axes, payload_bytes=n * 4, op="sum"
        )
        for dispatch in ("miss", "hit"):
            before = eng.telemetry.hits
            eng.offload(
                desc.encode(), None if coll == CollType.BARRIER else x
            )
            cache = "hit" if eng.telemetry.hits > before else "miss"
            assert cache == dispatch, (
                f"planned {coll.name} repeat dispatch must {dispatch} the "
                f"schedule cache (got {cache})"
            )
            rows.append(
                f"planned_smoke,{coll.name.lower()},{dispatch},{cache},"
                f"{eng.telemetry.last_latency_s*1e6:.1f}"
            )
    snap = eng.telemetry.snapshot()
    assert snap["hit_rate"] == 0.5, snap
    assert snap["cache_size"] == len(CollType), snap
    assert set(snap["latency_by_coll_us"]) == {
        c.name.lower() for c in CollType
    }
    rows.append(
        f"planned_smoke_summary,hits,{snap['hits']},misses,{snap['misses']},"
        f"hit_rate,{snap['hit_rate']:.2f},cache_size,{snap['cache_size']}"
    )
    return rows


def smoke(time_budget_s: float = 8.0) -> List[str]:
    """The CI entry: budgeted tuning grid + engine + planner dispatch proof."""
    rows = run(
        ps=SMOKE_PS,
        payloads=SMOKE_PAYLOADS,
        iters=3,
        time_budget_s=time_budget_s,
    )
    rows += engine_smoke()
    rows += planned_smoke()
    rows += split_report(
        topologies=((2, 4), (4, 2)),
        payloads=(1024,),
        colls=("scan",),
        iters=2,
        time_budget_s=time_budget_s,
    )
    return rows
