"""Tuned-vs-static selection crossover report + offload-engine smoke.

The static selector prices schedules with TPU v5e ICI constants; the autotuner
re-fits the model from latencies measured on the backend actually running.
This benchmark runs a budgeted tuning pass, then emits one CSV row per grid
point comparing the two selections (and the measured latency of each choice),
plus an engine-dispatch section proving the descriptor cache: five CollTypes
through ``OffloadEngine.offload`` twice each, hit/miss telemetry printed.

CSV sections:
  tuned_vs_static,coll,p,msg_bytes,static_algo,tuned_algo,static_meas_us,tuned_meas_us,changed
  engine_smoke,coll,dispatch,cache,latency_us
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core import SUM, CollType, select_algorithm
from repro.core.selector import get_active_tuning, set_active_tuning
from repro.offload import OffloadEngine, TuningCache, autotune

SMOKE_PS = (2, 4, 8)
SMOKE_PAYLOADS = (1024, 65536)
FULL_PS = (2, 4, 8, 16)
FULL_PAYLOADS = (1024, 65536, 1 << 20)


def _measured(
    cache: TuningCache, coll: str, p: int, msg: int, algo: str
) -> Optional[float]:
    best: Dict[Tuple[str, str, int, int], float] = {}
    for m in cache.measurements:
        key = (m.coll, m.algo, m.p, m.payload_bytes)
        if key not in best or m.seconds < best[key]:
            best[key] = m.seconds
    return best.get((coll, algo, p, msg))


def run(
    *,
    ps=FULL_PS,
    payloads=FULL_PAYLOADS,
    iters: int = 5,
    time_budget_s: Optional[float] = None,
) -> List[str]:
    """Tune over the grid, then compare selections point by point."""
    rows: List[str] = []
    prior = get_active_tuning()
    cache = autotune(
        ps=ps, payloads=payloads, iters=iters, time_budget_s=time_budget_s
    )
    changed = 0
    try:
        for coll in ("scan", "exscan"):
            for p in ps:
                for msg in payloads:
                    set_active_tuning(None)
                    static = select_algorithm(p, msg, SUM, coll=coll)
                    cache.activate()
                    tuned = select_algorithm(p, msg, SUM, coll=coll)
                    s_us = _measured(cache, coll, p, msg, static)
                    t_us = _measured(cache, coll, p, msg, tuned)
                    diff = tuned != static
                    changed += int(diff)
                    rows.append(
                        f"tuned_vs_static,{coll},{p},{msg},{static},{tuned},"
                        f"{'' if s_us is None else f'{s_us*1e6:.1f}'},"
                        f"{'' if t_us is None else f'{t_us*1e6:.1f}'},"
                        f"{int(diff)}"
                    )
    finally:
        set_active_tuning(prior)
    fitted = cache.fitted_model()
    if fitted is not None:
        rows.append(
            f"fitted_model,alpha_s,{fitted.alpha:.3e},beta_s_per_byte,"
            f"{fitted.beta:.3e},gamma_s,{fitted.gamma:.3e}"
        )
    rows.append(f"tuned_vs_static_summary,changed_points,{changed}")
    return rows


def engine_smoke(p: int = 8, n: int = 64) -> List[str]:
    """All five CollTypes through the descriptor path, twice: the second
    dispatch of each must be a schedule-cache hit."""
    rows: List[str] = []
    eng = OffloadEngine()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(p, n)).astype(np.float32))
    for coll in CollType:
        desc = eng.make_descriptor(
            coll.name, p=p, payload_bytes=n * 4, op="sum"
        )
        for dispatch in ("miss", "hit"):
            before = eng.telemetry.hits
            eng.offload(desc.encode(), x)
            cache = "hit" if eng.telemetry.hits > before else "miss"
            rows.append(
                f"engine_smoke,{coll.name.lower()},{dispatch},{cache},"
                f"{eng.telemetry.last_latency_s*1e6:.1f}"
            )
    snap = eng.telemetry.snapshot()
    rows.append(
        f"engine_smoke_summary,hits,{snap['hits']},misses,{snap['misses']},"
        f"hit_rate,{snap['hit_rate']:.2f}"
    )
    return rows


def smoke(time_budget_s: float = 8.0) -> List[str]:
    """The ~10 s CI entry: budgeted tuning grid + engine dispatch proof."""
    rows = run(
        ps=SMOKE_PS,
        payloads=SMOKE_PAYLOADS,
        iters=3,
        time_budget_s=time_budget_s,
    )
    rows += engine_smoke()
    return rows
