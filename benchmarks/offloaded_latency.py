"""Paper Figs. 6-7 analogue: in-network latency per algorithm AFTER offload.

The paper's 8ns on-NIC timer measures offload->release time — collective time
with host/driver overhead excluded. Our analogue has two parts:

  1. measured: per-schedule device execution time of the fused program on the
     simulated 8-rank mesh (host dispatch excluded by timing only the second
     of back-to-back calls on donated buffers);
  2. derived: the alpha-beta-gamma ICI model (core.selector.estimate_cost)
     evaluated at TPU v5e constants for the production 16-way model axis —
     the number the real pod would see, reported alongside so the crossovers
     the selector uses are visible.

Emits CSV rows: figure,algo,metric,msg_bytes,value_us
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TPU_V5E, estimate_cost, sim_scan, time_offloaded_scan

P_SIM = 8
P_PROD = 16
ALGOS = [
    "sequential",
    "sequential_pipelined",
    "hillis_steele",
    "recursive_doubling",
    "binomial_tree",
    "sklansky",
]
MSG_BYTES = [4, 64, 1024, 16384, 262144, 1 << 20]


def run() -> List[str]:
    rows = []
    rng = np.random.default_rng(0)
    for msg in MSG_BYTES:
        n = max(1, msg // 4)
        x = jnp.asarray(rng.normal(size=(P_SIM, n)).astype(np.float32))
        for algo in ALGOS:
            t = time_offloaded_scan(x, "sum", P_SIM, algorithm=algo, iters=20)
            rows.append(
                f"fig6_offloaded_avg,{algo},measured_sim8,{msg},{t*1e6:.2f}"
            )
            # derived in-network time on the production axis
            t_ici = estimate_cost(algo, P_PROD, msg, TPU_V5E)
            rows.append(
                f"fig6_offloaded_avg,{algo},derived_ici16,{msg},{t_ici*1e6:.3f}"
            )
    return rows


def selector_crossover() -> List[str]:
    """The paper's 'runtime picks algo_type': report the selected algorithm
    per (p, msg) from the cost model."""
    from repro.core import SUM, select_algorithm

    rows = []
    for p in (4, 8, 16, 64, 256):
        for msg in (64, 4096, 262144, 1 << 22):
            algo = select_algorithm(p, msg, SUM)
            rows.append(f"selector,{algo},selected,{msg},{p}")
    return rows


def main() -> None:
    print("figure,algo,metric,msg_bytes,value_us")
    for row in run() + selector_crossover():
        print(row)


if __name__ == "__main__":
    main()
