"""Multi-tenant collective offload service — the shared-NIC layer.

The paper's NetFPGA serves *every* host process posting an MPI_Scan through
one device; this package is that front end over :class:`~repro.offload.
OffloadEngine`:

  DescriptorBroker / ServiceClient — wire-encoded descriptor requests from
      many concurrent tenant streams, coalesced into fused engine dispatches
      with bounded queues, admission control, and a deadline flush (broker)
  ServiceTelemetry                 — per-tenant queue depth / latency
      histograms / rejection counts + broker coalescing stats, layered on
      EngineTelemetry (telemetry)
  TuningRegistry / FileTuningRegistry — merged tuning tables keyed by
      backend fingerprint: a pod tunes once, every worker and the broker
      inherit the split/algorithm winners (registry)
"""

from repro.service.broker import (
    DEFAULT_RESULT_TIMEOUT_S,
    AdmissionError,
    BrokerStopped,
    DescriptorBroker,
    QueueFullError,
    ServiceClient,
    ServiceTicket,
)
from repro.service.registry import (
    TUNING_REGISTRY_ENV,
    FileTuningRegistry,
    TuningRegistry,
    default_registry,
)
from repro.service.telemetry import (
    LATENCY_BUCKETS_US,
    LatencyHistogram,
    ServiceTelemetry,
    TenantStats,
)

__all__ = [
    "AdmissionError",
    "BrokerStopped",
    "DEFAULT_RESULT_TIMEOUT_S",
    "DescriptorBroker",
    "FileTuningRegistry",
    "LATENCY_BUCKETS_US",
    "LatencyHistogram",
    "QueueFullError",
    "ServiceClient",
    "ServiceTicket",
    "ServiceTelemetry",
    "TenantStats",
    "TUNING_REGISTRY_ENV",
    "TuningRegistry",
    "default_registry",
]
