"""Per-tenant service telemetry, layered on the engine's counters.

The broker is the NIC's request FIFO made multi-tenant: every client stream
gets its own submitted/completed/rejected/deadline-missed counters, a queue
depth gauge, and a log-bucketed latency histogram (submit-to-result wall
clock, the host-visible latency the paper's Fig. 4/5 measures), while the
coalescing stats (fused dispatches vs. fused requests) quantify how much
network-level combining the broker achieves — the software twin of the
NetFPGA combining packets from many host ranks in one pipeline pass.
:class:`ServiceTelemetry` snapshots all of it alongside the wrapped
:class:`~repro.offload.engine.EngineTelemetry` so one dict shows the whole
stack: tenant queues -> broker coalescing -> engine schedule cache.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, List, Optional

from repro.obs import metrics as obs_metrics

#: histogram bucket upper edges in microseconds (last bucket is open-ended)
LATENCY_BUCKETS_US = (
    50.0, 100.0, 250.0, 500.0, 1e3, 2.5e3, 5e3, 1e4, 2.5e4, 5e4, 1e5,
    2.5e5, 5e5, 1e6, 5e6,
)


@dataclasses.dataclass
class LatencyHistogram:
    """Log-bucketed latency histogram with count/sum/min/max (microseconds).

    Thread-safe on its own: ``record`` and the readers take the instance
    lock, so a histogram shared across tenant threads (or read by a
    snapshot mid-record) never shows torn count/sum/bucket state —
    ``ServiceTelemetry``'s outer lock is then a consistency guarantee
    across *tenants*, not the histogram's only defense.
    """

    counts: List[int] = dataclasses.field(
        default_factory=lambda: [0] * (len(LATENCY_BUCKETS_US) + 1)
    )
    count: int = 0
    total_us: float = 0.0
    max_us: float = 0.0
    min_us: float = 0.0
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False
    )

    def record(self, seconds: float) -> None:
        us = seconds * 1e6
        with self._lock:
            self.count += 1
            self.total_us += us
            self.max_us = max(self.max_us, us)
            self.min_us = us if self.count == 1 else min(self.min_us, us)
            for i, edge in enumerate(LATENCY_BUCKETS_US):
                if us <= edge:
                    self.counts[i] += 1
                    return
            self.counts[-1] += 1

    @property
    def mean_us(self) -> float:
        with self._lock:
            return self.total_us / self.count if self.count else 0.0

    def percentile_us(self, q: float) -> float:
        """Bucket-resolution percentile, clamped to the observed range.

        ``q`` is a quantile in [0, 1]. An empty histogram reports 0.0;
        ``q=0`` reports the observed minimum; ``q=1`` the observed maximum.
        In between, the answer is the upper edge of the bucket holding the
        q-quantile sample, clamped into ``[min_us, max_us]`` — so a single
        10 µs sample reports 10 at every quantile instead of the 50 µs
        bucket edge, and no percentile ever exceeds the recorded max (or
        undercuts the recorded min).
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} not in [0, 1]")
        with self._lock:
            if self.count == 0:
                return 0.0
            if q <= 0.0:
                return self.min_us
            rank = q * self.count
            seen = 0
            for i, c in enumerate(self.counts):
                seen += c
                if seen >= rank and c:
                    if i < len(LATENCY_BUCKETS_US):
                        return min(
                            max(LATENCY_BUCKETS_US[i], self.min_us),
                            self.max_us,
                        )
                    return self.max_us
            return self.max_us

    def count_at_or_below(self, threshold_us: float) -> int:
        """Samples that landed in buckets whose upper edge is within
        ``threshold_us`` — the "good event" count for a latency SLO.
        Bucket-resolution: a threshold between edges counts only the
        buckets entirely under it (conservative; never overcounts)."""
        with self._lock:
            n = 0
            for i, edge in enumerate(LATENCY_BUCKETS_US):
                if edge <= threshold_us:
                    n += self.counts[i]
            return n

    def snapshot(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "mean_us": self.mean_us,
            "p50_us": self.percentile_us(0.50),
            "p99_us": self.percentile_us(0.99),
            "max_us": self.max_us,
            "min_us": self.min_us,
        }


@dataclasses.dataclass
class TenantStats:
    """One client stream's counters (the per-host NIC doorbell registers)."""

    submitted: int = 0
    completed: int = 0
    rejected: int = 0
    errors: int = 0
    deadline_missed: int = 0
    queue_depth: int = 0
    max_queue_depth: int = 0
    latency: LatencyHistogram = dataclasses.field(
        default_factory=LatencyHistogram
    )

    def snapshot(self) -> Dict[str, Any]:
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "rejected": self.rejected,
            "errors": self.errors,
            "deadline_missed": self.deadline_missed,
            "queue_depth": self.queue_depth,
            "max_queue_depth": self.max_queue_depth,
            "latency": self.latency.snapshot(),
        }


class ServiceTelemetry:
    """Broker-wide counters + per-tenant stats, thread-safe.

    ``coalesce_factor`` is requests-per-engine-dispatch over everything the
    broker has flushed — the service's headline number: > 1 means concurrent
    tenants are genuinely sharing compiled collective dispatches.
    """

    def __init__(self, engine_telemetry: Any = None):
        self._lock = threading.Lock()
        self._engine_telemetry = engine_telemetry
        self.tenants: Dict[str, TenantStats] = {}
        self.fused_dispatches = 0
        self.fused_requests = 0
        self.flushes = 0
        self.deadline_flushes = 0

    def tenant(self, name: str) -> TenantStats:
        with self._lock:
            stats = self.tenants.get(name)
            if stats is None:
                stats = self.tenants[name] = TenantStats()
            return stats

    # -- recording (all called with the broker holding its own lock or from
    #    the single dispatch thread; the internal lock guards snapshots) ----

    @staticmethod
    def _requests_counter() -> "obs_metrics.Counter":
        return obs_metrics.get_registry().counter(
            "repro_service_requests_total",
            "service requests by tenant and outcome",
            labelnames=("tenant", "outcome"),
        )

    def record_submit(self, tenant: str) -> None:
        with self._lock:
            t = self.tenants.setdefault(tenant, TenantStats())
            t.submitted += 1
            t.queue_depth += 1
            t.max_queue_depth = max(t.max_queue_depth, t.queue_depth)
        self._requests_counter().inc(tenant=tenant, outcome="submitted")

    def record_reject(self, tenant: str) -> None:
        with self._lock:
            self.tenants.setdefault(tenant, TenantStats()).rejected += 1
        self._requests_counter().inc(tenant=tenant, outcome="rejected")

    def record_complete(
        self,
        tenant: str,
        latency_s: float,
        *,
        error: bool = False,
        deadline_missed: bool = False,
    ) -> None:
        with self._lock:
            t = self.tenants.setdefault(tenant, TenantStats())
            t.queue_depth = max(0, t.queue_depth - 1)
            if error:
                t.errors += 1
            else:
                t.completed += 1
                t.latency.record(latency_s)
            if deadline_missed:
                t.deadline_missed += 1
        self._requests_counter().inc(
            tenant=tenant, outcome="error" if error else "completed"
        )
        if deadline_missed:
            obs_metrics.get_registry().counter(
                "repro_service_deadline_misses_total",
                "requests completing after their deadline, by tenant",
                labelnames=("tenant",),
            ).inc(tenant=tenant)
        if not error:
            obs_metrics.get_registry().histogram(
                "repro_service_request_latency_us",
                "submit-to-result wall-clock latency per tenant",
                labelnames=("tenant",),
                buckets=LATENCY_BUCKETS_US,
            ).observe(latency_s * 1e6, tenant=tenant)

    def record_flush(
        self, n_requests: int, n_dispatches: int, *, deadline: bool = False
    ) -> None:
        with self._lock:
            self.flushes += 1
            self.fused_requests += n_requests
            self.fused_dispatches += n_dispatches
            if deadline:
                self.deadline_flushes += 1
        obs_metrics.get_registry().counter(
            "repro_service_flushes_total",
            "broker flush dispatches",
            labelnames=("deadline",),
        ).inc(deadline=str(bool(deadline)).lower())

    # -- reading -----------------------------------------------------------

    @property
    def coalesce_factor(self) -> float:
        with self._lock:
            if not self.fused_dispatches:
                return 0.0
            return self.fused_requests / self.fused_dispatches

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            snap: Dict[str, Any] = {
                "tenants": {
                    name: t.snapshot() for name, t in self.tenants.items()
                },
                "fused_requests": self.fused_requests,
                "fused_dispatches": self.fused_dispatches,
                "coalesce_factor": (
                    self.fused_requests / self.fused_dispatches
                    if self.fused_dispatches
                    else 0.0
                ),
                "flushes": self.flushes,
                "deadline_flushes": self.deadline_flushes,
            }
        if self._engine_telemetry is not None:
            snap["engine"] = self._engine_telemetry.snapshot()
        return snap


__all__ = [
    "LATENCY_BUCKETS_US",
    "LatencyHistogram",
    "ServiceTelemetry",
    "TenantStats",
]
