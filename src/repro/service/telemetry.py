"""Per-tenant service telemetry, layered on the engine's counters.

The broker is the NIC's request FIFO made multi-tenant: every client stream
gets its own submitted/completed/rejected/deadline-missed counters, a queue
depth gauge, and a log-bucketed latency histogram (submit-to-result wall
clock, the host-visible latency the paper's Fig. 4/5 measures), while the
coalescing stats (fused dispatches vs. fused requests) quantify how much
network-level combining the broker achieves — the software twin of the
NetFPGA combining packets from many host ranks in one pipeline pass.
:class:`ServiceTelemetry` snapshots all of it alongside the wrapped
:class:`~repro.offload.engine.EngineTelemetry` so one dict shows the whole
stack: tenant queues -> broker coalescing -> engine schedule cache.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, List, Optional

#: histogram bucket upper edges in microseconds (last bucket is open-ended)
LATENCY_BUCKETS_US = (
    50.0, 100.0, 250.0, 500.0, 1e3, 2.5e3, 5e3, 1e4, 2.5e4, 5e4, 1e5,
    2.5e5, 5e5, 1e6, 5e6,
)


@dataclasses.dataclass
class LatencyHistogram:
    """Log-bucketed latency histogram with count/sum/max (microseconds)."""

    counts: List[int] = dataclasses.field(
        default_factory=lambda: [0] * (len(LATENCY_BUCKETS_US) + 1)
    )
    count: int = 0
    total_us: float = 0.0
    max_us: float = 0.0

    def record(self, seconds: float) -> None:
        us = seconds * 1e6
        self.count += 1
        self.total_us += us
        self.max_us = max(self.max_us, us)
        for i, edge in enumerate(LATENCY_BUCKETS_US):
            if us <= edge:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    @property
    def mean_us(self) -> float:
        return self.total_us / self.count if self.count else 0.0

    def percentile_us(self, q: float) -> float:
        """Bucket-resolution percentile (upper edge of the q-quantile bucket;
        the open last bucket reports the observed max)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} not in [0, 1]")
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                if i < len(LATENCY_BUCKETS_US):
                    return LATENCY_BUCKETS_US[i]
                return self.max_us
        return self.max_us

    def snapshot(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "mean_us": self.mean_us,
            "p50_us": self.percentile_us(0.50),
            "p99_us": self.percentile_us(0.99),
            "max_us": self.max_us,
        }


@dataclasses.dataclass
class TenantStats:
    """One client stream's counters (the per-host NIC doorbell registers)."""

    submitted: int = 0
    completed: int = 0
    rejected: int = 0
    errors: int = 0
    deadline_missed: int = 0
    queue_depth: int = 0
    max_queue_depth: int = 0
    latency: LatencyHistogram = dataclasses.field(
        default_factory=LatencyHistogram
    )

    def snapshot(self) -> Dict[str, Any]:
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "rejected": self.rejected,
            "errors": self.errors,
            "deadline_missed": self.deadline_missed,
            "queue_depth": self.queue_depth,
            "max_queue_depth": self.max_queue_depth,
            "latency": self.latency.snapshot(),
        }


class ServiceTelemetry:
    """Broker-wide counters + per-tenant stats, thread-safe.

    ``coalesce_factor`` is requests-per-engine-dispatch over everything the
    broker has flushed — the service's headline number: > 1 means concurrent
    tenants are genuinely sharing compiled collective dispatches.
    """

    def __init__(self, engine_telemetry: Any = None):
        self._lock = threading.Lock()
        self._engine_telemetry = engine_telemetry
        self.tenants: Dict[str, TenantStats] = {}
        self.fused_dispatches = 0
        self.fused_requests = 0
        self.flushes = 0
        self.deadline_flushes = 0

    def tenant(self, name: str) -> TenantStats:
        with self._lock:
            stats = self.tenants.get(name)
            if stats is None:
                stats = self.tenants[name] = TenantStats()
            return stats

    # -- recording (all called with the broker holding its own lock or from
    #    the single dispatch thread; the internal lock guards snapshots) ----

    def record_submit(self, tenant: str) -> None:
        with self._lock:
            t = self.tenants.setdefault(tenant, TenantStats())
            t.submitted += 1
            t.queue_depth += 1
            t.max_queue_depth = max(t.max_queue_depth, t.queue_depth)

    def record_reject(self, tenant: str) -> None:
        with self._lock:
            self.tenants.setdefault(tenant, TenantStats()).rejected += 1

    def record_complete(
        self,
        tenant: str,
        latency_s: float,
        *,
        error: bool = False,
        deadline_missed: bool = False,
    ) -> None:
        with self._lock:
            t = self.tenants.setdefault(tenant, TenantStats())
            t.queue_depth = max(0, t.queue_depth - 1)
            if error:
                t.errors += 1
            else:
                t.completed += 1
                t.latency.record(latency_s)
            if deadline_missed:
                t.deadline_missed += 1

    def record_flush(
        self, n_requests: int, n_dispatches: int, *, deadline: bool = False
    ) -> None:
        with self._lock:
            self.flushes += 1
            self.fused_requests += n_requests
            self.fused_dispatches += n_dispatches
            if deadline:
                self.deadline_flushes += 1

    # -- reading -----------------------------------------------------------

    @property
    def coalesce_factor(self) -> float:
        with self._lock:
            if not self.fused_dispatches:
                return 0.0
            return self.fused_requests / self.fused_dispatches

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            snap: Dict[str, Any] = {
                "tenants": {
                    name: t.snapshot() for name, t in self.tenants.items()
                },
                "fused_requests": self.fused_requests,
                "fused_dispatches": self.fused_dispatches,
                "coalesce_factor": (
                    self.fused_requests / self.fused_dispatches
                    if self.fused_dispatches
                    else 0.0
                ),
                "flushes": self.flushes,
                "deadline_flushes": self.deadline_flushes,
            }
        if self._engine_telemetry is not None:
            snap["engine"] = self._engine_telemetry.snapshot()
        return snap


__all__ = [
    "LATENCY_BUCKETS_US",
    "LatencyHistogram",
    "ServiceTelemetry",
    "TenantStats",
]
