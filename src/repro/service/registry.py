"""Shared tuning-table registry: tune once per backend, inherit everywhere.

The ROADMAP's "remote tuning-table sharing" item: the JSON tuning table used
to be strictly per-machine, so every worker in a pod re-measured the same
grid. The registry keys merged tables by **backend fingerprint**
(``platform:device_kind:machine``, the same string
:meth:`~repro.offload.tuning_cache.TuningCache.load_compatible` checks) and
folds each published table into the entry for its fingerprint via
:meth:`TuningCache.merge` — lower measured cost wins per grid point, and
cross-fingerprint merges are structurally impossible because the fingerprint
*is* the key. A worker (or the broker) then fetches the one merged table for
its own backend and activates it, inheriting split/algorithm winners that
other workers measured.

Two backings, one interface:

  * :class:`TuningRegistry` — in-process dict; the broker's default.
  * :class:`FileTuningRegistry` — one JSON file per fingerprint under a
    shared directory (NFS / persistent volume), so the merge survives the
    process and crosses host boundaries. Publishes are read-merge-write with
    an atomic rename; last-writer-wins races lose at most the slower of two
    concurrent measurements, never the table.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import tempfile
from pathlib import Path
from typing import Dict, List, Optional

from repro.offload.tuning_cache import TuningCache, _backend_fingerprint

#: env var naming a shared registry directory to use by default
TUNING_REGISTRY_ENV = "REPRO_TUNING_REGISTRY"


def _copy(cache: TuningCache) -> TuningCache:
    """Value-copy through the JSON schema (what persistence round-trips)."""
    d = cache.to_json()
    clone = TuningCache(backend=cache.backend)
    from repro.offload.tuning_cache import Measurement, SplitMeasurement

    clone.measurements = [
        Measurement.from_json(m) for m in d["measurements"]
    ]
    clone.split_measurements = [
        SplitMeasurement.from_json(m) for m in d["split_measurements"]
    ]
    return clone


class TuningRegistry:
    """Dict-backed registry of merged tuning tables, keyed by fingerprint."""

    def __init__(self) -> None:
        self._tables: Dict[str, TuningCache] = {}

    def publish(self, cache: TuningCache) -> TuningCache:
        """Merge a table into its fingerprint's entry; return the merged
        table (a copy — the caller's table is never aliased)."""
        entry = self._tables.get(cache.backend)
        if entry is None:
            merged = _copy(cache)
        else:
            merged = entry.merge(_copy(cache))
        self._tables[cache.backend] = merged
        return _copy(merged)

    def fetch(self, backend: Optional[str] = None) -> Optional[TuningCache]:
        """The merged table for a fingerprint (default: this backend's), or
        None when nothing was ever published for it."""
        backend = backend or _backend_fingerprint()
        entry = self._tables.get(backend)
        return _copy(entry) if entry is not None else None

    def backends(self) -> List[str]:
        return sorted(self._tables)


def _slug(backend: str) -> str:
    """Filesystem-safe name for one fingerprint (readable prefix + hash)."""
    safe = re.sub(r"[^A-Za-z0-9_.-]+", "_", backend)[:48]
    digest = hashlib.blake2s(backend.encode("utf-8")).hexdigest()[:10]
    return f"{safe}-{digest}"


class FileTuningRegistry(TuningRegistry):
    """Registry persisted as one JSON table per fingerprint in a directory."""

    def __init__(self, root: "str | Path"):
        super().__init__()
        self.root = Path(root)

    def _path(self, backend: str) -> Path:
        return self.root / f"{_slug(backend)}.json"

    def publish(self, cache: TuningCache) -> TuningCache:
        path = self._path(cache.backend)
        merged = _copy(cache)
        if path.exists():
            existing = TuningCache.load(path)
            if existing.backend != cache.backend:  # hash-collision guard
                raise ValueError(
                    f"registry file {path} holds backend "
                    f"{existing.backend!r}, expected {cache.backend!r}"
                )
            merged = existing.merge(merged)
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(
            dir=str(path.parent), prefix=path.name, suffix=".tmp"
        )
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(merged.to_json(), f, indent=2)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        self._tables[cache.backend] = merged
        return _copy(merged)

    def fetch(self, backend: Optional[str] = None) -> Optional[TuningCache]:
        backend = backend or _backend_fingerprint()
        path = self._path(backend)
        if not path.exists():
            return None
        cache = TuningCache.load(path)
        self._tables[backend] = cache
        return _copy(cache)

    def backends(self) -> List[str]:
        found = set(self._tables)
        if self.root.exists():
            for p in self.root.glob("*.json"):
                try:
                    found.add(str(json.loads(p.read_text())["backend"]))
                except (ValueError, KeyError):
                    continue
        return sorted(found)


def default_registry() -> Optional[FileTuningRegistry]:
    """The registry named by ``$REPRO_TUNING_REGISTRY``, if set."""
    root = os.environ.get(TUNING_REGISTRY_ENV)
    return FileTuningRegistry(root) if root else None


__all__ = [
    "FileTuningRegistry",
    "TUNING_REGISTRY_ENV",
    "TuningRegistry",
    "default_registry",
]
