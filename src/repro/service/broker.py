"""Multi-tenant descriptor broker: many client streams, one offload engine.

The paper's NetFPGA is a *shared* device: every host rank posts its request
packet at the same NIC, and the firmware combines compatible requests inside
one hardware pipeline pass. :class:`DescriptorBroker` is that front end in
software. Many in-process :class:`ServiceClient` handles (one per tenant)
submit wire-encoded :class:`~repro.core.packet.CollectiveDescriptor`
requests into bounded queues; the broker groups compatible requests into
**coalesced dispatches** — one stacked payload through the wrapped
:class:`~repro.offload.OffloadEngine` per fused group — and distributes the
unstacked results back through per-request tickets.

Coalescing rules (all must hold for two requests to fuse):

  * identical *normalized* descriptor words — same coll/op/dtype/count,
    same comm_size, same topology (axes + split), same algo; per-rank
    fields (rank, msg_type) are normalized away exactly like the engine's
    schedule-cache key;
  * identical payload structure: same pytree treedef and same leaf
    shapes/dtypes (so the payloads stack).

Fused payloads stack along a new batch axis *behind* the rank axis
(``(p, n) -> (p, k, n)``); every collective in the repo reduces along the
leading rank axis elementwise over the rest, so the fused result is
**bitwise identical** to k separate dispatches — the service never changes
numerics, only amortizes dispatch and compilation. Fused widths are padded
to the next power of two with zero columns (``coalesce_pad_pow2``): the
padding rides the elementwise batch axis and is dropped at unstack time, so
a broker compiles at most log2(max_coalesce) fused shapes per descriptor
instead of one per group size the traffic happens to produce.

Flow control, like the paper's ACK-based back-to-back flow control:

  * per-tenant bounded queues — a client over its bound either blocks
    (``block=True``, bounded by ``timeout``) or is rejected with
    :class:`QueueFullError`; other tenants are unaffected;
  * broker-wide admission control — ``max_pending`` caps total queued
    requests and ``max_tenants`` caps open client streams
    (:class:`AdmissionError`);
  * a **deadline-based flush**: a request waits at most
    ``flush_interval_s`` for companions before its group dispatches, so a
    lone tenant is never starved waiting for traffic that isn't coming.

The broker runs its flush loop on a daemon thread (``start()``/``stop()``);
``drain()`` pumps synchronously for deterministic tests and for use without
a thread. Execution mode is fixed per broker: sim (default) or the engine's
driver mode (``axis_name=...``, ``mesh=...``), where each fused dispatch is
one compiled ``jit(shard_map(...))`` program over the mesh.
"""

from __future__ import annotations

import itertools
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packet import CollType, CollectiveDescriptor
from repro.obs import events as obs_events
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing
from repro.offload import reliability as _rel
from repro.offload.engine import AxisSpec, OffloadEngine
from repro.service.telemetry import ServiceTelemetry

PyTree = Any

#: default bound on ``ServiceTicket.result()`` — callers that don't pass a
#: timeout must never block forever on an abandoned request (a crashed
#: broker, a stopped flush loop); pass ``timeout=None`` explicitly to wait
#: unboundedly
DEFAULT_RESULT_TIMEOUT_S = 120.0

_UNSET = object()


class QueueFullError(RuntimeError):
    """A tenant exceeded its queue bound (or the broker its pending cap)."""


class AdmissionError(RuntimeError):
    """The broker refused to open another client stream."""


class BrokerStopped(RuntimeError):
    """Submitted to (or waited on) a broker that has shut down."""


class ServiceTicket:
    """One request's future: filled by the broker's flush, read by the
    submitting tenant."""

    def __init__(self, tenant: str, seqno: int):
        self.tenant = tenant
        self.seqno = seqno
        self._event = threading.Event()
        self._result: PyTree = None
        self._error: Optional[BaseException] = None

    def _fulfill(self, result: PyTree) -> None:
        self._result = result
        self._event.set()

    def _fail(self, err: BaseException) -> None:
        self._error = err
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Any = _UNSET) -> PyTree:
        """Wait for the result (or raise the request's failure).

        ``timeout`` defaults to :data:`DEFAULT_RESULT_TIMEOUT_S`; pass
        ``None`` to wait forever (explicit opt-in only).
        """
        if timeout is _UNSET:
            timeout = DEFAULT_RESULT_TIMEOUT_S
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.tenant}#{self.seqno} not completed within "
                f"{timeout}s"
            )
        if self._error is not None:
            raise self._error
        return self._result


class _Request:
    __slots__ = (
        "tenant", "desc", "payload", "ticket", "submit_t", "flush_at",
        "deadline_at", "group_key", "submit_span_id", "submit_us",
        "checksum",
    )

    def __init__(self, tenant, desc, payload, ticket, submit_t, flush_at,
                 deadline_at, checksum=None):
        self.tenant = tenant
        self.desc = desc
        self.payload = payload
        self.ticket = ticket
        self.submit_t = submit_t
        self.flush_at = flush_at
        self.deadline_at = deadline_at
        # submit-time payload digest (reliability mode): verified again at
        # dispatch so at-rest corruption is caught and quarantined
        self.checksum = checksum
        # trace linkage: the submitting side's span id and enqueue time on
        # the tracer clock, so the dispatch thread can retroactively record
        # this request's broker.queue_wait span with the right parent
        self.submit_span_id: Optional[int] = None
        self.submit_us: float = 0.0
        # computed once at submit time: encoding the normalized descriptor
        # and walking the payload pytree per flush cycle would repeat per
        # queued request on every wakeup
        self.group_key = (
            desc.normalized().encode().tobytes(),
            _payload_signature(payload),
        )


def _payload_signature(x: PyTree) -> Optional[Tuple]:
    if x is None:
        return None
    leaves, treedef = jax.tree.flatten(x)
    return (
        str(treedef),
        tuple((tuple(jnp.shape(l)), str(jnp.result_type(l))) for l in leaves),
    )


class ServiceClient:
    """One tenant's handle on the broker: bounded submit + ticket results."""

    def __init__(
        self,
        broker: "DescriptorBroker",
        tenant: str,
        *,
        max_queue_depth: int = 32,
        block: bool = False,
    ):
        self.broker = broker
        self.tenant = tenant
        self.max_queue_depth = int(max_queue_depth)
        self.block = bool(block)
        self._seq = itertools.count()
        self._closed = False

    def submit(
        self,
        descriptor: "CollectiveDescriptor | np.ndarray",
        x: Optional[PyTree] = None,
        *,
        block: Optional[bool] = None,
        timeout: Optional[float] = None,
        deadline_s: Optional[float] = None,
    ) -> ServiceTicket:
        """Enqueue one wire-encoded request; returns immediately with a
        ticket (backpressure permitting)."""
        if self._closed:
            raise BrokerStopped(f"client {self.tenant!r} is closed")
        return self.broker._submit(
            self,
            descriptor,
            x,
            block=self.block if block is None else block,
            timeout=timeout,
            deadline_s=deadline_s,
        )

    def offload(
        self,
        descriptor: "CollectiveDescriptor | np.ndarray",
        x: Optional[PyTree] = None,
        *,
        timeout: Optional[float] = 60.0,
    ) -> PyTree:
        """Synchronous convenience: submit and wait for the result."""
        return self.submit(descriptor, x).result(timeout)

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self.broker._release_client(self)


class DescriptorBroker:
    """Coalescing front end over one :class:`OffloadEngine`."""

    def __init__(
        self,
        engine: Optional[OffloadEngine] = None,
        *,
        axis_name: AxisSpec = None,
        mesh: Any = None,
        flush_interval_s: float = 0.002,
        max_coalesce: int = 64,
        max_pending: int = 1024,
        max_tenants: int = 64,
        registry: Any = None,
        coalesce_pad_pow2: bool = True,
        reliability: "_rel.ReliabilityPolicy | bool | None" = None,
    ):
        if mesh is not None and axis_name is None:
            raise ValueError("driver mode (mesh=...) requires axis_name")
        self.engine = engine if engine is not None else OffloadEngine()
        # the reliable dispatch path is opt-in: None keeps the historical
        # fail-the-whole-group-once semantics byte-for-byte
        if reliability is True:
            reliability = _rel.ReliabilityPolicy()
        self.reliability: Optional[_rel.ReliabilityPolicy] = (
            reliability or None
        )
        self._dispatcher: Optional[_rel.ReliableDispatcher] = (
            None
            if self.reliability is None
            else _rel.ReliableDispatcher.from_policy(
                self.engine, self.reliability
            )
        )
        self.axis_name = axis_name
        self.mesh = mesh
        self.flush_interval_s = float(flush_interval_s)
        self.max_coalesce = max(1, int(max_coalesce))
        self.coalesce_pad_pow2 = bool(coalesce_pad_pow2)
        self.max_pending = int(max_pending)
        self.max_tenants = int(max_tenants)
        self.registry = registry
        self.telemetry = ServiceTelemetry(self.engine.telemetry)
        self.tuning_table = None
        if registry is not None:
            table = registry.fetch()
            if table is not None:
                self.tuning_table = table.activate()

        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._queue: List[_Request] = []
        # requests handed to a dispatch but not completed, per tenant; they
        # still count against the tenant's queue bound so a slow engine
        # can't be outrun by resubmission
        self._inflight: Dict[str, int] = {}
        self._clients: Dict[str, ServiceClient] = {}
        self._thread: Optional[threading.Thread] = None
        self._stopping = False
        self._tenant_seq = itertools.count()

    # -- client lifecycle --------------------------------------------------

    def client(
        self,
        tenant: Optional[str] = None,
        *,
        max_queue_depth: int = 32,
        block: bool = False,
    ) -> ServiceClient:
        """Open one tenant stream (admission-controlled)."""
        with self._lock:
            if self._stopping:
                raise BrokerStopped("broker is shut down")
            if tenant is None:
                tenant = f"tenant{next(self._tenant_seq)}"
            if tenant in self._clients:
                raise AdmissionError(f"tenant {tenant!r} already has a stream")
            if len(self._clients) >= self.max_tenants:
                raise AdmissionError(
                    f"broker at max_tenants={self.max_tenants}; "
                    f"refusing stream for {tenant!r}"
                )
            handle = ServiceClient(
                self, tenant, max_queue_depth=max_queue_depth, block=block
            )
            self._clients[tenant] = handle
            return handle

    def _release_client(self, client: ServiceClient) -> None:
        with self._lock:
            self._clients.pop(client.tenant, None)

    def make_descriptor(self, coll: "CollType | str", **kw):
        """Build a request descriptor through the engine's selector (under
        the registry-activated tuning table when one was fetched)."""
        return self.engine.make_descriptor(coll, **kw)

    # -- submission --------------------------------------------------------

    def _submit(
        self,
        client: ServiceClient,
        descriptor: "CollectiveDescriptor | np.ndarray",
        x: Optional[PyTree],
        *,
        block: bool,
        timeout: Optional[float],
        deadline_s: Optional[float],
    ) -> ServiceTicket:
        desc = OffloadEngine._as_descriptor(descriptor)
        tenant = client.tenant
        tracer = obs_tracing.get_tracer()
        submit_t0 = obs_tracing.now_us() if tracer.enabled else 0.0
        with self._cond:
            if self._stopping:
                raise BrokerStopped("broker is shut down")

            def over_bound() -> bool:
                depth = sum(
                    1 for r in self._queue if r.tenant == tenant
                ) + self._inflight.get(tenant, 0)
                return (
                    depth >= client.max_queue_depth
                    or len(self._queue) >= self.max_pending
                )

            if over_bound():
                if not block:
                    self.telemetry.record_reject(tenant)
                    raise QueueFullError(
                        f"tenant {tenant!r} at queue bound "
                        f"{client.max_queue_depth} (broker pending "
                        f"{len(self._queue)}/{self.max_pending})"
                    )
                start = time.monotonic()
                while over_bound():
                    remaining = (
                        None
                        if timeout is None
                        else timeout - (time.monotonic() - start)
                    )
                    if remaining is not None and remaining <= 0:
                        self.telemetry.record_reject(tenant)
                        raise QueueFullError(
                            f"tenant {tenant!r} blocked on full queue for "
                            f"{timeout}s"
                        )
                    self._cond.wait(remaining)
                    if self._stopping:
                        raise BrokerStopped("broker is shut down")
            now = time.monotonic()
            ticket = ServiceTicket(tenant, next(client._seq))
            checksum = None
            if (
                self.reliability is not None
                and self.reliability.checksums
                and x is not None
            ):
                checksum = _rel.payload_checksum(x)
            req = _Request(
                tenant,
                desc,
                x,
                ticket,
                now,
                now + self.flush_interval_s,
                None if deadline_s is None else now + float(deadline_s),
                checksum,
            )
            if tracer.enabled:
                # the span covers admission + any backpressure wait; its id
                # parents the queue_wait span the dispatch thread records
                req.submit_us = obs_tracing.now_us()
                req.submit_span_id = tracer.add_span(
                    "service.submit", "service",
                    submit_t0, req.submit_us,
                    parent_id=tracer.current_span_id(),
                    tenant=tenant,
                    coll=desc.coll_type.name.lower(),
                    seqno=ticket.seqno,
                )
            self._queue.append(req)
            self.telemetry.record_submit(tenant)
            self._cond.notify_all()
        return ticket

    # -- flush loop --------------------------------------------------------

    def start(self) -> "DescriptorBroker":
        with self._lock:
            if self._thread is not None:
                return self
            self._stopping = False
            self._thread = threading.Thread(
                target=self._loop, name="descriptor-broker", daemon=True
            )
            self._thread.start()
        return self

    def stop(self, *, drain: bool = True, timeout: float = 60.0) -> None:
        """Stop the flush loop; by default dispatch whatever is queued first."""
        with self._cond:
            self._stopping = True
            self._cond.notify_all()
        thread = self._thread
        if thread is not None:
            thread.join(timeout=timeout)
            if thread.is_alive():
                # a wedged dispatch (e.g. a hung compile) must not be raced
                # by a force-pump, and `running` must keep reporting it
                raise TimeoutError(
                    f"broker flush thread did not stop within {timeout}s; "
                    "a dispatch is still running"
                )
            self._thread = None
        if drain:
            self._pump(force=True)
        with self._cond:
            dropped, self._queue = self._queue, []
        now = time.monotonic()
        for req in dropped:
            # account the drop before failing the ticket so queue_depth and
            # submitted == completed + errors + rejected stay consistent
            self.telemetry.record_complete(
                req.tenant, now - req.submit_t, error=True
            )
            req.ticket._fail(BrokerStopped("broker stopped"))

    def __enter__(self) -> "DescriptorBroker":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def pending(self) -> int:
        with self._lock:
            return len(self._queue)

    def drain(self) -> int:
        """Synchronously dispatch everything queued (maximal coalescing);
        returns the number of requests completed. The deterministic pump for
        tests and threadless embedding."""
        return self._pump(force=True)

    def _loop(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._stopping:
                    self._cond.wait()
                if self._stopping:
                    return
                # the deadline flush: wait until the oldest queued request's
                # window closes, letting companions accumulate, never longer
                wakeup = min(r.flush_at for r in self._queue)
                delay = wakeup - time.monotonic()
                if delay > 0:
                    self._cond.wait(delay)
                    continue
            self._pump(force=False)

    def _pump(self, *, force: bool) -> int:
        with self._cond:
            now = time.monotonic()
            if force:
                batch, self._queue = self._queue[:], []
            else:
                # take every group with at least one expired member: the
                # expired request pulls its (younger) companions along
                expired_keys = {
                    r.group_key for r in self._queue if r.flush_at <= now
                }
                batch = [
                    r for r in self._queue if r.group_key in expired_keys
                ]
                self._queue = [
                    r for r in self._queue if r.group_key not in expired_keys
                ]
            for req in batch:
                self._inflight[req.tenant] = (
                    self._inflight.get(req.tenant, 0) + 1
                )
            self._cond.notify_all()
        if not batch:
            return 0
        groups: Dict[Tuple, List[_Request]] = {}
        for req in batch:
            groups.setdefault(req.group_key, []).append(req)
        completed = 0
        for reqs in groups.values():
            for chunk_at in range(0, len(reqs), self.max_coalesce):
                chunk = reqs[chunk_at : chunk_at + self.max_coalesce]
                self._dispatch_group(chunk, deadline=not force)
                completed += len(chunk)
        return completed

    def _dispatch_group(
        self, reqs: List[_Request], *, deadline: bool = False
    ) -> None:
        desc = reqs[0].desc
        barrier = desc.coll_type == CollType.BARRIER
        start_t = time.monotonic()
        tracer = obs_tracing.get_tracer()
        if tracer.enabled:
            # queue_wait runs from each request's enqueue to this dispatch:
            # it starts on the client thread and ends here, so it is
            # recorded retroactively with the submit span as parent
            dispatch_t0 = obs_tracing.now_us()
            for req in reqs:
                tracer.add_span(
                    "broker.queue_wait", "broker",
                    req.submit_us or dispatch_t0, dispatch_t0,
                    parent_id=req.submit_span_id,
                    tenant=req.tenant,
                )
        group_cm = tracer.span(
            "broker.dispatch_group", "broker",
            coll=desc.coll_type.name.lower(),
            group=len(reqs),
            deadline=deadline,
        )
        group_cm.__enter__()
        try:
            # the optimized flag shapes the compiled schedule, so a fused
            # group must agree on it. Normal grouping guarantees this (the
            # flag travels in the normalized words the group key hashes);
            # the check guards direct/manual dispatch paths.
            flags = {bool(r.desc.optimized) for r in reqs}
            if len(flags) > 1:
                raise ValueError(
                    "cannot coalesce requests with mixed plan-optimizer "
                    "flags: optimized and unoptimized descriptors compile "
                    "different schedules"
                )
            if self._dispatcher is None:
                try:
                    outcomes = [(reqs, self._run_group(reqs), None)]
                except Exception as e:  # noqa: BLE001 - via tickets
                    outcomes = [(reqs, [None] * len(reqs), e)]
            else:
                outcomes = self._run_group_reliable(reqs)
        except Exception as e:  # noqa: BLE001 - reported through tickets
            outcomes = [(reqs, [None] * len(reqs), e)]
        finally:
            group_cm.__exit__(None, None, None)
        done_t = time.monotonic()
        any_err = any(err is not None for _, _, err in outcomes)
        self.telemetry.record_flush(len(reqs), 1, deadline=deadline)
        obs_events.record(
            "flush",
            coll=desc.coll_type.name.lower(),
            requests=len(reqs),
            deadline=deadline,
            error=any_err,
        )
        with self._cond:
            for req in reqs:
                n = self._inflight.get(req.tenant, 0) - 1
                if n > 0:
                    self._inflight[req.tenant] = n
                else:
                    self._inflight.pop(req.tenant, None)
            self._cond.notify_all()
        for sub, results, err in outcomes:
            for req, result in zip(sub, results):
                missed = (
                    req.deadline_at is not None and done_t > req.deadline_at
                )
                if missed:
                    # the post-hoc diagnosis record: was the miss queue
                    # time (waited too long for a flush) or dispatch time
                    # (the group itself was slow)?
                    obs_events.record(
                        "deadline_miss",
                        tenant=req.tenant,
                        coll=desc.coll_type.name.lower(),
                        group=len(reqs),
                        queue_wait_s=round(start_t - req.submit_t, 6),
                        dispatch_s=round(done_t - start_t, 6),
                        overrun_s=round(done_t - req.deadline_at, 6),
                    )
                self.telemetry.record_complete(
                    req.tenant,
                    done_t - req.submit_t,
                    error=err is not None,
                    deadline_missed=missed,
                )
                if err is not None:
                    req.ticket._fail(err)
                else:
                    req.ticket._fulfill(result)

    def _run_group(self, reqs: List[_Request]) -> List[PyTree]:
        """Dispatch one compatible group (stacked when fusable); returns
        per-request results. In reliability mode each request's submit-time
        checksum is re-verified first — a poisoned payload fails the whole
        attempt with an attributed IntegrityError, which the bisection
        driver then isolates — and the dispatch runs through the
        ReliableDispatcher (retries/breaker/degradation) bounded by the
        group's earliest request deadline."""
        desc = reqs[0].desc
        barrier = desc.coll_type == CollType.BARRIER
        if self._dispatcher is None:
            dispatch = lambda d, x: self.engine.offload(  # noqa: E731
                d, x, axis_name=self.axis_name, mesh=self.mesh
            )
        else:
            for r in reqs:
                if r.checksum is not None:
                    _rel.verify_payload(
                        r.payload, r.checksum,
                        request=f"{r.tenant}#{r.ticket.seqno}",
                    )
            deadlines = [
                r.deadline_at for r in reqs if r.deadline_at is not None
            ]
            deadline_at = min(deadlines) if deadlines else None
            dispatch = lambda d, x: self._dispatcher.offload(  # noqa: E731
                d, x, self.axis_name, self.mesh, deadline=deadline_at
            )
        if barrier or len(reqs) == 1:
            out = dispatch(desc, reqs[0].payload)
            return [out] * len(reqs)
        payloads = [r.payload for r in reqs]
        if self.coalesce_pad_pow2:
            width = 1 << (len(payloads) - 1).bit_length()
            pad = jax.tree.map(jnp.zeros_like, payloads[0])
            payloads += [pad] * (width - len(payloads))
        stacked = jax.tree.map(
            lambda *leaves: jnp.stack(leaves, axis=1),
            *payloads,
        )
        fused = dispatch(desc, stacked)
        return [
            jax.tree.map(lambda l, i=i: l[:, i], fused)
            for i in range(len(reqs))
        ]

    def _run_group_reliable(
        self, reqs: List[_Request]
    ) -> List[Tuple[List[_Request], List[PyTree], Optional[BaseException]]]:
        """Dispatch with group bisection: a failed fused dispatch splits in
        half, so exactly the poisoned request(s) are quarantined — their
        tickets fail with the *original* error — while clean neighbors
        retry and complete. Returns ``(sub_requests, results, error)``
        leaves covering ``reqs``.

        Deliberately an iterative worklist, not a recursive closure: a
        closure calling itself is a reference cycle (function ↔ cell)
        that keeps every captured payload/result buffer alive until the
        *cyclic* gc runs, and stalling multi-MiB device buffers like that
        defeats the allocator's reuse on the hot path (measured as a
        payload-scaling dispatch slowdown). Plain refcounting must be
        able to free each sub-group's buffers the moment its outcome is
        recorded.
        """
        outcomes: List[
            Tuple[List[_Request], List[PyTree], Optional[BaseException]]
        ] = []
        coll = reqs[0].desc.coll_type.name.lower()
        nreqs = len(reqs)
        # LIFO worklist, right half pushed first → left-to-right order,
        # same as the recursion it replaces
        work: List[List[_Request]] = [list(reqs)]
        while work:
            sub = work.pop()
            try:
                outcomes.append((sub, self._run_group(sub), None))
                continue
            except Exception as e:  # noqa: BLE001 - via tickets
                if len(sub) > 1 and self.reliability.bisect:
                    obs_events.record(
                        "bisect",
                        coll=coll,
                        requests=len(sub),
                        error=type(e).__name__,
                    )
                    obs_metrics.get_registry().counter(
                        "repro_reliability_events_total",
                        "reliable-dispatch retries/degrades/breaker skips",
                        labelnames=("kind",),
                    ).inc(kind="bisect")
                    mid = (len(sub) + 1) // 2
                    work.append(sub[mid:])
                    work.append(sub[:mid])
                    continue
                err: BaseException = e
                if (
                    isinstance(err, _rel.RetryExhaustedError)
                    and err.last_error is not None
                ):
                    err = err.last_error
                if nreqs > 1:
                    obs_events.record(
                        "quarantine",
                        tenant=sub[0].tenant,
                        seqno=sub[0].ticket.seqno,
                        coll=coll,
                        error=type(err).__name__,
                    )
                    obs_metrics.get_registry().counter(
                        "repro_reliability_events_total",
                        "reliable-dispatch retries/degrades/breaker skips",
                        labelnames=("kind",),
                    ).inc(kind="quarantine")
                outcomes.append((sub, [None] * len(sub), err))
        return outcomes


__all__ = [
    "AdmissionError",
    "BrokerStopped",
    "DEFAULT_RESULT_TIMEOUT_S",
    "DescriptorBroker",
    "QueueFullError",
    "ServiceClient",
    "ServiceTicket",
]
