"""Performance-iteration flags (the EXPERIMENTS.md section Perf knobs).

Every beyond-paper optimization is gated so the paper-faithful BASELINE and
each optimized variant lower from the same code. Flags are read from env
(REPRO_OPT_*) once at import, or set programmatically via ``set_flags`` —
the dry-run driver passes ``--opt k=v,...``.

Knobs:
  seq_shard_attn     (0/1)  shard flash-attention query blocks over the model
                            axis when heads don't divide it (fixes the
                            replicated-attention waste on whisper/smollm/
                            qwen2.5/qwen2-vl).
  remat_policy       (none | save_block_outputs)
                            layer-remat policy; save_block_outputs names the
                            post-collective block outputs so the backward
                            pass does NOT re-run forward TP collectives.
  scan_algorithm     (binomial_tree | sklansky | hillis_steele | ...)
                            algo_type for the SSM inter-chunk dist_exscan.
  scan_payload_bf16  (0/1)  carry the scan collective's (decay, state) pair
                            in bf16 on the wire.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Optional


@dataclasses.dataclass
class PerfFlags:
    seq_shard_attn: bool = False
    remat_policy: str = "none"
    scan_algorithm: str = "binomial_tree"
    scan_payload_bf16: bool = False
    attn_probs_bf16: bool = False   # exp(s-m) weights in bf16 for the PV matmul
    attn_kv_block: int = 1024       # flash KV block (bigger = fewer o-rescales)
    tp_reduce_bf16: bool = False    # force bf16 payloads on TP all-reduces by
                                    # emitting bf16 dots for psum'd projections
    explicit_tp: bool = False       # run attention/MLP projections in
                                    # shard_map with explicitly-owned psums
                                    # (payload dtype + placement controlled)
    ssm_chunk: int = 0              # override SSD chunk length (0 = config)
    attn_seq_over_tp: bool = False  # replicate attention projections and
                                    # shard flash q-blocks over the model axis
                                    # instead of TP heads (small-d models:
                                    # kills the dx all-reduces entirely)


def _from_env() -> PerfFlags:
    return PerfFlags(
        seq_shard_attn=os.environ.get("REPRO_OPT_SEQ_SHARD_ATTN", "0") == "1",
        remat_policy=os.environ.get("REPRO_OPT_REMAT_POLICY", "none"),
        scan_algorithm=os.environ.get(
            "REPRO_OPT_SCAN_ALGORITHM", "binomial_tree"
        ),
        scan_payload_bf16=os.environ.get("REPRO_OPT_SCAN_PAYLOAD_BF16", "0") == "1",
        attn_probs_bf16=os.environ.get("REPRO_OPT_ATTN_PROBS_BF16", "0") == "1",
        attn_kv_block=int(os.environ.get("REPRO_OPT_ATTN_KV_BLOCK", "1024")),
        tp_reduce_bf16=os.environ.get("REPRO_OPT_TP_REDUCE_BF16", "0") == "1",
        explicit_tp=os.environ.get("REPRO_OPT_EXPLICIT_TP", "0") == "1",
        ssm_chunk=int(os.environ.get("REPRO_OPT_SSM_CHUNK", "0")),
        attn_seq_over_tp=os.environ.get("REPRO_OPT_ATTN_SEQ_OVER_TP", "0") == "1",
    )


FLAGS = _from_env()


def set_flags(**kwargs) -> PerfFlags:
    global FLAGS
    FLAGS = dataclasses.replace(FLAGS, **kwargs)
    return FLAGS


def parse_opt_string(opt: Optional[str]) -> None:
    """'seq_shard_attn=1,remat_policy=save_block_outputs' -> set_flags."""
    if not opt:
        return
    kw = {}
    for pair in opt.split(","):
        k, v = pair.split("=")
        k = k.strip()
        v = v.strip()
        if k in ("seq_shard_attn", "scan_payload_bf16", "attn_probs_bf16", "tp_reduce_bf16", "explicit_tp", "attn_seq_over_tp"):
            kw[k] = v in ("1", "true", "True")
        elif k in ("attn_kv_block", "ssm_chunk"):
            kw[k] = int(v)
        else:
            kw[k] = v
    set_flags(**kw)
