from repro.roofline import hlo_cost  # submodule (keep name unshadowed)
from repro.roofline.analysis import Roofline, analyze_hlo, model_flops
