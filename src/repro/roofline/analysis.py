"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds per step:

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

HLO_FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device SPMD
module). Collective bytes are NOT in cost_analysis: we parse the optimized
HLO text and apply a per-kind wire-cost model (ring algorithms):

    all-reduce        2 * bytes * (g-1)/g
    all-gather        bytes_out * (g-1)/g
    reduce-scatter    bytes_in * (g-1)/g
    all-to-all        bytes * (g-1)/g
    collective-permute bytes

where g is the replica-group size parsed from the op.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Tuple

# TPU v5e constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# matches e.g. "f32[256,1024]{1,0}" or "bf16[8]"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    # replica_groups={{0,1,2,...},{...}} -> size of first group
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    # iota format: replica_groups=[8,32]<=[256] -> groups of 32
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    wire_bytes: Dict[str, float]   # cost-model bytes on the wire per device

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())


def parse_collectives(hlo_text: str, default_group: int) -> CollectiveStats:
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    wire: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # result-type = before ' = ', op after
        m = re.match(r"%?[\w.\-]+ = (.+?) (\w[\w\-]*)\(", stripped)
        if not m:
            continue
        result_type, op = m.group(1), m.group(2)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):  # e.g. all-reduce-start
                kind = c
                break
        if kind is None:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        nbytes = _shape_bytes(result_type)
        g = _group_size(stripped, default_group)
        if g <= 1:
            continue
        frac = (g - 1) / g
        if kind == "all-reduce":
            cost = 2.0 * nbytes * frac
        elif kind == "collective-permute":
            cost = float(nbytes)
        else:
            cost = nbytes * frac
        counts[kind] += 1
        wire[kind] += cost
    return CollectiveStats(counts=counts, wire_bytes=wire)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    n_chips: int

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "n_chips": self.n_chips,
        }


def model_flops(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (inference)."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analyze(
    cost: Dict[str, float],
    hlo_text: str,
    n_chips: int,
    default_group: int,
) -> Tuple[Roofline, CollectiveStats]:
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    stats = parse_collectives(hlo_text, default_group)
    return (
        Roofline(
            flops_per_device=flops,
            bytes_per_device=nbytes,
            collective_bytes=stats.total_wire_bytes,
            n_chips=n_chips,
        ),
        stats,
    )


def analyze_hlo(hlo_text: str, n_chips: int, default_group: int):
    """Trip-count-aware analysis (the authoritative path; see hlo_cost.py).

    cost_analysis() counts while bodies once, so scanned-layer programs would
    be undercounted by the layer count — hlo_cost re-derives FLOPs, HBM bytes
    and collective wire bytes with loop trip multipliers.
    """
    from repro.roofline.hlo_cost import hlo_cost

    c = hlo_cost(hlo_text, default_group)
    roof = Roofline(
        flops_per_device=c.flops,
        bytes_per_device=c.bytes,
        collective_bytes=c.total_coll_bytes,
        n_chips=n_chips,
    )
    return roof, c
