"""Trip-count-aware HLO cost model.

``compiled.cost_analysis()`` counts while-loop bodies ONCE — with layer stacks
lowered as ``lax.scan`` that undercounts FLOPs/bytes/collectives by the trip
count (62x for gemma3). This parser walks the optimized HLO text instead:

  * dot ops: 2 x out_elems x contraction_size
  * arithmetic elementwise / reduce: 1 flop per element
  * fusion/call: cost of the called computation
  * while: (body + cond) x trip count, parsed from the condition's s32
    constant bound (lax.scan lowers to `compare(i, constant(T)), LT`)
  * HBM traffic: operand + result bytes of computation-scope ops (ops inside
    fusion computations stream through registers/VMEM and are not counted)
  * collective wire bytes: ring cost model x trip count
      all-reduce 2B(g-1)/g | all-gather/reduce-scatter/all-to-all B(g-1)/g |
      collective-permute B

Validated against closed-form expectations in tests/test_hlo_cost.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_ARITH_1FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "not", "clamp",
    "floor", "ceil", "round-nearest-afz", "sign", "remainder", "atan2",
    "power", "shift-left", "shift-right-logical", "shift-right-arithmetic",
}
_TRANSCENDENTAL = {"exponential", "tanh", "log", "rsqrt", "sqrt", "logistic",
                   "cosine", "sine", "expm1", "log1p", "cbrt", "erf"}
_ZERO_COST = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "reshape", "transpose", "broadcast", "copy", "convert", "iota", "slice",
    "dynamic-slice", "dynamic-update-slice", "concatenate", "pad", "reverse",
    "gather", "scatter", "rng", "rng-bit-generator", "after-all", "custom-call",
    "copy-start", "copy-done", "partition-id", "replica-id", "domain",
    "optimization-barrier", "infeed", "outfeed", "reduce-precision",
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_elems(shape_str: str) -> Tuple[int, int]:
    """(total_elements, total_bytes) over all array shapes in the string."""
    elems = 0
    nbytes = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_type: str
    operands: List[str]
    attrs: str
    operand_str: str = ""


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    result_types: Dict[str, str]
    by_name: Dict[str, Op] = dataclasses.field(default_factory=dict)


_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w.\-]+) \(.*\)\s*->\s*.*\{")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT )?%?([\w.\-]+) = ((?:\([^)]*\)|[\w\[\]{},]+?)) ([\w\-]+)\((.*?)\)(.*)$"
)
_OPERAND_REF = re.compile(r"%([\w.\-]+)")


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(1), [], {})
                if line.startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, rtype, kind, operand_str, attrs = m.groups()
        operands = _OPERAND_REF.findall(operand_str)
        op = Op(name, kind, rtype, operands, attrs, operand_str)
        cur.ops.append(op)
        cur.result_types[name] = rtype
        cur.by_name[name] = op
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


def _called(attrs: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", attrs)
    return m.group(1) if m else None


def _trip_count(cond: Computation, comps: Dict[str, Computation]) -> int:
    """Largest s32 constant reachable in the condition computation."""
    best = 1

    def scan(c: Computation, depth=0):
        nonlocal best
        if depth > 3:
            return
        for op in c.ops:
            if op.kind == "constant" and op.result_type.startswith("s32"):
                m = re.match(r"\s*(-?\d+)\s*$", op.operand_str)
                if m:
                    best = max(best, int(m.group(1)))
            for key in ("calls", "condition", "body", "to_apply"):
                sub = _called(op.attrs, key)
                if sub and sub in comps:
                    scan(comps[sub], depth + 1)

    scan(cond)
    return best


def _collective_payload_bytes(op: Op, comp: Computation,
                              comps: Dict[str, Computation]) -> int:
    """Wire payload of a collective, billed at its SEMANTIC dtype.

    The CPU backend's float-normalization pass rewrites bf16 reductions as
    convert(bf16->f32) -> all-reduce(f32) -> convert(->bf16); a TPU executes
    that all-reduce natively in bf16. When a collective operand is produced
    by a pure widening convert (bare, or wrapped in a layout-pure kLoop
    fusion of converts/bitcasts/copies), bill the convert's SOURCE dtype —
    that is what a TPU would put on the wire. f32 payloads produced by real
    f32 computation are not downgraded.
    """

    _LAYOUT_PURE = {"convert", "bitcast", "copy", "reshape", "transpose",
                    "parameter", "tuple", "get-tuple-element", "add",
                    "bitcast-convert"}

    def src_bytes(name: str, depth: int = 0) -> int:
        t = comp.result_types.get(name)
        if t is None:
            return 0
        elems, b = _shape_elems(t)
        if depth >= 3:
            return b
        prod = comp.by_name.get(name)
        if prod is None:
            return b
        cands = []
        if prod.kind == "convert" and prod.operands:
            cands = prod.operands[:1]
        elif prod.kind == "fusion":
            callee_name = _called(prod.attrs, "calls")
            callee = comps.get(callee_name) if callee_name else None
            if callee is not None and all(
                o.kind in _LAYOUT_PURE for o in callee.ops
            ):
                cands = prod.operands
        best = b
        for o in cands:
            ct = comp.result_types.get(o)
            if ct is None:
                continue
            celems, _cb = _shape_elems(ct)
            if celems == elems:
                best = min(best, src_bytes(o, depth + 1))
        return best

    if not op.operands:
        _, b = _shape_elems(op.result_type)
        return b
    total = sum(src_bytes(o) for o in op.operands)
    if total <= 0:
        _, total = _shape_elems(op.result_type)
    return total


def _group_size(attrs: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    return default


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems, _ = _shape_elems(op.result_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    if not m or not op.operands:
        return 2.0 * out_elems
    lhs_type = comp.result_types.get(op.operands[0], "")
    sm = _SHAPE_RE.search(lhs_type)
    if not sm:
        return 2.0 * out_elems
    dims = [int(d) for d in sm.group(2).split(",")] if sm.group(2) else []
    contract = 1
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(dims):
            contract *= dims[idx]
    return 2.0 * out_elems * contract


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES}
    )
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES}
    )

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in _COLLECTIVES:
            self.coll_bytes[k] += other.coll_bytes[k] * mult
            self.coll_counts[k] += other.coll_counts[k] * mult

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


def _op_bytes(op: Op, comp: Computation) -> float:
    """HBM traffic of a computation-scope op: operands + result."""
    _, out_b = _shape_elems(op.result_type)
    in_b = 0
    for o in op.operands:
        t = comp.result_types.get(o)
        if t is None:
            continue
        _, b = _shape_elems(t)
        in_b += b
    return float(in_b + out_b)


# Ops whose operands genuinely stream from HBM on TPU (fusion anchors).
_ANCHOR_KINDS = {
    "dot", "convolution", "reduce", "reduce-window", "sort", "gather",
    "scatter", "dynamic-slice", "dynamic-update-slice", "concatenate",
    "pad", "copy", "cholesky", "triangular-solve", "fft",
}
_ELEMENTWISE_FUSIBLE = _ARITH_1FLOP | _TRANSCENDENTAL | {
    "convert", "broadcast", "reshape", "transpose", "slice", "iota",
    "bitcast", "reverse", "reduce-precision", "map", "exponential-minus-one",
}


def _fusion_is_elementwise(callee: Computation) -> bool:
    """True if a fused computation contains no anchor op (TPU would fuse the
    whole thing into its consumers/producers)."""
    for op in callee.ops:
        if op.kind in _ANCHOR_KINDS:
            return False
        if op.kind in ("fusion", "call", "while", "conditional"):
            return False
    return True


def _traffic_bytes(op: Op, comp: Computation, comps: Dict[str, Computation]) -> float:
    """TPU-fusion-adjusted HBM traffic model.

    The CPU backend wraps every elementwise op in its own kLoop fusion, so
    counting operands+result for all of them wildly overstates what a TPU
    (which fuses elementwise chains into matmul neighbours) would move.
    Model: anchor ops (dot/reduce/scatter/cache-update/...) pay operands +
    result; elementwise(-only fusions) pay result bytes only — every
    intermediate is counted once, as its producer's output.
    """
    kind = op.kind
    if kind in ("parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "after-all", "while", "conditional"):
        return 0.0
    # In-place / sparse-touch ops: TPU (with donation/aliasing) moves only
    # the touched slice, not the whole buffer.
    if kind == "dynamic-update-slice":
        # read + write of the update slice (operand 1)
        if len(op.operands) > 1:
            t = comp.result_types.get(op.operands[1])
            if t:
                _, b = _shape_elems(t)
                return 2.0 * b
        return 0.0
    if kind in ("dynamic-slice", "gather", "slice"):
        _, out_b = _shape_elems(op.result_type)
        return 2.0 * out_b
    if kind == "copy":
        # loop-carry copies alias away under donation; count one write
        _, out_b = _shape_elems(op.result_type)
        return float(out_b)
    if kind in ("fusion", "call"):
        callee = _called(op.attrs, "calls") or _called(op.attrs, "to_apply")
        if callee and callee in comps:
            cc = comps[callee]
            if _fusion_is_elementwise(cc):
                _, out_b = _shape_elems(op.result_type)
                return float(out_b)
            # fusion whose anchors are all in-place/sparse-touch ops: bill
            # the slice rules plus elementwise outputs, not the buffers
            anchors = [o for o in cc.ops if o.kind in _ANCHOR_KINDS]
            inplace = ("dynamic-update-slice", "dynamic-slice", "gather",
                       "slice", "copy")
            if anchors and all(a.kind in inplace for a in anchors):
                return float(sum(_traffic_bytes(a, cc, comps) for a in anchors))
        return _op_bytes(op, comp)
    if kind in _ANCHOR_KINDS:
        return _op_bytes(op, comp)
    # bare elementwise at computation scope
    _, out_b = _shape_elems(op.result_type)
    return float(out_b)


def comp_cost(
    comp: Computation,
    comps: Dict[str, Computation],
    default_group: int,
    _memo: Dict[str, Cost],
    *,
    fused: bool = False,
) -> Cost:
    key = comp.name + ("#f" if fused else "")
    if key in _memo:
        return _memo[key]
    total = Cost()
    for op in comp.ops:
        kind = op.kind
        # --- collectives ---
        is_coll = None
        for c in _COLLECTIVES:
            if kind == c or kind == c + "-start":
                is_coll = c
                break
        if is_coll:
            nbytes = _collective_payload_bytes(op, comp, comps)
            g = _group_size(op.attrs, default_group)
            if g > 1:
                frac = (g - 1) / g
                if is_coll == "all-reduce":
                    wire = 2.0 * nbytes * frac
                elif is_coll == "collective-permute":
                    wire = float(nbytes)
                else:
                    wire = nbytes * frac
                total.coll_bytes[is_coll] += wire
                total.coll_counts[is_coll] += 1
            if not fused:
                total.bytes += _op_bytes(op, comp)
            continue
        if kind == "while":
            body = _called(op.attrs, "body")
            cond = _called(op.attrs, "condition")
            trip = _trip_count(comps[cond], comps) if cond in comps else 1
            sub = Cost()
            if body in comps:
                sub.add(comp_cost(comps[body], comps, default_group, _memo))
            if cond in comps:
                sub.add(comp_cost(comps[cond], comps, default_group, _memo))
            total.add(sub, mult=trip)
            continue
        if kind in ("fusion", "call", "async-start"):
            callee = _called(op.attrs, "calls") or _called(op.attrs, "to_apply")
            if callee and callee in comps:
                sub = comp_cost(comps[callee], comps, default_group, _memo, fused=True)
                # fused interior: flops count, interior bytes don't
                total.flops += sub.flops
                for k in _COLLECTIVES:
                    total.coll_bytes[k] += sub.coll_bytes[k]
                    total.coll_counts[k] += sub.coll_counts[k]
            if not fused:
                total.bytes += _traffic_bytes(op, comp, comps)
            continue
        if kind == "conditional":
            branches = re.findall(r"branch_computations=\{([^}]*)\}", op.attrs)
            names = []
            if branches:
                names = _OPERAND_REF.findall(branches[0]) or [
                    b.strip().lstrip("%") for b in branches[0].split(",")
                ]
            best = Cost()
            for n in names:
                if n in comps:
                    c = comp_cost(comps[n], comps, default_group, _memo)
                    if c.flops >= best.flops:
                        best = c
            total.add(best)
            if not fused:
                total.bytes += _op_bytes(op, comp)
            continue
        # --- plain ops ---
        if kind == "dot" or kind == "convolution":
            total.flops += _dot_flops(op, comp)
        elif kind in ("reduce", "reduce-window"):
            in_elems = 0
            for o in op.operands:
                t = comp.result_types.get(o)
                if t:
                    e, _ = _shape_elems(t)
                    in_elems += e
            total.flops += in_elems
        elif kind in _ARITH_1FLOP or kind in _TRANSCENDENTAL:
            e, _ = _shape_elems(op.result_type)
            total.flops += e
        elif kind in _ZERO_COST or kind.endswith("-done"):
            pass
        # bytes: only at computation scope (not inside fusions),
        # TPU-fusion-adjusted
        if not fused:
            total.bytes += _traffic_bytes(op, comp, comps)
    _memo[key] = total
    return total


def hlo_cost(text: str, default_group: int) -> Cost:
    comps, entry = parse_module(text)
    if entry is None:
        return Cost()
    return comp_cost(comps[entry], comps, default_group, {})
