"""Version-compat shims for the jax API surface this repo depends on.

``jax.shard_map`` was promoted out of ``jax.experimental`` only in newer jax
releases; on 0.4.x the top-level attribute raises ``AttributeError`` through
the deprecation machinery. Every call site imports :data:`shard_map` from here
so the repo runs on either side of the promotion.
"""

from __future__ import annotations

import jax


def _resolve_shard_map():
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm
    from jax.experimental.shard_map import shard_map as exp_sm  # jax <= 0.4.x

    import functools
    import inspect

    accepted = set(inspect.signature(exp_sm).parameters)

    @functools.wraps(exp_sm)
    def sm(f, **kwargs):
        # Newer jax renamed check_rep -> check_vma; translate (or drop) so
        # call sites can use the modern spelling everywhere.
        if "check_vma" in kwargs and "check_vma" not in accepted:
            val = kwargs.pop("check_vma")
            if "check_rep" in accepted:
                kwargs["check_rep"] = val
        return exp_sm(f, **kwargs)

    return sm


def axis_size(axis_name) -> int:
    """Static size of a named mesh axis, from inside an SPMD context.

    ``jax.lax.axis_size`` appeared after 0.4.x; the fallback reads the axis
    frame that shard_map pushes (its ``size`` is a Python int at trace time).
    """
    size_fn = getattr(jax.lax, "axis_size", None)
    if size_fn is not None:
        return int(size_fn(axis_name))
    import jax.core as _core  # jax <= 0.4.x

    frame = _core.axis_frame(axis_name)  # returns a frame or the bare size
    return int(getattr(frame, "size", frame))


try:
    shard_map = _resolve_shard_map()
except ImportError:  # pragma: no cover - neither location present
    raise ImportError(
        "no shard_map found in jax or jax.experimental.shard_map; "
        f"jax=={jax.__version__} is unsupported"
    ) from None
