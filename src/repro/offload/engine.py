"""The offload engine: one descriptor in, one result out.

This is the software analogue of the paper's NIC firmware loop. The NetFPGA
accepted a single self-describing packet (Fig. 1) and ran the whole collective
in hardware; here :class:`OffloadEngine` accepts a
:class:`~repro.core.packet.CollectiveDescriptor` (or its encoded uint32 word
vector straight off the wire), compiles the described schedule once, caches it
keyed by the descriptor words, and dispatches every subsequent identical
request straight from the cache — with hit/miss/latency telemetry standing in
for the paper's 8 ns on-NIC timer.

Three execution modes, mirroring the repo's backends:

  * **sim** (``axis_name=None``): payloads are stacked ``(p, ...)`` arrays on
    one device; the engine owns the dispatch, jits the fused schedule, and
    measures wall-clock latency per offload.
  * **spmd** (``axis_name="..."``): called from *inside* ``shard_map``; the
    cached schedule closure is inlined into the caller's trace (the compiled
    XLA program is the "NIC"), so the engine counts hits/misses but leaves
    timing to the profiler.
  * **driver** (``axis_name=...`` plus ``mesh=...``): called from *outside*
    any trace. The engine wraps the schedule in its own
    ``jit(shard_map(...))`` over the given mesh, compiles it once per
    descriptor, and dispatches the compiled program on every offload — the
    closest software analogue of the paper's host/NIC split: the host
    computes locally, rings the doorbell with a descriptor, and the
    pre-programmed engine runs the collective. Payload layout is the sim
    contract (stacked ``(p, ...)`` leaves, leading axis in the plan's
    *logical* rank order); sharding in/out follows the descriptor's split,
    so repeat dispatches move no data. Latency is wall-clock, like sim.

All five descriptor CollTypes dispatch through the same path: SCAN, EXSCAN,
REDUCE, ALLREDUCE, BARRIER. Descriptors carrying a multi-axis topology
(``axes`` + ``split``) compile through the collective planner
(:mod:`repro.offload.planner`): the plan's phase list is derived from the
descriptor, run through the plan-optimizer pass pipeline when the
descriptor's ``optimized`` flag is set (:mod:`repro.offload.passes` —
SCAN+TOTAL fusion, dead-phase elimination, permute threading), lowered
through the same sim/spmd backend pair, and cached under a fingerprint of
the *optimized plan* rather than the raw words — descriptors whose plans
converge after the passes (different ``comm_id``; ``(2,4)`` split ``(1,0)``
vs ``(4,2)`` split ``(0,1)``; size-1 axes pruned) share one compiled
schedule, so the optimizer shrinks compile count as well as round count. In
spmd mode ``axis_name`` is a tuple naming the physical mesh axes in
descriptor order. :meth:`OffloadEngine.profile_offload` additionally runs
one dispatch under ``jax.profiler`` and feeds the device-side schedule time
back into the telemetry (``device_latency_by_coll_us``), the
measured-on-device latency source for driver/SPMD modes.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg
from repro.core.operators import AssocOp, get_operator
from repro.core.packet import (
    CollType,
    CollectiveDescriptor,
    MsgType,
    WireDType,
    WireOp,
)
from repro.core.reduce_ops import (
    allreduce_schedule,
    barrier_schedule,
    reduce_schedule,
)
from repro.core.scan_collective import dist_exscan, dist_scan, sim_scan
from repro.core.selector import select_algorithm
from repro.obs import events as obs_events
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing
from repro.offload import planner

PyTree = Any
AxisSpec = Union[str, Sequence[str], None]

#: the coll kind each CollType tunes/selects against (the measured tables are
#: keyed by these names — never price a reduce with the scan table)
COLL_KIND = {
    CollType.SCAN: "scan",
    CollType.EXSCAN: "exscan",
    CollType.REDUCE: "reduce",
    CollType.ALLREDUCE: "allreduce",
    CollType.BARRIER: "barrier",
}

_WIRE_OP_NAMES = {
    WireOp.SUM: "sum",
    WireOp.PROD: "prod",
    WireOp.MAX: "max",
    WireOp.MIN: "min",
    WireOp.SSD: "ssd",
    WireOp.FLASH: "flash",
}
_WIRE_OP_IDS = {v: k for k, v in _WIRE_OP_NAMES.items()}

_WIRE_DTYPES = {
    WireDType.INT32: jnp.int32,
    WireDType.FLOAT32: jnp.float32,
    WireDType.BFLOAT16: jnp.bfloat16,
    WireDType.FLOAT16: jnp.float16,
    WireDType.INT8: jnp.int8,
}
_WIRE_DTYPE_IDS = {jnp.dtype(v): k for k, v in _WIRE_DTYPES.items()}


_chaos_mod = None


def _chaos_active() -> bool:
    """Whether a chaos-injector scope is installed.

    Lazy import: ``repro.runtime`` must not load at offload import time
    (its ``__init__`` pulls the trainer stack, which imports this
    package); after the first call this is a module-attribute read.
    """
    global _chaos_mod
    if _chaos_mod is None:
        from repro.runtime import chaos

        _chaos_mod = chaos
    return _chaos_mod.active()


def wire_op_name(op: WireOp) -> str:
    return _WIRE_OP_NAMES[WireOp(op)]


def wire_op_id(name: str) -> WireOp:
    try:
        return _WIRE_OP_IDS[name]
    except KeyError:
        raise ValueError(
            f"operator {name!r} has no wire id; known: {sorted(_WIRE_OP_IDS)}"
        ) from None


def wire_dtype(dt: WireDType):
    return _WIRE_DTYPES[WireDType(dt)]


@dataclasses.dataclass
class EngineTelemetry:
    """Counters the engine maintains per dispatch (the NIC status registers)."""

    hits: int = 0
    misses: int = 0
    dispatches: int = 0
    compiles: int = 0
    errors: int = 0
    calls_by_coll: Dict[str, int] = dataclasses.field(default_factory=dict)
    total_latency_s: float = 0.0
    last_latency_s: float = 0.0
    timed_dispatches: int = 0
    cache_size: int = 0
    cache_clears: int = 0
    latency_by_coll: Dict[str, Tuple[float, int]] = dataclasses.field(
        default_factory=dict
    )
    device_latency_by_coll: Dict[str, Tuple[float, int]] = dataclasses.field(
        default_factory=dict
    )
    latency_source_by_coll: Dict[str, str] = dataclasses.field(
        default_factory=dict
    )
    profiler_fallbacks: int = 0
    profiler_fallback_reasons: Dict[str, int] = dataclasses.field(
        default_factory=dict
    )
    backend_fallbacks: int = 0
    backend_fallback_reasons: Dict[str, int] = dataclasses.field(
        default_factory=dict
    )

    def record_dispatch(self, coll: str, latency_s: Optional[float]) -> None:
        self.dispatches += 1
        self.calls_by_coll[coll] = self.calls_by_coll.get(coll, 0) + 1
        reg = obs_metrics.get_registry()
        reg.counter(
            "repro_engine_dispatches_total",
            "engine offload dispatches",
            labelnames=("coll",),
        ).inc(coll=coll)
        if latency_s is not None:
            self.timed_dispatches += 1
            self.total_latency_s += latency_s
            self.last_latency_s = latency_s
            tot, n = self.latency_by_coll.get(coll, (0.0, 0))
            self.latency_by_coll[coll] = (tot + latency_s, n + 1)
            self.latency_source_by_coll.setdefault(coll, "wall")
            reg.histogram(
                "repro_engine_dispatch_latency_us",
                "wall-clock latency of timed engine dispatches",
                labelnames=("coll",),
            ).observe(latency_s * 1e6, coll=coll)

    def record_device_latency(
        self, coll: str, latency_s: float, *, source: str = "profiler"
    ) -> None:
        """A per-schedule device timing from a profiler trace (or, when the
        trace could not be parsed, the wall fallback — labeled as such).
        This is the measured-on-device source behind ``latency_by_coll_us``:
        the wall numbers include dispatch/transfer/sync, the profiler
        numbers are the collective itself. The accumulated mean is never
        mixed-source: the first trace-derived sample evicts any wall
        fallbacks, and wall fallbacks never dilute a profiler-labeled mean.
        """
        prior = self.latency_source_by_coll.get(coll)
        if source == "profiler":
            if prior != "profiler":
                self.device_latency_by_coll.pop(coll, None)
            self.latency_source_by_coll[coll] = "profiler"
        elif prior == "profiler":
            return  # keep the device-only mean; drop the wall sample
        elif prior is None:
            self.latency_source_by_coll[coll] = source
        tot, n = self.device_latency_by_coll.get(coll, (0.0, 0))
        self.device_latency_by_coll[coll] = (tot + latency_s, n + 1)
        if source == "profiler":
            obs_metrics.get_registry().histogram(
                "repro_engine_device_latency_us",
                "profiler-derived device-side schedule latency",
                labelnames=("coll",),
            ).observe(latency_s * 1e6, coll=coll)

    def record_profiler_fallback(self, coll: str, reason: str) -> None:
        """A ``profile_offload`` run degraded to ``source="wall"`` — count
        it and the why, so dashboards can alert on profiler degradation
        instead of quietly trusting wall numbers."""
        self.profiler_fallbacks += 1
        self.profiler_fallback_reasons[reason] = (
            self.profiler_fallback_reasons.get(reason, 0) + 1
        )
        obs_metrics.get_registry().counter(
            "repro_engine_profiler_fallbacks_total",
            "profile_offload runs that fell back to wall-clock timing",
            labelnames=("coll", "reason"),
        ).inc(coll=coll, reason=reason)
        obs_events.record("profiler_fallback", coll=coll, reason=reason)

    def record_backend_fallback(self, coll: str, reason: str) -> None:
        """A descriptor named a lowering backend whose capability check
        missed for its plan, and the dispatch fell back to the registry
        default. Counted once per unique (descriptor, axis-binding)
        resolution, not per dispatch, mirroring the memoized resolution."""
        self.backend_fallbacks += 1
        self.backend_fallback_reasons[reason] = (
            self.backend_fallback_reasons.get(reason, 0) + 1
        )
        obs_metrics.get_registry().counter(
            "repro_engine_backend_fallbacks_total",
            "lowering-backend requests that fell back to the default",
            labelnames=("coll", "reason"),
        ).inc(coll=coll, reason=reason)
        obs_events.record("backend_fallback", coll=coll, reason=reason)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    @property
    def mean_latency_s(self) -> float:
        return (
            self.total_latency_s / self.timed_dispatches
            if self.timed_dispatches
            else 0.0
        )

    def snapshot(self) -> Dict[str, Any]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hit_rate,
            "dispatches": self.dispatches,
            "compiles": self.compiles,
            "errors": self.errors,
            "cache_size": self.cache_size,
            "cache_clears": self.cache_clears,
            "calls_by_coll": dict(self.calls_by_coll),
            "mean_latency_us": self.mean_latency_s * 1e6,
            "last_latency_us": self.last_latency_s * 1e6,
            "latency_by_coll_us": {
                coll: (tot / n) * 1e6 if n else 0.0
                for coll, (tot, n) in self.latency_by_coll.items()
            },
            "device_latency_by_coll_us": {
                coll: (tot / n) * 1e6 if n else 0.0
                for coll, (tot, n) in self.device_latency_by_coll.items()
            },
            "latency_source_by_coll": dict(self.latency_source_by_coll),
            "profiler_fallbacks": self.profiler_fallbacks,
            "profiler_fallback_reasons": dict(self.profiler_fallback_reasons),
            "backend_fallbacks": self.backend_fallbacks,
            "backend_fallback_reasons": dict(self.backend_fallback_reasons),
        }


@dataclasses.dataclass(frozen=True)
class CompiledSchedule:
    """A cache entry: the closure that runs one descriptor's collective."""

    key: bytes
    coll: str
    algo: str
    op_name: str
    p: int
    fn: Callable[[PyTree], PyTree]


class OffloadEngine:
    """Descriptor-driven collective dispatch with a compiled-schedule cache.

    The cache key is the encoded descriptor word vector with the per-rank
    fields (rank, msg_type) normalized away — every rank of a communicator,
    and every repeat offload, shares one compiled schedule, which is exactly
    the "program the NIC once, stream requests" contract of the paper.
    """

    def __init__(self) -> None:
        self._cache: Dict[bytes, CompiledSchedule] = {}
        # planned descriptors cache-key on the *optimized plan*, not the
        # raw words: requests whose plans converge after the pass pipeline
        # (different comm_id; (2,4) split (1,0) vs (4,2) split (0,1); size-1
        # axes pruned away) share one compiled schedule, so fusion also
        # shrinks compile count. _plan_memo maps normalized words -> plan;
        # _fp_memo memoizes the plan fingerprint per (words, axis names)
        # so a repeat dispatch is a dict lookup, not a rehash of the phase
        # list; _plans stashes the plan under the final key for _compile.
        self._plan_memo: Dict[bytes, Any] = {}
        self._fp_memo: Dict[Tuple[bytes, Any], bytes] = {}
        self._plans: Dict[bytes, Any] = {}
        # memoized lowering-backend resolution per (requested name, plan,
        # axis binding): repeat dispatches neither re-run the capability
        # check nor re-count a fallback in telemetry
        self._backend_memo: Dict[Tuple[str, Any, Any], Tuple] = {}
        self.telemetry = EngineTelemetry()

    # -- descriptor helpers ------------------------------------------------

    @staticmethod
    def _as_descriptor(
        descriptor: "CollectiveDescriptor | np.ndarray",
    ) -> CollectiveDescriptor:
        if isinstance(descriptor, CollectiveDescriptor):
            return descriptor
        return CollectiveDescriptor.decode(np.asarray(descriptor))

    @staticmethod
    def _mode_tag(axis_name: AxisSpec, mesh: Any = None) -> str:
        if axis_name is None:
            mode = "<sim>"
        elif isinstance(axis_name, str):
            mode = axis_name
        else:
            mode = "|".join(axis_name)
        if mesh is not None:
            shape = ",".join(
                f"{n}={s}" for n, s in zip(mesh.axis_names, mesh.devices.shape)
            )
            # device identity matters: two same-shape meshes over different
            # (or reordered) devices must not share a compiled program
            devs = hashlib.blake2s(
                ",".join(
                    str(getattr(d, "id", d)) for d in mesh.devices.flat
                ).encode("utf-8")
            ).hexdigest()[:12]
            mode = f"driver[{shape}@{devs}]|{mode}"
        return mode

    @classmethod
    def _cache_key(
        cls, desc: CollectiveDescriptor, axis_name: AxisSpec, mesh: Any = None
    ) -> bytes:
        normalized = desc.normalized()
        mode = cls._mode_tag(axis_name, mesh)
        return normalized.encode().tobytes() + b"|" + mode.encode("utf-8")

    def _plan_for(self, desc: CollectiveDescriptor):
        """The (optimized, when flagged) plan a multi-axis descriptor names
        plus its normalized wire words, memoized on those words."""
        words = desc.normalized().encode().tobytes()
        plan = self._plan_memo.get(words)
        if plan is None:
            itemsize = jnp.dtype(wire_dtype(desc.data_type)).itemsize
            payload_bytes = max(1, int(desc.count)) * itemsize
            plan = planner.build_plan(
                desc.coll_type,
                desc.axes,
                get_operator(wire_op_name(desc.operation)),
                payload_bytes,
                order=desc.split,
                root=int(desc.root),
            )
            if desc.optimized:
                from repro.offload import passes

                plan = passes.optimize_plan(plan)
            if desc.chunks > 1:
                # the descriptor's chunk word is authoritative — resolved
                # at make_descriptor time (winner table or cost model), it
                # must not be re-derived here or brokered/cached dispatches
                # could disagree on the compiled schedule's shape
                plan = dataclasses.replace(plan, chunking=int(desc.chunks))
            self._plan_memo[words] = plan
        return plan, words

    def _resolve_backend(
        self, desc: CollectiveDescriptor, plan, axis_name: AxisSpec
    ) -> Tuple[str, Tuple]:
        """Resolve the descriptor's lowering-backend request through the
        registry for this plan + axis binding; returns ``(name,
        fingerprint_fields)``. Soft capability misses fall back to the mode
        default and are counted in telemetry exactly once per unique
        resolution (the memo doubles as the dedup set)."""
        names = None
        if axis_name is not None:
            names = (
                (axis_name,)
                if isinstance(axis_name, str)
                else tuple(axis_name)
            )
        memo_key = (desc.backend, plan, names)
        cached = self._backend_memo.get(memo_key)
        if cached is None:
            from repro.offload import backends

            backend, reason = backends.resolve(desc.backend, plan, names)
            if reason:
                self.telemetry.record_backend_fallback(
                    desc.coll_type.name.lower(), reason
                )
            cached = (backend.name, backend.fingerprint())
            self._backend_memo[memo_key] = cached
        return cached

    def _planned_cache_key(
        self,
        words: bytes,
        plan,
        axis_name: AxisSpec,
        mesh: Any = None,
        backend_fields: Tuple = (),
    ) -> bytes:
        """Key a planned request on everything its lowering reads — and
        nothing more. In sim mode that is the logical structure alone; in
        spmd/driver modes the physical axis names per logical level join
        the fingerprint (two plans with one logical shape but different
        splits bind levels to different named axes). The digest is a pure
        function of (plan, names), so repeat dispatches resolve it from
        ``_fp_memo`` without rehashing the phase list."""
        names_l: Optional[Tuple[str, ...]] = None
        if axis_name is not None:
            names = (
                (axis_name,)
                if isinstance(axis_name, str)
                else tuple(axis_name)
            )
            if len(names) == len(plan.sizes):
                names_l = tuple(names[i] for i in plan.order)
            else:  # malformed; let _compile raise with its clear error
                names_l = names
        digest = self._fp_memo.get((words, names_l, backend_fields))
        if digest is None:
            fields = (
                plan.coll.name,
                plan.op_name,
                plan.logical_sizes,
                plan.result,
                plan.optimized,
                names_l,
                tuple(
                    (
                        int(ph.kind), ph.level, ph.algorithm,
                        ph.inclusive, ph.root, ph.src, ph.dst, ph.dst2,
                        ph.guard_levels,
                    )
                    for ph in plan.phases
                ),
            )
            # chunked plans get an extra fingerprint field; C=1 keeps the
            # pre-chunking digest bit-for-bit (cache-key stability)
            if plan.chunking > 1:
                fields = fields + (("chunks", int(plan.chunking)),)
            # ditto the backend: the mode defaults contribute no fields
            # (fingerprint() is empty), so every pre-registry key survives
            fields = fields + backend_fields
            digest = hashlib.blake2s(repr(fields).encode("utf-8")).digest()
            self._fp_memo[(words, names_l, backend_fields)] = digest
        mode = self._mode_tag(axis_name, mesh)
        return b"plan|" + digest + b"|" + mode.encode("utf-8")

    def make_descriptor(
        self,
        coll: "CollType | str",
        *,
        p: Optional[int] = None,
        payload_bytes: int,
        op: "AssocOp | str" = "sum",
        algorithm: str = "auto",
        comm_id: int = 0,
        root: int = 0,
        data_type: WireDType = WireDType.FLOAT32,
        count: Optional[int] = None,
        axes: Optional[Sequence[int]] = None,
        split: "str | Sequence[int]" = "auto",
        optimize: "str | bool" = "auto",
        chunks: "str | int" = "auto",
        backend: str = "auto",
    ) -> CollectiveDescriptor:
        """Build an offload request, resolving ``algorithm="auto"`` through
        the (tuning-table-aware) selector — the host-side half of the paper's
        'intelligent selection'. Selection consults the cost table of the
        *requested* coll kind (scan/exscan/reduce/allreduce/barrier), never a
        stand-in.

        With ``axes`` (2-3 mesh-axis sizes, outermost first), the request is
        a planned hierarchical collective: ``split="auto"`` asks the planner
        for the tuned logical axis order, and the resolved ``algo_type``
        names the innermost intra-phase schedule (per-phase algorithms are
        re-derived from the plan at compile time). ``optimize`` controls the
        plan-optimizer pass pipeline (``repro.offload.passes``): ``"auto"``
        consults the measured fusion winner / cost model
        (:func:`~repro.offload.passes.choose_optimization`), True/False
        force it. The resolved flag is encoded on the wire (word 16) so
        brokered and cached dispatches agree on whether passes ran.
        ``chunks`` is the chunked-streaming chunk count: ``"auto"``
        resolves through the measured schedule winner / pipelined cost
        model (:func:`~repro.offload.passes.choose_schedule` when
        ``optimize`` is also auto, :func:`~repro.offload.passes.
        select_chunking` otherwise), an int forces it; the resolved count
        travels as the 17th wire word when > 1 (single-axis requests
        always run unchunked).
        ``backend`` names the lowering backend for planned requests:
        ``"auto"`` consults the autotuner's measured backend winner
        (:func:`~repro.offload.passes.choose_backend`, falling back to the
        mode default when untuned), an explicit registry name ("pallas")
        pins it — subject to the soft capability fallback at compile time.
        Single-axis requests always use the mode default (the descriptor
        rejects a named backend without a topology).
        """
        if isinstance(coll, str):
            coll = CollType[coll.upper()]
        op = get_operator(op)
        if axes is not None:
            axes = tuple(int(a) for a in axes)
            if p is None:
                p = int(np.prod(axes))
        if p is None:
            raise ValueError("either p or axes is required")
        order: "tuple[int, ...]" = ()
        optimized = False
        chunk_count = 1
        backend_name = "" if backend == "auto" else str(backend)
        if axes is not None and len(axes) > 1:
            from repro.offload import passes

            if backend == "auto":
                backend_name = passes.choose_backend(
                    coll, axes, payload_bytes, op
                )

            if optimize == "auto" and chunks == "auto":
                # one resolution for both schedule halves: the measured
                # schedule winner (when tuned) or the cost model decides
                # fusion and chunk count together
                optimized, chunk_count = passes.choose_schedule(
                    coll, axes, payload_bytes, op
                )
            else:
                if optimize == "auto":
                    optimized = passes.choose_optimization(
                        coll, axes, payload_bytes, op
                    )
                else:
                    optimized = bool(optimize)
                if chunks == "auto":
                    plan = planner.build_plan(
                        coll, axes, op, payload_bytes, optimize=optimized
                    )
                    chunk_count = (
                        plan.chunking
                        if optimized
                        else passes.select_chunking(
                            plan, payload_bytes
                        ).chunking
                    )
                else:
                    chunk_count = int(chunks)
            order = (
                planner.plan_axis_order(
                    coll, axes, payload_bytes, op, optimize=optimized
                )
                if split == "auto"
                else tuple(int(i) for i in split)
            )
            if algorithm == "auto":
                # the innermost intra phase's schedule, for the wire field
                inner_p = axes[order[-1]]
                algorithm = select_algorithm(
                    inner_p, payload_bytes, op, coll=COLL_KIND[coll]
                )
        else:
            if chunks != "auto" and int(chunks) > 1:
                raise ValueError(
                    "chunked streaming requires a multi-axis (planned) "
                    f"request; got chunks={chunks} without axes"
                )
            if algorithm == "auto":
                algorithm = select_algorithm(
                    p, payload_bytes, op, coll=COLL_KIND[coll]
                )
        itemsize = jnp.dtype(wire_dtype(data_type)).itemsize
        if count is None:
            count = max(1, payload_bytes // itemsize)
        elif count * itemsize != payload_bytes:
            # plan compilation re-derives the payload from count * itemsize;
            # a divergent explicit count would tune the phases for a
            # different payload than the split/algo_type were selected for
            raise ValueError(
                f"count={count} x {itemsize}B contradicts "
                f"payload_bytes={payload_bytes}"
            )
        return CollectiveDescriptor(
            comm_id=comm_id,
            comm_size=p,
            coll_type=coll,
            algo_type=algorithm,
            root=root,
            operation=wire_op_id(op.name),
            data_type=data_type,
            count=count,
            axes=axes if (axes is not None and len(axes) > 1) else (),
            split=order,
            optimized=optimized,
            chunks=chunk_count,
            backend=backend_name,
        )

    # -- dispatch ----------------------------------------------------------

    def offload(
        self,
        descriptor: "CollectiveDescriptor | np.ndarray",
        x: Optional[PyTree] = None,
        axis_name: AxisSpec = None,
        mesh: Any = None,
    ) -> PyTree:
        """Run the collective the descriptor describes; return its result.

        ``x`` is the per-rank contribution: a stacked ``(p, ...)`` pytree in
        sim and driver modes (leading axis in the plan's *logical* rank
        order), the local shard inside ``shard_map`` in spmd mode. BARRIER
        ignores ``x``. For a planned multi-axis descriptor, ``axis_name`` is
        the tuple of physical mesh-axis names in descriptor ``axes`` order.
        Passing ``mesh`` (with ``axis_name``) selects driver mode: the
        engine owns the ``jit(shard_map(...))`` program, compiled on first
        dispatch and streamed from the cache afterwards.

        When a collecting tracer is installed (:mod:`repro.obs.tracing`)
        the dispatch is wrapped in ``engine``-category spans, and planned
        *sim*-mode requests run the eager traced plan interpreter — cached
        under a separate key, so the jitted schedule the default path uses
        is untouched — emitting one span per plan phase and one per
        communication round. Driver/spmd dispatches only get the host-side
        spans around the dispatch: inside jit there is no per-round host
        work to measure. With the default no-op tracer this method's
        behavior (and the compiled schedule cache) is byte-for-byte the
        untraced path.
        """
        tracer = obs_tracing.get_tracer()
        if not tracer.enabled:
            return self._offload(descriptor, x, axis_name, mesh, None)
        with tracer.span("engine.offload", "engine") as span:
            return self._offload(descriptor, x, axis_name, mesh, span)

    def _offload(
        self,
        descriptor: "CollectiveDescriptor | np.ndarray",
        x: Optional[PyTree],
        axis_name: AxisSpec,
        mesh: Any,
        span: Any,
    ) -> PyTree:
        try:
            desc = self._as_descriptor(descriptor)
        except Exception:
            self.telemetry.errors += 1
            raise
        if axis_name is not None and not isinstance(axis_name, str):
            axis_name = tuple(axis_name) or None
        if mesh is not None and axis_name is None:
            raise ValueError("driver mode (mesh=...) requires axis_name")
        # planned sim requests run the eager traced interpreter under a
        # tracer; it lives under its own cache key so the default jitted
        # schedule is never evicted or shadowed
        traced = span is not None and axis_name is None and mesh is None
        if len(desc.axes) > 1:
            try:
                plan, words = self._plan_for(desc)
            except Exception:
                self.telemetry.errors += 1
                raise
            _, bfields = self._resolve_backend(desc, plan, axis_name)
            key = self._planned_cache_key(
                words, plan, axis_name, mesh, backend_fields=bfields
            )
            if not traced and axis_name is None and mesh is None \
                    and _chaos_active():
                # a chaos scope must see (and be able to fail) individual
                # messages, which jit would bake into the compiled program:
                # route the dispatch onto the same eager interpreter — and
                # the same cache key — the tracer uses
                traced = True
            if traced:
                key += b"|traced"
            self._plans.setdefault(key, plan)
        else:
            traced = False
            key = self._cache_key(desc, axis_name, mesh)
        if span is not None:
            span.set(
                coll=desc.coll_type.name.lower(),
                mode=self._mode_tag(axis_name, mesh),
                p=int(desc.comm_size),
                traced_plan=traced,
            )
        sched = self._cache.get(key)
        if sched is None:
            tracer = obs_tracing.get_tracer() if span is not None else None
            try:
                if span is not None:
                    with tracer.span(
                        "engine.compile", "engine",
                        coll=desc.coll_type.name.lower(),
                    ):
                        sched = self._compile(
                            desc, key, axis_name, mesh, traced=traced
                        )
                else:
                    sched = self._compile(
                        desc, key, axis_name, mesh, traced=traced
                    )
            except Exception:
                self.telemetry.errors += 1
                raise
            self._cache[key] = sched
            self.telemetry.misses += 1
            self.telemetry.compiles += 1
            self.telemetry.cache_size = len(self._cache)
            cache_state = "miss"
            if span is not None:
                span.set(cache="miss")
            obs_metrics.get_registry().counter(
                "repro_engine_cache_events_total",
                "compiled-schedule cache lookups",
                labelnames=("event",),
            ).inc(event="miss")
            obs_events.record(
                "cache_miss", coll=sched.coll, scope="schedule"
            )
        else:
            self.telemetry.hits += 1
            cache_state = "hit"
            if span is not None:
                span.set(cache="hit")
            obs_metrics.get_registry().counter(
                "repro_engine_cache_events_total",
                "compiled-schedule cache lookups",
                labelnames=("event",),
            ).inc(event="hit")

        timed = axis_name is None or mesh is not None
        if desc.coll_type == CollType.BARRIER:
            if mesh is not None and x is None:
                x = jnp.zeros((desc.comm_size,), jnp.float32)
        elif timed:
            self._validate_sim_payload(desc, x)

        if timed:
            t0 = time.perf_counter()
            out = sched.fn(x)
            out = jax.tree.map(lambda a: a.block_until_ready(), out)
            latency = time.perf_counter() - t0
        else:
            out = sched.fn(x)
            latency = None  # inside a trace: the profiler owns timing
        self.telemetry.record_dispatch(sched.coll, latency)
        obs_events.record(
            "dispatch",
            coll=sched.coll,
            cache=cache_state,
            latency_us=None if latency is None else round(latency * 1e6, 1),
        )
        return out

    def profile_offload(
        self,
        descriptor: "CollectiveDescriptor | np.ndarray",
        x: Optional[PyTree] = None,
        *,
        axis_name: AxisSpec = None,
        mesh: Any = None,
        warmup: int = 1,
        trace_dir: Optional[str] = None,
    ):
        """Dispatch once under a ``jax.profiler`` trace and record the
        device-side schedule time into the telemetry (the SPMD/driver-mode
        latency story: the engine counts hits/misses inside ``shard_map``
        and the profiler owns timing — this wires the profiler's numbers
        back in). Returns a :class:`repro.offload.profiling.DeviceTiming`.
        Pass ``trace_dir`` to keep the profiler trace on disk (e.g. for
        :func:`repro.obs.export.merge_device_trace`).
        """
        from repro.offload.profiling import profile_offload as _profile

        return _profile(
            self, descriptor, x, axis_name=axis_name, mesh=mesh,
            warmup=warmup, trace_dir=trace_dir,
        )

    def cache_size(self) -> int:
        return len(self._cache)

    def clear(self) -> None:
        # reset the gauge at clear time: a remesh-triggered clear must not
        # keep reporting the pre-clear size until the next dispatch. The
        # plan memos clear too: a retune can change the per-phase
        # algorithms (and the fused-vs-unfused choice) a plan compiles to.
        self._cache.clear()
        self._plan_memo.clear()
        self._fp_memo.clear()
        self._plans.clear()
        self._backend_memo.clear()
        self.telemetry.cache_size = 0
        self.telemetry.cache_clears += 1

    # -- internals ---------------------------------------------------------

    @staticmethod
    def _validate_sim_payload(desc: CollectiveDescriptor, x: PyTree) -> None:
        if x is None:
            raise ValueError(
                f"{desc.coll_type.name} offload requires a payload"
            )
        for leaf in jax.tree.leaves(x):
            if jnp.ndim(leaf) < 1 or leaf.shape[0] != desc.comm_size:
                raise ValueError(
                    "sim-mode payload leaves need a leading rank axis of "
                    f"comm_size={desc.comm_size}; got shape {jnp.shape(leaf)}"
                )

    def _compile(
        self,
        desc: CollectiveDescriptor,
        key: bytes,
        axis_name: AxisSpec,
        mesh: Any = None,
        *,
        traced: bool = False,
    ) -> CompiledSchedule:
        op = get_operator(wire_op_name(desc.operation))
        algo = desc.algo_type
        coll = desc.coll_type
        p = int(desc.comm_size)
        root = int(desc.root)
        if coll == CollType.REDUCE and not 0 <= root < p:
            raise ValueError(
                f"REDUCE root={root} out of range for comm_size={p}"
            )

        if len(desc.axes) > 1:
            fn, bname = self._build_planned(
                desc, op, axis_name, plan=self._plans.get(key),
                traced=traced,
            )
            algo = f"plan{desc.split}:{algo}"
            if desc.optimized:
                algo = f"opt:{algo}"
            if desc.chunks > 1:
                algo = f"chunk{desc.chunks}:{algo}"
            if bname is not None:
                # only non-default backends tag the schedule, so the algo
                # strings pre-registry callers assert on are unchanged
                algo = f"{bname}:{algo}"
            if traced:
                algo = f"traced:{algo}"
        elif axis_name is not None:
            one = axis_name
            if not isinstance(one, str):
                if len(one) != 1:
                    raise ValueError(
                        f"descriptor has no multi-axis topology; pass one "
                        f"mesh axis name, not {one!r}"
                    )
                (one,) = one
            fn = self._build_spmd(coll, op, algo, one, root)
        else:
            fn = jax.jit(self._build_sim(coll, op, algo, p, root))
        if mesh is not None:
            fn = self._build_driver(desc, fn, axis_name, mesh)
        return CompiledSchedule(
            key=key,
            coll=coll.name.lower(),
            algo=algo,
            op_name=op.name,
            p=p,
            fn=fn,
        )

    @staticmethod
    def _build_driver(
        desc: CollectiveDescriptor,
        inner: Callable[[PyTree], PyTree],
        axis_name: AxisSpec,
        mesh: Any,
    ) -> Callable[[PyTree], PyTree]:
        """Wrap a spmd schedule closure in the engine's own shard_map + jit.

        The payload is the sim-mode stacked ``(p, ...)`` contract with the
        leading axis in *logical* rank order; the in/out spec shards it
        across the physical axes in the descriptor split's logical order
        (see ``sharding.specs.plan_spec``), so the stacked global array and
        the per-rank shards line up with zero data movement.
        """
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map

        names = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
        missing = [n for n in names if n not in mesh.axis_names]
        if missing:
            raise ValueError(
                f"axes {missing} not in mesh axes {mesh.axis_names}"
            )
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        expect = desc.axes if len(desc.axes) > 1 else (desc.comm_size,)
        for n, want in zip(names, expect):
            if int(sizes[n]) != int(want):
                raise ValueError(
                    f"descriptor axis size {want} != mesh axis "
                    f"{n!r} size {sizes[n]}"
                )
        if len(desc.axes) > 1:
            order = desc.split or tuple(range(len(desc.axes)))
            names_l = tuple(names[i] for i in order)
        else:
            names_l = names
        entry = names_l[0] if len(names_l) == 1 else names_l
        spec = P(entry)

        def body(xs: PyTree) -> PyTree:
            xs = jax.tree.map(lambda a: a[0], xs)
            out = inner(xs)
            return jax.tree.map(lambda a: jnp.asarray(a)[None], out)

        return jax.jit(
            shard_map(
                body,
                mesh=mesh,
                in_specs=(spec,),
                out_specs=spec,
                check_vma=False,
            )
        )

    def _build_planned(
        self,
        desc: CollectiveDescriptor,
        op: AssocOp,
        axis_name: AxisSpec,
        plan,
        traced: bool = False,
    ) -> "Tuple[Callable[[PyTree], PyTree], Optional[str]]":
        """Lower a multi-axis descriptor through the lowering-backend
        registry; returns ``(fn, backend_tag)`` where the tag is the
        resolved backend's name for non-defaults and ``None`` when the mode
        default lowered the plan (the compiled algo string stays as-is).

        ``plan`` is the dispatch path's already-built (and, when the
        descriptor is flagged, pass-optimized) plan — ``offload`` stashes
        it under the cache key before compiling, so there is exactly one
        place plans are constructed (:meth:`_plan_for`). ``traced`` builds
        the *eager* span-emitting sim interpreter (never jitted: its whole
        point is measuring per-round host time).
        """
        from repro.offload import backends

        if plan is None:
            raise ValueError(
                "planned compile without a stashed plan; dispatch through "
                "offload(), which builds it via _plan_for"
            )
        if axis_name is not None and (
            isinstance(axis_name, str) or len(axis_name) != len(desc.axes)
        ):
            raise ValueError(
                f"planned descriptor spans axes {desc.axes}; pass one mesh "
                f"axis name per axis (got {axis_name!r})"
            )
        bname, _ = self._resolve_backend(desc, plan, axis_name)
        backend = backends.get_backend(bname)
        tag = (
            bname
            if bname != backends.default_backend_name(axis_name)
            else None
        )
        if axis_name is None:
            fn = backend.lower(plan, op, traced=traced)
            # the traced interpreters are eager on purpose
            return (fn if traced else jax.jit(fn)), tag
        return backend.lower(plan, op, axis_names=tuple(axis_name)), tag

    @staticmethod
    def _build_sim(
        coll: CollType, op: AssocOp, algo: str, p: int, root: int
    ) -> Callable[[PyTree], PyTree]:
        if coll == CollType.SCAN:
            return lambda x: sim_scan(x, op, p, algorithm=algo, inclusive=True)
        if coll == CollType.EXSCAN:
            return lambda x: sim_scan(
                x, op, p, algorithm=algo, inclusive=False
            )
        if coll == CollType.REDUCE:
            return lambda x: reduce_schedule(
                alg.SimBackend(p), x, op, root=root, algorithm=algo
            )
        if coll == CollType.ALLREDUCE:
            return lambda x: allreduce_schedule(
                alg.SimBackend(p), x, op, algorithm=algo
            )
        if coll == CollType.BARRIER:
            return lambda _x: barrier_schedule(alg.SimBackend(p), algorithm=algo)
        raise ValueError(f"unknown coll_type {coll!r}")

    @staticmethod
    def _build_spmd(
        coll: CollType, op: AssocOp, algo: str, axis_name: str, root: int
    ) -> Callable[[PyTree], PyTree]:
        if coll == CollType.SCAN:
            return lambda x: dist_scan(x, op, axis_name, algorithm=algo)
        if coll == CollType.EXSCAN:
            return lambda x: dist_exscan(x, op, axis_name, algorithm=algo)
        if coll == CollType.REDUCE:
            return lambda x: reduce_schedule(
                alg.SpmdBackend(axis_name), x, op, root=root, algorithm=algo
            )
        if coll == CollType.ALLREDUCE:
            return lambda x: allreduce_schedule(
                alg.SpmdBackend(axis_name), x, op, algorithm=algo
            )
        if coll == CollType.BARRIER:
            return lambda _x: barrier_schedule(
                alg.SpmdBackend(axis_name), algorithm=algo
            )
        raise ValueError(f"unknown coll_type {coll!r}")
