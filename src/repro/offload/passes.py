"""Plan-optimizer pass pipeline: runs between ``build_plan`` and lowering.

The paper's NetFPGA wins because the NIC folds the scan's combine, forward,
and total steps into ONE pass over the wire instead of issuing separate
host-driven rounds; Traeff's round-efficient Exscan analysis says the latency
term is dominated by round count, and sPIN argues offload pipelines should
fuse streaming stages rather than ping-pong to the host. This module applies
that lesson to the :class:`~repro.offload.planner.CollectivePlan` IR, which
``build_plan`` emits as independent per-axis phases that each pay a full
round and re-derive the same permute chains:

  * :func:`fuse_scan_total` — **SCAN+TOTAL fusion.** For non-windowed
    associative operators the scan's last-rank value *is* the axis total, so
    an intra-axis SCAN phase followed by a TOTAL on the same axis and the
    same input register collapses into one ``FUSED_SCAN_TOTAL`` phase that
    emits both registers from a single communication schedule
    (:func:`repro.core.algorithms.scan_total_schedule`,
    ``ceil(log2 p) + 1`` rounds instead of ``2*ceil(log2 p)``).
  * :func:`eliminate_dead_phases` — **dead-phase elimination.** Phases
    spanning size-1 logical axes are no-ops (a scan, total, reduce, or
    barrier over one rank returns its input; an exclusive scan returns the
    operator identity); they are removed by rewriting the register dataflow
    (aliases + identity tracking), COMBINE phases whose carry is a known
    identity or whose guard covers only size-1 levels fold away, and a
    backward liveness sweep drops phases whose outputs nothing consumes
    (redundant barriers included).
  * **Permute elimination** is a *flag*, not a phase rewrite:
    ``optimize_plan`` marks the plan ``optimized=True`` and the sim
    interpreter (:func:`~repro.offload.planner.lower_sim`) threads register
    layouts through consecutive phases — the shared logical<->physical
    permute chain is computed once per plan, not once per phase, with
    COMBINE operands normalized back to the natural mesh order because the
    guard mask is built over the un-permuted logical mesh (the
    COMBINE-aware dataflow check). ``lower_spmd`` needs no permutes at all
    (named axes), so the flag is a no-op there by construction.

Every pass is semantics-preserving: the optimized plan is bitwise-equal to
the unfused plan for every CollType and axis order given exact arithmetic
(hypothesis-tested in ``tests/test_passes.py``, SPMD-checked on the CI
mesh). :func:`plan_cost` prices the fused form, so
:func:`choose_optimization` (and through it ``make_descriptor``'s
``optimize="auto"``) picks fused vs. unfused per measured fusion winner
first, cost model second.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Set, Tuple

from repro.core.algorithms import (
    DOUBLING_ALGORITHMS,
    algorithm_step_count,
    num_steps,
    scan_total_step_count,
)
from repro.core.operators import AssocOp, get_operator
from repro.core.packet import CollType
from repro.core.selector import get_active_tuning
from repro.offload.planner import (
    CollectivePlan,
    PhaseKind,
    PlanPhase,
    build_plan,
    plan_cost,
)

#: the pipeline, in application order (chunk_selection needs the request's
#: payload size, so it only runs when ``optimize_plan`` is given one)
PASS_NAMES: Tuple[str, ...] = (
    "dead_phase_elimination",
    "scan_total_fusion",
    "permute_threading",
    "chunk_selection",
)

#: chunk counts the selection pass prices and the tuner measures
CHUNK_CANDIDATES: Tuple[int, ...] = (1, 2, 4, 8)

#: algorithm tag rendered for fused phases (not a per-step schedule name —
#: the fused lowering dispatches on the phase kind)
FUSED_ALGORITHM = "fused_doubling"


# ---------------------------------------------------------------------------
# Dead-phase elimination (size-1 axes, identity carries, dead registers)
# ---------------------------------------------------------------------------


def eliminate_dead_phases(plan: CollectivePlan) -> CollectivePlan:
    """Drop phases that provably compute nothing, rewriting dataflow.

    Forward walk: phases over size-1 logical axes alias their output to
    their input (or mark it as the operator identity, for exclusive scans);
    COMBINE folds away when its carry is a known identity or every guarded
    level has size 1 (the guard mask is then all-True, i.e. "keep local"
    everywhere). Backward walk: liveness from the plan result removes
    phases whose outputs are never consumed — which is also what deletes
    the axis-total feeding a carry ladder that itself dissolved.
    """
    logical = plan.logical_sizes
    alias: Dict[str, str] = {}
    identity_regs: Set[str] = set()
    out: list = []

    def res(name: str) -> str:
        while name in alias:
            name = alias[name]
        return name

    def define(name: str) -> None:
        alias.pop(name, None)
        identity_regs.discard(name)

    for ph in plan.phases:
        src = tuple(res(s) for s in ph.src)
        if ph.kind == PhaseKind.COMBINE:
            carry, local = src
            guards = tuple(lv for lv in ph.guard_levels if logical[lv] > 1)
            if carry in identity_regs or (ph.guard_levels and not guards):
                # an empty carry (or an all-True guard) keeps local verbatim;
                # when dst already IS the local register the fold is a pure
                # no-op (its value — identity marker included — survives)
                if local != ph.dst:
                    define(ph.dst)
                    alias[ph.dst] = local
                continue
            if local in identity_regs:
                # the local side dissolved (exclusive scan over a size-1
                # level): materialize the identity so the guard still
                # selects between it and the carry
                out.append(
                    PlanPhase(PhaseKind.IDENTITY, -1, src=("x",), dst=local)
                )
                identity_regs.discard(local)
            define(ph.dst)
            out.append(
                dataclasses.replace(ph, src=src, guard_levels=guards)
            )
            continue
        if ph.kind == PhaseKind.IDENTITY:
            define(ph.dst)
            identity_regs.add(ph.dst)
            continue
        p_axis = logical[ph.level]
        if p_axis <= 1:
            # one rank along this level: the phase is the identity map
            # (exclusive scans yield the operator identity instead)
            if ph.kind == PhaseKind.FUSED_SCAN_TOTAL and src[0] != ph.dst2:
                define(ph.dst2)
                alias[ph.dst2] = src[0]
            if ph.kind in (
                PhaseKind.SCAN, PhaseKind.FUSED_SCAN_TOTAL
            ) and not ph.inclusive:
                define(ph.dst)
                identity_regs.add(ph.dst)
            elif src[0] != ph.dst:
                define(ph.dst)
                alias[ph.dst] = src[0]
            # else: an in-place no-op — the register (and any identity
            # marker it carries) is untouched
            continue
        if src[0] in identity_regs:
            # a kept communication phase consuming a known identity: keep
            # correctness by materializing it (build_plan never produces
            # this shape; re-optimized plans defensively might)
            out.append(
                PlanPhase(PhaseKind.IDENTITY, -1, src=("x",), dst=src[0])
            )
            identity_regs.discard(src[0])
        define(ph.dst)
        if ph.kind == PhaseKind.FUSED_SCAN_TOTAL:
            define(ph.dst2)
        out.append(dataclasses.replace(ph, src=src))

    result = res(plan.result)
    if result in identity_regs:
        out.append(PlanPhase(PhaseKind.IDENTITY, -1, src=("x",), dst=result))

    # backward liveness: drop phases no consumer (or the result) reads
    live: Set[str] = {result}
    kept: list = []
    for ph in reversed(out):
        defs = {ph.dst}
        if ph.kind == PhaseKind.FUSED_SCAN_TOTAL:
            defs.add(ph.dst2)
        if not defs & live:
            continue
        if ph.kind == PhaseKind.FUSED_SCAN_TOTAL and ph.dst not in live:
            # only the total output is consumed: demote to a plain TOTAL
            ph = PlanPhase(
                PhaseKind.TOTAL, ph.level, "recursive_doubling",
                src=ph.src, dst=ph.dst2,
            )
        elif ph.kind == PhaseKind.FUSED_SCAN_TOTAL and ph.dst2 not in live:
            ph = PlanPhase(
                PhaseKind.SCAN, ph.level, "hillis_steele",
                inclusive=ph.inclusive, src=ph.src, dst=ph.dst,
            )
        live.discard(ph.dst)
        live.update(ph.src)
        if ph.kind == PhaseKind.FUSED_SCAN_TOTAL:
            live.discard(ph.dst2)
            live.update(ph.src)
        kept.append(ph)
    kept.reverse()
    return dataclasses.replace(plan, phases=tuple(kept), result=result)


# ---------------------------------------------------------------------------
# SCAN+TOTAL fusion
# ---------------------------------------------------------------------------


def fuse_scan_total(plan: CollectivePlan) -> CollectivePlan:
    """Fuse each adjacent (SCAN, TOTAL) pair on one axis and one input.

    The pair pattern is exactly what ``build_plan`` emits for SCAN/EXSCAN
    at every ladder level: an intra-axis scan of register ``r`` directly
    followed by the order-respecting total of the same ``r`` along the same
    level. Both outputs then come from one
    :func:`~repro.core.algorithms.scan_total_schedule` run. The dataflow
    check is structural: fusion requires the total to read the *same*
    register the scan read (never the scan's output), so reordering
    hazards cannot arise.
    """
    phases = plan.phases
    out: list = []
    i = 0
    while i < len(phases):
        ph = phases[i]
        if ph.kind == PhaseKind.SCAN and i + 1 < len(phases):
            nxt = phases[i + 1]
            if (
                nxt.kind == PhaseKind.TOTAL
                and nxt.level == ph.level
                and nxt.src == ph.src
                and ph.dst not in nxt.src
            ):
                out.append(
                    PlanPhase(
                        PhaseKind.FUSED_SCAN_TOTAL,
                        ph.level,
                        FUSED_ALGORITHM,
                        inclusive=ph.inclusive,
                        src=ph.src,
                        dst=ph.dst,
                        dst2=nxt.dst,
                    )
                )
                i += 2
                continue
        out.append(ph)
        i += 1
    return dataclasses.replace(plan, phases=tuple(out))


# ---------------------------------------------------------------------------
# Chunk selection
# ---------------------------------------------------------------------------


def _has_pipelined_phase(plan: CollectivePlan) -> bool:
    """Does any phase have a round-pipelined chunked form worth pricing?"""
    logical = plan.logical_sizes
    for ph in plan.phases:
        if ph.kind == PhaseKind.FUSED_SCAN_TOTAL and logical[ph.level] > 1:
            return True
        if (
            ph.kind == PhaseKind.SCAN
            and ph.algorithm in DOUBLING_ALGORITHMS
            and logical[ph.level] > 1
        ):
            return True
    return False


def select_chunking(
    plan: CollectivePlan,
    payload_bytes: int,
    *,
    candidates: Sequence[int] = CHUNK_CANDIDATES,
) -> CollectivePlan:
    """Pick the cheapest chunk count for one plan under the active cost
    model — the chunk-selection pass.

    Each candidate C prices the pipelined phases as ``(R + C - 1) *
    (alpha + B*beta/C)`` (see :func:`~repro.offload.planner.plan_cost`), so
    C > 1 only wins above the payload threshold where the serialized link
    term outweighs the extra pipeline-fill alphas; ties keep the smaller C
    (C=1 is the exact legacy lowering, byte-stable on the wire). Plans with
    no pipelined phase (pure reductions, non-doubling scan algorithms) stay
    at C=1 unconditionally.
    """
    if not _has_pipelined_phase(plan):
        return plan if plan.chunking == 1 else dataclasses.replace(
            plan, chunking=1
        )
    best: Optional[Tuple[float, int]] = None
    for c in sorted({max(1, int(c)) for c in candidates}):
        cand = dataclasses.replace(plan, chunking=c)
        key = (plan_cost(cand, payload_bytes), c)
        if best is None or key < best:
            best = key
    chosen = best[1]
    if chosen == plan.chunking:
        return plan
    return dataclasses.replace(plan, chunking=chosen)


# ---------------------------------------------------------------------------
# The pipeline
# ---------------------------------------------------------------------------


def optimize_plan(
    plan: CollectivePlan,
    *,
    passes: Sequence[str] = PASS_NAMES,
    payload_bytes: Optional[int] = None,
) -> CollectivePlan:
    """Run the pass pipeline over one plan; idempotent.

    ``passes`` subsets :data:`PASS_NAMES` (unknown names raise). The
    returned plan carries ``optimized=True``, which (a) switches
    ``lower_sim`` to the layout-threading interpreter (permute
    elimination) and (b) marks the wire flag ``make_descriptor`` encodes so
    brokered and cached dispatches agree on whether passes ran.
    ``chunk_selection`` needs the request's payload size to price the
    pipeline, so it only runs when ``payload_bytes`` is given.
    """
    unknown = set(passes) - set(PASS_NAMES)
    if unknown:
        raise ValueError(
            f"unknown passes {sorted(unknown)}; known: {list(PASS_NAMES)}"
        )
    if "dead_phase_elimination" in passes:
        plan = eliminate_dead_phases(plan)
    if "scan_total_fusion" in passes:
        plan = fuse_scan_total(plan)
    if "permute_threading" in passes and not plan.optimized:
        plan = dataclasses.replace(plan, optimized=True)
    if "chunk_selection" in passes and payload_bytes is not None:
        plan = select_chunking(plan, payload_bytes)
    return plan


# ---------------------------------------------------------------------------
# Round accounting and the fused-vs-unfused decision
# ---------------------------------------------------------------------------


def plan_comm_rounds(plan: CollectivePlan) -> int:
    """Communication rounds (permute steps on the critical path) of a plan.

    This is the quantity the paper's offload collapses and the number
    ``BENCH_fusion.json`` reports: COMBINE/IDENTITY phases are local (zero
    rounds); an exclusive scan pays its structural shift unless the
    inverse-op trick applies; allreduce-shaped phases (TOTAL/BARRIER) run
    the butterfly at power-of-two sizes and scan+broadcast otherwise; a
    REDUCE pays one root-relocation hop when the root is not rank p-1.
    """
    op = get_operator(plan.op_name)
    logical = plan.logical_sizes
    rounds = 0
    for ph in plan.phases:
        if ph.kind in (PhaseKind.COMBINE, PhaseKind.IDENTITY):
            continue
        p = logical[ph.level]
        if p <= 1:
            continue
        if ph.kind == PhaseKind.SCAN:
            r = algorithm_step_count(ph.algorithm, p)
            if not ph.inclusive and not (
                ph.algorithm == "invertible_doubling"
                and op.inverse is not None
                and op.commutative
            ):
                r += 1
        elif ph.kind == PhaseKind.FUSED_SCAN_TOTAL:
            r = scan_total_step_count(p)
        elif ph.kind in (PhaseKind.TOTAL, PhaseKind.BARRIER):
            r = (
                num_steps(p)
                if p & (p - 1) == 0
                else algorithm_step_count(ph.algorithm, p) + 1
            )
        elif ph.kind == PhaseKind.REDUCE:
            r = algorithm_step_count(ph.algorithm, p)
            if ph.root != p - 1:
                r += 1
        else:  # pragma: no cover - exhaustive
            raise ValueError(f"unknown phase kind {ph.kind!r}")
        rounds += r
    return rounds


def choose_optimization(
    coll: "CollType | str",
    sizes: Sequence[int],
    payload_bytes: int,
    op: "AssocOp | str" = "sum",
) -> bool:
    """Should the pass pipeline run for this request? The ``optimize="auto"``
    resolution ``make_descriptor`` uses.

    Resolution mirrors the selector: a measured fusion winner from the
    active tuning table (``TuningCache.fusion_winner``) rules when one
    exists for this (coll, sizes) at a nearby payload; otherwise the
    optimized and raw plans are priced with :func:`plan_cost` and the
    optimized form wins ties (it never adds rounds). A plan the passes
    cannot change at all reports False, so the wire flag stays meaningful.
    """
    if isinstance(coll, str):
        coll = CollType[coll.upper()]
    op = get_operator(op)
    sizes = tuple(int(s) for s in sizes)

    tuning = get_active_tuning()
    if tuning is not None:
        winner = getattr(tuning, "fusion_winner", lambda *a, **k: None)(
            coll.name.lower(), sizes, payload_bytes
        )
        if winner is not None:
            return bool(winner)

    raw = build_plan(coll, sizes, op, payload_bytes, order="auto")
    opt = optimize_plan(raw)
    if opt.phases == raw.phases:
        return False
    return plan_cost(opt, payload_bytes) <= plan_cost(raw, payload_bytes)


def choose_schedule(
    coll: "CollType | str",
    sizes: Sequence[int],
    payload_bytes: int,
    op: "AssocOp | str" = "sum",
) -> Tuple[bool, int]:
    """The full (optimize?, chunk count) schedule decision for one request
    — what ``make_descriptor``'s ``optimize="auto"`` / ``chunks="auto"``
    resolves through.

    Resolution mirrors the selector: a measured schedule winner from the
    active tuning table (``TuningCache.schedule_winner``, written by
    ``tune_schedule``) rules when one exists for this (coll, sizes) at a
    nearby payload; otherwise the pass pipeline's own cost pricing decides
    both halves (fusion via the fused-vs-raw comparison, chunking via
    :func:`select_chunking` on whichever form won).
    """
    if isinstance(coll, str):
        coll = CollType[coll.upper()]
    op = get_operator(op)
    sizes = tuple(int(s) for s in sizes)

    tuning = get_active_tuning()
    if tuning is not None:
        winner = getattr(tuning, "schedule_winner", lambda *a, **k: None)(
            coll.name.lower(), sizes, payload_bytes
        )
        if winner is not None:
            return bool(winner[0]), max(1, int(winner[1]))

    raw = build_plan(coll, sizes, op, payload_bytes, order="auto")
    opt = optimize_plan(raw, payload_bytes=payload_bytes)
    if opt.phases != raw.phases and plan_cost(
        opt, payload_bytes
    ) <= plan_cost(raw, payload_bytes):
        return True, opt.chunking
    return False, select_chunking(raw, payload_bytes).chunking


def choose_backend(
    coll: "CollType | str",
    sizes: Sequence[int],
    payload_bytes: int,
    op: "AssocOp | str" = "sum",
) -> str:
    """Which lowering backend should lower this request? The
    ``backend="auto"`` resolution ``make_descriptor`` uses.

    Purely measurement-driven: a backend winner recorded by
    ``tune_schedule`` in the active tuning table
    (``TuningCache.backend_winner``) rules when one exists for this
    (coll, sizes) at a nearby payload; untuned requests return the mode
    default ("", wire backend id 0) — there is no cost model for the fused
    kernel, so nothing speculative ever changes a descriptor's bytes. A
    measured winner still goes through the registry's capability check at
    compile time (soft fallback), so a stale table cannot break dispatch.
    """
    if isinstance(coll, str):
        coll = CollType[coll.upper()]
    sizes = tuple(int(s) for s in sizes)

    tuning = get_active_tuning()
    if tuning is not None:
        winner = getattr(tuning, "backend_winner", lambda *a, **k: None)(
            coll.name.lower(), sizes, payload_bytes
        )
        if winner is not None:
            return str(winner)
    return ""


__all__ = [
    "CHUNK_CANDIDATES",
    "FUSED_ALGORITHM",
    "PASS_NAMES",
    "choose_backend",
    "choose_optimization",
    "choose_schedule",
    "eliminate_dead_phases",
    "fuse_scan_total",
    "optimize_plan",
    "plan_comm_rounds",
    "select_chunking",
]
