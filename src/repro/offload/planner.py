"""Topology-aware collective planner: N-level decomposition for every CollType.

The paper's NetFPGA ran one collective over one 8-host ring; the host runtime
made an "intelligent selection" of the per-ring algorithm. At pod scale the
runtime must select the *decomposition* too: which mesh axis each phase spans,
in which order, and which schedule runs on each axis. Following sPIN's lesson
that offload engines generalize when the schedule is a compiled *plan* rather
than a hardcoded pipeline, this module owns the phase structure for all five
descriptor CollTypes over 1D, 2D, and 3D (pod-axis) meshes:

  * :class:`CollectivePlan` — the IR: a tuple of :class:`PlanPhase` records
    (intra-axis scan, carry exscan, order-respecting axis total, tree reduce,
    barrier, guarded local combine) over *logical levels* (level 0 outermost
    in global rank order, the last level innermost), plus the chosen mapping
    of logical levels onto physical mesh axes (the ``split``).
  * :func:`build_plan` — builds the phase list for any CollType x mesh shape.
    SCAN/EXSCAN use the N-level block-scan recursion (intra scan, axis totals,
    carry exscan over the outer levels — where Traeff's Exscan analysis says
    naive decompositions waste rounds — and a guarded combine); REDUCE runs a
    per-axis tree reduction to the root's coordinates; ALLREDUCE chains
    order-respecting axis totals innermost-first (correct for non-commutative
    operators); BARRIER fences every axis.
  * :func:`plan_axis_order` — the tuned split: consults the active
    :class:`~repro.offload.tuning_cache.TuningCache` (measured split winners
    first, then the least-squares-fitted LinkModel via ``fitted_model()``)
    and falls back to the static TPU constants; per-phase algorithms come
    from :func:`~repro.core.selector.select_algorithm` with the *real* coll
    kind of each phase, never a flat per-axis "auto".
  * :func:`lower_sim` / :func:`lower_spmd` — lower one plan through both
    backends: stacked ``(p, ...)`` arrays on one device, or named mesh axes
    inside ``shard_map``. Both interpret the identical phase list, so the
    sim path is a bit-accurate rehearsal of the SPMD program. These two are
    the *mode-default* entries of the lowering-backend registry
    (:mod:`repro.offload.backends`); the engine resolves every planned
    dispatch through that registry, which also hosts the fused-Pallas-kernel
    lowering (:mod:`repro.kernels.pallas_collective`).

Plans are wire-representable: ``OffloadEngine.make_descriptor(axes=...)``
encodes (axes, split) into the descriptor, so multi-axis plans cache-key and
round-trip like every other offload request.
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import algorithms as alg
from repro.core.operators import MAX, AssocOp, get_operator
from repro.core.packet import MAX_AXES, CollType
from repro.core.reduce_ops import allreduce_schedule, reduce_schedule
from repro.core.scan_collective import dist_exscan, dist_scan, sim_scan
from repro.core.selector import (
    TPU_V5E,
    LinkModel,
    estimate_cost,
    get_active_tuning,
    select_algorithm,
)

PyTree = Any


class PhaseKind(enum.IntEnum):
    """What one plan phase does. All but COMBINE/IDENTITY span one axis."""

    SCAN = 0      # intra-axis prefix (inclusive or exclusive)
    TOTAL = 1     # order-respecting allreduce along the axis (block totals)
    REDUCE = 2    # tree reduction to a root coordinate along the axis
    BARRIER = 3   # zero-payload fence along the axis
    COMBINE = 4   # local fold of a carry into a prefix, guarded at level 0
    FUSED_SCAN_TOTAL = 5  # scan AND axis total from one schedule (passes)
    IDENTITY = 6  # local: materialize the operator identity (passes)


# coll kind each phase kind tunes against in the measured tables
_PHASE_COLL = {
    PhaseKind.TOTAL: "allreduce",
    PhaseKind.REDUCE: "reduce",
    PhaseKind.BARRIER: "barrier",
}


@dataclasses.dataclass(frozen=True)
class PlanPhase:
    """One step of a CollectivePlan.

    ``level`` indexes the *logical* axis the phase spans (COMBINE and
    IDENTITY are local: level is -1). ``src``/``dst`` name registers of the
    plan interpreter; COMBINE reads ``src = (carry, local)`` and keeps
    ``local`` unchanged on ranks whose coordinates are zero along every
    level in ``guard_levels`` (the ranks whose carry is empty).
    FUSED_SCAN_TOTAL writes two registers: ``dst`` receives the scan and
    ``dst2`` the axis total, both from one communication schedule.
    """

    kind: PhaseKind
    level: int
    algorithm: str = "hillis_steele"
    inclusive: bool = True
    root: int = 0
    src: Tuple[str, ...] = ("x",)
    dst: str = "y"
    dst2: str = ""
    guard_levels: Tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class CollectivePlan:
    """The planner IR: phases + the logical-to-physical axis mapping.

    ``sizes`` are the physical mesh-axis sizes (outermost-first, as the
    descriptor carries them); ``order[i]`` is the physical axis placed at
    logical level ``i``. ``logical_sizes`` is therefore the shape the flat
    rank range factors into, outermost level first.
    """

    coll: CollType
    op_name: str
    sizes: Tuple[int, ...]
    order: Tuple[int, ...]
    phases: Tuple[PlanPhase, ...]
    result: str = "y"
    optimized: bool = False
    #: payload chunk count. 1 = the classic whole-payload schedule (the
    #: lowerings take the exact legacy code path). C > 1 splits the payload
    #: into C contiguous chunks along its innermost dim and pipelines them
    #: across exchange rounds (sPIN-style streaming); values are bitwise
    #: identical, only the round interleave changes.
    chunking: int = 1

    @property
    def logical_sizes(self) -> Tuple[int, ...]:
        return tuple(self.sizes[i] for i in self.order)

    @property
    def p(self) -> int:
        return math.prod(self.sizes)

    def describe(self) -> str:
        """One line per phase — the plan's schedule_trace analogue.

        Optimized plans render their fused phases and ONE permute-chain line
        for the whole plan (the layout moves the threaded interpreter makes)
        instead of the implicit per-phase to-front/to-back pair, which is
        what keeps ``planner_check`` output readable after the pass
        pipeline has rewritten the phase list.
        """
        header = (
            f"{self.coll.name} over {self.sizes} split={self.order} "
            f"(logical {self.logical_sizes})"
        )
        if self.optimized:
            header += " [optimized]"
        if self.chunking > 1:
            header += f" [chunked x{self.chunking}]"
        lines = [header]
        for ph in self.phases:
            if ph.kind == PhaseKind.COMBINE:
                lines.append(
                    f"  combine {ph.src[0]} into {ph.src[1]} -> {ph.dst} "
                    f"(guard levels {ph.guard_levels})"
                )
            elif ph.kind == PhaseKind.IDENTITY:
                lines.append(f"  identity {ph.src[0]} -> {ph.dst} (local)")
            elif ph.kind == PhaseKind.FUSED_SCAN_TOTAL:
                extra = "" if ph.inclusive else " exclusive"
                lines.append(
                    f"  fused_scan_total{extra} level {ph.level} "
                    f"(p={self.logical_sizes[ph.level]}) [{ph.algorithm}] "
                    f"{ph.src[0]} -> {ph.dst}, {ph.dst2}"
                )
            else:
                extra = "" if ph.inclusive else " exclusive"
                lines.append(
                    f"  {ph.kind.name.lower()}{extra} level {ph.level} "
                    f"(p={self.logical_sizes[ph.level]}) "
                    f"[{ph.algorithm}] {ph.src[0]} -> {ph.dst}"
                )
        if self.optimized:
            moves = plan_layout_moves(self)
            chain = (
                " -> ".join(
                    f"{reg}@{'nat' if lv is None else f'L{lv}'}"
                    for reg, lv in moves
                )
                if moves
                else "(none)"
            )
            lines.append(
                f"  permute chain (once per plan, {len(moves)} moves): "
                f"{chain}"
            )
        return "\n".join(lines)


def plan_layout_moves(plan: "CollectivePlan") -> Tuple[Tuple[str, Any], ...]:
    """The per-plan permute chain: each ``(register, level)`` is one
    ``moveaxis`` the threaded sim interpreter performs (``level`` is the
    logical level moved to the front; ``None`` is the natural mesh order —
    a fronted-to-fronted conversion goes via natural, so it renders as two
    entries, exactly mirroring ``lower_sim``'s ``get_reg``).

    The unoptimized interpreter fronts every phase operand and moves every
    output straight back — one move per input plus one per output, always.
    The optimized interpreter (``plan.optimized``) keeps each register in
    its produced layout and converts lazily, *memoizing every view*, so a
    register consumed twice in one layout pays its conversion once: the
    shared logical<->physical permute chain is computed once per plan, not
    once per phase. This function is the exact static form of that
    bookkeeping, used by :meth:`CollectivePlan.describe` and the
    pass-pipeline tests (for plans with ``optimized=False`` it reports the
    per-phase front-and-back chain instead).
    """
    moves: list = []
    views: Dict[str, set] = {}

    def define(name: str, layout) -> None:
        views[name] = {layout}

    def fetch(name: str, want) -> None:
        have = views.setdefault(name, {None})
        if want in have:
            return
        if None not in have:
            moves.append((name, None))
            have.add(None)
        if want is not None:
            moves.append((name, want))
            have.add(want)

    for ph in plan.phases:
        if ph.kind == PhaseKind.COMBINE:
            fetch(ph.src[0], None)
            fetch(ph.src[1], None)
            define(ph.dst, None)
        elif ph.kind == PhaseKind.IDENTITY:
            fetch(ph.src[0], None)
            define(ph.dst, None)
        elif plan.optimized:
            fetch(ph.src[0], ph.level)
            define(ph.dst, ph.level)
            if ph.kind == PhaseKind.FUSED_SCAN_TOTAL:
                define(ph.dst2, ph.level)
        else:
            # _along_axis fronts the operand and moves every output back
            # to natural immediately, with no view sharing
            moves.append((ph.src[0], ph.level))
            moves.append((ph.dst, None))
            define(ph.dst, None)
            if ph.kind == PhaseKind.FUSED_SCAN_TOTAL:
                moves.append((ph.dst2, None))
                define(ph.dst2, None)
    fetch(plan.result, None)
    return tuple(moves)


@dataclasses.dataclass(frozen=True)
class PlanLayout:
    """The logical<->physical data layout a plan's split implies.

    A non-identity split changes global rank order: logical level ``i`` runs
    over physical axis ``order[i]``, so the flat rank that owns block ``r`` of
    a logical-rank-ordered payload is *not* ``r``. This object owns the two
    flat permutations (as reshape/transpose, exact for any payload dims) so
    callers never hand-derive the transpose again:

      * :meth:`to_physical` — logical-rank-ordered leading axis -> physical
        (lex over the physical mesh axes, outermost first);
      * :meth:`to_logical` — the inverse;
      * :meth:`spec_axes` — the physical axis *names* in logical order, the
        input to :func:`repro.sharding.specs.plan_spec` (shard a logical
        array with that spec and no data movement is needed at all).
    """

    sizes: Tuple[int, ...]
    order: Tuple[int, ...]

    def __post_init__(self):
        if sorted(self.order) != list(range(len(self.sizes))):
            raise ValueError(
                f"order {self.order!r} is not a permutation of "
                f"range({len(self.sizes)})"
            )

    @property
    def logical_sizes(self) -> Tuple[int, ...]:
        return tuple(self.sizes[i] for i in self.order)

    @property
    def inverse(self) -> Tuple[int, ...]:
        """``inverse[physical_axis] = logical_level`` (the transpose axes)."""
        inv = [0] * len(self.order)
        for level, axis in enumerate(self.order):
            inv[axis] = level
        return tuple(inv)

    @property
    def p(self) -> int:
        return math.prod(self.sizes)

    def spec_axes(self, axis_names: Sequence[str]) -> Tuple[str, ...]:
        """Physical mesh-axis names reordered to logical (split) order."""
        if len(axis_names) != len(self.sizes):
            raise ValueError(
                f"layout spans {len(self.sizes)} axes; got names {axis_names}"
            )
        return tuple(axis_names[i] for i in self.order)

    def _permute(self, x, from_sizes, axes):
        k = len(self.sizes)
        xp = np if isinstance(x, np.ndarray) else jnp
        lead = x.shape[1:]
        arr = xp.reshape(x, tuple(from_sizes) + lead)
        arr = xp.transpose(arr, tuple(axes) + tuple(range(k, k + len(lead))))
        return xp.reshape(arr, (self.p,) + lead)

    def to_physical(self, x):
        """Logical-rank-ordered leading axis -> physical rank order."""
        return self._permute(x, self.logical_sizes, self.inverse)

    def to_logical(self, x):
        """Physical-rank-ordered leading axis -> logical rank order."""
        return self._permute(x, self.sizes, self.order)

    def permutation(self) -> np.ndarray:
        """``perm[physical_rank] = logical_rank`` as a flat index vector."""
        return np.asarray(
            self.to_physical(np.arange(self.p, dtype=np.int64))
        )


def plan_layout(plan) -> PlanLayout:
    """Layout for anything carrying a split: a :class:`CollectivePlan`
    (``sizes``/``order``) or an encoded-topology descriptor (``axes``/
    ``split`` — an empty split means the identity order)."""
    sizes = getattr(plan, "sizes", None)
    if sizes is None:
        sizes = getattr(plan, "axes", None)
    if not sizes:
        raise ValueError(f"{plan!r} carries no multi-axis topology")
    sizes = tuple(int(s) for s in sizes)
    order = getattr(plan, "order", None)
    if order is None:
        order = getattr(plan, "split", None)
    order = tuple(int(i) for i in order) if order else tuple(range(len(sizes)))
    return PlanLayout(sizes=sizes, order=order)


# ---------------------------------------------------------------------------
# Plan construction
# ---------------------------------------------------------------------------


def _phase_algorithm(
    kind: PhaseKind,
    inclusive: bool,
    p_axis: int,
    payload_bytes: int,
    op: AssocOp,
    override: Optional[str],
) -> str:
    if override is not None and override != "auto":
        return override
    if kind == PhaseKind.SCAN:
        coll = "scan" if inclusive else "exscan"
    else:
        coll = _PHASE_COLL[kind]
    if kind == PhaseKind.BARRIER:
        # the fence runs MAX on a token regardless of the request's operator,
        # so applicability (e.g. invertible_doubling) is judged against MAX
        op, payload_bytes = MAX, 4
    return select_algorithm(p_axis, payload_bytes, op, coll=coll)


def _exscan_phases(
    levels: Sequence[int],
    src: str,
    out: str,
    tag: str,
    algo: Callable[[PhaseKind, bool, int], str],
) -> Tuple[PlanPhase, ...]:
    """Recursive exclusive scan of ``src`` over the flattened ``levels``
    (outermost..innermost) into register ``out`` — the carry ladder."""
    if len(levels) == 1:
        lv = levels[0]
        return (
            PlanPhase(
                PhaseKind.SCAN, lv, algo(PhaseKind.SCAN, False, lv),
                inclusive=False, src=(src,), dst=out,
            ),
        )
    inner = levels[-1]
    local = f"{tag}e{inner}"
    totals = f"{tag}t{inner}"
    carry = f"{tag}c{inner}"
    phases = (
        PlanPhase(
            PhaseKind.SCAN, inner, algo(PhaseKind.SCAN, False, inner),
            inclusive=False, src=(src,), dst=local,
        ),
        PlanPhase(
            PhaseKind.TOTAL, inner, algo(PhaseKind.TOTAL, True, inner),
            src=(src,), dst=totals,
        ),
    )
    phases += _exscan_phases(levels[:-1], totals, carry, tag + "o", algo)
    phases += (
        PlanPhase(
            PhaseKind.COMBINE, -1, src=(carry, local), dst=out,
            guard_levels=tuple(levels[:-1]),
        ),
    )
    return phases


def build_plan(
    coll: "CollType | str",
    sizes: Sequence[int],
    op: "AssocOp | str",
    payload_bytes: int,
    *,
    order: "str | Sequence[int]" = "auto",
    root: int = 0,
    inclusive: bool = True,
    level_algorithms: Optional[Sequence[Optional[str]]] = None,
    optimize: bool = False,
) -> CollectivePlan:
    """Build the N-level plan for one collective over one mesh shape.

    Args:
      coll: descriptor CollType (EXSCAN implies the exclusive scan form).
      sizes: physical mesh-axis sizes, outermost first (1-3 axes).
      op: operator (affects algorithm applicability, not phase structure).
      payload_bytes: per-rank payload, priced by the per-phase selector.
      order: "auto" for the tuned split, or an explicit permutation of
        ``range(len(sizes))`` mapping logical levels to physical axes.
      root: flat root rank (REDUCE only) — decomposed into per-level
        coordinates in logical rank order.
      level_algorithms: optional per-*logical-level* algorithm override
        (None or "auto" entries fall back to the selector); used by the
        legacy hierarchical wrappers.
      optimize: run the plan-optimizer pass pipeline
        (:func:`repro.offload.passes.optimize_plan`) over the built plan —
        SCAN+TOTAL fusion, dead-phase elimination, permute threading. With
        ``order="auto"`` the tuned split is also priced on optimized plans.
    """
    if isinstance(coll, str):
        coll = CollType[coll.upper()]
    op = get_operator(op)
    sizes = tuple(int(s) for s in sizes)
    if not 1 <= len(sizes) <= MAX_AXES:
        raise ValueError(f"need 1..{MAX_AXES} mesh axes, got {sizes}")
    if any(s < 1 for s in sizes):
        raise ValueError(f"axis sizes must be positive: {sizes}")
    if order == "auto":
        order = plan_axis_order(
            coll, sizes, payload_bytes, op, optimize=optimize
        )
    order = tuple(int(i) for i in order)
    if sorted(order) != list(range(len(sizes))):
        raise ValueError(
            f"order {order!r} is not a permutation of range({len(sizes)})"
        )
    logical = tuple(sizes[i] for i in order)
    k = len(logical)

    def algo(kind: PhaseKind, incl: bool, level: int) -> str:
        override = None
        if level_algorithms is not None:
            override = level_algorithms[level]
        return _phase_algorithm(
            kind, incl, logical[level], payload_bytes, op, override
        )

    if coll == CollType.EXSCAN:
        inclusive = False

    if coll in (CollType.SCAN, CollType.EXSCAN):
        innermost = k - 1
        phases: Tuple[PlanPhase, ...] = (
            PlanPhase(
                PhaseKind.SCAN, innermost,
                algo(PhaseKind.SCAN, inclusive, innermost),
                inclusive=inclusive, src=("x",), dst="y",
            ),
        )
        if k > 1:
            phases += (
                PlanPhase(
                    PhaseKind.TOTAL, innermost,
                    algo(PhaseKind.TOTAL, True, innermost),
                    src=("x",), dst="t",
                ),
            )
            phases += _exscan_phases(tuple(range(k - 1)), "t", "c", "", algo)
            phases += (
                PlanPhase(
                    PhaseKind.COMBINE, -1, src=("c", "y"), dst="y",
                    guard_levels=tuple(range(k - 1)),
                ),
            )
        result = "y"
    elif coll in (CollType.REDUCE, CollType.ALLREDUCE, CollType.BARRIER):
        # one phase per level, innermost first, chained through "y" — the
        # per-axis tree reduce / ordered total / fence all share this shape
        kind = {
            CollType.REDUCE: PhaseKind.REDUCE,
            CollType.ALLREDUCE: PhaseKind.TOTAL,
            CollType.BARRIER: PhaseKind.BARRIER,
        }[coll]
        coords = (0,) * k
        if coll == CollType.REDUCE:
            if not 0 <= root < math.prod(sizes):
                raise ValueError(f"root={root} out of range for mesh {sizes}")
            coords = _unflatten(root, logical)
        phases = ()
        src = "x"
        for level in range(k - 1, -1, -1):
            phases += (
                PlanPhase(
                    kind, level, algo(kind, True, level),
                    root=coords[level], src=(src,), dst="y",
                ),
            )
            src = "y"
        result = "y"
    else:
        raise ValueError(f"unknown coll_type {coll!r}")

    plan = CollectivePlan(
        coll=coll,
        op_name=op.name,
        sizes=sizes,
        order=order,
        phases=phases,
        result=result,
    )
    if optimize:
        from repro.offload.passes import optimize_plan

        plan = optimize_plan(plan, payload_bytes=payload_bytes)
    return plan


def _unflatten(rank: int, logical_sizes: Sequence[int]) -> Tuple[int, ...]:
    """Flat rank -> per-level coordinates in logical (lex) order."""
    coords = []
    rem = rank
    for s in reversed(logical_sizes):
        coords.append(rem % s)
        rem //= s
    return tuple(reversed(coords))


# ---------------------------------------------------------------------------
# Plan costing and the tuned axis split
# ---------------------------------------------------------------------------


def plan_cost(
    plan: CollectivePlan,
    payload_bytes: int,
    model: Optional[LinkModel] = None,
) -> float:
    """Predicted latency: sum of the per-phase alpha-beta-gamma estimates.

    COMBINE and IDENTITY phases are local (zero network cost); a REDUCE
    phase pays one extra root-relocation hop on top of its tree schedule. A
    FUSED_SCAN_TOTAL phase is priced as its own schedule — ``log2(p)+1``
    rounds carrying two payloads per doubling step — which is what lets the
    tuner and ``plan_axis_order`` trade the fused form (roughly half the
    rounds, one payload traversal) against the unfused pair (the alpha term
    halves; the beta term gains one extra payload, so huge messages can
    still prefer the unfused plan).

    Chunked plans (``plan.chunking > 1``) price their pipelined phases as
    ``(R + C - 1) * (alpha + B*beta/C)``: R rounds of per-round payload B
    split into C chunks, with chunk c's round r overlapping chunk c+1's
    round r-1, so the pipeline is R + C - 1 steps each carrying one chunk.
    At C=1 this reduces exactly to the unchunked ``R*alpha + R*B*beta``.
    Chunking therefore wins only when the serialized link term ``B*beta``
    outweighs the extra pipeline-fill alphas — i.e. above a payload
    threshold near ``(C/(C-1)) * (C-1)/(R-1) * alpha/beta`` — which is what
    keeps small payloads at C=1.
    """
    if model is None:
        tuning = get_active_tuning()
        fitted = tuning.fitted_model() if tuning is not None else None
        model = fitted if fitted is not None else TPU_V5E
    logical = plan.logical_sizes
    C = max(1, int(plan.chunking))
    total = 0.0

    def pipelined(rounds: int, nbytes: int, hops: float) -> float:
        return (
            (rounds + C - 1) * (model.alpha + nbytes * model.beta / C)
            + hops * model.gamma
        )

    for ph in plan.phases:
        if ph.kind in (PhaseKind.COMBINE, PhaseKind.IDENTITY):
            continue
        p_axis = logical[ph.level]
        if ph.kind == PhaseKind.FUSED_SCAN_TOTAL:
            if p_axis > 1:
                # each doubling step is one full-duplex pairwise exchange
                # (prefix forward, suffix back between the same pair) —
                # priced like recursive_doubling's butterfly: one payload
                # per step — plus the final single-hop suffix shift
                lg = alg.num_steps(p_axis)
                up_hops = sum(
                    min(1 << i, p_axis - (1 << i)) if model.ring else 1 << i
                    for i in range(lg)
                )
                total += pipelined(lg + 1, payload_bytes, up_hops + 1.0)
            continue
        if (
            ph.kind == PhaseKind.SCAN
            and C > 1
            and ph.algorithm in alg.DOUBLING_ALGORITHMS
            and p_axis > 1
        ):
            # the pipelined doubling form; the exclusive structural shift
            # rides the pipeline as one extra round
            lg = alg.num_steps(p_axis)
            shift = 0 if ph.inclusive else 1
            hops = float(shift) + sum(
                min(1 << i, p_axis - (1 << i)) if model.ring else 1 << i
                for i in range(lg)
            )
            total += pipelined(lg + shift, payload_bytes, hops)
            continue
        nbytes = 4 if ph.kind == PhaseKind.BARRIER else payload_bytes
        total += estimate_cost(ph.algorithm, p_axis, nbytes, model)
        if ph.kind == PhaseKind.REDUCE and p_axis > 1:
            total += model.alpha + nbytes * model.beta + model.gamma
    return total


def plan_axis_order(
    coll: "CollType | str",
    sizes: Sequence[int],
    payload_bytes: int,
    op: "AssocOp | str" = "sum",
    *,
    optimize: bool = False,
) -> Tuple[int, ...]:
    """Choose the logical axis order (the split) for one topology.

    Resolution mirrors ``select_algorithm``: a measured split winner from the
    active tuning table rules when one exists for this (coll, sizes) at a
    nearby payload; otherwise every permutation is priced with
    :func:`plan_cost` under the fitted-or-static LinkModel. Ties keep the
    physical order (identity split) for stability. With ``optimize=True``
    every candidate is run through the pass pipeline before pricing, so the
    chosen split is the one that is cheapest *after* fusion and dead-phase
    elimination — a split that exposes a fusible SCAN+TOTAL pair can beat
    one that looks cheaper raw.
    """
    if isinstance(coll, str):
        coll = CollType[coll.upper()]
    op = get_operator(op)
    sizes = tuple(int(s) for s in sizes)
    n = len(sizes)
    if n == 1:
        return (0,)

    tuning = get_active_tuning()
    if tuning is not None:
        winner = getattr(tuning, "split_winner", lambda *a, **k: None)(
            coll.name.lower(), sizes, payload_bytes
        )
        if winner is not None and sorted(winner) == list(range(n)):
            return tuple(winner)

    if optimize:
        from repro.offload.passes import optimize_plan

    best: Optional[Tuple[float, int, Tuple[int, ...]]] = None
    identity = tuple(range(n))
    for perm in itertools.permutations(range(n)):
        plan = build_plan(
            coll, sizes, op, payload_bytes, order=perm,
            root=0, inclusive=True,
        )
        if optimize:
            plan = optimize_plan(plan)
        cost = plan_cost(plan, payload_bytes)
        key = (cost, 0 if perm == identity else 1, perm)
        if best is None or key < best:
            best = key
    return best[2]


# ---------------------------------------------------------------------------
# Lowering: sim (stacked arrays) and SPMD (shard_map) interpreters
# ---------------------------------------------------------------------------


def _sim_scan_chunked(
    backend: "alg.Backend",
    stacked: PyTree,
    op: AssocOp,
    p: int,
    *,
    algorithm: str,
    inclusive: bool,
    chunks: int,
) -> PyTree:
    """Chunked ``sim_scan``: identical values, pipelined exchange rounds.

    Only the doubling family has a round-pipelined form; other algorithms
    (and payloads that cannot be split — e.g. scalar-per-rank leaves, whose
    last axis on the sim backend is the *rank* axis) fall back to the plain
    whole-payload schedule. The exclusive handling mirrors ``sim_scan``
    line for line: the inverse-op trick where applicable, else the
    structural shift (riding the pipeline as round 0) with rank 0's
    identity fill, and always the final rank-0 mask — applied to the
    concatenated result, which is bitwise the same as per-chunk application
    because every mask is elementwise.
    """
    if (
        p == 1
        or algorithm not in alg.DOUBLING_ALGORITHMS
        or not alg.chunkable(stacked, chunks, min_ndim=2)
    ):
        return sim_scan(
            stacked, op, p, algorithm=algorithm, inclusive=inclusive,
            backend=backend,
        )
    if inclusive:
        return alg.chunked_scan_schedule(backend, stacked, op, chunks=chunks)
    identity = op.identity_like(stacked)
    rank = backend.rank()
    if (
        algorithm == "invertible_doubling"
        and op.inverse is not None
        and op.commutative
    ):
        inc = alg.chunked_scan_schedule(backend, stacked, op, chunks=chunks)
        ex = op.combine(inc, op.inverse(stacked))
        return alg._bwhere(rank != 0, ex, identity)
    out = alg.chunked_scan_schedule(
        backend, stacked, op, chunks=chunks, shift_first=True,
        identity=None if op.zero_identity else identity,
    )
    return alg._bwhere(rank != 0, out, identity)


def _spmd_scan_chunked(
    backend: "alg.SpmdBackend",
    x: PyTree,
    op: AssocOp,
    *,
    algorithm: str,
    inclusive: bool,
    chunks: int,
) -> PyTree:
    """Chunked ``dist_scan``/``dist_exscan`` body over one named axis.

    Mirrors those functions exactly (including the exclusive form's
    *absence* of a final rank-0 mask on the structural path — the shifted
    identity fill already leaves rank 0 holding the identity).
    """
    p = backend.p
    if (
        p == 1
        or algorithm not in alg.DOUBLING_ALGORITHMS
        or not alg.chunkable(x, chunks)
    ):
        if inclusive:
            return dist_scan(x, op, backend.axis_name, algorithm=algorithm)
        return dist_exscan(x, op, backend.axis_name, algorithm=algorithm)
    if inclusive:
        return alg.chunked_scan_schedule(backend, x, op, chunks=chunks)
    identity = op.identity_like(x)
    if algorithm == "invertible_doubling" and op.inverse is not None:
        if not op.commutative:
            raise ValueError(
                "inverse-based exscan requires a commutative operator; "
                f"{op.name!r} is not"
            )
        inc = alg.chunked_scan_schedule(backend, x, op, chunks=chunks)
        ex = op.combine(inc, op.inverse(x))
        rank = backend.rank()
        return alg._bwhere(rank == 0, identity, ex)
    return alg.chunked_scan_schedule(
        backend, x, op, chunks=chunks, shift_first=True,
        identity=None if op.zero_identity else identity,
    )


def _chunked_scan_total(
    backend: "alg.Backend",
    tree: PyTree,
    op: AssocOp,
    *,
    inclusive: bool,
    chunks: int,
    min_ndim: int = 1,
) -> Tuple[PyTree, PyTree]:
    """Fused scan+total with the pipelined chunked schedule when the payload
    splits, else the plain fused schedule."""
    if backend.p == 1 or not alg.chunkable(tree, chunks, min_ndim=min_ndim):
        return alg.scan_total_schedule(backend, tree, op, inclusive=inclusive)
    return alg.chunked_scan_total_schedule(
        backend, tree, op, chunks=chunks, inclusive=inclusive
    )


def _along_axis(tree: PyTree, axis: int, fn: Callable[[PyTree], PyTree]) -> PyTree:
    """Run a leading-rank-axis schedule along mesh axis ``axis`` of stacked
    leaves; the other mesh axes ride along as payload dims."""
    moved = jax.tree.map(lambda a: jnp.moveaxis(a, axis, 0), tree)
    out = fn(moved)
    return jax.tree.map(lambda a: jnp.moveaxis(a, 0, axis), out)


def _zero_coord_mask(
    logical_sizes: Sequence[int], guard_levels: Sequence[int]
) -> jnp.ndarray:
    """Boolean (logical mesh)-shaped mask: True where every guarded level's
    coordinate is zero (the ranks whose incoming carry is empty)."""
    k = len(logical_sizes)
    mask = jnp.ones(tuple(logical_sizes), bool)
    for lv in guard_levels:
        coord = jnp.arange(logical_sizes[lv]).reshape(
            (1,) * lv + (logical_sizes[lv],) + (1,) * (k - 1 - lv)
        )
        mask = mask & (coord == 0)
    return mask


def lower_sim(
    plan: CollectivePlan,
    op: "AssocOp | str | None" = None,
    *,
    traced: bool = False,
):
    """Compile a plan to a function over flat stacked ``(p, ...)`` leaves.

    The input's leading axis is the flat rank in logical order; internally it
    is reshaped to the logical mesh shape, phases run along single mesh axes,
    and the output is flattened back — directly comparable (bitwise, given
    exact arithmetic) to the flat single-axis reference collective.

    With ``traced=True`` the interpreter emits one ``phase``-category span
    per plan phase and one ``round``-category span per communication round
    (every ``backend.permute``, via :class:`repro.obs.tracing.
    TracingBackend`, which blocks on each permuted result so the span
    duration is the per-round host constant). The traced interpreter must
    run *eagerly* — never under ``jax.jit``, where per-round host time does
    not exist — and resolves the active tracer at call time, so one traced
    callable serves successive ``tracing()`` contexts. Phase/round
    latencies also land in the shared metrics registry
    (``repro_phase_latency_us`` / ``repro_round_latency_us``). The traced
    path performs the same arithmetic as the untraced one (blocking does
    not change values), but is only built on request and cached separately
    by the engine, so the default path is untouched.

    Interpreter layouts: the unoptimized path permutes every phase operand
    to the front and back again (two ``moveaxis`` per phase). For an
    *optimized* plan (``plan.optimized``, set by the pass pipeline) the
    interpreter instead threads layouts: every register remembers which
    logical level is currently fronted and converts lazily, only when a
    consumer needs a different layout, memoizing each view — the shared
    logical<->physical permute chain is computed once per plan, not once
    per phase (``plan_layout_moves`` is the static form). COMBINE operands
    are normalized to the natural mesh order first, because its guard mask
    is built over the un-permuted logical mesh (the dataflow check that
    makes permute elimination COMBINE-aware). Both interpreters compute
    identical values (``moveaxis`` is exact), so optimization never changes
    bits.
    """
    op = get_operator(plan.op_name if op is None else op)
    logical = plan.logical_sizes
    k = len(logical)
    p_total = plan.p
    threaded = plan.optimized
    chunks = max(1, int(plan.chunking))
    coll_name = plan.coll.name.lower()

    def to_mesh(tree: PyTree) -> PyTree:
        return jax.tree.map(
            lambda a: a.reshape(logical + a.shape[1:]), tree
        )

    def to_flat(tree: PyTree) -> PyTree:
        return jax.tree.map(
            lambda a: a.reshape((p_total,) + a.shape[k:]), tree
        )

    def run(x: Optional[PyTree]) -> PyTree:
        # register name -> {layout: view}; layout None is the natural mesh
        # order, an int means that logical level is moved to axis 0
        regs: Dict[str, Dict[Optional[int], PyTree]] = {}

        def set_reg(name: str, tree: PyTree, layout: Optional[int]) -> None:
            regs[name] = {layout: tree}

        def get_reg(name: str, layout: Optional[int]) -> PyTree:
            views = regs[name]
            if layout in views:
                return views[layout]
            if None not in views:
                lv, tree = next(iter(views.items()))
                views[None] = jax.tree.map(
                    lambda a: jnp.moveaxis(a, 0, lv), tree
                )
            if layout is None:
                return views[None]
            views[layout] = jax.tree.map(
                lambda a: jnp.moveaxis(a, layout, 0), views[None]
            )
            return views[layout]

        if traced:
            from repro.obs import metrics as obs_metrics
            from repro.obs import tracing as obs_tracing
            from repro.runtime import chaos as runtime_chaos

            tracer = obs_tracing.get_tracer()
            if not getattr(tracer, "enabled", False):
                # chaos-only eager runs (no collecting tracer) skip the
                # span plumbing entirely
                tracer = None
            chaos_injector = runtime_chaos.get_injector()
        else:
            tracer = None
            chaos_injector = None

        if plan.coll == CollType.BARRIER:
            set_reg("x", jnp.ones(logical, jnp.float32), None)
        else:
            set_reg("x", to_mesh(x), None)
        for ph in plan.phases:
            phase_name = ph.kind.name
            if tracer is not None:
                phase_cm = tracer.span(
                    f"plan.phase:{phase_name}:L{ph.level}",
                    "phase",
                    kind=phase_name,
                    level=ph.level,
                    algorithm=ph.algorithm,
                    coll=coll_name,
                )
                phase_span = phase_cm.__enter__()
                phase_t0 = obs_tracing.now_us()
            if ph.kind == PhaseKind.COMBINE:
                carry = get_reg(ph.src[0], None)
                local = get_reg(ph.src[1], None)
                merged = op.combine(carry, local)
                if ph.guard_levels:
                    mask = _zero_coord_mask(logical, ph.guard_levels)
                    merged = alg._bwhere(mask, local, merged)
                set_reg(ph.dst, merged, None)
                if tracer is not None:
                    phase_cm.__exit__(None, None, None)
                    obs_metrics.observe_phase(
                        coll_name, phase_name,
                        obs_tracing.now_us() - phase_t0,
                    )
                continue
            if ph.kind == PhaseKind.IDENTITY:
                set_reg(ph.dst, op.identity_like(get_reg(ph.src[0], None)), None)
                if tracer is not None:
                    phase_cm.__exit__(None, None, None)
                    obs_metrics.observe_phase(
                        coll_name, phase_name,
                        obs_tracing.now_us() - phase_t0,
                    )
                continue
            p_axis = logical[ph.level]
            backend = alg.SimBackend(p_axis)
            if chaos_injector is not None:
                # innermost wrapper: link-probed single-pair permutes and
                # traced rounds both see per-message chaos decisions
                backend = runtime_chaos.ChaosBackend(
                    backend, chaos_injector, level=ph.level
                )
            if tracer is not None:
                if getattr(tracer, "link_probe", False):
                    # per-link attribution: decompose each round's permute
                    # into individually-timed (src, dst) messages (exact
                    # merge — see LinkProbeBackend), child spans of the
                    # round span TracingBackend opens around the call
                    from repro.obs import health as obs_health

                    backend = obs_health.LinkProbeBackend(
                        backend,
                        tracer,
                        level=ph.level,
                        injector=getattr(tracer, "link_injector", None),
                        detector=getattr(tracer, "link_detector", None),
                    )
                backend = obs_tracing.TracingBackend(
                    backend,
                    tracer,
                    phase=f"{phase_name}:L{ph.level}",
                    on_round=lambda idx, dur_us, _k=phase_name: (
                        obs_metrics.observe_round(coll_name, _k, idx, dur_us)
                    ),
                )
            if ph.kind == PhaseKind.SCAN:
                if chunks > 1:
                    fn = lambda t: _sim_scan_chunked(  # noqa: E731
                        backend, t, op, p_axis, algorithm=ph.algorithm,
                        inclusive=ph.inclusive, chunks=chunks,
                    )
                else:
                    fn = lambda t: sim_scan(  # noqa: E731
                        t, op, p_axis, algorithm=ph.algorithm,
                        inclusive=ph.inclusive, backend=backend,
                    )
            elif ph.kind == PhaseKind.FUSED_SCAN_TOTAL:
                if chunks > 1:
                    fn = lambda t: _chunked_scan_total(  # noqa: E731
                        backend, t, op, inclusive=ph.inclusive,
                        chunks=chunks, min_ndim=2,
                    )
                else:
                    fn = lambda t: alg.scan_total_schedule(  # noqa: E731
                        backend, t, op, inclusive=ph.inclusive
                    )
            elif ph.kind == PhaseKind.TOTAL:
                fn = lambda t: allreduce_schedule(  # noqa: E731
                    backend, t, op, algorithm=ph.algorithm
                )
            elif ph.kind == PhaseKind.REDUCE:
                fn = lambda t: reduce_schedule(  # noqa: E731
                    backend, t, op, root=ph.root, algorithm=ph.algorithm
                )
            elif ph.kind == PhaseKind.BARRIER:
                # not reduce_ops.barrier_schedule: that mints a fresh token
                # per call, but a multi-axis fence must *thread* one token
                # through the levels so each axis fence data-depends on the
                # previous (transitive all-to-all ordering XLA can't reorder)
                fn = lambda t: allreduce_schedule(  # noqa: E731
                    backend, t, MAX, algorithm=ph.algorithm
                )
            else:  # pragma: no cover - exhaustive
                raise ValueError(f"unknown phase kind {ph.kind!r}")
            if threaded:
                out = fn(get_reg(ph.src[0], ph.level))
                if ph.kind == PhaseKind.FUSED_SCAN_TOTAL:
                    set_reg(ph.dst, out[0], ph.level)
                    set_reg(ph.dst2, out[1], ph.level)
                else:
                    set_reg(ph.dst, out, ph.level)
            else:
                src = get_reg(ph.src[0], None)
                out = _along_axis(src, ph.level, fn)
                if ph.kind == PhaseKind.FUSED_SCAN_TOTAL:
                    set_reg(ph.dst, out[0], None)
                    set_reg(ph.dst2, out[1], None)
                else:
                    set_reg(ph.dst, out, None)
            if tracer is not None:
                phase_span.set(rounds=getattr(backend, "rounds", 0))
                phase_cm.__exit__(None, None, None)
                obs_metrics.observe_phase(
                    coll_name, phase_name, obs_tracing.now_us() - phase_t0
                )
        return to_flat(get_reg(plan.result, None))

    return run


def lower_spmd(
    plan: CollectivePlan,
    axis_names: Sequence[str],
    op: "AssocOp | str | None" = None,
):
    """Compile a plan to a function callable inside ``shard_map``.

    ``axis_names`` name the *physical* mesh axes in the same order as
    ``plan.sizes``; the plan's split decides which named axis each logical
    level runs over. Global rank order is lex over the logical levels —
    callers lay data out accordingly (outermost logical level varies
    slowest).
    """
    op = get_operator(plan.op_name if op is None else op)
    axis_names = tuple(axis_names)
    if len(axis_names) != len(plan.sizes):
        raise ValueError(
            f"plan spans {len(plan.sizes)} axes; got names {axis_names}"
        )
    names_l = tuple(axis_names[i] for i in plan.order)
    chunks = max(1, int(plan.chunking))

    def run(x: Optional[PyTree]) -> PyTree:
        regs: Dict[str, PyTree] = {}
        if plan.coll == CollType.BARRIER:
            regs["x"] = jnp.ones((), jnp.float32)
        else:
            regs["x"] = x
        for ph in plan.phases:
            if ph.kind == PhaseKind.COMBINE:
                carry, local = regs[ph.src[0]], regs[ph.src[1]]
                merged = op.combine(carry, local)
                cond = None
                for lv in ph.guard_levels:
                    z = lax.axis_index(names_l[lv]) == 0
                    cond = z if cond is None else (cond & z)
                if cond is not None:
                    merged = alg._bwhere(cond, local, merged)
                regs[ph.dst] = merged
                continue
            if ph.kind == PhaseKind.IDENTITY:
                regs[ph.dst] = op.identity_like(regs[ph.src[0]])
                continue
            src = regs[ph.src[0]]
            name = names_l[ph.level]
            backend = alg.SpmdBackend(name, plan.logical_sizes[ph.level])
            if ph.kind == PhaseKind.FUSED_SCAN_TOTAL:
                if chunks > 1:
                    y, t = _chunked_scan_total(
                        backend, src, op, inclusive=ph.inclusive,
                        chunks=chunks,
                    )
                else:
                    y, t = alg.scan_total_schedule(
                        backend, src, op, inclusive=ph.inclusive
                    )
                regs[ph.dst] = y
                regs[ph.dst2] = t
                continue
            if ph.kind == PhaseKind.SCAN:
                if chunks > 1:
                    out = _spmd_scan_chunked(
                        backend, src, op, algorithm=ph.algorithm,
                        inclusive=ph.inclusive, chunks=chunks,
                    )
                elif ph.inclusive:
                    out = dist_scan(src, op, name, algorithm=ph.algorithm)
                else:
                    out = dist_exscan(src, op, name, algorithm=ph.algorithm)
            elif ph.kind == PhaseKind.TOTAL:
                out = allreduce_schedule(
                    backend, src, op, algorithm=ph.algorithm
                )
            elif ph.kind == PhaseKind.REDUCE:
                out = reduce_schedule(
                    backend, src, op, root=ph.root, algorithm=ph.algorithm
                )
            elif ph.kind == PhaseKind.BARRIER:
                # same token-threading rationale as the sim interpreter
                out = allreduce_schedule(
                    backend, src, MAX, algorithm=ph.algorithm
                )
            else:  # pragma: no cover - exhaustive
                raise ValueError(f"unknown phase kind {ph.kind!r}")
            regs[ph.dst] = out
        return regs[plan.result]

    return run
