"""Measured-cost autotuner for the offload engine.

``select_algorithm`` defaults to TPU v5e ICI constants — the production
target's topology, not necessarily the backend actually running. This module
re-derives the cost model the way the paper's host runtime would: time every
schedule on the *actual* backend over a (p, payload) grid, record per-point
winners, and least-squares fit the LinkModel's alpha/beta/gamma against the
:func:`~repro.core.selector.cost_features` design matrix. The result is a
:class:`~repro.offload.tuning_cache.TuningCache` that, once activated,
replaces the static constants underneath every ``algorithm="auto"`` call.

Both collectives the engine scans with are measured: inclusive ("scan") and
exclusive ("exscan"), because the invertible-doubling subtraction trick only
pays off in the exclusive form — a distinction the static model cannot see.
"""

from __future__ import annotations

import time
from typing import Iterable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algorithms import ALGORITHMS
from repro.core.operators import AssocOp, get_operator
from repro.core.scan_collective import sim_scan
from repro.offload.tuning_cache import TuningCache

DEFAULT_PS: Tuple[int, ...] = (2, 4, 8, 16)
DEFAULT_PAYLOADS: Tuple[int, ...] = (1024, 65536, 1 << 20)
DEFAULT_COLLS: Tuple[str, ...] = ("scan", "exscan")


def _applicable(algo: str, op: AssocOp) -> bool:
    return algo != "invertible_doubling" or (
        op.inverse is not None and op.commutative
    )


def time_sim_collective(
    coll: str,
    algo: str,
    p: int,
    payload_bytes: int,
    op: "AssocOp | str" = "sum",
    *,
    iters: int = 5,
    seed: int = 0,
) -> float:
    """Median wall-clock seconds of the fused (single-dispatch) schedule on
    the simulator backend — the offloaded path the engine actually runs."""
    op = get_operator(op)
    n = max(1, payload_bytes // 4)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(p, n)).astype(np.float32))
    inclusive = coll == "scan"
    fused = jax.jit(
        lambda s: sim_scan(s, op, p, algorithm=algo, inclusive=inclusive)
    )
    out = fused(x)
    jax.tree.map(lambda a: a.block_until_ready(), out)  # warm the jit
    times = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        out = fused(x)
        jax.tree.map(lambda a: a.block_until_ready(), out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def autotune(
    *,
    ps: Sequence[int] = DEFAULT_PS,
    payloads: Sequence[int] = DEFAULT_PAYLOADS,
    colls: Sequence[str] = DEFAULT_COLLS,
    algorithms: Optional[Iterable[str]] = None,
    op: "AssocOp | str" = "sum",
    iters: int = 5,
    time_budget_s: Optional[float] = None,
    verbose: bool = False,
) -> TuningCache:
    """Micro-benchmark the full (coll, algo, p, payload) grid into a cache.

    ``time_budget_s`` bounds total wall clock: once exceeded, the remaining
    grid points are skipped (winners/fit use whatever was measured) — this is
    what keeps the CI smoke run inside its ~10 s envelope.
    """
    op = get_operator(op)
    cache = TuningCache()
    algos = list(algorithms) if algorithms is not None else sorted(ALGORITHMS)
    t_start = time.perf_counter()
    skipped = 0
    for p in ps:
        for payload in payloads:
            for coll in colls:
                for algo in algos:
                    if not _applicable(algo, op):
                        continue
                    if (
                        time_budget_s is not None
                        and time.perf_counter() - t_start > time_budget_s
                    ):
                        skipped += 1
                        continue
                    t = time_sim_collective(
                        coll, algo, p, payload, op, iters=iters
                    )
                    cache.record(coll, algo, p, payload, t)
                    if verbose:
                        print(
                            f"tune {coll:6s} p={p:3d} bytes={payload:8d} "
                            f"{algo:22s} {t*1e6:10.1f}us"
                        )
    if verbose and skipped:
        print(f"tune: time budget hit, skipped {skipped} grid points")
    # Materialize winners + fit eagerly so save() is cheap and callers can
    # inspect the result right away.
    cache.fitted_model()
    _ = cache.winners
    return cache
