"""Measured-cost autotuner for the offload engine.

``select_algorithm`` defaults to TPU v5e ICI constants — the production
target's topology, not necessarily the backend actually running. This module
re-derives the cost model the way the paper's host runtime would: time every
schedule on the *actual* backend over a (p, payload) grid, record per-point
winners, and least-squares fit the LinkModel's alpha/beta/gamma against the
:func:`~repro.core.selector.cost_features` design matrix. The result is a
:class:`~repro.offload.tuning_cache.TuningCache` that, once activated,
replaces the static constants underneath every ``algorithm="auto"`` call.

All five descriptor coll kinds are measured — scan, exscan, reduce,
allreduce, barrier — so ``algorithm="auto"`` for every CollType resolves
against its *own* measured table, never a scan stand-in. (scan vs exscan
matters because the invertible-doubling subtraction trick only pays off in
the exclusive form — a distinction the static model cannot see.)

:func:`tune_splits` is the topology-level pass: it times whole
planner-lowered collectives for every logical axis order of each mesh shape
and records the winners, which ``plan_axis_order`` consults before any
model-predicted split.
"""

from __future__ import annotations

import time
from typing import Iterable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algorithms import ALGORITHMS
from repro.core.operators import AssocOp, get_operator
from repro.core.reduce_ops import sim_allreduce, sim_barrier, sim_reduce
from repro.core.scan_collective import sim_scan
from repro.offload.tuning_cache import TuningCache

DEFAULT_PS: Tuple[int, ...] = (2, 4, 8, 16)
DEFAULT_PAYLOADS: Tuple[int, ...] = (1024, 65536, 1 << 20)
DEFAULT_COLLS: Tuple[str, ...] = (
    "scan", "exscan", "reduce", "allreduce", "barrier",
)
DEFAULT_TOPOLOGIES: Tuple[Tuple[int, ...], ...] = (
    (2, 4), (4, 2), (2, 8), (4, 4), (2, 2, 2), (2, 2, 4),
)


def _applicable(algo: str, op: AssocOp) -> bool:
    return algo != "invertible_doubling" or (
        op.inverse is not None and op.commutative
    )


def _sim_collective_fn(coll: str, algo: str, p: int, op: AssocOp):
    """The fused single-dispatch schedule for one measured coll kind."""
    if coll in ("scan", "exscan"):
        inclusive = coll == "scan"
        return lambda s: sim_scan(
            s, op, p, algorithm=algo, inclusive=inclusive
        )
    if coll == "reduce":
        return lambda s: sim_reduce(s, op, p, root=0, algorithm=algo)
    if coll == "allreduce":
        return lambda s: sim_allreduce(s, op, p, algorithm=algo)
    if coll == "barrier":
        return lambda _s: sim_barrier(p, algorithm=algo)
    raise ValueError(f"unknown coll kind {coll!r}")


def time_sim_collective(
    coll: str,
    algo: str,
    p: int,
    payload_bytes: int,
    op: "AssocOp | str" = "sum",
    *,
    iters: int = 5,
    seed: int = 0,
) -> float:
    """Median wall-clock seconds of the fused (single-dispatch) schedule on
    the simulator backend — the offloaded path the engine actually runs."""
    op = get_operator(op)
    n = max(1, payload_bytes // 4)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(p, n)).astype(np.float32))
    fused = jax.jit(_sim_collective_fn(coll, algo, p, op))
    out = fused(x)
    jax.tree.map(lambda a: a.block_until_ready(), out)  # warm the jit
    times = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        out = fused(x)
        jax.tree.map(lambda a: a.block_until_ready(), out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def autotune(
    *,
    ps: Sequence[int] = DEFAULT_PS,
    payloads: Sequence[int] = DEFAULT_PAYLOADS,
    colls: Sequence[str] = DEFAULT_COLLS,
    algorithms: Optional[Iterable[str]] = None,
    op: "AssocOp | str" = "sum",
    iters: int = 5,
    time_budget_s: Optional[float] = None,
    verbose: bool = False,
) -> TuningCache:
    """Micro-benchmark the full (coll, algo, p, payload) grid into a cache.

    ``time_budget_s`` bounds total wall clock: once exceeded, the remaining
    grid points are skipped (winners/fit use whatever was measured) — this is
    what keeps the CI smoke run inside its ~10 s envelope.
    """
    from repro.core.operators import MAX

    op = get_operator(op)
    cache = TuningCache()
    algos = list(algorithms) if algorithms is not None else sorted(ALGORITHMS)
    t_start = time.perf_counter()
    skipped = 0
    for p in ps:
        for payload in payloads:
            for coll in colls:
                coll_op = MAX if coll == "barrier" else op
                coll_algos = [a for a in algos if _applicable(a, coll_op)]
                # allreduce (and barrier on top of it) runs the fixed
                # recursive-doubling butterfly at power-of-two p — the
                # algorithm argument only matters off-pow2, so measure one
                # representative schedule instead of one per algorithm
                if coll in ("allreduce", "barrier") and p & (p - 1) == 0:
                    coll_algos = coll_algos[:1] if (
                        "recursive_doubling" not in coll_algos
                    ) else ["recursive_doubling"]
                for algo in coll_algos:
                    if (
                        time_budget_s is not None
                        and time.perf_counter() - t_start > time_budget_s
                    ):
                        skipped += 1
                        continue
                    t = time_sim_collective(
                        coll, algo, p, payload, op, iters=iters
                    )
                    cache.record(coll, algo, p, payload, t)
                    if verbose:
                        print(
                            f"tune {coll:6s} p={p:3d} bytes={payload:8d} "
                            f"{algo:22s} {t*1e6:10.1f}us"
                        )
    if verbose and skipped:
        print(f"tune: time budget hit, skipped {skipped} grid points")
    # Materialize winners + fit eagerly so save() is cheap and callers can
    # inspect the result right away.
    cache.fitted_model()
    _ = cache.winners
    return cache


def amortize_inner(payload_bytes: int, cap: int = 16) -> int:
    """How many schedule runs to fold into one jitted dispatch.

    Per-dispatch wall clock at small payloads measures the Python/runtime
    dispatch floor (~tens of us), not the schedule: two schedules whose true
    costs differ 3x time identically. Chaining ``inner`` runs inside one
    ``fori_loop`` amortizes the floor away; large payloads keep ``inner``
    small so one sample stays cheap."""
    if payload_bytes <= 4096:
        return cap
    if payload_bytes <= 65536:
        return min(cap, 4)
    return min(cap, 2)


def time_planned_collective(
    coll: str,
    sizes: Sequence[int],
    order: Sequence[int],
    payload_bytes: int,
    op: "AssocOp | str" = "sum",
    *,
    iters: int = 5,
    seed: int = 0,
    optimized: bool = False,
    chunking: int = 1,
    inner: int = 1,
    backend: str = "",
) -> float:
    """Median wall-clock seconds of one whole planner-lowered collective on
    the sim backend, for a fixed logical axis order (``optimized=True``
    times the pass-pipeline form of the same plan; ``chunking`` > 1 times
    the chunked-streaming lowering of it; ``backend`` names a non-default
    lowering backend to time — raises when the plan is outside that
    backend's capabilities, so a sample is never silently the default).

    ``inner`` > 1 chains that many schedule runs inside one jitted
    ``fori_loop`` dispatch and divides the wall time by ``inner``, so the
    per-dispatch floor is amortized out of the sample (the schedule output
    feeds the next iteration's input, keeping every run data-dependent —
    XLA cannot elide or overlap them)."""
    import dataclasses
    import math

    from repro.offload.passes import optimize_plan
    from repro.offload.planner import build_plan, lower_sim

    op = get_operator(op)
    p_total = math.prod(int(s) for s in sizes)
    n = max(1, payload_bytes // 4)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(p_total, n)).astype(np.float32))
    plan = build_plan(coll, sizes, op, payload_bytes, order=tuple(order))
    if optimized:
        plan = optimize_plan(plan)
    if chunking != 1:
        plan = dataclasses.replace(plan, chunking=int(chunking))
    if backend:
        from repro.offload import backends as registry

        run = registry.get_backend(backend).lower(plan, op)
    else:
        run = lower_sim(plan, op)
    inner = max(1, int(inner))
    if coll.lower() == "barrier":
        inner = 1  # the fence takes no payload to thread through iterations
    if inner > 1:
        fused = jax.jit(
            lambda t: jax.lax.fori_loop(0, inner, lambda _i, a: run(a), t)
        )
    else:
        fused = jax.jit(run)
    arg = None if coll.lower() == "barrier" else x
    out = fused(arg)
    jax.tree.map(lambda a: a.block_until_ready(), out)  # warm the jit
    times = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        out = fused(arg)
        jax.tree.map(lambda a: a.block_until_ready(), out)
        times.append((time.perf_counter() - t0) / inner)
    times.sort()
    return times[len(times) // 2]


def tune_splits(
    *,
    topologies: Sequence[Sequence[int]] = DEFAULT_TOPOLOGIES,
    payloads: Sequence[int] = (1024, 65536),
    colls: Sequence[str] = ("scan", "allreduce"),
    op: "AssocOp | str" = "sum",
    iters: int = 3,
    time_budget_s: Optional[float] = None,
    cache: Optional[TuningCache] = None,
    verbose: bool = False,
) -> TuningCache:
    """Measure every logical axis order of every mesh shape — the topology
    half of the autotuner. Winners feed ``plan_axis_order``; by construction
    the recorded winner is never slower than any fixed order measured."""
    import itertools

    op = get_operator(op)
    cache = cache if cache is not None else TuningCache()
    t_start = time.perf_counter()
    skipped = 0
    for sizes in topologies:
        sizes = tuple(int(s) for s in sizes)
        for payload in payloads:
            for coll in colls:
                for order in itertools.permutations(range(len(sizes))):
                    if (
                        time_budget_s is not None
                        and time.perf_counter() - t_start > time_budget_s
                    ):
                        skipped += 1
                        continue
                    t = time_planned_collective(
                        coll, sizes, order, payload, op, iters=iters
                    )
                    cache.record_split(coll, sizes, order, payload, t)
                    if verbose:
                        print(
                            f"tune-split {coll:9s} {str(sizes):12s} "
                            f"order={order} bytes={payload:8d} "
                            f"{t*1e6:10.1f}us"
                        )
    if verbose and skipped:
        print(f"tune-split: time budget hit, skipped {skipped} points")
    _ = cache.split_winners
    return cache


DEFAULT_CHUNKS: Tuple[int, ...] = (1, 2, 4, 8)


def _plan_for_variant(coll, sizes, order, payload, op, optimized, chunking):
    """The exact plan :func:`time_planned_collective` would time for one
    schedule-grid variant — used to capability-check non-default backends
    before spending a sample on them."""
    import dataclasses

    from repro.offload.passes import optimize_plan
    from repro.offload.planner import build_plan

    plan = build_plan(coll, sizes, op, payload, order=tuple(order))
    if optimized:
        plan = optimize_plan(plan)
    if chunking != 1:
        plan = dataclasses.replace(plan, chunking=int(chunking))
    return plan


def tune_schedule(
    *,
    topologies: Sequence[Sequence[int]] = DEFAULT_TOPOLOGIES,
    payloads: Sequence[int] = (1024, 65536),
    colls: Sequence[str] = ("scan", "exscan"),
    chunks: Sequence[int] = DEFAULT_CHUNKS,
    backends: Sequence[str] = ("", "pallas"),
    op: "AssocOp | str" = "sum",
    iters: int = 3,
    time_budget_s: Optional[float] = None,
    cache: Optional[TuningCache] = None,
    verbose: bool = False,
) -> TuningCache:
    """Measure the full (fused, unfused) x chunk-count schedule grid per
    (coll, mesh shape, payload) point — the generalized form of the old
    fused-vs-unfused tuner. The recorded winners feed
    ``TuningCache.schedule_winner``, which ``choose_schedule`` (and through
    it ``make_descriptor``'s ``optimize="auto"`` / ``chunks="auto"``)
    consults before the plan cost model, so both the fusion decision and
    the chunk count are made per *measured* winner wherever one exists.

    ``backends`` additionally races each variant across lowering backends
    ("" is the op-per-round default): variants outside a named backend's
    capabilities are skipped, never timed-as-default, so every recorded row
    really ran what its ``backend`` column says. The cross-backend
    reduction (``TuningCache.backend_winner``) feeds ``choose_backend`` /
    ``make_descriptor(backend="auto")``. Note the stock topology grid is
    multi-axis, where the fused-kernel backend declines every plan — pass
    an effectively-single-axis topology (e.g. ``(1, 8)``) to actually race
    it.

    Samples use amortized timing (:func:`amortize_inner`): ``inner``
    schedule runs chained inside one jitted dispatch, so small-payload
    points measure the schedule rather than the dispatch floor."""
    op = get_operator(op)
    cache = cache if cache is not None else TuningCache()
    chunk_grid = tuple(dict.fromkeys(int(c) for c in chunks)) or (1,)
    backend_grid = tuple(dict.fromkeys(str(b) for b in backends)) or ("",)
    t_start = time.perf_counter()
    skipped = 0
    unsupported = 0
    for sizes in topologies:
        sizes = tuple(int(s) for s in sizes)
        order = tuple(range(len(sizes)))
        for payload in payloads:
            inner = amortize_inner(payload)
            for coll in colls:
                # budget-check once per grid point: a half-measured grid
                # would record a categorical "winner" that was never
                # actually compared against its alternatives
                if (
                    time_budget_s is not None
                    and time.perf_counter() - t_start > time_budget_s
                ):
                    skipped += 1
                    continue
                for optimized in (False, True):
                    for c in chunk_grid:
                        for bname in backend_grid:
                            if bname:
                                from repro.offload import (
                                    backends as registry,
                                )

                                plan = _plan_for_variant(
                                    coll, sizes, order, payload, op,
                                    optimized, c,
                                )
                                ok, _ = registry.get_backend(
                                    bname
                                ).capabilities(plan)
                                if not ok:
                                    unsupported += 1
                                    continue
                            t = time_planned_collective(
                                coll, sizes, order, payload, op,
                                iters=iters, optimized=optimized,
                                chunking=c, inner=inner, backend=bname,
                            )
                            cache.record_schedule(
                                coll, sizes, optimized, c, payload, t,
                                backend=bname,
                            )
                            if verbose:
                                tag = "opt" if optimized else "raw"
                                if bname:
                                    tag = f"{tag}+{bname}"
                                print(
                                    f"tune-schedule {coll:9s} "
                                    f"{str(sizes):12s} "
                                    f"{tag} C={c} bytes={payload:8d} "
                                    f"{t*1e6:10.1f}us"
                                )
    if verbose and skipped:
        print(f"tune-schedule: time budget hit, skipped {skipped} points")
    if verbose and unsupported:
        print(
            f"tune-schedule: {unsupported} variant(s) outside a named "
            f"backend's capabilities were skipped"
        )
    _ = cache.schedule_winners
    return cache


def tune_fusion(
    *,
    topologies: Sequence[Sequence[int]] = DEFAULT_TOPOLOGIES,
    payloads: Sequence[int] = (1024, 65536),
    colls: Sequence[str] = ("scan", "exscan"),
    op: "AssocOp | str" = "sum",
    iters: int = 3,
    time_budget_s: Optional[float] = None,
    cache: Optional[TuningCache] = None,
    verbose: bool = False,
) -> TuningCache:
    """Measure each planned collective with the plan-optimizer passes on
    and off — :func:`tune_schedule` restricted to the unchunked schedule
    and the default lowering backend, kept as the cheap fusion-only entry
    point."""
    return tune_schedule(
        topologies=topologies, payloads=payloads, colls=colls,
        chunks=(1,), backends=("",), op=op, iters=iters,
        time_budget_s=time_budget_s, cache=cache, verbose=verbose,
    )
