"""The reliable dispatch layer: checksums, retries, breakers, degradation.

The paper's NetFPGA collectives ride raw Ethernet media-access frames — a
medium that loses and corrupts packets — so a deployable offload engine
needs the reliability protocol the NIC-based collective literature builds
first (PAPERS.md, cs/0402027: NIC-level ACK/retransmit; 1709.05483:
per-packet handlers). This module is that protocol's software analogue,
sitting between the service broker and the engine:

* :func:`payload_checksum` / :func:`verify_payload` — a canonical-bytes
  checksum over a payload pytree (dtype, shape, and tree structure mixed
  in), computed at broker submit and re-verified at dispatch so at-rest
  corruption surfaces as a typed
  :class:`~repro.core.packet.IntegrityError` instead of a silently wrong
  prefix sum. The digest is a vectorized position-weighted XOR fold with
  tiered coverage (full single-bit detection for leaves <= 16 KiB,
  deterministic word-sampling above — see :func:`_fold_bytes`; it must
  fit inside the < 2% reliability-overhead CI gate; it is not
  cryptographic). Descriptor words get a real CRC32 via
  ``repro.core.packet.wire_checksum`` — they are tiny.

* :class:`RetryPolicy` — bounded attempts with deterministic exponential
  backoff that never sleeps (or retries) past an absolute deadline.
  Retryable faults are the *transient transport* kinds:
  :class:`~repro.runtime.chaos.TransportError` (lost message — a
  retransmit fixes it) and in-flight :class:`IntegrityError` (receiver
  CRC reject — ditto). Exhaustion raises :class:`RetryExhaustedError`
  carrying the last underlying error.

* :class:`CircuitBreaker` — per-(backend, coll) keyed; trips open after
  ``failure_threshold`` consecutive failures, fails fast while open, and
  recovers through half-open probes after ``cooldown_s``. State changes
  land in the flight recorder and the ``repro_breaker_state`` gauge;
  ``snapshot()`` feeds ``HealthMonitor.healthz()``.

* :class:`ReliableDispatcher` — wraps ``engine.offload`` with the
  graceful-degradation chain: requested backend (e.g. pallas) → default
  backend → raw (unoptimized, unchunked) plan → :func:`reference_collective`
  (direct raw-``lax`` schedules, no engine machinery, immune to chaos).
  Each stage runs under the retry policy and its own breaker key; every
  retry, degradation, and breaker transition is counted in telemetry,
  metrics, and the flight recorder. Caller bugs (``ValueError`` & co.)
  and host-failure signals (``SimulatedFailure`` — the remesh loop owns
  those) propagate immediately, undegraded.

The broker composes these per coalesced group and adds bisection: a
failed fused dispatch splits its group to quarantine exactly the poisoned
request(s) while clean neighbors retry and complete (see
``repro.service.broker``).
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.packet import CollectiveDescriptor, CollType, IntegrityError
from repro.obs import events as obs_events
from repro.obs import metrics as obs_metrics
from repro.runtime.chaos import TransportError

__all__ = [
    "CircuitBreaker",
    "CircuitOpenError",
    "IntegrityError",
    "ReliabilityPolicy",
    "ReliableDispatcher",
    "RetryExhaustedError",
    "RetryPolicy",
    "TransportError",
    "payload_checksum",
    "reference_collective",
    "verify_payload",
]

PyTree = Any


class RetryExhaustedError(RuntimeError):
    """Every attempt of a retryable dispatch failed.

    ``last_error`` is the final underlying fault — the broker unwraps it
    when failing a quarantined ticket, so callers see the *original*
    error, not the retry bookkeeping.
    """

    def __init__(
        self, message: str, *, last_error: Optional[BaseException] = None,
        attempts: int = 0,
    ):
        super().__init__(message)
        self.last_error = last_error
        self.attempts = attempts


class CircuitOpenError(RuntimeError):
    """Dispatch refused because every eligible stage's breaker is open."""


#: transient transport faults a retry can fix (a retransmit re-sends the
#: frame; chaos decisions advance per message, so a retry draws fresh ones)
RETRYABLE_ERRORS: Tuple[type, ...] = (TransportError, IntegrityError)


# ---------------------------------------------------------------------------
# Payload integrity
# ---------------------------------------------------------------------------

#: odd 64-bit lane weights (splitmix64 outputs) — position sensitivity
#: across the fold so swapped blocks don't cancel like plain XOR would
_LANE_WEIGHTS = (
    0x9E3779B97F4A7C15,
    0xBF58476D1CE4E5B9,
    0x94D049BB133111EB,
    0xD6E8FEB86659FD93,
    0xA5A5A5A5A5A5A5A5 | 1,
    0xC2B2AE3D27D4EB4F,
    0x165667B19E3779F9,
    0x27D4EB2F165667C5,
)
_MASK64 = (1 << 64) - 1


#: full single-bit coverage up to this many 64-byte blocks per leaf;
#: larger leaves fold a deterministic stride-sample of the same size
#: (``$REPRO_CHECKSUM_FULL=1`` forces full coverage at any size)
_FULL_COVER_BLOCKS = 256  # 16 KiB

#: contiguous sampled runs per oversized leaf (see ``_fold_bytes``)
_SAMPLE_RUNS = 32


_FULL_COVERAGE: Optional[bool] = None


def _full_coverage() -> bool:
    # read once: os.environ lookups cost ~15 us here, far too slow for a
    # per-fold check (tests reset the cache via _reset_full_coverage)
    global _FULL_COVERAGE
    if _FULL_COVERAGE is None:
        _FULL_COVERAGE = (
            os.environ.get("REPRO_CHECKSUM_FULL", "") not in ("", "0")
        )
    return _FULL_COVERAGE


def _reset_full_coverage() -> None:
    global _FULL_COVERAGE
    _FULL_COVERAGE = None


def _mix_lanes(col: List[int], h: int) -> int:
    for c, w in zip(col, _LANE_WEIGHTS):
        h ^= (c * w) & _MASK64
        h = ((h << 7) | (h >> 57)) & _MASK64
    return h


def _fold_bytes(view: np.ndarray, h: int) -> int:
    """Fold a flat uint8 array into ``h`` (64-bit lanes, weighted mix).

    Leaves up to ``_FULL_COVER_BLOCKS`` 64-byte blocks are folded in
    full — any single flipped bit changes the digest. Above that the
    fold covers ``_SAMPLE_RUNS`` evenly spaced **contiguous runs**
    totalling the same byte budget, plus the final partial block, so

    * corruption touching any contiguous region of ``>= nbytes /
      _SAMPLE_RUNS`` bytes (slice-scale software corruption — aliasing,
      row mutation — the dominant at-rest failure mode) always spans a
      run start and is detected unless the corrupted words' per-lane
      sum deltas cancel mod 2**64 — never the case for a single flipped
      word or a uniform mask (see the lane-sum note below), and
    * an isolated single-word event is detected with probability
      ``~ 16 KiB / nbytes`` (it must land in a sampled run; once
      sampled, detection is certain).

    Contiguous runs — not a word stride — keep the sampled fold O(16
    KiB) in *memory traffic* too: a stride touches every cache line of
    the payload, which both costs bandwidth and evicts the dispatch's
    working set. The tiered trade is deliberate and load-bearing for
    the < 2% reliability overhead gate: a full pass over a multi-MiB
    payload costs the same order as the simulated dispatch itself.
    ``$REPRO_CHECKSUM_FULL=1`` opts a deployment into full coverage at
    any size.
    """
    n = view.size
    tail = n % 64
    body = view[: n - tail]
    if body.size:
        w = body.view(np.uint64)
        nw = w.size
        cap = _FULL_COVER_BLOCKS * 8  # budget in 8-byte words
        if nw > cap and not _full_coverage():
            spacing = nw // _SAMPLE_RUNS
            runlen = cap // _SAMPLE_RUNS
            w = np.ascontiguousarray(
                w[: _SAMPLE_RUNS * spacing]
                .reshape(_SAMPLE_RUNS, spacing)[:, :runlen]
            ).reshape(-1)
        # modular *sum* per lane, not xor: xor cancels exactly whenever
        # an even number of a lane's words get the same corruption mask
        # (a uniform bit-flip over a slice is the textbook case); a
        # wrapping sum moves by each word's data-dependent delta, so any
        # single flipped word always lands and uniform masks cannot
        # cancel. Reducing along the last (contiguous) axis is ~4x
        # faster than a strided interleaved-column layout.
        col = np.add.reduce(w.reshape(8, -1), axis=1).tolist()
        h = _mix_lanes(col, h)
    if tail:
        last = np.zeros(64, np.uint8)
        last[:tail] = view[n - tail:]
        h = _mix_lanes(last.view(np.uint64).tolist(), h)
    h ^= n
    return (h * 0x9E3779B97F4A7C15) & _MASK64


#: (treedef, per-leaf (dtype, shape)) -> structure digest; payloads are
#: few distinct shapes per process, so this almost always hits
_META_CACHE: Dict[Any, int] = {}


def payload_checksum(tree: PyTree) -> int:
    """64-bit canonical-bytes checksum of a payload pytree.

    Covers every leaf's dtype/shape and the tree structure, plus the
    leaf bytes under the tiered-coverage rule of :func:`_fold_bytes`
    (full single-bit detection for leaves <= 16 KiB — which includes
    every descriptor and control payload — block-sampled above, full
    everywhere with ``$REPRO_CHECKSUM_FULL=1``). Fixed cost is a few
    microseconds, which is what lets the broker checksum every submit
    and re-verify every dispatch inside the < 2% overhead gate.
    """
    from jax import tree_util

    leaves, treedef = tree_util.tree_flatten(tree)
    arrs = [np.ascontiguousarray(np.asarray(leaf)) for leaf in leaves]
    key = (treedef,) + tuple((a.dtype.str, a.shape) for a in arrs)
    h = _META_CACHE.get(key)
    if h is None:
        h = zlib.crc32(repr(key).encode("utf-8")) & _MASK64
        if len(_META_CACHE) < 1024:
            _META_CACHE[key] = h
    for a in arrs:
        h = _fold_bytes(a.reshape(-1).view(np.uint8), h)
    return h


def verify_payload(
    tree: PyTree, checksum: int, *, request: Optional[str] = None
) -> None:
    """Recompute and compare; mismatch raises :class:`IntegrityError`
    stamped with ``request`` (and recorded) so the broker can quarantine
    the poisoned submission without retrying it."""
    actual = payload_checksum(tree)
    if actual != checksum:
        obs_events.record(
            "integrity_fail", request=request, scope="payload"
        )
        obs_metrics.get_registry().counter(
            "repro_integrity_failures_total",
            "payload/descriptor checksum verification failures",
            labelnames=("scope",),
        ).inc(scope="payload")
        raise IntegrityError(
            f"payload checksum mismatch for request "
            f"{request or '<unattributed>'}: got {actual:#018x}, "
            f"expected {checksum:#018x} (corrupted at rest)",
            request=request,
        )


# ---------------------------------------------------------------------------
# Retry policy
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded, deadline-aware retry with deterministic backoff.

    ``backoff(attempt)`` is exact exponential (no jitter — determinism is
    a feature here: chaos tests must be reproducible), capped at
    ``max_backoff_s``. ``run`` never sleeps past an absolute ``deadline``
    (``time.monotonic`` timebase, matching the broker's ``deadline_at``):
    if the next backoff would cross it, the attempt budget is forfeit and
    :class:`RetryExhaustedError` carries the last fault.
    """

    max_attempts: int = 3
    backoff_s: float = 0.001
    multiplier: float = 2.0
    max_backoff_s: float = 0.1
    retryable: Tuple[type, ...] = RETRYABLE_ERRORS

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")

    def backoff(self, attempt: int) -> float:
        return min(
            self.backoff_s * self.multiplier ** attempt, self.max_backoff_s
        )

    def run(
        self,
        fn: Callable[[], Any],
        *,
        deadline: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
        on_retry: Optional[Callable[[int, BaseException], None]] = None,
    ) -> Any:
        attempt = 0
        while True:
            try:
                return fn()
            except self.retryable as err:
                if attempt + 1 >= self.max_attempts:
                    raise RetryExhaustedError(
                        f"dispatch failed after {attempt + 1} attempts: "
                        f"{type(err).__name__}: {err}",
                        last_error=err,
                        attempts=attempt + 1,
                    ) from err
                pause = self.backoff(attempt)
                if deadline is not None and clock() + pause > deadline:
                    raise RetryExhaustedError(
                        f"dispatch failed after {attempt + 1} attempts and "
                        f"the {pause * 1e3:.3g} ms backoff would cross the "
                        f"deadline: {type(err).__name__}: {err}",
                        last_error=err,
                        attempts=attempt + 1,
                    ) from err
                if on_retry is not None:
                    on_retry(attempt, err)
                if pause > 0:
                    sleep(pause)
                attempt += 1


# ---------------------------------------------------------------------------
# Circuit breaker
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _BreakerEntry:
    state: str = "closed"  # closed | open | half_open
    consecutive: int = 0
    opened_at: float = 0.0
    probes: int = 0
    trips: int = 0


class CircuitBreaker:
    """Keyed circuit breaker (keys are ``(backend_label, coll_name)``).

    ``allow(key)`` answers "may this stage attempt a dispatch now":
    closed → yes; open → no until ``cooldown_s`` elapsed, then the key
    moves to half-open; half-open → yes for up to ``half_open_probes``
    in-flight probes. ``record_success`` closes a half-open key and
    resets the failure streak; ``record_failure`` re-opens a half-open
    key immediately and opens a closed key once ``failure_threshold``
    consecutive failures accumulate. The clock is injectable so recovery
    is testable without real cooldowns.
    """

    def __init__(
        self,
        *,
        failure_threshold: int = 5,
        cooldown_s: float = 30.0,
        half_open_probes: int = 1,
        clock: Callable[[], float] = time.monotonic,
    ):
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        self.failure_threshold = int(failure_threshold)
        self.cooldown_s = float(cooldown_s)
        self.half_open_probes = max(1, int(half_open_probes))
        self.clock = clock
        self._lock = threading.Lock()
        self._entries: Dict[Tuple[str, str], _BreakerEntry] = {}

    def _entry(self, key: Tuple[str, str]) -> _BreakerEntry:
        e = self._entries.get(key)
        if e is None:
            e = self._entries[key] = _BreakerEntry()
        return e

    def _transition(
        self, key: Tuple[str, str], e: _BreakerEntry, state: str
    ) -> None:
        e.state = state
        obs_events.record(
            f"breaker_{state}", backend=key[0], coll=key[1],
            consecutive=e.consecutive,
        )
        obs_metrics.get_registry().gauge(
            "repro_breaker_state",
            "circuit-breaker state (0 closed, 1 half-open, 2 open)",
            labelnames=("backend", "coll"),
        ).set(
            {"closed": 0, "half_open": 1, "open": 2}[state],
            backend=key[0], coll=key[1],
        )

    def allow(self, key: Tuple[str, str]) -> bool:
        with self._lock:
            e = self._entry(key)
            if e.state == "closed":
                return True
            if e.state == "open":
                if self.clock() - e.opened_at < self.cooldown_s:
                    return False
                e.probes = 0
                self._transition(key, e, "half_open")
            # half-open: admit a bounded number of probes
            if e.probes >= self.half_open_probes:
                return False
            e.probes += 1
            return True

    def record_success(self, key: Tuple[str, str]) -> None:
        with self._lock:
            e = self._entry(key)
            e.consecutive = 0
            if e.state != "closed":
                self._transition(key, e, "closed")

    def record_failure(self, key: Tuple[str, str]) -> None:
        with self._lock:
            e = self._entry(key)
            e.consecutive += 1
            if e.state == "half_open" or (
                e.state == "closed"
                and e.consecutive >= self.failure_threshold
            ):
                e.opened_at = self.clock()
                e.trips += 1
                self._transition(key, e, "open")

    def state(self, key: Tuple[str, str]) -> str:
        with self._lock:
            return self._entry(key).state

    def open_keys(self) -> List[Tuple[str, str]]:
        with self._lock:
            return [
                k for k, e in self._entries.items() if e.state != "closed"
            ]

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """JSON-ready state by ``"backend|coll"`` key (``/healthz`` body)."""
        with self._lock:
            return {
                f"{k[0]}|{k[1]}": {
                    "state": e.state,
                    "consecutive_failures": e.consecutive,
                    "trips": e.trips,
                }
                for k, e in self._entries.items()
            }


# ---------------------------------------------------------------------------
# Raw-lax reference (last rung of the degradation ladder)
# ---------------------------------------------------------------------------


def reference_collective(
    desc: "CollectiveDescriptor | np.ndarray", x: Optional[PyTree]
) -> PyTree:
    """Run the descriptor's collective with the direct raw-``lax``
    schedules — no planner, no optimizer, no schedule cache, no chunking,
    and a fresh ``SimBackend`` that no chaos wrapper ever touches.

    This is the degradation chain's floor: slower (whole-mesh flat
    schedules, re-traced per call) but structurally incapable of failing
    for any reason the fancier paths can. Payload contract is the sim
    layout: stacked ``(p, ...)`` leaves in the plan's logical rank order.
    For exact operators (int dtypes, MAX/MIN) the result is bitwise-equal
    to the planned schedule; float SUM may differ in rounding (different
    combine tree), which is the documented accuracy cost of degrading.
    """
    from repro.core import algorithms as alg
    from repro.core.operators import get_operator
    from repro.core.reduce_ops import (
        allreduce_schedule,
        barrier_schedule,
        reduce_schedule,
    )
    from repro.core.scan_collective import sim_scan
    from repro.offload.engine import OffloadEngine, wire_op_name

    desc = OffloadEngine._as_descriptor(desc)
    op = get_operator(wire_op_name(desc.operation))
    p = int(desc.comm_size)
    if desc.coll_type == CollType.BARRIER:
        return barrier_schedule(alg.SimBackend(p))
    if x is None:
        raise ValueError("reference_collective needs a payload")
    if desc.coll_type == CollType.SCAN:
        return sim_scan(x, op, p, algorithm="recursive_doubling")
    if desc.coll_type == CollType.EXSCAN:
        return sim_scan(
            x, op, p, algorithm="recursive_doubling", inclusive=False
        )
    if desc.coll_type == CollType.REDUCE:
        return reduce_schedule(
            alg.SimBackend(p), x, op, root=int(desc.root)
        )
    if desc.coll_type == CollType.ALLREDUCE:
        return allreduce_schedule(alg.SimBackend(p), x, op)
    raise ValueError(f"unknown coll_type {desc.coll_type!r}")


# ---------------------------------------------------------------------------
# The reliable dispatcher
# ---------------------------------------------------------------------------

#: faults the degradation ladder may step down on; anything else (caller
#: bugs, SimulatedFailure host loss) propagates to its owner undegraded
DEGRADABLE_ERRORS: Tuple[type, ...] = (
    RetryExhaustedError,
    TransportError,
    IntegrityError,
    CircuitOpenError,
    NotImplementedError,
)


@dataclasses.dataclass
class ReliabilityPolicy:
    """Broker-facing configuration bundle for the reliable dispatch path.

    ``checksums`` gates submit-time payload checksums; ``bisect`` gates
    group bisection on fused-dispatch failure; ``degrade`` gates the
    fallback ladder (off = retries only, then fail).
    """

    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    breaker: Optional[CircuitBreaker] = dataclasses.field(
        default_factory=CircuitBreaker
    )
    degrade: bool = True
    checksums: bool = True
    bisect: bool = True


class ReliableDispatcher:
    """``engine.offload`` with retries, breakers, and degradation.

    ``fault_injector`` optionally hooks a
    ``repro.runtime.fault.FailureInjector`` whose ``check_dispatch()``
    runs before every attempt (probabilistic per-dispatch fault mode).
    ``clock``/``sleep`` are injectable for tests.
    """

    def __init__(
        self,
        engine: Any,
        *,
        retry: Optional[RetryPolicy] = None,
        breaker: Optional[CircuitBreaker] = None,
        degrade: bool = True,
        fault_injector: Optional[Any] = None,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
    ):
        self.engine = engine
        self.retry = retry if retry is not None else RetryPolicy()
        self.breaker = breaker
        self.degrade = bool(degrade)
        self.fault_injector = fault_injector
        self._clock = clock
        self._sleep = sleep
        self.counts: Dict[str, int] = {
            "dispatches": 0,
            "retries": 0,
            "degrades": 0,
            "breaker_skips": 0,
            "reference_dispatches": 0,
        }
        # (coll_name, ladder) per descriptor — building the ladder costs
        # two dataclasses.replace calls, too much for the happy path's
        # per-dispatch budget (the < 2% overhead gate)
        self._chains: Dict[
            CollectiveDescriptor,
            Tuple[str, List[Tuple[str, Optional[CollectiveDescriptor]]]],
        ] = {}

    @classmethod
    def from_policy(
        cls, engine: Any, policy: ReliabilityPolicy, **kw: Any
    ) -> "ReliableDispatcher":
        return cls(
            engine,
            retry=policy.retry,
            breaker=policy.breaker,
            degrade=policy.degrade,
            **kw,
        )

    # -- the degradation ladder -------------------------------------------

    @staticmethod
    def strategies(
        desc: CollectiveDescriptor, *, degrade: bool = True
    ) -> List[Tuple[str, Optional[CollectiveDescriptor]]]:
        """``(stage_label, descriptor)`` rungs, strongest first; the
        ``None`` descriptor marks the raw-lax reference rung."""
        chain: List[Tuple[str, Optional[CollectiveDescriptor]]] = [
            (desc.backend or "default", desc)
        ]
        if degrade:
            if desc.backend:
                chain.append(
                    ("default", dataclasses.replace(desc, backend=""))
                )
            if desc.optimized or desc.chunks > 1:
                chain.append(
                    (
                        "raw",
                        dataclasses.replace(
                            desc, backend="", optimized=False, chunks=1
                        ),
                    )
                )
            chain.append(("reference", None))
        return chain

    def _note(self, kind: str, **fields: Any) -> None:
        obs_events.record(kind, **fields)
        obs_metrics.get_registry().counter(
            "repro_reliability_events_total",
            "reliable-dispatch retries/degrades/breaker skips",
            labelnames=("kind",),
        ).inc(kind=kind)

    def offload(
        self,
        descriptor: "CollectiveDescriptor | np.ndarray",
        x: Optional[PyTree] = None,
        axis_name: Any = None,
        mesh: Any = None,
        *,
        deadline: Optional[float] = None,
    ) -> PyTree:
        """Dispatch with the full reliability stack; see class docs.

        ``deadline`` is an absolute ``time.monotonic`` instant (the
        broker passes its tickets' ``deadline_at``); retries never sleep
        past it.
        """
        desc = self.engine._as_descriptor(descriptor)
        self.counts["dispatches"] += 1
        cached = self._chains.get(desc)
        if cached is None:
            cached = (
                desc.coll_type.name.lower(),
                self.strategies(desc, degrade=self.degrade),
            )
            if len(self._chains) < 256:
                self._chains[desc] = cached
        coll, chain = cached
        last_err: Optional[BaseException] = None
        for i, (label, d) in enumerate(chain):
            key = (label, coll)
            if self.breaker is not None and not self.breaker.allow(key):
                self.counts["breaker_skips"] += 1
                self._note(
                    "breaker_skip", backend=label, coll=coll,
                    stage=i, of=len(chain),
                )
                if i == len(chain) - 1:
                    raise CircuitOpenError(
                        f"no dispatch stage available for {coll}: circuit "
                        f"open through {label!r}"
                    ) from last_err
                continue

            if d is None:
                run = lambda: reference_collective(desc, x)  # noqa: E731
            else:
                run = lambda d=d: self.engine.offload(  # noqa: E731
                    d, x, axis_name, mesh
                )

            def attempt(run=run):
                if self.fault_injector is not None:
                    self.fault_injector.check_dispatch()
                return run()

            def on_retry(n: int, err: BaseException) -> None:
                self.counts["retries"] += 1
                self._note(
                    "retry", backend=label, coll=coll, attempt=n + 1,
                    error=type(err).__name__,
                )

            try:
                out = self.retry.run(
                    attempt,
                    deadline=deadline,
                    clock=self._clock,
                    sleep=self._sleep,
                    on_retry=on_retry,
                )
            except DEGRADABLE_ERRORS as err:
                if self.breaker is not None:
                    self.breaker.record_failure(key)
                last_err = err
                if i == len(chain) - 1:
                    raise
                self.counts["degrades"] += 1
                self._note(
                    "degrade",
                    coll=coll,
                    frm=label,
                    to=chain[i + 1][0],
                    error=type(err).__name__,
                )
                continue
            except Exception:
                # caller bugs and host failures are not transport faults:
                # no fallback may mask them, and they say nothing about
                # the backend's health, so the breaker ignores them
                raise
            if self.breaker is not None:
                self.breaker.record_success(key)
            if label == "reference":
                self.counts["reference_dispatches"] += 1
            return out
        raise CircuitOpenError(
            f"no dispatch stage available for {coll}"
        ) from last_err
