"""Persistent tuning table: measured latencies, per-point winners, and the
least-squares-fitted LinkModel.

The NetFPGA paper leaves ``algo_type`` to the host runtime's "intelligent
selection"; this module is where that intelligence persists. The autotuner
(:mod:`repro.offload.tuner`) records micro-benchmark latencies for every
(coll, algorithm, p, payload) grid point, this cache reduces them to

  * ``winners`` — the measured-fastest applicable algorithm per grid point,
    consulted first by ``select_algorithm`` (nearest grid point in log2
    space when the query falls off-grid);
  * ``fitted`` — alpha/beta/gamma solved from the measurements against
    :func:`repro.core.selector.cost_features`, used for points too far from
    any measurement;
  * ``split_winners`` — the measured-fastest logical axis order per
    (coll, mesh shape, payload) — consulted by the collective planner's
    ``plan_axis_order`` before any model-predicted split;
  * ``fusion_winners`` — the measured fused-vs-unfused decision per
    (coll, mesh shape, payload) — consulted by the plan optimizer's
    ``choose_optimization`` before the plan cost model;
  * ``backend_winners`` — the measured-fastest *lowering backend* per
    (coll, mesh shape, payload), from ``tune_schedule`` racing the
    op-per-round default against the fused Pallas kernel — consulted by
    ``choose_backend`` (``make_descriptor(backend="auto")``);

and round-trips the whole table through JSON so one tuning run serves every
subsequent process on the same backend (`REPRO_TUNING_TABLE` env var or an
explicit ``load``). Tables loaded from ambient paths (the env var / the
default cache dir) are fingerprint-checked: a table fitted on a different
backend is rejected with a warning (:meth:`TuningCache.load_compatible`)
rather than silently mispricing every selection; an explicit ``load()``
stays strict and raises only on schema mismatch.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import platform
import warnings
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.selector import (
    LinkModel,
    cost_features,
    set_active_tuning,
)

SCHEMA_VERSION = 1

#: env var pointing at a tuning table to auto-load at launch
TUNING_TABLE_ENV = "REPRO_TUNING_TABLE"

# Queries farther than this (in |log2| distance on p and payload combined)
# from every measured grid point fall through to the fitted model.
_MAX_GRID_DISTANCE = 3.0


@dataclasses.dataclass(frozen=True)
class Measurement:
    """One micro-benchmark sample: median seconds for a full collective."""

    coll: str            # "scan" | "exscan" | "reduce" | "allreduce" | "barrier"
    algo: str
    p: int
    payload_bytes: int
    seconds: float

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "Measurement":
        return Measurement(
            coll=str(d["coll"]),
            algo=str(d["algo"]),
            p=int(d["p"]),
            payload_bytes=int(d["payload_bytes"]),
            seconds=float(d["seconds"]),
        )


@dataclasses.dataclass(frozen=True)
class SplitMeasurement:
    """One planned-collective sample: median seconds for a whole plan run
    with a specific logical axis order over a specific mesh shape."""

    coll: str
    sizes: Tuple[int, ...]   # physical mesh-axis sizes, outermost first
    order: Tuple[int, ...]   # logical level -> physical axis index
    payload_bytes: int
    seconds: float

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["sizes"] = list(self.sizes)
        d["order"] = list(self.order)
        return d

    @staticmethod
    def from_json(d: dict) -> "SplitMeasurement":
        return SplitMeasurement(
            coll=str(d["coll"]),
            sizes=tuple(int(v) for v in d["sizes"]),
            order=tuple(int(v) for v in d["order"]),
            payload_bytes=int(d["payload_bytes"]),
            seconds=float(d["seconds"]),
        )


@dataclasses.dataclass(frozen=True)
class FusionMeasurement:
    """One plan-schedule sample: median seconds of a whole planned
    collective with the pass pipeline on (``optimized=True``) or off and a
    specific payload chunk count, for one (coll, mesh shape, payload). The
    reduction over these is the measured (fused, chunks) schedule winner
    that ``choose_schedule``/``choose_optimization`` consult.

    ``chunks`` defaults to 1 so tables written before chunked streaming
    existed load unchanged (same schema version); ``backend`` (the
    *lowering* backend name — "" for the mode default, "pallas" for the
    fused-kernel lowering, distinct from the table-level hardware
    fingerprint) likewise defaults to "" so pre-registry tables load
    unchanged."""

    coll: str
    sizes: Tuple[int, ...]
    optimized: bool
    payload_bytes: int
    seconds: float
    chunks: int = 1
    backend: str = ""

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["sizes"] = list(self.sizes)
        return d

    @staticmethod
    def from_json(d: dict) -> "FusionMeasurement":
        return FusionMeasurement(
            coll=str(d["coll"]),
            sizes=tuple(int(v) for v in d["sizes"]),
            optimized=bool(d["optimized"]),
            payload_bytes=int(d["payload_bytes"]),
            seconds=float(d["seconds"]),
            chunks=int(d.get("chunks", 1)),
            backend=str(d.get("backend", "")),
        )


class TuningCache:
    """Measurements + winners + fitted model, with JSON persistence."""

    def __init__(self, *, backend: Optional[str] = None):
        self.backend = backend or _backend_fingerprint()
        self.measurements: List[Measurement] = []
        self.split_measurements: List[SplitMeasurement] = []
        self.fusion_measurements: List[FusionMeasurement] = []
        self._winners: Dict[Tuple[str, int, int], str] = {}
        self._split_winners: Dict[
            Tuple[str, Tuple[int, ...], int], Tuple[int, ...]
        ] = {}
        self._fusion_winners: Dict[
            Tuple[str, Tuple[int, ...], int], bool
        ] = {}
        self._schedule_winners: Dict[
            Tuple[str, Tuple[int, ...], int], Tuple[bool, int]
        ] = {}
        self._backend_winners: Dict[
            Tuple[str, Tuple[int, ...], int], str
        ] = {}
        self._fitted: Optional[LinkModel] = None

    # -- recording ---------------------------------------------------------

    def record(
        self, coll: str, algo: str, p: int, payload_bytes: int, seconds: float
    ) -> None:
        self.measurements.append(
            Measurement(coll, algo, int(p), int(payload_bytes), float(seconds))
        )
        self._winners = {}  # invalidate
        self._fitted = None

    def record_split(
        self,
        coll: str,
        sizes: Sequence[int],
        order: Sequence[int],
        payload_bytes: int,
        seconds: float,
    ) -> None:
        self.split_measurements.append(
            SplitMeasurement(
                coll,
                tuple(int(s) for s in sizes),
                tuple(int(i) for i in order),
                int(payload_bytes),
                float(seconds),
            )
        )
        self._split_winners = {}  # invalidate

    def record_fusion(
        self,
        coll: str,
        sizes: Sequence[int],
        optimized: bool,
        payload_bytes: int,
        seconds: float,
        chunks: int = 1,
        backend: str = "",
    ) -> None:
        self.fusion_measurements.append(
            FusionMeasurement(
                coll,
                tuple(int(s) for s in sizes),
                bool(optimized),
                int(payload_bytes),
                float(seconds),
                int(chunks),
                str(backend),
            )
        )
        self._fusion_winners = {}  # invalidate
        self._schedule_winners = {}
        self._backend_winners = {}

    def record_schedule(
        self,
        coll: str,
        sizes: Sequence[int],
        optimized: bool,
        chunks: int,
        payload_bytes: int,
        seconds: float,
        backend: str = "",
    ) -> None:
        """One (fused?, chunks) schedule variant sample — the generalized
        form of :meth:`record_fusion` the chunk-aware tuner writes.
        ``backend`` is the lowering backend the sample ran under ("" for
        the mode default)."""
        self.record_fusion(
            coll, sizes, optimized, payload_bytes, seconds, chunks=chunks,
            backend=backend,
        )

    # -- merging -----------------------------------------------------------

    def merge(self, other: "TuningCache") -> "TuningCache":
        """Fold another table's measurements into this one, in place.

        Only tables measured on the *same* backend fingerprint may merge —
        latencies from different hardware are not comparable, and a merged
        table silently mixing them would mis-rank every selection — so a
        mismatch raises. Same-key samples (identical coll/algo/p/payload, or
        coll/sizes/order/payload for splits) keep the lower measured cost:
        re-measurement can only sharpen a winner, never regress it. The
        merged table round-trips through :meth:`save`/:meth:`load_compatible`
        like any single-host table, which is what lets a registry serve one
        pod-wide table assembled from many workers' partial tuning runs.
        """
        if other.backend != self.backend:
            raise ValueError(
                f"cannot merge tuning tables across backends: this table "
                f"was measured on {self.backend!r}, the other on "
                f"{other.backend!r}"
            )
        best: Dict[Tuple[str, str, int, int], Measurement] = {}
        for m in (*self.measurements, *other.measurements):
            key = (m.coll, m.algo, m.p, m.payload_bytes)
            cur = best.get(key)
            if cur is None or m.seconds < cur.seconds:
                best[key] = m
        self.measurements = [best[k] for k in sorted(best)]
        best_split: Dict[
            Tuple[str, Tuple[int, ...], Tuple[int, ...], int],
            SplitMeasurement,
        ] = {}
        for s in (*self.split_measurements, *other.split_measurements):
            key = (s.coll, s.sizes, s.order, s.payload_bytes)
            cur = best_split.get(key)
            if cur is None or s.seconds < cur.seconds:
                best_split[key] = s
        self.split_measurements = [best_split[k] for k in sorted(best_split)]
        best_fusion: Dict[
            Tuple[str, Tuple[int, ...], bool, int, str, int],
            FusionMeasurement,
        ] = {}
        for f in (*self.fusion_measurements, *other.fusion_measurements):
            key = (
                f.coll, f.sizes, f.optimized, f.chunks, f.backend,
                f.payload_bytes,
            )
            cur = best_fusion.get(key)
            if cur is None or f.seconds < cur.seconds:
                best_fusion[key] = f
        self.fusion_measurements = [
            best_fusion[k] for k in sorted(best_fusion)
        ]
        self._winners = {}
        self._split_winners = {}
        self._fusion_winners = {}
        self._schedule_winners = {}
        self._backend_winners = {}
        self._fitted = None
        return self

    # -- reductions --------------------------------------------------------

    @property
    def winners(self) -> Dict[Tuple[str, int, int], str]:
        if not self._winners and self.measurements:
            best: Dict[Tuple[str, int, int], Tuple[float, str]] = {}
            for m in self.measurements:
                key = (m.coll, m.p, m.payload_bytes)
                cur = best.get(key)
                if cur is None or (m.seconds, m.algo) < cur:
                    best[key] = (m.seconds, m.algo)
            self._winners = {k: algo for k, (_, algo) in best.items()}
        return self._winners

    @property
    def split_winners(
        self,
    ) -> Dict[Tuple[str, Tuple[int, ...], int], Tuple[int, ...]]:
        if not self._split_winners and self.split_measurements:
            best: Dict[
                Tuple[str, Tuple[int, ...], int],
                Tuple[float, Tuple[int, ...]],
            ] = {}
            for m in self.split_measurements:
                key = (m.coll, m.sizes, m.payload_bytes)
                cur = best.get(key)
                if cur is None or (m.seconds, m.order) < cur:
                    best[key] = (m.seconds, m.order)
            self._split_winners = {
                k: order for k, (_, order) in best.items()
            }
        return self._split_winners

    @property
    def schedule_winners(
        self,
    ) -> Dict[Tuple[str, Tuple[int, ...], int], Tuple[bool, int]]:
        """(coll, sizes, payload) -> measured-fastest (optimized, chunks).

        Ties break toward the optimized form (the pass pipeline never adds
        communication rounds), then toward fewer chunks (the simpler
        schedule; C=1 is the exact legacy lowering). Only default-backend
        rows compete here: the (optimized, chunks) winner keeps meaning
        "fastest op-per-round schedule" regardless of what the fused-kernel
        lowering measured — the backend choice is a separate reduction
        (:attr:`backend_winners`)."""
        if not self._schedule_winners and self.fusion_measurements:
            best: Dict[
                Tuple[str, Tuple[int, ...], int], Tuple[float, int, int]
            ] = {}
            for m in self.fusion_measurements:
                if m.backend:
                    continue
                key = (m.coll, m.sizes, m.payload_bytes)
                cand = (m.seconds, 0 if m.optimized else 1, m.chunks)
                cur = best.get(key)
                if cur is None or cand < cur:
                    best[key] = cand
            self._schedule_winners = {
                k: (flag == 0, chunks)
                for k, (_, flag, chunks) in best.items()
            }
        return self._schedule_winners

    @property
    def backend_winners(
        self,
    ) -> Dict[Tuple[str, Tuple[int, ...], int], str]:
        """(coll, sizes, payload) -> measured-fastest lowering backend.

        All rows compete across backends; ties break toward "" (the mode
        default — the op-per-round lowering is the reference semantics and
        needs no capability check). Populated only when at least one
        non-default row exists for the grid point, so a table tuned before
        the registry never steers ``backend="auto"``."""
        if not self._backend_winners and self.fusion_measurements:
            pts_with_alt = {
                (m.coll, m.sizes, m.payload_bytes)
                for m in self.fusion_measurements
                if m.backend
            }
            best: Dict[
                Tuple[str, Tuple[int, ...], int], Tuple[float, int, str]
            ] = {}
            for m in self.fusion_measurements:
                key = (m.coll, m.sizes, m.payload_bytes)
                if key not in pts_with_alt:
                    continue
                cand = (m.seconds, 1 if m.backend else 0, m.backend)
                cur = best.get(key)
                if cur is None or cand < cur:
                    best[key] = cand
            self._backend_winners = {
                k: name for k, (_, _, name) in best.items()
            }
        return self._backend_winners

    def backend_winner(
        self, coll: str, sizes: Sequence[int], payload_bytes: int
    ) -> Optional[str]:
        """Measured-fastest lowering backend for this exact mesh shape at
        the nearest measured payload (log2 distance), or None when no
        backend race was ever recorded for the shape —
        ``choose_backend`` then keeps the mode default."""
        sizes = tuple(int(s) for s in sizes)
        best: Optional[Tuple[float, str]] = None
        for (c, gs, gm), name in self.backend_winners.items():
            if c != coll or gs != sizes:
                continue
            dist = abs(
                math.log2(max(payload_bytes, 1)) - math.log2(max(gm, 1))
            )
            if best is None or dist < best[0]:
                best = (dist, name)
        if best is None or best[0] > 4 * _MAX_GRID_DISTANCE:
            return None
        return best[1]

    @property
    def fusion_winners(
        self,
    ) -> Dict[Tuple[str, Tuple[int, ...], int], bool]:
        """(coll, sizes, payload) -> the fused half of the schedule winner
        (kept for callers that only care about the optimizer flag)."""
        if not self._fusion_winners and self.fusion_measurements:
            self._fusion_winners = {
                k: opt for k, (opt, _) in self.schedule_winners.items()
            }
        return self._fusion_winners

    def schedule_winner(
        self, coll: str, sizes: Sequence[int], payload_bytes: int
    ) -> Optional[Tuple[bool, int]]:
        """Measured-fastest (optimized, chunks) schedule for this exact mesh
        shape at the nearest measured payload (log2 distance), or None when
        the shape was never schedule-tuned — ``choose_schedule`` then falls
        back to the plan cost model."""
        sizes = tuple(int(s) for s in sizes)
        best: Optional[Tuple[float, Tuple[bool, int]]] = None
        for (c, gs, gm), win in self.schedule_winners.items():
            if c != coll or gs != sizes:
                continue
            dist = abs(
                math.log2(max(payload_bytes, 1)) - math.log2(max(gm, 1))
            )
            if best is None or dist < best[0]:
                best = (dist, win)
        if best is None or best[0] > 4 * _MAX_GRID_DISTANCE:
            return None
        return best[1]

    def fusion_winner(
        self, coll: str, sizes: Sequence[int], payload_bytes: int
    ) -> Optional[bool]:
        """Measured fused-vs-unfused winner for this exact mesh shape at
        the nearest measured payload (log2 distance), or None when the
        shape was never fusion-tuned — ``choose_optimization`` then falls
        back to the plan cost model."""
        win = self.schedule_winner(coll, sizes, payload_bytes)
        return None if win is None else win[0]

    def fitted_model(self) -> Optional[LinkModel]:
        """Least-squares (alpha, beta, gamma) over the inclusive-scan
        measurements; None until enough samples exist."""
        if self._fitted is None:
            rows, targets = [], []
            for m in self.measurements:
                if m.coll != "scan":
                    continue
                try:
                    rows.append(cost_features(m.algo, m.p, m.payload_bytes))
                except ValueError:
                    continue
                targets.append(m.seconds)
            if len(rows) >= 3:
                coef, *_ = np.linalg.lstsq(
                    np.asarray(rows, dtype=np.float64),
                    np.asarray(targets, dtype=np.float64),
                    rcond=None,
                )
                # a negative fitted constant means the feature is noise at
                # this backend's scale; clamp to a tiny positive epsilon so
                # the model stays physical (and ties still break on steps).
                a, b, g = (max(float(c), 1e-12) for c in coef)
                self._fitted = LinkModel(alpha=a, beta=b, gamma=g, ring=True)
        return self._fitted

    # -- selector interface ------------------------------------------------

    def lookup(
        self, p: int, payload_bytes: int, coll: str = "scan"
    ) -> Optional[str]:
        """Measured winner at the nearest grid point, or None when the query
        is too far from everything measured (off-grid -> fitted model)."""
        table = self.winners
        best: Optional[Tuple[float, str]] = None
        for (c, gp, gm), algo in table.items():
            if c != coll:
                continue
            dist = abs(math.log2(max(p, 1)) - math.log2(max(gp, 1))) + 0.25 * abs(
                math.log2(max(payload_bytes, 1)) - math.log2(max(gm, 1))
            )
            if best is None or dist < best[0]:
                best = (dist, algo)
        if best is None or best[0] > _MAX_GRID_DISTANCE:
            return None
        return best[1]

    def split_winner(
        self, coll: str, sizes: Sequence[int], payload_bytes: int
    ) -> Optional[Tuple[int, ...]]:
        """Measured-fastest logical axis order for this exact mesh shape, at
        the nearest measured payload (log2 distance); None when this shape
        (or coll) was never split-tuned — the planner then falls back to the
        fitted cost model."""
        sizes = tuple(int(s) for s in sizes)
        best: Optional[Tuple[float, Tuple[int, ...]]] = None
        for (c, gs, gm), order in self.split_winners.items():
            if c != coll or gs != sizes:
                continue
            dist = abs(
                math.log2(max(payload_bytes, 1)) - math.log2(max(gm, 1))
            )
            if best is None or dist < best[0]:
                best = (dist, order)
        if best is None or best[0] > 4 * _MAX_GRID_DISTANCE:
            return None
        return best[1]

    # -- persistence -------------------------------------------------------

    def to_json(self) -> dict:
        fitted = self.fitted_model()
        return {
            "schema_version": SCHEMA_VERSION,
            "backend": self.backend,
            "measurements": [m.to_json() for m in self.measurements],
            "split_measurements": [
                m.to_json() for m in self.split_measurements
            ],
            "fusion_measurements": [
                m.to_json() for m in self.fusion_measurements
            ],
            "winners": [
                {"coll": c, "p": p, "payload_bytes": m, "algo": algo}
                for (c, p, m), algo in sorted(self.winners.items())
            ],
            "fitted": None
            if fitted is None
            else {
                "alpha": fitted.alpha,
                "beta": fitted.beta,
                "gamma": fitted.gamma,
                "ring": fitted.ring,
            },
        }

    def save(self, path: "str | Path") -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json(), indent=2))
        return path

    @classmethod
    def load(cls, path: "str | Path") -> "TuningCache":
        d = json.loads(Path(path).read_text())
        if d.get("schema_version") != SCHEMA_VERSION:
            raise ValueError(
                f"tuning table {path} has schema {d.get('schema_version')}, "
                f"expected {SCHEMA_VERSION}"
            )
        cache = cls(backend=d.get("backend"))
        for m in d.get("measurements", []):
            cache.measurements.append(Measurement.from_json(m))
        for m in d.get("split_measurements", []):
            cache.split_measurements.append(SplitMeasurement.from_json(m))
        for m in d.get("fusion_measurements", []):
            cache.fusion_measurements.append(FusionMeasurement.from_json(m))
        f = d.get("fitted")
        if f is not None:
            cache._fitted = LinkModel(
                alpha=float(f["alpha"]),
                beta=float(f["beta"]),
                gamma=float(f["gamma"]),
                ring=bool(f.get("ring", True)),
            )
        return cache

    @classmethod
    def load_compatible(cls, path: "str | Path") -> "Optional[TuningCache]":
        """Load a table only if it was fitted on *this* backend.

        Ambient tables (``$REPRO_TUNING_TABLE`` / the default cache path)
        travel with home directories and container images; silently applying
        constants measured on a different backend would mis-rank every
        schedule. On a fingerprint mismatch this warns and returns None so
        callers fall back to the static constants; ``load()`` keeps the
        strict raise-on-schema-only behavior for explicitly named tables.
        """
        cache = cls.load(path)
        current = _backend_fingerprint()
        if cache.backend != current:
            warnings.warn(
                f"tuning table {path} was measured on backend "
                f"{cache.backend!r} but this process runs on {current!r}; "
                "ignoring it (static cost constants stay active). Re-run "
                "the autotuner on this backend to regenerate it.",
                RuntimeWarning,
                stacklevel=2,
            )
            return None
        return cache

    # -- activation --------------------------------------------------------

    def activate(self) -> "TuningCache":
        """Make this table the one ``select_algorithm`` consults."""
        set_active_tuning(self)
        return self


def deactivate() -> None:
    set_active_tuning(None)


def load_default_table() -> Optional[TuningCache]:
    """Load + activate the table named by ``$REPRO_TUNING_TABLE``, if any.

    Fingerprint-checked: a table measured on another backend is ignored
    (with a warning) rather than activated.
    """
    path = os.environ.get(TUNING_TABLE_ENV)
    if not path or not Path(path).exists():
        return None
    cache = TuningCache.load_compatible(path)
    return cache.activate() if cache is not None else None


def _backend_fingerprint() -> str:
    try:
        import jax

        dev = jax.devices()[0]
        return f"{dev.platform}:{dev.device_kind}:{platform.machine()}"
    except Exception:  # pragma: no cover - jax init failure
        return f"unknown:{platform.machine()}"
