"""Persistent tuning table: measured latencies, per-point winners, and the
least-squares-fitted LinkModel.

The NetFPGA paper leaves ``algo_type`` to the host runtime's "intelligent
selection"; this module is where that intelligence persists. The autotuner
(:mod:`repro.offload.tuner`) records micro-benchmark latencies for every
(coll, algorithm, p, payload) grid point, this cache reduces them to

  * ``winners`` — the measured-fastest applicable algorithm per grid point,
    consulted first by ``select_algorithm`` (nearest grid point in log2
    space when the query falls off-grid);
  * ``fitted`` — alpha/beta/gamma solved from the measurements against
    :func:`repro.core.selector.cost_features`, used for points too far from
    any measurement;

and round-trips the whole table through JSON so one tuning run serves every
subsequent process on the same backend (`REPRO_TUNING_TABLE` env var or an
explicit ``load``).
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import platform
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.selector import (
    LinkModel,
    cost_features,
    set_active_tuning,
)

SCHEMA_VERSION = 1

#: env var pointing at a tuning table to auto-load at launch
TUNING_TABLE_ENV = "REPRO_TUNING_TABLE"

# Queries farther than this (in |log2| distance on p and payload combined)
# from every measured grid point fall through to the fitted model.
_MAX_GRID_DISTANCE = 3.0


@dataclasses.dataclass(frozen=True)
class Measurement:
    """One micro-benchmark sample: median seconds for a full collective."""

    coll: str            # "scan" | "exscan"
    algo: str
    p: int
    payload_bytes: int
    seconds: float

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "Measurement":
        return Measurement(
            coll=str(d["coll"]),
            algo=str(d["algo"]),
            p=int(d["p"]),
            payload_bytes=int(d["payload_bytes"]),
            seconds=float(d["seconds"]),
        )


class TuningCache:
    """Measurements + winners + fitted model, with JSON persistence."""

    def __init__(self, *, backend: Optional[str] = None):
        self.backend = backend or _backend_fingerprint()
        self.measurements: List[Measurement] = []
        self._winners: Dict[Tuple[str, int, int], str] = {}
        self._fitted: Optional[LinkModel] = None

    # -- recording ---------------------------------------------------------

    def record(
        self, coll: str, algo: str, p: int, payload_bytes: int, seconds: float
    ) -> None:
        self.measurements.append(
            Measurement(coll, algo, int(p), int(payload_bytes), float(seconds))
        )
        self._winners = {}  # invalidate
        self._fitted = None

    # -- reductions --------------------------------------------------------

    @property
    def winners(self) -> Dict[Tuple[str, int, int], str]:
        if not self._winners and self.measurements:
            best: Dict[Tuple[str, int, int], Tuple[float, str]] = {}
            for m in self.measurements:
                key = (m.coll, m.p, m.payload_bytes)
                cur = best.get(key)
                if cur is None or (m.seconds, m.algo) < cur:
                    best[key] = (m.seconds, m.algo)
            self._winners = {k: algo for k, (_, algo) in best.items()}
        return self._winners

    def fitted_model(self) -> Optional[LinkModel]:
        """Least-squares (alpha, beta, gamma) over the inclusive-scan
        measurements; None until enough samples exist."""
        if self._fitted is None:
            rows, targets = [], []
            for m in self.measurements:
                if m.coll != "scan":
                    continue
                try:
                    rows.append(cost_features(m.algo, m.p, m.payload_bytes))
                except ValueError:
                    continue
                targets.append(m.seconds)
            if len(rows) >= 3:
                coef, *_ = np.linalg.lstsq(
                    np.asarray(rows, dtype=np.float64),
                    np.asarray(targets, dtype=np.float64),
                    rcond=None,
                )
                # a negative fitted constant means the feature is noise at
                # this backend's scale; clamp to a tiny positive epsilon so
                # the model stays physical (and ties still break on steps).
                a, b, g = (max(float(c), 1e-12) for c in coef)
                self._fitted = LinkModel(alpha=a, beta=b, gamma=g, ring=True)
        return self._fitted

    # -- selector interface ------------------------------------------------

    def lookup(
        self, p: int, payload_bytes: int, coll: str = "scan"
    ) -> Optional[str]:
        """Measured winner at the nearest grid point, or None when the query
        is too far from everything measured (off-grid -> fitted model)."""
        table = self.winners
        best: Optional[Tuple[float, str]] = None
        for (c, gp, gm), algo in table.items():
            if c != coll:
                continue
            dist = abs(math.log2(max(p, 1)) - math.log2(max(gp, 1))) + 0.25 * abs(
                math.log2(max(payload_bytes, 1)) - math.log2(max(gm, 1))
            )
            if best is None or dist < best[0]:
                best = (dist, algo)
        if best is None or best[0] > _MAX_GRID_DISTANCE:
            return None
        return best[1]

    # -- persistence -------------------------------------------------------

    def to_json(self) -> dict:
        fitted = self.fitted_model()
        return {
            "schema_version": SCHEMA_VERSION,
            "backend": self.backend,
            "measurements": [m.to_json() for m in self.measurements],
            "winners": [
                {"coll": c, "p": p, "payload_bytes": m, "algo": algo}
                for (c, p, m), algo in sorted(self.winners.items())
            ],
            "fitted": None
            if fitted is None
            else {
                "alpha": fitted.alpha,
                "beta": fitted.beta,
                "gamma": fitted.gamma,
                "ring": fitted.ring,
            },
        }

    def save(self, path: "str | Path") -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json(), indent=2))
        return path

    @classmethod
    def load(cls, path: "str | Path") -> "TuningCache":
        d = json.loads(Path(path).read_text())
        if d.get("schema_version") != SCHEMA_VERSION:
            raise ValueError(
                f"tuning table {path} has schema {d.get('schema_version')}, "
                f"expected {SCHEMA_VERSION}"
            )
        cache = cls(backend=d.get("backend"))
        for m in d.get("measurements", []):
            cache.measurements.append(Measurement.from_json(m))
        f = d.get("fitted")
        if f is not None:
            cache._fitted = LinkModel(
                alpha=float(f["alpha"]),
                beta=float(f["beta"]),
                gamma=float(f["gamma"]),
                ring=bool(f.get("ring", True)),
            )
        return cache

    # -- activation --------------------------------------------------------

    def activate(self) -> "TuningCache":
        """Make this table the one ``select_algorithm`` consults."""
        set_active_tuning(self)
        return self


def deactivate() -> None:
    set_active_tuning(None)


def load_default_table() -> Optional[TuningCache]:
    """Load + activate the table named by ``$REPRO_TUNING_TABLE``, if any."""
    path = os.environ.get(TUNING_TABLE_ENV)
    if not path or not Path(path).exists():
        return None
    return TuningCache.load(path).activate()


def _backend_fingerprint() -> str:
    try:
        import jax

        dev = jax.devices()[0]
        return f"{dev.platform}:{dev.device_kind}:{platform.machine()}"
    except Exception:  # pragma: no cover - jax init failure
        return f"unknown:{platform.machine()}"
