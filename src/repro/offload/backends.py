"""The lowering-backend registry: how a ``CollectivePlan`` becomes code.

``planner.py`` owns the plan IR and the two op-per-round lowerings
(``lower_sim``, ``lower_spmd``); this module owns the *contract* those
lowerings satisfy, which used to be implicit in mode branches spread across
the engine, the passes, and the tuner. A :class:`LoweringBackend` exposes:

  name           registry key ("sim", "spmd", "pallas")
  capabilities   can this backend lower this plan (under these axis names)?
                 Returns ``(ok, reason)`` with a stable reason token so the
                 engine can attribute fallbacks in telemetry.
  lower          plan -> schedule callable, same calling convention as the
                 planner lowerings (stacked ``(p, ...)`` leaves without
                 ``axis_names``, per-rank under ``shard_map`` with them)
  fingerprint    extra cache-key fields. Empty for the mode defaults, so
                 every pre-registry cache key (and the broker's group keys)
                 stays byte-identical; a non-default backend contributes
                 ``(("backend", name),)`` and gets its own cache rows.

``resolve`` is the single soft-fallback point: ask for a backend by name,
get the default back (plus the capability-miss reason) when the plan is
outside the named backend's support — the engine counts those in
``EngineTelemetry.backend_fallbacks``.

The legacy two-level hierarchical entry points (previously
``repro.offload.hierarchical``) live here too: they are exactly the
registry-backed API applied to a 2-axis plan, so the thin-wrapper module
was folded in.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Protocol, Sequence, Tuple

import jax

from repro.core.operators import AssocOp, get_operator
from repro.core.scan_collective import _payload_bytes
from repro.offload.planner import (
    CollectivePlan,
    build_plan,
    lower_sim,
    lower_spmd,
)

PyTree = Any

#: name the wire format / descriptors use for "whatever the mode default
#: is" — encodes as backend id 0, so default descriptors keep their bytes
DEFAULT_BACKEND = ""


class LoweringBackend(Protocol):
    """The contract a plan lowering plugs into the registry with."""

    name: str

    def capabilities(
        self,
        plan: CollectivePlan,
        axis_names: Optional[Sequence[str]] = None,
    ) -> Tuple[bool, str]:
        """``(ok, reason)`` — can this backend lower ``plan``? ``reason``
        is a stable telemetry token when it can't ("" when it can)."""
        ...

    def lower(
        self,
        plan: CollectivePlan,
        op: "AssocOp | str | None" = None,
        *,
        axis_names: Optional[Sequence[str]] = None,
        traced: bool = False,
    ) -> Callable:
        """Compile ``plan`` to a schedule callable."""
        ...

    def fingerprint(self) -> Tuple[Tuple[str, str], ...]:
        """Cache-key fields this backend adds. MUST be empty for the mode
        defaults (key stability); non-defaults return (("backend", name),)."""
        ...


@dataclasses.dataclass(frozen=True)
class SimLowering:
    """Op-per-round interpreter over stacked leaves (the engine's sim mode)."""

    name: str = "sim"

    def capabilities(self, plan, axis_names=None):
        if axis_names is not None:
            return False, "needs_stacked_input"
        return True, ""

    def lower(self, plan, op=None, *, axis_names=None, traced=False):
        return lower_sim(plan, op, traced=traced)

    def fingerprint(self):
        return ()


@dataclasses.dataclass(frozen=True)
class SpmdLowering:
    """Op-per-round ppermute schedule inside shard_map (spmd/driver modes)."""

    name: str = "spmd"

    def capabilities(self, plan, axis_names=None):
        if axis_names is None:
            return False, "needs_axis_names"
        return True, ""

    def lower(self, plan, op=None, *, axis_names=None, traced=False):
        return lower_spmd(plan, axis_names, op)

    def fingerprint(self):
        return ()


@dataclasses.dataclass(frozen=True)
class PallasLowering:
    """Fused-kernel backend: every exchange round of a comm phase runs
    inside one Pallas kernel (``repro.kernels.pallas_collective``)."""

    name: str = "pallas"

    def capabilities(self, plan, axis_names=None):
        from repro.kernels import pallas_collective

        return pallas_collective.supports_plan(plan, axis_names)

    def lower(self, plan, op=None, *, axis_names=None, traced=False):
        from repro.kernels import pallas_collective

        return pallas_collective.lower_pallas(
            plan, op, axis_names=axis_names, traced=traced
        )

    def fingerprint(self):
        return (("backend", self.name),)


_REGISTRY: Dict[str, LoweringBackend] = {}


def register_backend(backend: LoweringBackend) -> LoweringBackend:
    _REGISTRY[backend.name] = backend
    return backend


def backend_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def default_backend_name(
    axis_names: Optional[Sequence[str]] = None,
) -> str:
    """The backend a mode resolves to when none is named: the op-per-round
    interpreter for stacked inputs, the ppermute schedule under shard_map."""
    return "sim" if axis_names is None else "spmd"


def get_backend(name: str) -> LoweringBackend:
    key = name or DEFAULT_BACKEND
    if key == DEFAULT_BACKEND:
        raise ValueError(
            "the default backend is mode-dependent; resolve it with "
            "default_backend_name(axis_names)"
        )
    try:
        return _REGISTRY[key]
    except KeyError:
        raise ValueError(
            f"unknown lowering backend {name!r}; registered: "
            f"{', '.join(backend_names())}"
        ) from None


def resolve(
    name: str,
    plan: CollectivePlan,
    axis_names: Optional[Sequence[str]] = None,
) -> Tuple[LoweringBackend, str]:
    """Resolve ``name`` for ``plan``, soft-falling back to the mode default.

    Returns ``(backend, fallback_reason)``; ``fallback_reason`` is "" when
    the named backend (or the default, for ``name == ""``) was used, and
    the capability-miss token when the request fell back — the engine
    counts those per reason in telemetry. Unknown names raise (a typo is a
    bug, a capability miss is not).
    """
    default = _REGISTRY[default_backend_name(axis_names)]
    if (name or DEFAULT_BACKEND) == DEFAULT_BACKEND:
        return default, ""
    backend = get_backend(name)
    if backend.name == default.name:
        return default, ""
    ok, reason = backend.capabilities(plan, axis_names)
    if ok:
        return backend, ""
    return default, reason or "unsupported"


register_backend(SimLowering())
register_backend(SpmdLowering())
register_backend(PallasLowering())


# ---------------------------------------------------------------------------
# Two-level hierarchical entry points (folded in from offload/hierarchical)
# ---------------------------------------------------------------------------
#
# The original module hand-rolled the classic block-scan decomposition
# (intra-row scan, carry exscan along the orthogonal axis, guarded local
# combine) for 2D meshes; that schedule is now just a 2-axis plan lowered
# through the registry default. With global rank order outer-major
# (global = outer * p_inner + inner) the result equals the flat single-axis
# scan over p_outer * p_inner ranks — bitwise, because carries always enter
# the combine on the left.


def _two_level_plan(op, sizes, payload_bytes, *, inclusive, algorithms):
    return build_plan(
        "SCAN" if inclusive else "EXSCAN",
        sizes,
        op,
        payload_bytes,
        order=(0, 1),
        level_algorithms=algorithms,
    )


def dist_hierarchical_scan(
    x: PyTree,
    op: "AssocOp | str",
    inner_axis: str,
    outer_axis: str,
    *,
    inclusive: bool = True,
    inner_algorithm: str = "auto",
    outer_algorithm: str = "auto",
) -> PyTree:
    """Two-level scan across ``outer_axis``-major ``inner_axis``-minor order.

    Call inside ``shard_map`` over a mesh with both axes active. Equivalent
    to a flat scan over the p_outer * p_inner ranks in (outer, inner) order,
    but each phase's schedule only ever spans one axis — which is what keeps
    every hop on a physical ring of the 2D torus.
    """
    from repro.compat import axis_size

    op = get_operator(op)
    axis_names = (outer_axis, inner_axis)
    plan = _two_level_plan(
        op,
        (axis_size(outer_axis), axis_size(inner_axis)),
        _payload_bytes(x),
        inclusive=inclusive,
        algorithms=(outer_algorithm, inner_algorithm),
    )
    backend, _ = resolve(DEFAULT_BACKEND, plan, axis_names)
    return backend.lower(plan, op, axis_names=axis_names)(x)


def sim_hierarchical_scan(
    stacked: PyTree,
    op: "AssocOp | str",
    p_outer: int,
    p_inner: int,
    *,
    inclusive: bool = True,
    inner_algorithm: str = "hillis_steele",
    outer_algorithm: str = "hillis_steele",
) -> PyTree:
    """Single-device realization over stacked (p_outer, p_inner, ...) leaves."""
    op = get_operator(op)
    plan = _two_level_plan(
        op,
        (p_outer, p_inner),
        _payload_bytes(stacked),
        inclusive=inclusive,
        algorithms=(outer_algorithm, inner_algorithm),
    )
    backend, _ = resolve(DEFAULT_BACKEND, plan)
    flat = flat_equivalent(stacked, p_outer, p_inner)
    out = backend.lower(plan, op)(flat)
    return jax.tree.map(
        lambda a: a.reshape((p_outer, p_inner) + a.shape[1:]), out
    )


def flat_equivalent(
    stacked_2d: PyTree, p_outer: int, p_inner: int
) -> PyTree:
    """Reshape a (p_outer, p_inner, ...) stacked pytree to the flat
    (p_outer * p_inner, ...) layout the hierarchical result must match."""
    return jax.tree.map(
        lambda a: a.reshape((p_outer * p_inner,) + a.shape[2:]), stacked_2d
    )
