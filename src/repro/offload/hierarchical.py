"""Hierarchical (two-level) scans over 2D meshes.

A single ring/axis is the paper's world (8 hosts, one NetFPGA). To scale the
offloaded scan past one axis we use the classic block-scan decomposition —
the same idiom every work-efficient GPU scan uses across thread blocks:

  1. **intra-axis scan**: every row (the fast, inner mesh axis) runs the
     ordinary offloaded inclusive scan;
  2. **carry exscan**: each row's total is exclusive-scanned along the
     orthogonal (outer) axis — the "block sums" pass;
  3. **local combine**: every rank folds its incoming outer carry into its
     intra-row prefix (rows on the first outer rank keep theirs as-is).

With global rank order defined outer-major (global = outer * p_inner +
inner), the result equals the flat single-axis scan over p_outer * p_inner
ranks — bitwise, whenever the operator's combine order is respected (it is:
carries always enter on the left).

Both realizations of the repo's backend pair are provided:
``dist_hierarchical_scan`` composes the SPMD collectives over two named mesh
axes inside ``shard_map``; ``sim_hierarchical_scan`` runs the identical
schedule on stacked ``(p_outer, p_inner, ...)`` arrays for tests and
benchmarks.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import algorithms as alg
from repro.core.operators import AssocOp, get_operator
from repro.core.reduce_ops import allreduce_schedule
from repro.core.scan_collective import dist_exscan, dist_scan, sim_scan

PyTree = Any


def dist_hierarchical_scan(
    x: PyTree,
    op: "AssocOp | str",
    inner_axis: str,
    outer_axis: str,
    *,
    inclusive: bool = True,
    inner_algorithm: str = "auto",
    outer_algorithm: str = "auto",
) -> PyTree:
    """Two-level scan across ``outer_axis``-major ``inner_axis``-minor order.

    Call inside ``shard_map`` over a mesh with both axes active. Equivalent to
    a flat scan over the p_outer * p_inner ranks in (outer, inner) order, but
    each phase's schedule only ever spans one axis — which is what keeps every
    hop on a physical ring of the 2D torus.
    """
    op = get_operator(op)
    # Phase 1: intra-row prefix in whichever form the caller wants (row
    # totals come from the allreduce below, not from the inclusive scan).
    if inclusive:
        y_local = dist_scan(x, op, inner_axis, algorithm=inner_algorithm)
    else:
        y_local = dist_exscan(x, op, inner_axis, algorithm=inner_algorithm)
    # Phase 2: row totals everywhere (order-respecting allreduce), then the
    # carry exscan along the orthogonal axis.
    total = allreduce_schedule(
        alg.SpmdBackend(inner_axis), x, op
    )
    carry = dist_exscan(total, op, outer_axis, algorithm=outer_algorithm)
    # Phase 3: local combine; the first outer rank has no incoming carry.
    out = op.combine(carry, y_local)
    outer_rank = lax.axis_index(outer_axis)
    return alg._bwhere(outer_rank == 0, y_local, out)


def sim_hierarchical_scan(
    stacked: PyTree,
    op: "AssocOp | str",
    p_outer: int,
    p_inner: int,
    *,
    inclusive: bool = True,
    inner_algorithm: str = "hillis_steele",
    outer_algorithm: str = "hillis_steele",
) -> PyTree:
    """Single-device realization over stacked (p_outer, p_inner, ...) leaves."""
    op = get_operator(op)

    def swap(tree: PyTree) -> PyTree:
        return jax.tree.map(lambda a: jnp.swapaxes(a, 0, 1), tree)

    # Phase 1: inner scans, outer axis riding along as payload.
    y = swap(
        sim_scan(swap(stacked), op, p_inner, algorithm=inner_algorithm)
    )
    y_local = y
    if not inclusive:
        y_local = swap(
            sim_scan(
                swap(stacked),
                op,
                p_inner,
                algorithm=inner_algorithm,
                inclusive=False,
            )
        )
    # Phase 2: row totals are the last inner column; carry-exscan them.
    totals = jax.tree.map(lambda a: a[:, p_inner - 1], y)
    carry = sim_scan(
        totals, op, p_outer, algorithm=outer_algorithm, inclusive=False
    )
    carry_wide = jax.tree.map(lambda a: jnp.expand_dims(a, 1), carry)
    # Phase 3: local combine, first outer row exempt.
    out = op.combine(carry_wide, y_local)
    first_outer = (jnp.arange(p_outer) == 0)[:, None]
    return alg._bwhere(first_outer, y_local, out)


def flat_equivalent(
    stacked_2d: PyTree, p_outer: int, p_inner: int
) -> PyTree:
    """Reshape a (p_outer, p_inner, ...) stacked pytree to the flat
    (p_outer * p_inner, ...) layout the hierarchical result must match."""
    return jax.tree.map(
        lambda a: a.reshape((p_outer * p_inner,) + a.shape[2:]), stacked_2d
    )
