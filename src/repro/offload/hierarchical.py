"""Compatibility wrappers: two-level (2D-mesh) scans via the planner.

This module used to hand-roll the classic block-scan decomposition (intra-row
scan, carry exscan along the orthogonal axis, guarded local combine) for 2D
meshes only. That schedule is now one instance of the general collective
planner (:mod:`repro.offload.planner`), which builds the same phase list —
for any CollType, over 1-3 mesh axes, with tuned axis splits — as a
:class:`~repro.offload.planner.CollectivePlan` and lowers it through both
backends. The entry points below keep the original signatures so existing
callers and tests keep working; new code should plan directly::

    plan = build_plan("SCAN", (p_outer, p_inner), op, payload_bytes)
    out = lower_sim(plan)(flat_stacked)

With global rank order defined outer-major (global = outer * p_inner +
inner), the planned result equals the flat single-axis scan over
p_outer * p_inner ranks — bitwise, whenever the operator's combine order is
respected (it is: carries always enter on the left).
"""

from __future__ import annotations

from typing import Any

import jax

from repro.core.operators import AssocOp, get_operator
from repro.core.scan_collective import _payload_bytes
from repro.offload.planner import build_plan, lower_sim, lower_spmd

PyTree = Any


def dist_hierarchical_scan(
    x: PyTree,
    op: "AssocOp | str",
    inner_axis: str,
    outer_axis: str,
    *,
    inclusive: bool = True,
    inner_algorithm: str = "auto",
    outer_algorithm: str = "auto",
) -> PyTree:
    """Two-level scan across ``outer_axis``-major ``inner_axis``-minor order.

    Call inside ``shard_map`` over a mesh with both axes active. Equivalent
    to a flat scan over the p_outer * p_inner ranks in (outer, inner) order,
    but each phase's schedule only ever spans one axis — which is what keeps
    every hop on a physical ring of the 2D torus.
    """
    from repro.compat import axis_size

    op = get_operator(op)
    sizes = (axis_size(outer_axis), axis_size(inner_axis))
    plan = build_plan(
        "SCAN" if inclusive else "EXSCAN",
        sizes,
        op,
        _payload_bytes(x),
        order=(0, 1),
        level_algorithms=(outer_algorithm, inner_algorithm),
    )
    return lower_spmd(plan, (outer_axis, inner_axis), op)(x)


def sim_hierarchical_scan(
    stacked: PyTree,
    op: "AssocOp | str",
    p_outer: int,
    p_inner: int,
    *,
    inclusive: bool = True,
    inner_algorithm: str = "hillis_steele",
    outer_algorithm: str = "hillis_steele",
) -> PyTree:
    """Single-device realization over stacked (p_outer, p_inner, ...) leaves."""
    op = get_operator(op)
    plan = build_plan(
        "SCAN" if inclusive else "EXSCAN",
        (p_outer, p_inner),
        op,
        _payload_bytes(stacked),
        order=(0, 1),
        level_algorithms=(outer_algorithm, inner_algorithm),
    )
    flat = flat_equivalent(stacked, p_outer, p_inner)
    out = lower_sim(plan, op)(flat)
    return jax.tree.map(
        lambda a: a.reshape((p_outer, p_inner) + a.shape[1:]), out
    )


def flat_equivalent(
    stacked_2d: PyTree, p_outer: int, p_inner: int
) -> PyTree:
    """Reshape a (p_outer, p_inner, ...) stacked pytree to the flat
    (p_outer * p_inner, ...) layout the hierarchical result must match."""
    return jax.tree.map(
        lambda a: a.reshape((p_outer * p_inner,) + a.shape[2:]), stacked_2d
    )
