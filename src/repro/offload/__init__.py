"""Descriptor-driven offload engine — the software analogue of the paper's
NIC firmware.

  OffloadEngine          — one descriptor in, one result out, with a
                           compiled-schedule cache + telemetry (engine)
  planner                — topology-aware collective planner: CollectivePlan
                           IR, N-level decomposition for every CollType,
                           tuned axis splits, sim + spmd lowering (planner)
  autotune / TuningCache — measured-cost autotuner + persisted tuning table
                           that re-fits the selector's LinkModel and records
                           axis-split winners (tuner, tuning_cache)
  backends               — the lowering-backend registry: sim/spmd/pallas
                           behind one LoweringBackend contract, plus the
                           legacy two-level *_hierarchical_scan entry points
                           (backends)
"""

from repro.offload.backends import (
    DEFAULT_BACKEND,
    LoweringBackend,
    PallasLowering,
    SimLowering,
    SpmdLowering,
    backend_names,
    default_backend_name,
    dist_hierarchical_scan,
    flat_equivalent,
    get_backend,
    register_backend,
    resolve,
    sim_hierarchical_scan,
)
from repro.offload.engine import (
    COLL_KIND,
    CompiledSchedule,
    EngineTelemetry,
    OffloadEngine,
    wire_dtype,
    wire_op_id,
    wire_op_name,
)
from repro.offload.passes import (
    CHUNK_CANDIDATES,
    PASS_NAMES,
    choose_backend,
    choose_optimization,
    choose_schedule,
    eliminate_dead_phases,
    fuse_scan_total,
    optimize_plan,
    plan_comm_rounds,
    select_chunking,
)
from repro.offload.planner import (
    CollectivePlan,
    PhaseKind,
    PlanLayout,
    PlanPhase,
    build_plan,
    lower_sim,
    lower_spmd,
    plan_axis_order,
    plan_cost,
    plan_layout,
    plan_layout_moves,
)
from repro.offload.profiling import (
    DeviceTiming,
    parse_device_us,
    profile_offload,
)
from repro.offload.tuner import (
    DEFAULT_CHUNKS,
    DEFAULT_PAYLOADS,
    DEFAULT_PS,
    DEFAULT_TOPOLOGIES,
    amortize_inner,
    autotune,
    time_planned_collective,
    time_sim_collective,
    tune_fusion,
    tune_schedule,
    tune_splits,
)
from repro.offload.tuning_cache import (
    TUNING_TABLE_ENV,
    FusionMeasurement,
    Measurement,
    SplitMeasurement,
    TuningCache,
    deactivate,
    load_default_table,
)

# keep this import last: reliability pulls repro.runtime (for the chaos
# error types), whose trainer stack re-enters this package mid-init and
# needs every name above already bound
from repro.offload.reliability import (  # noqa: E402
    CircuitBreaker,
    CircuitOpenError,
    IntegrityError,
    ReliabilityPolicy,
    ReliableDispatcher,
    RetryExhaustedError,
    RetryPolicy,
    payload_checksum,
    reference_collective,
    verify_payload,
)

__all__ = [
    "CircuitBreaker",
    "CircuitOpenError",
    "IntegrityError",
    "ReliabilityPolicy",
    "ReliableDispatcher",
    "RetryExhaustedError",
    "RetryPolicy",
    "payload_checksum",
    "reference_collective",
    "verify_payload",
    "CHUNK_CANDIDATES",
    "COLL_KIND",
    "CollectivePlan",
    "CompiledSchedule",
    "DEFAULT_BACKEND",
    "DEFAULT_CHUNKS",
    "DEFAULT_PAYLOADS",
    "DEFAULT_PS",
    "DEFAULT_TOPOLOGIES",
    "DeviceTiming",
    "EngineTelemetry",
    "FusionMeasurement",
    "LoweringBackend",
    "Measurement",
    "OffloadEngine",
    "PASS_NAMES",
    "PallasLowering",
    "PhaseKind",
    "SimLowering",
    "SpmdLowering",
    "PlanLayout",
    "PlanPhase",
    "SplitMeasurement",
    "TUNING_TABLE_ENV",
    "TuningCache",
    "amortize_inner",
    "autotune",
    "backend_names",
    "build_plan",
    "choose_backend",
    "choose_optimization",
    "choose_schedule",
    "deactivate",
    "default_backend_name",
    "dist_hierarchical_scan",
    "get_backend",
    "register_backend",
    "resolve",
    "eliminate_dead_phases",
    "flat_equivalent",
    "fuse_scan_total",
    "load_default_table",
    "lower_sim",
    "lower_spmd",
    "optimize_plan",
    "parse_device_us",
    "plan_axis_order",
    "plan_comm_rounds",
    "plan_cost",
    "plan_layout",
    "plan_layout_moves",
    "profile_offload",
    "select_chunking",
    "sim_hierarchical_scan",
    "time_planned_collective",
    "time_sim_collective",
    "tune_fusion",
    "tune_schedule",
    "tune_splits",
    "wire_dtype",
    "wire_op_id",
    "wire_op_name",
]
