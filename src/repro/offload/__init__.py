"""Descriptor-driven offload engine — the software analogue of the paper's
NIC firmware.

  OffloadEngine          — one descriptor in, one result out, with a
                           compiled-schedule cache + telemetry (engine)
  planner                — topology-aware collective planner: CollectivePlan
                           IR, N-level decomposition for every CollType,
                           tuned axis splits, sim + spmd lowering (planner)
  autotune / TuningCache — measured-cost autotuner + persisted tuning table
                           that re-fits the selector's LinkModel and records
                           axis-split winners (tuner, tuning_cache)
  *_hierarchical_scan    — legacy two-level 2D entry points, now thin
                           wrappers over the planner (hierarchical)
"""

from repro.offload.engine import (
    COLL_KIND,
    CompiledSchedule,
    EngineTelemetry,
    OffloadEngine,
    wire_dtype,
    wire_op_id,
    wire_op_name,
)
from repro.offload.hierarchical import (
    dist_hierarchical_scan,
    flat_equivalent,
    sim_hierarchical_scan,
)
from repro.offload.planner import (
    CollectivePlan,
    PhaseKind,
    PlanLayout,
    PlanPhase,
    build_plan,
    lower_sim,
    lower_spmd,
    plan_axis_order,
    plan_cost,
    plan_layout,
)
from repro.offload.tuner import (
    DEFAULT_PAYLOADS,
    DEFAULT_PS,
    DEFAULT_TOPOLOGIES,
    autotune,
    time_planned_collective,
    time_sim_collective,
    tune_splits,
)
from repro.offload.tuning_cache import (
    TUNING_TABLE_ENV,
    Measurement,
    SplitMeasurement,
    TuningCache,
    deactivate,
    load_default_table,
)

__all__ = [
    "COLL_KIND",
    "CollectivePlan",
    "CompiledSchedule",
    "DEFAULT_PAYLOADS",
    "DEFAULT_PS",
    "DEFAULT_TOPOLOGIES",
    "EngineTelemetry",
    "Measurement",
    "OffloadEngine",
    "PhaseKind",
    "PlanLayout",
    "PlanPhase",
    "SplitMeasurement",
    "TUNING_TABLE_ENV",
    "TuningCache",
    "autotune",
    "build_plan",
    "deactivate",
    "dist_hierarchical_scan",
    "flat_equivalent",
    "load_default_table",
    "lower_sim",
    "lower_spmd",
    "plan_axis_order",
    "plan_cost",
    "plan_layout",
    "sim_hierarchical_scan",
    "time_planned_collective",
    "time_sim_collective",
    "tune_splits",
    "wire_dtype",
    "wire_op_id",
    "wire_op_name",
]
