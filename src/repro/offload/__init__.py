"""Descriptor-driven offload engine — the software analogue of the paper's
NIC firmware.

  OffloadEngine          — one descriptor in, one result out, with a
                           compiled-schedule cache + telemetry (engine)
  autotune / TuningCache — measured-cost autotuner + persisted tuning table
                           that re-fits the selector's LinkModel (tuner,
                           tuning_cache)
  *_hierarchical_scan    — two-level scans over 2D meshes (hierarchical)
"""

from repro.offload.engine import (
    CompiledSchedule,
    EngineTelemetry,
    OffloadEngine,
    wire_dtype,
    wire_op_id,
    wire_op_name,
)
from repro.offload.hierarchical import (
    dist_hierarchical_scan,
    flat_equivalent,
    sim_hierarchical_scan,
)
from repro.offload.tuner import (
    DEFAULT_PAYLOADS,
    DEFAULT_PS,
    autotune,
    time_sim_collective,
)
from repro.offload.tuning_cache import (
    TUNING_TABLE_ENV,
    Measurement,
    TuningCache,
    deactivate,
    load_default_table,
)

__all__ = [
    "CompiledSchedule",
    "DEFAULT_PAYLOADS",
    "DEFAULT_PS",
    "EngineTelemetry",
    "Measurement",
    "OffloadEngine",
    "TUNING_TABLE_ENV",
    "TuningCache",
    "autotune",
    "deactivate",
    "dist_hierarchical_scan",
    "flat_equivalent",
    "load_default_table",
    "sim_hierarchical_scan",
    "time_sim_collective",
    "wire_dtype",
    "wire_op_id",
    "wire_op_name",
]
