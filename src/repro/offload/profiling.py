"""Profiler-measured schedule latency: device timings into EngineTelemetry.

In sim and driver mode the engine times dispatches with a host wall clock;
inside ``shard_map`` (spmd mode) it "leaves latency to the profiler". This
module closes that loop: one dispatch runs under ``jax.profiler`` with a
:class:`jax.profiler.TraceAnnotation` naming the schedule, the emitted
``*.trace.json.gz`` chrome trace is parsed with the stdlib (no tensorboard
dependency), and the *device-side execution time* inside the annotation
window — the union of XLA executable-run event intervals, so nested events
never double-count — is recorded into
:class:`~repro.offload.engine.EngineTelemetry` as a **measured-on-device**
latency source, distinct from the wall-clock numbers. That is the software
analogue of the paper's 8 ns on-NIC timer: the host clock sees dispatch +
transfer + sync; the trace sees the collective itself.

When the runtime cannot produce or parse a trace (a second concurrent
profiler session, a backend without the chrome-trace export), measurement
falls back to the annotation's own wall duration and is labeled
``source="wall"`` so dashboards never mistake it for a device number —
and the *reason* for the degradation is recorded
(:attr:`DeviceTiming.fallback_reason`, counted into
``EngineTelemetry.snapshot()["profiler_fallback_reasons"]`` and the
``repro_engine_profiler_fallbacks_total`` metric), so a profiler that has
silently stopped producing traces shows up on a dashboard instead of
quietly substituting wall numbers.

When a collecting tracer is installed (:mod:`repro.obs.tracing`), the
profiled dispatch additionally emits a host-side span *named exactly like
the TraceAnnotation tag*. The same name then appears in both the host span
trace and the profiler's chrome trace, which is the anchor
:func:`repro.obs.export.merge_device_trace` uses to align the two clocks
into one host+device timeline.
"""

from __future__ import annotations

import contextlib
import dataclasses
import glob
import gzip
import json
import os
import re
import tempfile
import time
from typing import Any, List, Optional, Tuple

import jax

PyTree = Any

#: every annotation this module emits starts with this prefix
ANNOTATION_PREFIX = "repro_offload"

#: trace event names that mark device-side executable execution. CPU runs
#: emit TfrtCpuExecutable events; GPU/TPU runs emit XlaModule/stream events.
_DEVICE_EVENT_RE = re.compile(
    r"Executable::Execute|ExecuteHelper|XlaModule|ExecutorExecute"
)


@dataclasses.dataclass(frozen=True)
class DeviceTiming:
    """One profiled dispatch: where each number came from."""

    coll: str
    device_us: float       # union of device-exec intervals in the window
    wall_us: float         # host wall clock around the same dispatch
    source: str            # "profiler" (trace-derived) or "wall" (fallback)
    events: int            # device-exec events attributed to the window
    trace_path: Optional[str] = None
    #: why source degraded to "wall": "trace_start_failed" (most often a
    #: concurrent profiler session), "stop_failed", "no_trace_file", or
    #: "parse_failed"; None when the profiler delivered
    fallback_reason: Optional[str] = None


@contextlib.contextmanager
def _noop():
    yield


def _newest_trace_file(trace_dir: str) -> Optional[str]:
    paths = glob.glob(
        os.path.join(trace_dir, "**", "*.trace.json.gz"), recursive=True
    )
    return max(paths, key=os.path.getmtime) if paths else None


def _interval_union_us(intervals: List[Tuple[float, float]]) -> float:
    total = 0.0
    end = -1.0
    for lo, hi in sorted(intervals):
        if lo > end:
            total += hi - lo
            end = hi
        elif hi > end:
            total += hi - end
            end = hi
    return total


def parse_device_us(
    trace_path: str, annotation: str
) -> Optional[Tuple[float, int]]:
    """(device µs, event count) for one annotation window, or None.

    Reads the chrome-trace JSON jax writes next to its xplane protobuf.
    The annotation's complete event bounds the window; device time is the
    interval union of executable-execution events overlapping it (clipped
    to the window), so nested Execute/ExecuteHelper pairs count once.
    """
    try:
        trace = json.loads(gzip.open(trace_path, "rb").read())
    except (OSError, ValueError):
        return None
    events = trace.get("traceEvents", [])
    window: Optional[Tuple[float, float]] = None
    for e in events:
        if e.get("ph") == "X" and e.get("name") == annotation:
            t0 = float(e.get("ts", 0.0))
            window = (t0, t0 + float(e.get("dur", 0.0)))
            break
    if window is None:
        return None
    lo_w, hi_w = window
    intervals: List[Tuple[float, float]] = []
    for e in events:
        if e.get("ph") != "X" or not _DEVICE_EVENT_RE.search(
            str(e.get("name", ""))
        ):
            continue
        lo = float(e.get("ts", 0.0))
        hi = lo + float(e.get("dur", 0.0))
        lo, hi = max(lo, lo_w), min(hi, hi_w)
        if hi > lo:
            intervals.append((lo, hi))
    if not intervals:
        return None
    return _interval_union_us(intervals), len(intervals)


def profile_offload(
    engine,
    descriptor,
    x: Optional[PyTree] = None,
    *,
    axis_name=None,
    mesh=None,
    warmup: int = 1,
    trace_dir: Optional[str] = None,
) -> DeviceTiming:
    """Dispatch one descriptor under a profiler trace; feed the telemetry.

    Works in sim mode and in driver mode (both are host-dispatched: the
    engine owns the program, so the trace brackets exactly one schedule).
    ``warmup`` dispatches first so compilation never pollutes the window.
    The measurement lands in ``engine.telemetry`` via
    ``record_device_latency`` and is what puts a measured-on-device source
    behind ``latency_by_coll_us`` in ``EngineTelemetry.snapshot()``.
    """
    from repro.obs import tracing as obs_tracing

    desc = engine._as_descriptor(descriptor)
    coll = desc.coll_type.name.lower()
    for _ in range(max(0, warmup)):
        engine.offload(desc, x, axis_name=axis_name, mesh=mesh)
    tag = f"{ANNOTATION_PREFIX}:{coll}:p{desc.comm_size}"
    owned = trace_dir is None
    tmp = tempfile.mkdtemp(prefix="repro_prof_") if owned else trace_dir
    parsed: Optional[Tuple[float, int]] = None
    trace_path: Optional[str] = None
    fallback_reason: Optional[str] = None
    span_tracer = obs_tracing.get_tracer()
    try:
        # trace machinery failures (a concurrent profiler session, a
        # backend without the chrome export) degrade to the wall-clock
        # source — but a failing DISPATCH always propagates
        try:
            jax.profiler.start_trace(tmp)
            tracing = True
        except Exception:
            tracing = False
            fallback_reason = "trace_start_failed"
        t0 = time.perf_counter()
        t0_us = obs_tracing.now_us()
        try:
            with jax.profiler.TraceAnnotation(tag) if tracing else _noop():
                out = engine.offload(desc, x, axis_name=axis_name, mesh=mesh)
                jax.tree.map(lambda a: a.block_until_ready(), out)
        finally:
            wall_us = (time.perf_counter() - t0) * 1e6
            if span_tracer.enabled:
                # host span named exactly like the TraceAnnotation tag —
                # the clock-alignment anchor for merge_device_trace
                span_tracer.add_span(
                    tag, "profile", t0_us, obs_tracing.now_us(),
                    parent_id=span_tracer.current_span_id(),
                    coll=coll, annotation=True,
                )
            if tracing:
                try:
                    jax.profiler.stop_trace()
                except Exception:
                    tracing = False
                    fallback_reason = "stop_failed"
        if tracing:
            try:
                trace_path = _newest_trace_file(tmp)
                if trace_path is None:
                    fallback_reason = "no_trace_file"
                else:
                    parsed = parse_device_us(trace_path, tag)
                    if parsed is None:
                        fallback_reason = "parse_failed"
            except Exception:
                parsed = None
                fallback_reason = "parse_failed"
    finally:
        if owned:
            import shutil

            shutil.rmtree(tmp, ignore_errors=True)
            trace_path = None
    if parsed is not None:
        device_us, n_events = parsed
        source = "profiler"
        fallback_reason = None
    else:
        device_us, n_events = wall_us, 0
        source = "wall"
        if fallback_reason is None:
            fallback_reason = "trace_start_failed"
        record = getattr(
            engine.telemetry, "record_profiler_fallback", None
        )
        if record is not None:
            record(coll, fallback_reason)
    engine.telemetry.record_device_latency(
        coll, device_us * 1e-6, source=source
    )
    return DeviceTiming(
        coll=coll,
        device_us=device_us,
        wall_us=wall_us,
        source=source,
        events=n_events,
        trace_path=trace_path,
        fallback_reason=fallback_reason,
    )


__all__ = [
    "ANNOTATION_PREFIX",
    "DeviceTiming",
    "parse_device_us",
    "profile_offload",
]
