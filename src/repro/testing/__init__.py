"""Subprocess-entry checks that need a multi-device (forced host) platform.

The container has one physical CPU device and jax locks the device count at
first init, so anything needing a real mesh runs as ``python -m
repro.testing.<module>`` in a fresh subprocess that sets
``xla_force_host_platform_device_count`` before importing jax. Never set that
flag globally — smoke tests and benchmarks must see 1 device.
"""
