"""Observability smoke check: traced dispatch -> spans, metrics, merge.

Run:  python -m repro.testing.obs_check [outer inner]

One planned SCAN dispatches through an ``OffloadEngine`` in sim mode over
an (outer, inner) mesh shape, twice: once with the default no-op tracer
(the baseline) and once under a collecting :mod:`repro.obs.tracing`
tracer. The check then asserts the whole observability contract at once:

  * the traced result is **bitwise identical** to the untraced baseline —
    tracing must never change the computation;
  * the span tree is well-formed: an ``engine.offload`` root, >= 1
    ``phase`` span, and for every *communication* phase span (one that
    reports ``rounds > 0``) at least one ``round`` span whose
    ``parent_id`` is that phase — exactly as many as the phase reported;
  * every span nests inside its parent's [start, end] window;
  * ``EngineTelemetry.snapshot()`` still exposes the pre-observability
    keys (dispatches/cache_hits/latency sources) — dashboards keep
    working — plus the new profiler-fallback counters;
  * the Prometheus rendering contains the engine dispatch counter and the
    per-round latency histogram that ``observe_round`` feeds;
  * a profiled dispatch merges with the host spans into one Perfetto
    trace (device events + clock alignment asserted only when the
    profiler actually delivered; a wall fallback is reported, not
    failed — profiling.py's fallback counters own that signal).

Prints an ``obs_check_summary`` CSV row for the CI gate and ALL-OK; exits
nonzero on any violation. Used by scripts/ci.sh and tests/test_obs.py.
"""

import sys
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing
from repro.offload import OffloadEngine

#: snapshot keys that existed before the obs layer; removing one breaks
#: every dashboard reading engine telemetry
PRE_OBS_SNAPSHOT_KEYS = (
    "hits",
    "misses",
    "hit_rate",
    "dispatches",
    "compiles",
    "errors",
    "cache_size",
    "cache_clears",
    "calls_by_coll",
    "mean_latency_us",
    "last_latency_us",
    "latency_by_coll_us",
    "device_latency_by_coll_us",
    "latency_source_by_coll",
)


def main() -> None:
    axes = (
        (int(sys.argv[1]), int(sys.argv[2])) if len(sys.argv) > 2 else (2, 2)
    )
    p = int(np.prod(axes))
    n = 16
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(-5, 6, size=(p, n)).astype(np.float32))
    failures = 0

    def check(name, ok):
        nonlocal failures
        print(f"obs {name:42s} {'OK' if ok else 'FAIL'}")
        failures += 0 if ok else 1

    eng = OffloadEngine()
    desc = eng.make_descriptor(
        "scan", axes=axes, payload_bytes=n * 4, op="sum", optimize=True,
    )

    # baseline: default no-op tracer, jitted planned path
    baseline = np.asarray(eng.offload(desc, x))
    check("noop tracer leaves no spans", isinstance(
        obs_tracing.get_tracer(), obs_tracing.NoopTracer,
    ))

    # traced dispatch: collecting tracer -> eager interpreter + spans
    with obs_tracing.tracing() as tracer:
        traced = np.asarray(eng.offload(desc, x))
    check("traced result bitwise == untraced", np.array_equal(
        traced, baseline,
    ))

    spans = tracer.spans()
    by_id = {s.span_id: s for s in spans}
    engine_spans = [s for s in spans if s.cat == "engine"]
    phase_spans = [s for s in spans if s.cat == "phase"]
    round_spans = [s for s in spans if s.cat == "round"]
    check("engine.offload span present", any(
        s.name == "engine.offload" for s in engine_spans
    ))
    check(">= 1 phase span", len(phase_spans) >= 1)
    check(">= 1 round span", len(round_spans) >= 1)

    comm_phases = [s for s in phase_spans if s.args.get("rounds", 0) > 0]
    check(">= 1 communication phase", len(comm_phases) >= 1)
    rounds_ok = True
    for ph in comm_phases:
        children = [
            r for r in round_spans if r.parent_id == ph.span_id
        ]
        if len(children) != ph.args.get("rounds") or not children:
            rounds_ok = False
            print(
                f"  phase {ph.name}: {len(children)} round spans, "
                f"reported rounds={ph.args.get('rounds')}"
            )
    check("each comm phase owns its round spans", rounds_ok)

    nesting_ok = True
    for s in spans:
        parent = by_id.get(s.parent_id)
        if parent is None:
            continue
        if not (
            parent.start_us <= s.start_us
            and s.end_us <= parent.end_us + 1e-3
        ):
            nesting_ok = False
            print(f"  span {s.name} escapes parent {parent.name}")
    check("spans nest inside their parents", nesting_ok)

    snap = eng.telemetry.snapshot()
    check("pre-obs snapshot keys intact", all(
        k in snap for k in PRE_OBS_SNAPSHOT_KEYS
    ))
    check("fallback counters in snapshot", (
        "profiler_fallbacks" in snap
        and "profiler_fallback_reasons" in snap
    ))

    prom = obs_metrics.render_prometheus()
    check("prometheus: engine dispatch counter", (
        "repro_engine_dispatches_total" in prom
    ))
    check("prometheus: per-round histogram", (
        "repro_round_latency_us_bucket" in prom
    ))

    # host+device merge: profile one dispatch while the tracer collects
    with obs_tracing.tracing() as tracer:
        with tempfile.TemporaryDirectory() as td:
            timing = eng.profile_offload(desc, x, trace_dir=td)
            host = obs_export.spans_to_chrome(tracer.spans())
            merged = host
            aligned = False
            if timing.source == "profiler" and timing.trace_path:
                device = obs_export.load_chrome_trace(timing.trace_path)
                merged = obs_export.merge_device_trace(host, device)
                aligned = bool(merged.get("deviceClockAligned"))
    n_device = sum(
        1 for e in merged.get("traceEvents", [])
        if e.get("pid") == obs_export.DEVICE_PID
    )
    check("merged trace has host spans", any(
        e.get("pid") == obs_export.HOST_PID and e.get("ph") == "X"
        for e in merged.get("traceEvents", [])
    ))
    if timing.source == "profiler":
        check("merged trace has device events", n_device > 0)
        check("device clock aligned to host", aligned)
    else:
        print(
            f"obs (profiler unavailable: fallback="
            f"{timing.fallback_reason}; merge checked host-only)"
        )

    print(
        f"obs_check_summary,bitwise_equal,{int(np.array_equal(traced, baseline))},"
        f"phase_spans,{len(phase_spans)},round_spans,{len(round_spans)},"
        f"comm_phases,{len(comm_phases)},device_events,{n_device},"
        f"source,{timing.source}"
    )
    if failures:
        print(f"FAILURES: {failures}")
        sys.exit(1)
    print("ALL-OK")


if __name__ == "__main__":
    main()
