"""SPMD validation of dist_reduce / dist_allreduce / dist_barrier (8 devices).

Run: python -m repro.testing.reduce_check
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import sys  # noqa: E402

import jax  # noqa: E402
from repro.compat import shard_map  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh, PartitionSpec as P  # noqa: E402

from repro.core import dist_allreduce, dist_barrier, dist_reduce  # noqa: E402


def main() -> None:
    p = 8
    mesh = Mesh(np.array(jax.devices()), ("r",))
    rng = np.random.default_rng(0)
    x = rng.normal(size=(p, 32)).astype(np.float32)
    failures = 0

    # reduce to root=2: only root holds the sum, others identity (0)
    def red(xs):
        return dist_reduce(xs, "sum", "r", root=2)

    got = np.asarray(
        jax.jit(shard_map(red, mesh=mesh, in_specs=P("r"), out_specs=P("r")))(
            jnp.asarray(x)
        )
    )
    want_total = x.sum(0)
    ok = np.allclose(got[2], want_total, atol=1e-4) and np.allclose(
        np.delete(got, 2, axis=0), 0.0
    )
    print("reduce(root=2):", "OK" if ok else "FAIL")
    failures += 0 if ok else 1

    # allreduce: every rank has the total; matches lax.psum
    def ar(xs):
        return dist_allreduce(xs, "sum", "r")

    got = np.asarray(
        jax.jit(shard_map(ar, mesh=mesh, in_specs=P("r"), out_specs=P("r")))(
            jnp.asarray(x)
        )
    )
    ok = all(np.allclose(got[i], want_total, atol=1e-4) for i in range(p))
    print("allreduce:", "OK" if ok else "FAIL")
    failures += 0 if ok else 1

    # max-allreduce (non-zero identity path)
    def arm(xs):
        return dist_allreduce(xs, "max", "r")

    got = np.asarray(
        jax.jit(shard_map(arm, mesh=mesh, in_specs=P("r"), out_specs=P("r")))(
            jnp.asarray(x)
        )
    )
    ok = all(np.allclose(got[i], x.max(0)) for i in range(p))
    print("allreduce(max):", "OK" if ok else "FAIL")
    failures += 0 if ok else 1

    # barrier: compiles, returns 1.0 everywhere
    def bar(xs):
        t = dist_barrier("r")
        return xs * t

    got = np.asarray(
        jax.jit(shard_map(bar, mesh=mesh, in_specs=P("r"), out_specs=P("r")))(
            jnp.asarray(x)
        )
    )
    ok = np.allclose(got, x)
    print("barrier:", "OK" if ok else "FAIL")
    failures += 0 if ok else 1

    if failures:
        sys.exit(1)
    print("ALL-OK")


if __name__ == "__main__":
    main()
