"""SPMD validation of the offloaded scan: dist_scan under shard_map.

Run:  python -m repro.testing.spmd_check [ndev]
Prints one line per (algorithm, op, case) and a final ALL-OK. Exits nonzero on
the first mismatch. Used by tests/test_dist_scan.py via subprocess.
"""

import os
import sys

_NDEV = int(sys.argv[1]) if len(sys.argv) > 1 else 8
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_NDEV} "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
from repro.compat import shard_map  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh, PartitionSpec as P  # noqa: E402

from repro.core import (  # noqa: E402
    ALGORITHMS,
    SSD,
    dist_exscan,
    dist_scan,
    dist_scan_pair,
    get_operator,
)


def main() -> None:
    p = _NDEV
    assert len(jax.devices()) == p, (len(jax.devices()), p)
    mesh = Mesh(np.array(jax.devices()), ("r",))
    rng = np.random.default_rng(42)
    failures = 0

    def run(fn, x, op, algorithm, inclusive):
        def body(xs):
            f = dist_scan if inclusive else dist_exscan
            return f(xs, op, "r", algorithm=algorithm)

        m = shard_map(body, mesh=mesh, in_specs=P("r"), out_specs=P("r"))
        return np.asarray(jax.jit(m)(x))

    # sum / max over a (p, n) payload sharded one row per rank
    for opname in ("sum", "max"):
        op = get_operator(opname)
        x = rng.normal(size=(p, 64)).astype(np.float32)
        if opname == "sum":
            inc = np.cumsum(x, axis=0)
        else:
            inc = np.maximum.accumulate(x, axis=0)
        for algorithm in ALGORITHMS:
            if algorithm == "invertible_doubling" and (
                op.inverse is None or not op.commutative
            ):
                continue
            got = run(dist_scan, jnp.asarray(x), op, algorithm, True)
            ok = np.allclose(got, inc, atol=1e-4)
            print(f"scan   {opname:4s} {algorithm:22s} {'OK' if ok else 'FAIL'}")
            failures += 0 if ok else 1
            if opname == "sum":
                ex = np.concatenate([np.zeros((1, 64), np.float32), inc[:-1]])
                gex = run(dist_exscan, jnp.asarray(x), op, algorithm, False)
                ok = np.allclose(gex, ex, atol=1e-4)
                print(
                    f"exscan {opname:4s} {algorithm:22s} {'OK' if ok else 'FAIL'}"
                )
                failures += 0 if ok else 1

    # SSD pytree operator (the sequence-parallel Mamba2 state op)
    a = rng.uniform(0.5, 1.0, size=(p, 8)).astype(np.float32)
    b = rng.normal(size=(p, 8)).astype(np.float32)
    A = np.empty_like(a)
    B = np.empty_like(b)
    A[0], B[0] = a[0], b[0]
    for j in range(1, p):
        A[j] = a[j] * A[j - 1]
        B[j] = a[j] * B[j - 1] + b[j]
    for algorithm in ("sequential", "binomial_tree", "recursive_doubling",
                      "sklansky", "hillis_steele", "sequential_pipelined"):
        def body(xs):
            return dist_scan(xs, SSD, "r", algorithm=algorithm)

        m = shard_map(
            body, mesh=mesh, in_specs=((P("r"), P("r")),), out_specs=P("r")
        )
        ga, gb = jax.jit(m)((jnp.asarray(a), jnp.asarray(b)))
        ok = np.allclose(np.asarray(ga), A, atol=1e-4) and np.allclose(
            np.asarray(gb), B, atol=1e-4
        )
        print(f"scan   ssd  {algorithm:22s} {'OK' if ok else 'FAIL'}")
        failures += 0 if ok else 1

    # auto-selection end-to-end + scan_pair consistency
    x = rng.normal(size=(p, 32)).astype(np.float32)

    def body(xs):
        return dist_scan_pair(xs, "sum", "r", algorithm="auto")

    m = shard_map(body, mesh=mesh, in_specs=P("r"), out_specs=P("r"))
    ex, inc = jax.jit(m)(jnp.asarray(x))
    winc = np.cumsum(x, axis=0)
    wex = np.concatenate([np.zeros((1, 32), np.float32), winc[:-1]])
    ok = np.allclose(np.asarray(inc), winc, atol=1e-4) and np.allclose(
        np.asarray(ex), wex, atol=1e-4
    )
    print(f"pair   sum  {'auto':22s} {'OK' if ok else 'FAIL'}")
    failures += 0 if ok else 1

    if failures:
        print(f"FAILURES: {failures}")
        sys.exit(1)
    print("ALL-OK")


if __name__ == "__main__":
    main()
