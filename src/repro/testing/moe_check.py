"""EP MoE vs dense-dispatch equivalence on a forced 8-device mesh.

With a generous capacity factor (no drops), the expert-parallel sort/
all_to_all path must reproduce the dropless dense reference bit-close.
Run: python -m repro.testing.moe_check
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import dataclasses  # noqa: E402
import sys  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.models.moe import _dense_moe, init_moe, moe_block  # noqa: E402
from repro.sharding.specs import make_topology, use_topology  # noqa: E402


def main() -> None:
    cfg = dataclasses.replace(
        get_config("olmoe_1b_7b").reduced(),
        moe_num_experts=8,
        moe_top_k=2,
        capacity_factor=8.0,  # no drops -> exact match with dense path
    )
    key = jax.random.key(0)
    p = init_moe(key, cfg, jnp.float32)
    rng = np.random.default_rng(0)
    B, S = 4, 16
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))

    want, aux_want = _dense_moe(p, x, cfg, "silu")

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    topo = make_topology(mesh)
    with use_topology(topo):
        got, aux_got = jax.jit(lambda pp, xx: moe_block(pp, xx, cfg, act="silu"))(p, x)

    ok = np.allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)
    print("ep-vs-dense outputs:", "OK" if ok else "FAIL",
          float(np.abs(np.asarray(got) - np.asarray(want)).max()))
    ok2 = abs(float(aux_got["load_balance"]) - float(aux_want["load_balance"])) < 1e-3
    print("aux load_balance:", "OK" if ok2 else "FAIL")

    # capacity dropping: tiny capacity factor must not produce NaNs and must
    # reduce output magnitude (dropped tokens get zero expert contribution)
    cfg_drop = dataclasses.replace(cfg, capacity_factor=0.25)
    with use_topology(topo):
        got_d, _ = jax.jit(
            lambda pp, xx: moe_block(pp, xx, cfg_drop, act="silu")
        )(p, x)
    ok3 = np.isfinite(np.asarray(got_d)).all()
    print("capacity-drop finite:", "OK" if ok3 else "FAIL")

    if ok and ok2 and ok3:
        print("ALL-OK")
    else:
        sys.exit(1)


if __name__ == "__main__":
    main()
