"""Reliability-stack validation: chaos, checksums, bisection, breakers.

Run:  python -m repro.testing.chaos_check [pod data]

One seeded run (device count fixed before jax import, hence the
subprocess pattern) exercises the dispatch reliability contract end to
end on a pod x data mesh:

  1. **Bitwise recovery under chaos** — with a seeded
     :class:`~repro.runtime.chaos.ChaosInjector` dropping AND corrupting
     5% of individual messages, all five CollTypes submitted through a
     reliability-enabled :class:`~repro.service.DescriptorBroker` must
     complete **bitwise-equal** to their fault-free dispatches, purely
     via retries (chaos decisions advance per message, so retried
     dispatches draw fresh ones). At least one fault must actually have
     been injected and at least one retry taken — a clean run proves
     nothing.
  2. **Quarantine by bisection** — four tenants coalesce into one fused
     group; one queued payload is corrupted *at rest* (post-submit, so
     its submit-time checksum is stale). The drain must fail exactly the
     poisoned ticket with an attributed
     :class:`~repro.core.packet.IntegrityError` while the three clean
     neighbors complete bitwise-correct, with ``bisect`` and
     ``quarantine`` flight events recorded.
  3. **Breaker trip, degrade, recover** — under 100% drop chaos the
     engine stage exhausts retries; after ``failure_threshold``
     consecutive failures the (backend, coll) breaker opens, dispatches
     degrade to the raw-``lax`` reference (still bitwise-correct for the
     int32 payload), and ``/healthz`` flips to "alert" naming the open
     circuit. With chaos lifted and the (injected) clock past the
     cooldown, a half-open probe must close the breaker and ``/healthz``
     must return to "ok".

Emits a ``chaos_check_summary`` CSV row and a final ALL-OK; exits
nonzero on any violation. Used by scripts/ci.sh and
tests/test_reliability.py. (The companion < 2% overhead gate lives in
benchmarks/reliability_overhead.py + check_regression --reliability.)
"""

import os
import sys

_ARGS = [a for a in sys.argv[1:] if not a.startswith("-")]
_AXES = (int(_ARGS[0]), int(_ARGS[1])) if len(_ARGS) >= 2 else (2, 2)
_NDEV = _AXES[0] * _AXES[1]
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_NDEV} "
    + os.environ.get("XLA_FLAGS", "")
)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.packet import (  # noqa: E402
    CollType,
    CollectiveDescriptor,
    IntegrityError,
    WireDType,
)
from repro.obs import events as obs_events  # noqa: E402
from repro.obs import health as obs_health  # noqa: E402
from repro.offload import OffloadEngine  # noqa: E402
from repro.offload.reliability import (  # noqa: E402
    CircuitBreaker,
    ReliabilityPolicy,
    ReliableDispatcher,
    RetryPolicy,
)
from repro.runtime.chaos import ChaosInjector  # noqa: E402
from repro.service import DescriptorBroker  # noqa: E402

N = 64          # payload columns (int32: exact arithmetic -> bitwise gates)
SEED = 20140409  # the paper's year+month+day; any seed must work
CHAOS_RATE = 0.05

FAILURES = 0


def check(name: str, ok: bool) -> None:
    global FAILURES
    print(f"chaos {name:46s} {'OK' if ok else 'FAIL'}")
    FAILURES += 0 if ok else 1


def make_desc(coll: CollType) -> CollectiveDescriptor:
    return CollectiveDescriptor(
        comm_size=_NDEV,
        axes=_AXES,
        coll_type=coll,
        count=N,
        data_type=WireDType.INT32,
    )


def payload(i: int = 0):
    return jnp.arange(_NDEV * N, dtype=jnp.int32).reshape(_NDEV, N) + i


def main() -> None:
    # ---- 1. five CollTypes, bitwise through 5% drop+corrupt chaos --------
    policy = ReliabilityPolicy(
        retry=RetryPolicy(max_attempts=40, backoff_s=1e-5, max_backoff_s=1e-3)
    )
    broker = DescriptorBroker(reliability=policy)
    eng = broker.engine
    colls = [
        CollType.SCAN, CollType.EXSCAN, CollType.REDUCE,
        CollType.ALLREDUCE, CollType.BARRIER,
    ]
    # fault-free references first (the planned jitted path; the eager
    # chaos-path interpreter is bitwise-gated against it elsewhere)
    refs = {
        c: np.asarray(
            eng.offload(make_desc(c), None if c == CollType.BARRIER
                        else payload())
        )
        for c in colls
    }
    injector = ChaosInjector(SEED, drop=CHAOS_RATE, corrupt=CHAOS_RATE)
    client = broker.client("chaotic")
    bitwise_ok = True
    with injector.scope():
        for c in colls:
            t = client.submit(
                make_desc(c),
                None if c == CollType.BARRIER else payload(),
            )
            broker.drain()
            out = np.asarray(t.result(timeout=120.0))
            same = np.array_equal(out, refs[c])
            check(f"{c.name} bitwise under chaos", same)
            bitwise_ok = bitwise_ok and same
    faults = injector.faults_injected()
    retries = broker._dispatcher.counts["retries"]
    check("chaos actually injected faults", faults > 0)
    check("recovery actually took retries", retries > 0)
    bitwise_ok = bitwise_ok and faults > 0 and retries > 0

    # ---- 2. a poisoned request is quarantined by bisection ---------------
    quarantine_broker = DescriptorBroker(reliability=policy)
    qeng = quarantine_broker.engine
    desc = make_desc(CollType.SCAN)
    clients = [quarantine_broker.client(f"t{i}") for i in range(4)]
    tickets = [c.submit(desc, payload(i)) for i, c in enumerate(clients)]
    poisoned = 2
    bad = np.asarray(quarantine_broker._queue[poisoned].payload).copy()
    bad[1, 5] ^= 1  # one bit, at rest, after the submit-time checksum
    quarantine_broker._queue[poisoned].payload = jnp.asarray(bad)
    quarantine_broker.drain()
    quarantine_ok = True
    for i, t in enumerate(tickets):
        if i == poisoned:
            try:
                t.result(timeout=10.0)
                ok = False
            except IntegrityError as e:
                ok = e.request == f"t{poisoned}#0"
            check("poisoned ticket fails with IntegrityError", ok)
        else:
            out = np.asarray(t.result(timeout=10.0))
            ok = np.array_equal(out, np.asarray(qeng.offload(desc, payload(i))))
            check(f"clean neighbor t{i} bitwise-correct", ok)
        quarantine_ok = quarantine_ok and ok
    counts = obs_events.get_recorder().counts()
    check("bisect events recorded", counts.get("bisect", 0) >= 1)
    check("quarantine event recorded", counts.get("quarantine", 0) >= 1)
    quarantine_ok = quarantine_ok and (
        counts.get("bisect", 0) >= 1 and counts.get("quarantine", 0) >= 1
    )

    # ---- 3. breaker trips under sustained loss, degrades, recovers -------
    clk = {"t": 0.0}
    breaker = CircuitBreaker(
        failure_threshold=3, cooldown_s=5.0, clock=lambda: clk["t"]
    )
    dispatcher = ReliableDispatcher(
        OffloadEngine(),
        retry=RetryPolicy(max_attempts=2, backoff_s=0.0),
        breaker=breaker,
        clock=lambda: clk["t"],
        sleep=lambda s: None,
    )
    monitor = obs_health.HealthMonitor(breaker=breaker)
    key = ("default", "scan")
    storm = ChaosInjector(SEED + 1, drop=1.0)
    breaker_ok = True
    with storm.scope():
        for _ in range(4):
            out = np.asarray(dispatcher.offload(desc, payload()))
            same = np.array_equal(out, refs[CollType.SCAN])
            breaker_ok = breaker_ok and same
    check("degraded dispatches stay bitwise-correct", breaker_ok)
    opened = breaker.state(key) == "open"
    check("breaker opened after consecutive failures", opened)
    check("dispatches degraded to reference", (
        dispatcher.counts["degrades"] >= 3
        and dispatcher.counts["reference_dispatches"] == 4
        and dispatcher.counts["breaker_skips"] >= 1
    ))
    hz = monitor.healthz()
    healthz_alert = (
        hz["status"] == "alert"
        and hz["breakers"].get("default|scan", {}).get("state") == "open"
    )
    check("healthz reflects the open breaker", healthz_alert)
    breaker_ok = breaker_ok and opened and healthz_alert

    # chaos lifted + cooldown elapsed: half-open probe must close it
    clk["t"] += 10.0
    out = np.asarray(dispatcher.offload(desc, payload()))
    recovered = (
        np.array_equal(out, refs[CollType.SCAN])
        and breaker.state(key) == "closed"
    )
    check("half-open probe closes the breaker", recovered)
    hz = monitor.healthz()
    healthz_ok = (
        hz["status"] == "ok"
        and hz["breakers"].get("default|scan", {}).get("state") == "closed"
    )
    check("healthz back to ok after recovery", healthz_ok)
    breaker_ok = breaker_ok and recovered
    counts = obs_events.get_recorder().counts()
    check("breaker transitions recorded", (
        counts.get("breaker_open", 0) >= 1
        and counts.get("breaker_half_open", 0) >= 1
        and counts.get("breaker_closed", 0) >= 1
    ))

    print(
        f"chaos_check_summary,bitwise_equal,{int(bitwise_ok)},"
        f"faults,{faults},retries,{retries},"
        f"quarantine_ok,{int(quarantine_ok)},"
        f"breaker_ok,{int(breaker_ok)},"
        f"healthz_ok,{int(healthz_alert and healthz_ok)}"
    )
    if FAILURES:
        print(f"FAILURES: {FAILURES}")
        sys.exit(1)
    print("ALL-OK")


if __name__ == "__main__":
    main()
