"""Property-test shim: real hypothesis when installed, deterministic fallback
when not.

The test suite's property tests (`tests/test_scan_algorithms.py` etc.) were
written against hypothesis, which is not available in offline containers.
Importing ``given/settings/strategies`` from this module instead of from
``hypothesis`` keeps the full shrinking/fuzzing behavior wherever hypothesis
is installed, and otherwise degrades to a fixed, seeded sweep of examples —
enough to keep every property exercised (and the suite green) without network
access.

Only the strategy surface the suite actually uses is implemented:
``integers``, ``floats(width=)``, ``booleans``, ``sampled_from``, ``lists``,
and ``data`` (interactive draws). Example count per test is
``min(max_examples, REPRO_SHIM_MAX_EXAMPLES)`` (default 12) with a seed
derived from the test name, so failures reproduce exactly.
"""

from __future__ import annotations

import os
import zlib

try:  # pragma: no cover - exercised only where hypothesis exists
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    _CAP = int(os.environ.get("REPRO_SHIM_MAX_EXAMPLES", "12"))
    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw_fn, label: str):
            self._draw_fn = draw_fn
            self._label = label

        def draw(self, rng):
            return self._draw_fn(rng)

        def __repr__(self) -> str:
            return self._label

    class _DataStrategy:
        """Marker for hypothesis' interactive ``st.data()``."""

    class _DataObject:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.draw(self._rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                f"integers({min_value}, {max_value})",
            )

        @staticmethod
        def floats(min_value, max_value, width=64, **_ignored):
            def draw(rng):
                v = float(rng.uniform(min_value, max_value))
                if width == 32:
                    v = float(np.float32(v))
                return v

            return _Strategy(draw, f"floats({min_value}, {max_value})")

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)), "booleans()")

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)

            def draw(rng):
                return seq[int(rng.integers(len(seq)))]

            return _Strategy(draw, f"sampled_from({seq!r})")

        @staticmethod
        def lists(elements, min_size=0, max_size=None):
            def draw(rng):
                hi = max_size if max_size is not None else min_size + 10
                n = int(rng.integers(min_size, hi + 1))
                return [elements.draw(rng) for _ in range(n)]

            return _Strategy(draw, f"lists(..., {min_size}, {max_size})")

        @staticmethod
        def data():
            return _DataStrategy()

    strategies = _Strategies()

    def settings(max_examples=None, deadline=None, **_ignored):
        # Applied *outside* @given in the suite, so it decorates the runner
        # wrapper; the wrapper reads the attribute at call time.
        def deco(fn):
            if max_examples is not None:
                fn._shim_max_examples = max_examples
            return fn

        return deco

    def given(**strategy_kwargs):
        def deco(fn):
            def wrapper():
                requested = getattr(
                    wrapper, "_shim_max_examples", _DEFAULT_EXAMPLES
                )
                n = max(1, min(requested, _CAP))
                base = zlib.crc32(fn.__qualname__.encode("utf-8"))
                for i in range(n):
                    rng = np.random.default_rng((base + i) % (2**32))
                    kwargs = {}
                    for name, strat in strategy_kwargs.items():
                        if isinstance(strat, _DataStrategy):
                            kwargs[name] = _DataObject(rng)
                        else:
                            kwargs[name] = strat.draw(rng)
                    try:
                        fn(**kwargs)
                    except Exception as e:
                        shown = {
                            k: v
                            for k, v in kwargs.items()
                            if not isinstance(v, _DataObject)
                        }
                        raise AssertionError(
                            f"{fn.__qualname__} falsified on deterministic "
                            f"example {i}/{n}: {shown!r}"
                        ) from e

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco
