"""Sequence-parallel Mamba2 (dist_exscan across shards) vs single-device.

The SP path shards the sequence over an 8-way model axis; its output and
final SSD state must match the unsharded mixer. This is THE paper-technique
correctness gate: inter-chunk state crosses devices through the offloaded
scan collective, and the conv halo crosses through a neighbor ppermute.
Run: python -m repro.testing.mamba_sp_check
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import sys  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.models.mamba import init_mamba, mamba_mixer  # noqa: E402
from repro.sharding.specs import make_topology, use_topology  # noqa: E402


def main() -> None:
    cfg = get_config("mamba2_130m").reduced()
    key = jax.random.key(0)
    p = init_mamba(key, cfg, jnp.float32)
    rng = np.random.default_rng(0)
    B, S = 2, 128  # 8 shards x 16 tokens, chunk=16
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)).astype(np.float32) * 0.1)

    y_ref, cache_ref = mamba_mixer(p, x, cfg, seq_parallel=False)

    mesh = jax.make_mesh((1, 8), ("data", "model"))
    topo = make_topology(mesh)
    with use_topology(topo):
        y_sp, cache_sp = jax.jit(
            lambda pp, xx: mamba_mixer(pp, xx, cfg, seq_parallel=True)
        )(p, x)

    ok = np.allclose(np.asarray(y_ref), np.asarray(y_sp), atol=2e-3, rtol=2e-3)
    print("seq-parallel output:", "OK" if ok else "FAIL",
          float(np.abs(np.asarray(y_ref) - np.asarray(y_sp)).max()))
    ok2 = np.allclose(
        np.asarray(cache_ref["ssm"]), np.asarray(cache_sp["ssm"]),
        atol=2e-3, rtol=2e-3,
    )
    print("final SSD state:", "OK" if ok2 else "FAIL")
    ok3 = np.allclose(
        np.asarray(cache_ref["conv_x"]), np.asarray(cache_sp["conv_x"]),
        atol=1e-4,
    )
    print("conv tail:", "OK" if ok3 else "FAIL")

    # gradient flows through the collective
    def loss(pp):
        with use_topology(topo):
            y, _ = mamba_mixer(pp, x, cfg, seq_parallel=True)
        return jnp.sum(y * y)

    with use_topology(topo):
        g = jax.grad(loss)(p)
    gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
    ok4 = np.isfinite(gn) and gn > 0
    print("grad through dist_exscan:", "OK" if ok4 else "FAIL", gn)

    if ok and ok2 and ok3 and ok4:
        print("ALL-OK")
    else:
        sys.exit(1)


if __name__ == "__main__":
    main()
