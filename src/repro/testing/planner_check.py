"""SPMD validation of planner-lowered collectives on a real 3D (pod) mesh.

Run:  python -m repro.testing.planner_check [pod outer inner]
All five descriptor CollTypes dispatch through ``OffloadEngine`` as *planned*
multi-axis descriptors inside ``shard_map`` over a (pod, outer, inner) device
mesh, and every result is checked against the flat single-axis reference.
One case uses a non-identity split to validate the logical-order layout
contract (the split decides which physical axis varies fastest in global
rank order). Prints one line per case and a final ALL-OK; exits nonzero on
mismatch. Used by tests/test_planner.py via subprocess (device count must be
fixed before jax import).
"""

import os
import sys

_AXES = (
    (int(sys.argv[1]), int(sys.argv[2]), int(sys.argv[3]))
    if len(sys.argv) > 3
    else (2, 2, 2)
)
_P = _AXES[0] * _AXES[1] * _AXES[2]
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_P} "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh, PartitionSpec as P  # noqa: E402

from repro.compat import shard_map  # noqa: E402
from repro.core import SSD, sim_barrier, sim_reduce, sim_scan  # noqa: E402
from repro.offload import (  # noqa: E402
    OffloadEngine,
    build_plan,
    optimize_plan,
    plan_layout,
)
from repro.sharding.specs import plan_spec  # noqa: E402

AXIS_NAMES = ("pod", "outer", "inner")


def main() -> None:
    axes = _AXES
    ptotal = _P
    assert len(jax.devices()) == ptotal, (len(jax.devices()), ptotal)
    mesh = Mesh(np.array(jax.devices()).reshape(axes), AXIS_NAMES)
    eng = OffloadEngine()
    rng = np.random.default_rng(7)
    failures = 0
    n = 8
    spec = P(AXIS_NAMES)

    def run(desc, x, out_spec=None, in_spec=None):
        def body(xs):
            return eng.offload(desc, xs, axis_name=AXIS_NAMES)

        m = shard_map(
            body,
            mesh=mesh,
            in_specs=in_spec if in_spec is not None else spec,
            out_specs=out_spec if out_spec is not None else spec,
        )
        return jax.jit(m)(x)

    def check(name, ok):
        nonlocal failures
        print(f"planned3d {name:28s} {'x'.join(map(str, axes))} "
              f"{'OK' if ok else 'FAIL'}")
        failures += 0 if ok else 1

    x = rng.integers(-4, 5, size=(ptotal, n)).astype(np.float32)
    xj = jnp.asarray(x)

    # the plan trace, raw and optimized — describe() must stay readable
    # after the pass pipeline rewrites the phase list (fused phases render
    # with both outputs, the permute chain renders once per plan)
    plan = build_plan("SCAN", axes, "sum", n * 4, order=(0, 1, 2))
    print(plan.describe())
    print(optimize_plan(plan).describe())

    # SCAN / EXSCAN (identity split): bitwise vs the flat reference
    for coll, inclusive in (("SCAN", True), ("EXSCAN", False)):
        desc = eng.make_descriptor(
            coll, axes=axes, payload_bytes=n * 4, op="sum", split=(0, 1, 2)
        )
        got = np.asarray(run(desc, xj))
        want = np.asarray(
            sim_scan(xj, "sum", ptotal, algorithm="hillis_steele",
                     inclusive=inclusive)
        )
        check(f"{coll.lower()} sum", np.array_equal(got, want))

    # SCAN with a non-identity split: innermost logical level on the pod
    # axis. plan_spec shards the logical-rank-ordered payload straight onto
    # the physical mesh (no hand layout), so the result compares directly
    # against the flat logical reference.
    order = (1, 2, 0)
    desc = eng.make_descriptor(
        "SCAN", axes=axes, payload_bytes=n * 4, op="sum", split=order
    )
    layout = plan_layout(desc)
    lspec = plan_spec(layout, AXIS_NAMES, ndim=2)
    got = np.asarray(run(desc, xj, in_spec=lspec, out_spec=lspec))
    want = np.asarray(
        sim_scan(xj, "sum", ptotal, algorithm="hillis_steele")
    )
    check(f"scan sum split={order}", np.array_equal(got, want))
    # the layout's flat permutations agree with the spec-level placement
    rt = layout.to_logical(layout.to_physical(x))
    check("plan_layout round-trip", np.array_equal(np.asarray(rt), x))

    # REDUCE with the root off rank 0
    root = ptotal - 3
    desc = eng.make_descriptor(
        "REDUCE", axes=axes, payload_bytes=n * 4, op="sum", root=root,
        split=(0, 1, 2),
    )
    got = np.asarray(run(desc, xj))
    want = np.asarray(sim_reduce(xj, "sum", ptotal, root=root))
    check(f"reduce sum root={root}", np.array_equal(got, want))

    # ALLREDUCE max
    desc = eng.make_descriptor(
        "ALLREDUCE", axes=axes, payload_bytes=n * 4, op="max", split=(0, 1, 2)
    )
    got = np.asarray(run(desc, xj))
    want = np.broadcast_to(x.max(axis=0), x.shape)
    check("allreduce max", np.array_equal(got, want))

    # BARRIER: token of ones on every rank
    desc = eng.make_descriptor(
        "BARRIER", axes=axes, payload_bytes=4, op="sum", split=(0, 1, 2)
    )

    def barrier_body(xs):
        # per-rank scalar token -> singleton axis so shards concatenate
        return eng.offload(desc, xs, axis_name=AXIS_NAMES).reshape(1)

    m = shard_map(barrier_body, mesh=mesh, in_specs=spec, out_specs=spec)
    got = np.asarray(jax.jit(m)(jnp.zeros((ptotal,), jnp.float32)))
    want = np.asarray(sim_barrier(ptotal))
    check("barrier", np.array_equal(got, want))

    # non-commutative SSD pytree operator across all three axes
    a = rng.uniform(0.5, 1.0, size=(ptotal, n)).astype(np.float32)
    b = rng.normal(size=(ptotal, n)).astype(np.float32)
    A, B = np.empty_like(a), np.empty_like(b)
    A[0], B[0] = a[0], b[0]
    for j in range(1, ptotal):
        A[j] = a[j] * A[j - 1]
        B[j] = a[j] * B[j - 1] + b[j]
    desc = eng.make_descriptor(
        "SCAN", axes=axes, payload_bytes=2 * n * 4, op="ssd", split=(0, 1, 2)
    )
    ga, gb = run(
        desc,
        (jnp.asarray(a), jnp.asarray(b)),
        in_spec=((spec, spec),),
        out_spec=(spec, spec),
    )
    ok = np.allclose(np.asarray(ga), A, atol=1e-5) and np.allclose(
        np.asarray(gb), B, atol=1e-5
    )
    check("scan ssd", ok)

    # repeat dispatch of an identical descriptor must hit the plan cache
    hits_before = eng.telemetry.hits
    desc = eng.make_descriptor(
        "SCAN", axes=axes, payload_bytes=n * 4, op="sum", split=(0, 1, 2)
    )
    _ = run(desc, xj)
    check("plan cache hit", eng.telemetry.hits > hits_before)

    if failures:
        print(f"FAILURES: {failures}")
        sys.exit(1)
    print("ALL-OK")


if __name__ == "__main__":
    main()
