"""SPMD validation of the multi-tenant offload service on a real 2x2 mesh.

Run:  python -m repro.testing.service_check [pod data] [--clients N]
                                            [--requests N]

Four scenarios on one multi-device CPU process (device count must be fixed
before jax import, hence the subprocess pattern):

  1. **Concurrent bitwise equivalence** — N >= 4 client threads stream
     planned 2-axis descriptors (SCAN / ALLREDUCE / EXSCAN over the (pod,
     data) mesh, one with a non-identity split) through a started
     :class:`DescriptorBroker` in the engine's **driver mode**; every result
     must be bitwise equal to a direct per-client dispatch through an
     independent engine, and the measured coalesce factor must exceed 1.
  2. **Backpressure isolation** — one tenant with a tiny queue bound
     overruns it and observes rejection while the other tenants' in-flight
     results stay bitwise correct and their telemetry clean.
  3. **Registry inheritance** — two disjoint tuning tables merge under the
     shared registry and the broker plans a split winner contributed by the
     table this "worker" never measured.
  4. **Deadline flush** — a lone request completes within a bounded wait
     (no companion traffic needed).

Emits ``service_check`` CSV rows and a final ALL-OK; exits nonzero on any
mismatch. Used by tests/test_service_spmd.py and scripts/ci.sh.
"""

import argparse
import os
import sys
import tempfile
import threading
import time

_ARGS = [a for a in sys.argv[1:] if not a.startswith("-")]
_AXES = (int(_ARGS[0]), int(_ARGS[1])) if len(_ARGS) >= 2 else (2, 2)
_NDEV = _AXES[0] * _AXES[1]
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_NDEV} "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.core.selector import set_active_tuning  # noqa: E402
from repro.offload import OffloadEngine, TuningCache  # noqa: E402
from repro.service import (  # noqa: E402
    DescriptorBroker,
    FileTuningRegistry,
    QueueFullError,
)

AXIS_NAMES = ("pod", "data")
N = 32  # payload columns per request

FAILURES = 0


def check(name: str, ok: bool) -> None:
    global FAILURES
    print(f"service_check {name:42s} {'OK' if ok else 'FAIL'}")
    FAILURES += 0 if ok else 1


def _mesh() -> Mesh:
    devs = np.array(jax.devices()[:_NDEV])
    return Mesh(devs.reshape(_AXES), AXIS_NAMES)


def _descriptors(eng: OffloadEngine):
    """The request mix every tenant streams (planned 2-axis descriptors,
    one with a non-identity split)."""
    mk = eng.make_descriptor
    return [
        mk("SCAN", axes=_AXES, payload_bytes=N * 4, op="sum",
           split=(0, 1)),
        mk("ALLREDUCE", axes=_AXES, payload_bytes=N * 4, op="sum",
           split=(0, 1)),
        mk("EXSCAN", axes=_AXES, payload_bytes=N * 4, op="sum",
           split=(1, 0)),
    ]


def concurrent_bitwise_scenario(n_clients: int, n_requests: int) -> None:
    mesh = _mesh()
    broker = DescriptorBroker(
        OffloadEngine(),
        axis_name=AXIS_NAMES,
        mesh=mesh,
        flush_interval_s=0.25,
    ).start()
    direct = OffloadEngine()
    descs = _descriptors(broker.engine)
    rng = np.random.default_rng(11)
    payloads = {
        (c, r): jnp.asarray(
            rng.integers(-4, 5, size=(_NDEV, N)).astype(np.float32)
        )
        for c in range(n_clients)
        for r in range(n_requests)
    }
    clients = [broker.client(f"tenant{c}") for c in range(n_clients)]
    barrier = threading.Barrier(n_clients)
    results: dict = {}
    errors: list = []

    def work(c: int) -> None:
        try:
            for r in range(n_requests):
                # all tenants post the same round's descriptor inside one
                # flush window: the broker coalesces across tenants
                barrier.wait()
                ticket = clients[c].submit(
                    descs[r % len(descs)].encode(), payloads[(c, r)]
                )
                results[(c, r)] = ticket.result(60)
        except Exception as e:  # noqa: BLE001
            errors.append((c, e))

    threads = [
        threading.Thread(target=work, args=(c,)) for c in range(n_clients)
    ]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall_s = time.perf_counter() - t0
    broker.stop()
    check("no client errors", not errors)
    if errors:
        print(f"  first error: {errors[0]}")

    bitwise = True
    for (c, r), got in results.items():
        desc = descs[r % len(descs)]
        want = direct.offload(
            desc, payloads[(c, r)], axis_name=AXIS_NAMES, mesh=mesh
        )
        bitwise &= np.array_equal(np.asarray(got), np.asarray(want))
    check(
        "all results bitwise == direct dispatch",
        bitwise and len(results) == n_clients * n_requests,
    )
    snap = broker.telemetry.snapshot()
    factor = snap["coalesce_factor"]
    check("coalesce factor > 1", factor > 1.0)
    check(
        "every tenant completed every request",
        all(
            t["completed"] == n_requests and t["rejected"] == 0
            for t in snap["tenants"].values()
        ),
    )
    total = n_clients * n_requests
    print(
        f"service_check_stats,clients,{n_clients},requests,{total},"
        f"dispatches,{snap['fused_dispatches']},"
        f"coalesce_factor,{factor:.2f},"
        f"engine_cache_size,{snap['engine']['cache_size']},"
        f"wall_s,{wall_s:.2f}"
    )
    print(
        f"service_check_summary,bitwise_equal,{int(bitwise)},"
        f"coalesce_gt1,{int(factor > 1.0)},"
        f"coalesce_factor,{factor:.2f}"
    )


def backpressure_scenario() -> None:
    """One tenant overruns a 2-deep queue; rejection is observed and the
    other tenants' results stay bitwise correct."""
    mesh = _mesh()
    broker = DescriptorBroker(
        OffloadEngine(), axis_name=AXIS_NAMES, mesh=mesh
    )
    direct = OffloadEngine()
    desc = broker.engine.make_descriptor(
        "ALLREDUCE", axes=_AXES, payload_bytes=N * 4, op="sum", split=(0, 1)
    )
    rng = np.random.default_rng(5)
    xs = [
        jnp.asarray(rng.integers(-4, 5, size=(_NDEV, N)).astype(np.float32))
        for _ in range(5)
    ]
    small = broker.client("small", max_queue_depth=2)
    others = [broker.client(f"ok{i}") for i in range(2)]
    tickets = [c.submit(desc.encode(), x) for c, x in zip(others, xs)]
    small.submit(desc.encode(), xs[2])
    small.submit(desc.encode(), xs[3])
    rejected = False
    try:
        small.submit(desc.encode(), xs[4])
    except QueueFullError:
        rejected = True
    check("overrun tenant observes backpressure", rejected)
    broker.drain()
    ok = True
    for t, x in zip(tickets, xs):
        want = direct.offload(desc, x, axis_name=AXIS_NAMES, mesh=mesh)
        ok &= np.array_equal(np.asarray(t.result(30)), np.asarray(want))
    check("other tenants' results uncorrupted", ok)
    snap = broker.telemetry.snapshot()
    check(
        "rejection localized to the overrun tenant",
        snap["tenants"]["small"]["rejected"] == 1
        and snap["tenants"]["small"]["completed"] == 2
        and all(
            snap["tenants"][f"ok{i}"]["rejected"] == 0 for i in range(2)
        ),
    )


def registry_scenario() -> None:
    """Disjoint tables merge in the shared registry; the broker's planner
    adopts the split winner the *other* worker measured."""
    with tempfile.TemporaryDirectory() as root:
        mine, theirs = TuningCache(), TuningCache()
        mine.record_split("scan", _AXES, (0, 1), N * 4, 5e-3)
        theirs.record_split("scan", _AXES, (1, 0), N * 4, 1e-3)
        reg = FileTuningRegistry(root)
        reg.publish(mine)
        reg.publish(theirs)
        set_active_tuning(None)
        broker = DescriptorBroker(OffloadEngine(), registry=reg)
        desc = broker.make_descriptor(
            "SCAN", axes=_AXES, payload_bytes=N * 4, op="sum", split="auto"
        )
        check(
            "broker inherits other worker's split winner",
            desc.split == (1, 0) and broker.tuning_table is not None,
        )
        set_active_tuning(None)


def deadline_flush_scenario() -> None:
    """A lone request (no companion traffic) completes within a bounded
    wait: the deadline flush dispatches it alone."""
    mesh = _mesh()
    with DescriptorBroker(
        OffloadEngine(),
        axis_name=AXIS_NAMES,
        mesh=mesh,
        flush_interval_s=0.05,
    ) as broker:
        c = broker.client("lone")
        desc = broker.engine.make_descriptor(
            "SCAN", axes=_AXES, payload_bytes=N * 4, op="sum", split=(0, 1)
        )
        x = jnp.ones((_NDEV, N), jnp.float32)
        t0 = time.perf_counter()
        out = c.offload(desc.encode(), x, timeout=30)
        waited = time.perf_counter() - t0
        want = np.cumsum(np.ones((_NDEV, N), np.float32), axis=0)
        check(
            "lone request not starved",
            np.array_equal(np.asarray(out), want),
        )
        # generous bound: one flush window + one driver-mode compile
        print(f"service_check lone-request wait: {waited:.2f}s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("axes", nargs="*", type=int, default=list(_AXES))
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()
    assert len(jax.devices()) == _NDEV, (len(jax.devices()), _NDEV)
    assert args.clients >= 4, "acceptance requires >= 4 concurrent clients"

    concurrent_bitwise_scenario(args.clients, args.requests)
    backpressure_scenario()
    registry_scenario()
    deadline_flush_scenario()

    if FAILURES:
        print(f"FAILURES: {FAILURES}")
        sys.exit(1)
    print("ALL-OK")


if __name__ == "__main__":
    main()
