"""Health-stack validation: link attribution, SLO alerting, flight recorder.

Run:  python -m repro.testing.health_check [pod data]

One 2x2 run (device count fixed before jax import, hence the subprocess
pattern) exercises the whole :mod:`repro.obs.health` contract end to end:

  1. **Bitwise invariance** — the same planned SCAN dispatches three ways:
     sim baseline, **driver mode** on a real (pod, data) mesh, and sim
     under a link-probing tracer with a synthetic 10 ms delay planted on
     one link. All three results must be bitwise identical: neither the
     per-link probe decomposition nor the injected delay may change a
     single bit.
  2. **Attribution** — after warmup dispatches (per-pair compile noise
     must not poison the EWMAs), a :class:`LinkStragglerDetector` watches
     the probed dispatches and must name *exactly* the planted link
     (axis, src, dst) — no false positives on its same-axis peer or on
     the other axis — and hand the report to an ``on_report`` callback
     (the remesh-consumer hook).
  3. **SLO breach** — a broker tenant submits with an impossible deadline;
     ingesting the service telemetry into a :class:`HealthMonitor` must
     fire a multi-window burn-rate alert for that tenant, flip
     ``healthz()`` to "alert", and count the miss in
     ``repro_service_deadline_misses_total``.
  4. **Flight recorder** — the ring must contain the ``deadline_miss``,
     ``straggler_link`` and ``slo_alert`` events the run produced, and
     :meth:`FlightRecorder.dump` must write valid, self-consistent JSON.

Emits a ``health_check_summary`` CSV row and a final ALL-OK; exits
nonzero on any violation. Used by scripts/ci.sh and tests/test_health.py.
"""

import json
import os
import sys
import tempfile
from pathlib import Path

_ARGS = [a for a in sys.argv[1:] if not a.startswith("-")]
_AXES = (int(_ARGS[0]), int(_ARGS[1])) if len(_ARGS) >= 2 else (2, 2)
_NDEV = _AXES[0] * _AXES[1]
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_NDEV} "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.obs import events as obs_events  # noqa: E402
from repro.obs import health as obs_health  # noqa: E402
from repro.obs import metrics as obs_metrics  # noqa: E402
from repro.obs import tracing as obs_tracing  # noqa: E402
from repro.offload import OffloadEngine  # noqa: E402
from repro.service import DescriptorBroker  # noqa: E402

AXIS_NAMES = ("pod", "data")
N = 32  # payload columns

#: the link the injector slows — axis 1, because on a 2x2 mesh axis 0 has
#: a single link and peer-relative detection needs a same-axis baseline
SLOW_LINK = (1, 0, 1)
DELAY_S = 0.010

WARMUP_DISPATCHES = 2   # warm per-pair compile caches before measuring
PROBED_DISPATCHES = 6   # enough for min_samples + report_after consecutive

FAILURES = 0


def check(name: str, ok: bool) -> None:
    global FAILURES
    print(f"health {name:44s} {'OK' if ok else 'FAIL'}")
    FAILURES += 0 if ok else 1


def main() -> None:
    if _AXES[1] < 2:
        print(f"health_check: inner axis must be >= 2, got {_AXES}")
        sys.exit(2)

    eng = OffloadEngine()
    desc = eng.make_descriptor(
        "scan", axes=_AXES, payload_bytes=N * 4, op="sum", optimize=True,
    )
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((_NDEV, N)).astype(np.float32))

    # ---- 1. bitwise invariance across sim / driver / probed dispatch -----
    baseline = np.asarray(eng.offload(desc, x))

    mesh = Mesh(np.array(jax.devices()[:_NDEV]).reshape(_AXES), AXIS_NAMES)
    driver = np.asarray(
        eng.offload(desc, x, axis_name=AXIS_NAMES, mesh=mesh)
    )
    check("driver-mode result bitwise == sim", np.array_equal(
        driver, baseline,
    ))

    # warm the per-pair dispatch caches so the detector's first measured
    # samples are steady-state link latencies, not compile time
    with obs_tracing.tracing(obs_tracing.Tracer(link_probe=True)):
        for _ in range(WARMUP_DISPATCHES):
            eng.offload(desc, x)

    detector = obs_health.LinkStragglerDetector(
        min_samples=2, report_after=3, threshold=2.0,
    )
    reported: list = []
    detector.on_report(reported.append)
    injector = obs_health.LinkDelayInjector({SLOW_LINK: DELAY_S})
    tracer = obs_tracing.Tracer(
        link_probe=True, link_injector=injector, link_detector=detector,
    )
    probed = None
    with obs_tracing.tracing(tracer):
        for _ in range(PROBED_DISPATCHES):
            probed = np.asarray(eng.offload(desc, x))
    check("probed+injected result bitwise == sim", np.array_equal(
        probed, baseline,
    ))
    bitwise_ok = np.array_equal(driver, baseline) and np.array_equal(
        probed, baseline
    )

    # ---- 2. the planted link — and only it — is attributed ---------------
    spans = tracer.spans()
    link_spans = [s for s in spans if s.cat == "link"]
    round_ids = {s.span_id for s in spans if s.cat == "round"}
    check("link spans present", len(link_spans) > 0)
    check("link spans parented to round spans", all(
        s.parent_id in round_ids for s in link_spans
    ))

    top = detector.straggler()
    attribution_ok = (
        top is not None
        and (top["axis"], top["src"], top["dst"]) == SLOW_LINK
    )
    check("planted link named as straggler", attribution_ok)
    reports = detector.reports()
    check("no other link reported", len(reports) == 1)
    attribution_ok = attribution_ok and len(reports) == 1
    check("on_report callback fired", len(reported) == 1 and (
        (reported[0]["axis"], reported[0]["src"], reported[0]["dst"])
        == SLOW_LINK
    ))
    slow_rows = [
        r for r in detector.summary()
        if (r["axis"], r["src"], r["dst"]) == SLOW_LINK
    ]
    check("slow link EWMA reflects injected delay", bool(slow_rows) and (
        slow_rows[0]["ewma_us"] >= DELAY_S * 1e6 * 0.5
    ))

    # ---- 3. deadline-miss SLO burns -> alert -----------------------------
    monitor = obs_health.HealthMonitor(
        (
            obs_health.SLO(
                "deadline_miss",
                "tenant completions meeting their deadline",
                objective=0.99,
                fast_window_s=5.0,
                slow_window_s=30.0,
                min_events=1,
            ),
        ),
        link_detector=detector,
    )
    broker = DescriptorBroker(OffloadEngine()).start()
    try:
        client = broker.client("hurried")
        for _ in range(3):
            # a deadline no dispatch can meet: every completion is a miss
            client.submit(desc, x, deadline_s=1e-6).result(timeout=60.0)
    finally:
        broker.stop()
    monitor.ingest(service=broker.telemetry)
    alerts = monitor.evaluate()
    alert_ok = any(
        a.slo == "deadline_miss" and a.key == "hurried" for a in alerts
    )
    check("deadline-miss burn-rate alert fires", alert_ok)
    hz = monitor.healthz()
    check("healthz reports alert status", hz["status"] == "alert")
    check("healthz names the straggler link", any(
        (s["axis"], s["src"], s["dst"]) == SLOW_LINK
        for s in hz["stragglers"]
    ))
    prom = obs_metrics.render_prometheus()
    check("prometheus: deadline-miss counter", (
        "repro_service_deadline_misses_total" in prom
    ))
    check("prometheus: link straggler counter", (
        "repro_link_straggler_reports_total" in prom
    ))

    # ---- 4. flight recorder saw it all and dumps valid JSON --------------
    rec = obs_events.get_recorder()
    counts = rec.counts()
    check("flight: deadline_miss events", counts.get("deadline_miss", 0) >= 3)
    check("flight: straggler_link event", counts.get("straggler_link", 0) >= 1)
    check("flight: slo_alert event", counts.get("slo_alert", 0) >= 1)

    dump_ok = False
    with tempfile.TemporaryDirectory() as td:
        path = Path(td) / "flight.json"
        rec.dump(path, reason="health_check")
        try:
            data = json.loads(path.read_text())
            dump_ok = (
                isinstance(data, dict)
                and data.get("reason") == "health_check"
                and data.get("recorded", 0) >= len(data.get("events", []))
                and len(data.get("events", [])) > 0
                and all("kind" in e and "seq" in e for e in data["events"])
            )
        except (OSError, ValueError):
            dump_ok = False
    check("flight-recorder dump is valid JSON", dump_ok)

    top = top or {"axis": -1, "src": -1, "dst": -1}
    print(
        f"health_check_summary,bitwise_equal,{int(bitwise_ok)},"
        f"straggler_axis,{top['axis']},straggler_src,{top['src']},"
        f"straggler_dst,{top['dst']},attribution_ok,{int(attribution_ok)},"
        f"slo_alert,{int(alert_ok)},dump_valid,{int(dump_ok)},"
        f"link_spans,{len(link_spans)}"
    )
    if FAILURES:
        print(f"FAILURES: {FAILURES}")
        sys.exit(1)
    print("ALL-OK")


if __name__ == "__main__":
    main()
