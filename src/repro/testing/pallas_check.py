"""Bitwise validation of the fused-Pallas-kernel backend against lower_spmd.

Run:  python -m repro.testing.pallas_check [p]
One shard_map program per case on a 1D mesh of ``p`` host devices: the
plan is lowered once through ``lower_spmd`` (the op-per-round reference)
and once through ``lower_pallas`` (every exchange round of each phase
fused into one interpret-mode Pallas kernel with async-remote-copy sends
and semaphore waits), and the outputs must match bit for bit. Covers
SCAN/EXSCAN over sum, BARRIER, and the hand-fused FUSED_SCAN_TOTAL phase
in both inclusive and exclusive forms. Operators without a zero identity
(max, the SSD pytree operator) are *outside* the kernel's capability —
its ppermute-style zero-fill recv IS the identity handling — so for
those the check asserts ``supports_plan`` rejects the plan with the
stable ``op_flags`` token the engine's fallback telemetry counts.
Prints one ``pallas_check,...`` CSV row per case and a
final ALL-OK; exits nonzero on mismatch. Used by
tests/test_pallas_backend.py and ``scripts/ci.sh`` via subprocess (device
count must be fixed before jax import).
"""

import dataclasses
import os
import sys

_P = int(sys.argv[1]) if len(sys.argv) > 1 else 8
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_P} "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh, PartitionSpec as P  # noqa: E402

from repro.compat import shard_map  # noqa: E402
from repro.core import SSD  # noqa: E402
from repro.core.operators import get_operator  # noqa: E402
from repro.kernels import pallas_collective  # noqa: E402
from repro.offload.planner import (  # noqa: E402
    PhaseKind,
    PlanPhase,
    build_plan,
    lower_spmd,
)


def _fused_plan(p, op, payload_bytes, *, inclusive):
    """A hand-fused single-axis FUSED_SCAN_TOTAL plan (what the pass
    pipeline emits for SCAN+TOTAL pairs; built directly so the check does
    not depend on the optimizer's fusion trigger)."""
    base = build_plan(
        "SCAN" if inclusive else "EXSCAN", (p,), op, payload_bytes
    )
    phase = PlanPhase(
        PhaseKind.FUSED_SCAN_TOTAL,
        0,
        "fused_doubling",
        inclusive=inclusive,
        src=("x",),
        dst="y",
        dst2="t",
    )
    return dataclasses.replace(base, phases=(phase,), result="y")


def _run_pair(mesh, plan, op, x):
    """(reference, pallas) outputs of one plan under shard_map."""
    spec = P("i")

    def wrap(lowered):
        def body(*args):
            out = lowered(args[0] if args else None)
            # rank-0 leaves (the barrier token) need a leading axis for
            # the out_spec; payload leaves already carry the shard axis
            return jax.tree.map(
                lambda a: a[None] if jnp.ndim(a) == 0 else a, out
            )

        return jax.jit(
            shard_map(
                body,
                mesh=mesh,
                in_specs=(spec,) if x is not None else (),
                out_specs=spec,
                check_vma=False,
            )
        )

    ref_fn = wrap(lower_spmd(plan, ("i",), op))
    got_fn = wrap(
        pallas_collective.lower_pallas(
            plan, op, axis_names=("i",), interpret=True
        )
    )
    args = (x,) if x is not None else ()
    return ref_fn(*args), got_fn(*args)


def main() -> None:
    p = _P
    assert len(jax.devices()) == p, (len(jax.devices()), p)
    mesh = Mesh(np.array(jax.devices()), ("i",))
    rng = np.random.default_rng(11)
    failures = 0

    def report(case, ok):
        nonlocal failures
        print(f"pallas_check,{case},p,{p},bitwise,{int(ok)}")
        failures += 0 if ok else 1

    n = 32
    x = jnp.asarray(rng.integers(-4, 5, size=(p, n)).astype(np.float32))
    for coll in ("SCAN", "EXSCAN"):
        op = get_operator("sum")
        plan = build_plan(coll, (p,), op, 4 * n)
        ref, got = _run_pair(mesh, plan, op, x)
        ok = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got))
        )
        report(f"{coll.lower()}:sum", ok)

    # operators without a zero identity are outside the kernel's
    # capability envelope: the contract is a clean supports_plan
    # rejection (the engine soft-falls back on this token), never a
    # wrong answer or a crash inside the kernel
    for opname, op in (("max", get_operator("max")), ("ssd", SSD)):
        plan = build_plan("SCAN", (p,), op, 4 * n)
        supported, reason = pallas_collective.supports_plan(plan, ("i",))
        ok = (not supported) and reason == "op_flags"
        report(f"scan:{opname}:rejected:{reason or 'none'}", ok)

    # barrier (no payload; output is the fence token)
    op = get_operator("max")
    plan = build_plan("BARRIER", (p,), op, 4)
    ref, got = _run_pair(mesh, plan, op, None)
    ok = all(
        np.array_equal(np.asarray(u), np.asarray(v))
        for u, v in zip(jax.tree.leaves(ref), jax.tree.leaves(got))
    )
    report("barrier", ok)

    # hand-fused SCAN+TOTAL, both forms; lower_spmd returns the plan's
    # result register, so each output is observed by re-pointing `result`
    op = get_operator("sum")
    for inclusive in (True, False):
        for result in ("y", "t"):
            plan = dataclasses.replace(
                _fused_plan(p, op, 4 * n, inclusive=inclusive),
                result=result,
            )
            ref, got = _run_pair(mesh, plan, op, x)
            ok = all(
                np.array_equal(np.asarray(u), np.asarray(v))
                for u, v in zip(
                    jax.tree.leaves(ref), jax.tree.leaves(got)
                )
            )
            form = "inc" if inclusive else "exc"
            out = "scan" if result == "y" else "total"
            report(f"fused_scan_total:{form}:{out}", ok)

    if failures:
        print(f"FAILURES: {failures}")
        sys.exit(1)
    print("ALL-OK")


if __name__ == "__main__":
    main()
