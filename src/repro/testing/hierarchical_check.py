"""SPMD validation of the two-level hierarchical scan on a real 2D mesh.

Run:  python -m repro.testing.hierarchical_check [p_outer p_inner]
Prints one line per case and a final ALL-OK; exits nonzero on mismatch. Used
by tests/test_hierarchical_scan.py via subprocess (device count must be fixed
before jax import).
"""

import os
import sys

_PO = int(sys.argv[1]) if len(sys.argv) > 1 else 2
_PI = int(sys.argv[2]) if len(sys.argv) > 2 else 4
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_PO * _PI} "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
from repro.compat import shard_map  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh, PartitionSpec as P  # noqa: E402

from repro.core import SSD  # noqa: E402
from repro.offload import dist_hierarchical_scan  # noqa: E402


def main() -> None:
    po, pi = _PO, _PI
    ptotal = po * pi
    assert len(jax.devices()) == ptotal, (len(jax.devices()), ptotal)
    mesh = Mesh(
        np.array(jax.devices()).reshape(po, pi), ("outer", "inner")
    )
    rng = np.random.default_rng(7)
    failures = 0

    def run(x, op, inclusive):
        def body(xs):
            return dist_hierarchical_scan(
                xs, op, "inner", "outer", inclusive=inclusive
            )

        spec = P(("outer", "inner"))
        m = shard_map(body, mesh=mesh, in_specs=spec, out_specs=spec)
        return np.asarray(jax.jit(m)(x))

    n = 16
    x = rng.integers(-4, 5, size=(ptotal, n)).astype(np.float32)
    for opname, acc in (("sum", np.cumsum), ("max", np.maximum.accumulate)):
        want = acc(x, axis=0)
        got = run(jnp.asarray(x), opname, True)
        ok = np.array_equal(got, want)
        print(f"hier2d scan   {opname:4s} {po}x{pi} {'OK' if ok else 'FAIL'}")
        failures += 0 if ok else 1
    # exclusive sum
    want = np.concatenate([np.zeros((1, n), np.float32),
                           np.cumsum(x, axis=0)[:-1]])
    got = run(jnp.asarray(x), "sum", False)
    ok = np.array_equal(got, want)
    print(f"hier2d exscan sum  {po}x{pi} {'OK' if ok else 'FAIL'}")
    failures += 0 if ok else 1

    # non-commutative SSD pytree operator across both axes
    a = rng.uniform(0.5, 1.0, size=(ptotal, 8)).astype(np.float32)
    b = rng.normal(size=(ptotal, 8)).astype(np.float32)
    A = np.empty_like(a)
    B = np.empty_like(b)
    A[0], B[0] = a[0], b[0]
    for j in range(1, ptotal):
        A[j] = a[j] * A[j - 1]
        B[j] = a[j] * B[j - 1] + b[j]

    def body(xs):
        return dist_hierarchical_scan(xs, SSD, "inner", "outer")

    spec = P(("outer", "inner"))
    m = shard_map(
        body, mesh=mesh, in_specs=((spec, spec),), out_specs=(spec, spec)
    )
    ga, gb = jax.jit(m)((jnp.asarray(a), jnp.asarray(b)))
    ok = np.allclose(np.asarray(ga), A, atol=1e-5) and np.allclose(
        np.asarray(gb), B, atol=1e-5
    )
    print(f"hier2d scan   ssd  {po}x{pi} {'OK' if ok else 'FAIL'}")
    failures += 0 if ok else 1

    if failures:
        print(f"FAILURES: {failures}")
        sys.exit(1)
    print("ALL-OK")


if __name__ == "__main__":
    main()
