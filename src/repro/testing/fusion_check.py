"""SPMD validation of the plan-optimizer pass pipeline on a real mesh.

Run:  python -m repro.testing.fusion_check [outer inner]

Every CollType dispatches twice through one ``OffloadEngine`` in **driver
mode** over an (outer, inner) device mesh — once with the descriptor's
``optimized`` flag set (pass pipeline on: SCAN+TOTAL fusion, dead-phase
elimination, permute threading) and once without — and the results must be
bitwise identical to each other and to the flat single-axis reference.
SCAN/EXSCAN additionally run **inside** ``shard_map`` (spmd mode) so the
fused phase's ``lower_spmd`` path is exercised on real named axes, and one
optimized dispatch runs under ``jax.profiler`` so the telemetry gains a
measured-on-device latency source (``device_latency_by_coll_us``), closing
the ROADMAP "SPMD-mode engine telemetry" loop. Prints the optimized plan's
``describe()`` (fused phases + per-plan permute chain), one line per case,
a ``fusion_check_summary`` row for the CI gate, and ALL-OK; exits nonzero
on mismatch. Used by tests/test_passes.py via subprocess (device count
must be fixed before jax import).
"""

import os
import sys

_AXES = (
    (int(sys.argv[1]), int(sys.argv[2])) if len(sys.argv) > 2 else (2, 2)
)
_P = _AXES[0] * _AXES[1]
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_P} "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh, PartitionSpec as P  # noqa: E402

from repro.compat import shard_map  # noqa: E402
from repro.core import CollType, sim_reduce, sim_scan  # noqa: E402
from repro.offload import (  # noqa: E402
    OffloadEngine,
    build_plan,
    optimize_plan,
    plan_comm_rounds,
)

AXIS_NAMES = ("outer", "inner")


def main() -> None:
    axes = _AXES
    ptotal = _P
    assert len(jax.devices()) == ptotal, (len(jax.devices()), ptotal)
    mesh = Mesh(np.array(jax.devices()).reshape(axes), AXIS_NAMES)
    eng = OffloadEngine()
    rng = np.random.default_rng(11)
    failures = 0
    n = 8
    x = rng.integers(-5, 6, size=(ptotal, n)).astype(np.float32)
    xj = jnp.asarray(x)

    def check(name, ok):
        nonlocal failures
        print(f"fusion {name:34s} {'x'.join(map(str, axes))} "
              f"{'OK' if ok else 'FAIL'}")
        failures += 0 if ok else 1

    def flat_ref(coll, root=0):
        if coll == CollType.SCAN:
            return np.asarray(sim_scan(xj, "sum", ptotal,
                                       algorithm="hillis_steele"))
        if coll == CollType.EXSCAN:
            return np.asarray(sim_scan(xj, "sum", ptotal,
                                       algorithm="hillis_steele",
                                       inclusive=False))
        if coll == CollType.REDUCE:
            return np.asarray(sim_reduce(xj, "sum", ptotal, root=root))
        if coll == CollType.ALLREDUCE:
            return np.broadcast_to(x.sum(axis=0), x.shape).copy()
        return np.ones((ptotal,), np.float32)

    # the optimized plan the descriptors below compile, rendered for the
    # console: fused phases + the per-plan permute chain must be readable
    shown = optimize_plan(
        build_plan("SCAN", axes, "sum", n * 4, order=(0, 1))
    )
    raw_plan = build_plan("SCAN", axes, "sum", n * 4, order=(0, 1))
    print(shown.describe())
    print(f"fusion rounds scan {plan_comm_rounds(raw_plan)} -> "
          f"{plan_comm_rounds(shown)}")
    raw_ex = build_plan("EXSCAN", axes, "sum", n * 4, order=(0, 1))
    opt_ex = optimize_plan(raw_ex)
    print(f"fusion rounds exscan {plan_comm_rounds(raw_ex)} -> "
          f"{plan_comm_rounds(opt_ex)}")

    # driver mode: optimized vs raw vs flat, every CollType
    root = ptotal - 1 if ptotal > 1 else 0
    for coll in CollType:
        d_opt = eng.make_descriptor(
            coll.name, axes=axes, payload_bytes=n * 4, op="sum",
            root=root, split=(0, 1), optimize=True,
        )
        d_raw = eng.make_descriptor(
            coll.name, axes=axes, payload_bytes=n * 4, op="sum",
            root=root, split=(0, 1), optimize=False,
        )
        assert d_opt.optimized and not d_raw.optimized
        arg = None if coll == CollType.BARRIER else xj
        got_opt = np.asarray(
            eng.offload(d_opt, arg, axis_name=AXIS_NAMES, mesh=mesh)
        ).reshape(-1, *x.shape[1:] if coll != CollType.BARRIER else ())
        got_raw = np.asarray(
            eng.offload(d_raw, arg, axis_name=AXIS_NAMES, mesh=mesh)
        ).reshape(got_opt.shape)
        want = flat_ref(coll, root=root).reshape(got_opt.shape)
        check(f"driver {coll.name.lower()} opt==raw",
              np.array_equal(got_opt, got_raw))
        check(f"driver {coll.name.lower()} opt==flat",
              np.array_equal(got_opt, want))

    # spmd mode: the fused phase inside shard_map on real named axes
    spec = P(AXIS_NAMES)
    for coll in (CollType.SCAN, CollType.EXSCAN):
        d_opt = eng.make_descriptor(
            coll.name, axes=axes, payload_bytes=n * 4, op="sum",
            split=(0, 1), optimize=True,
        )

        def body(xs, desc=d_opt):
            return eng.offload(desc, xs, axis_name=AXIS_NAMES)

        got = np.asarray(
            jax.jit(shard_map(body, mesh=mesh, in_specs=spec,
                              out_specs=spec))(xj)
        )
        check(f"spmd {coll.name.lower()} fused==flat",
              np.array_equal(got, flat_ref(coll)))

    # profiler-sourced device telemetry on an optimized driver dispatch
    d_opt = eng.make_descriptor(
        "SCAN", axes=axes, payload_bytes=n * 4, op="sum",
        split=(0, 1), optimize=True,
    )
    timing = eng.profile_offload(d_opt, xj, axis_name=AXIS_NAMES, mesh=mesh)
    snap = eng.telemetry.snapshot()
    dev_us = snap["device_latency_by_coll_us"].get("scan", 0.0)
    print(f"fusion profiled scan device_us={dev_us:.1f} "
          f"source={timing.source} events={timing.events}")
    # the acceptance criterion is *profiler-sourced* latency: a wall-clock
    # fallback means the trace pipeline broke and must fail the check
    check("device latency recorded", dev_us > 0)
    check("latency source is the profiler",
          snap["latency_source_by_coll"].get("scan") == "profiler")
    device_ok = dev_us > 0 and timing.source == "profiler"

    rounds_reduced = int(
        plan_comm_rounds(opt_ex) < plan_comm_rounds(raw_ex)
        and plan_comm_rounds(shown) <= plan_comm_rounds(raw_plan)
    )
    bitwise_ok = int(failures == 0)
    print(
        f"fusion_check_summary,bitwise_equal,{bitwise_ok},"
        f"device_latency,{int(device_ok)},rounds_reduced,{rounds_reduced},"
        f"source,{timing.source}"
    )
    if failures:
        print(f"FAILURES: {failures}")
        sys.exit(1)
    print("ALL-OK")


if __name__ == "__main__":
    main()
