"""int8+error-feedback gradient sync: convergence parity vs f32 DP.

8-way data-parallel toy regression trained twice — exact f32 psum vs
compressed_allreduce_mean — final losses must both reach tolerance and track
each other. Run: python -m repro.testing.compressed_dp_check
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import sys  # noqa: E402
from functools import partial  # noqa: E402

import jax  # noqa: E402
from repro.compat import shard_map  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh, PartitionSpec as P  # noqa: E402

from repro.optim.compression import compressed_allreduce_mean  # noqa: E402


def main() -> None:
    mesh = Mesh(np.array(jax.devices()), ("dp",))
    rng = np.random.default_rng(0)
    w_true = rng.normal(size=(16,)).astype(np.float32)
    X = rng.normal(size=(8, 64, 16)).astype(np.float32)  # per-rank shards
    y = X @ w_true + 0.01 * rng.normal(size=(8, 64)).astype(np.float32)

    def local_grad(w, Xl, yl):
        pred = Xl @ w
        return Xl.T @ (pred - yl) / yl.size

    def make_train(compressed: bool):
        def step(w, err, Xl, yl):
            Xl, yl = Xl[0], yl[0]  # strip the sharded leading rank dim
            g = local_grad(w, Xl, yl)
            if compressed:
                gm, err = compressed_allreduce_mean({"w": g}, "dp", err)
                g = gm["w"]
            else:
                g = jax.lax.pmean(g, "dp")
            return w - 0.1 * g, err

        mapped = shard_map(
            step, mesh=mesh,
            in_specs=(P(), {"w": P()}, P("dp", None, None), P("dp", None)),
            out_specs=(P(), {"w": P()}),
            check_vma=False,
        )
        return jax.jit(mapped)

    losses = {}
    for compressed in (False, True):
        w = jnp.zeros(16)
        err = {"w": jnp.zeros(16)}
        train = make_train(compressed)
        for _ in range(150):
            w, err = train(w, err, jnp.asarray(X), jnp.asarray(y))
        loss = float(np.mean((X.reshape(-1, 16) @ np.asarray(w) - y.reshape(-1)) ** 2))
        losses[compressed] = loss
        print(f"compressed={compressed}: final mse {loss:.5f}")

    ok = losses[True] < 5e-3 and losses[False] < 5e-3
    print("convergence parity:", "OK" if ok else "FAIL")
    print("ALL-OK" if ok else "FAILED")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
