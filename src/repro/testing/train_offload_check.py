"""End-to-end validation of the offloaded training path on a real DP mesh.

Run:  python -m repro.testing.train_offload_check [pod data] [--steps N]
                                                  [--bench-iters N]

Three scenarios on one multi-device CPU process (device count must be fixed
before jax import, hence the subprocess pattern):

  1. **Bitwise step equivalence** — two steps of ``build_dp_train_step`` on a
     (pod, data) mesh with the gradient allreduce / metric means / example
     EXSCAN dispatched through ``OffloadEngine`` planned descriptors, against
     the identically-structured raw ``lax`` reference: loss, grad_norm and
     every updated parameter must match bit for bit, and the step-2 dispatch
     of every descriptor must hit the compiled-plan cache.
  2. **Planner-first recovery** — a Trainer on the same mesh with an injected
     failure: the adopted mesh must equal ``plan_remesh``'s output, the
     notify-remesh hook must clear the engine's plan cache, and the cache
     must repopulate from the trainer's own descriptors on the next step.
  3. **Plan-not-halving** — a (data=4, model=1) mesh losing 3 hosts: the
     adopted data axis is the planner's floor-pow2 answer (1), not the
     hardcoded halving (2) the old recovery loop applied.

Emits ``trainer_step``/``trainer_offload`` CSV rows (consumed by
``benchmarks.trainer_step``) and a final ALL-OK; exits nonzero on mismatch.
"""

import argparse
import os
import sys
import tempfile
import time

_ARGS = [a for a in sys.argv[1:] if not a.startswith("-")]
_AXES = (int(_ARGS[0]), int(_ARGS[1])) if len(_ARGS) >= 2 else (2, 2)
_NDEV = _AXES[0] * _AXES[1]
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_NDEV} "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.configs.base import ShapeConfig  # noqa: E402
from repro.data.pipeline import DataConfig, batches  # noqa: E402
from repro.launch.offload_runtime import (  # noqa: E402
    build_offload_engine,
    detach_remesh_hook,
)
from repro.launch.steps import build_dp_train_step  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.optim.adamw import AdamWConfig, init_opt_state  # noqa: E402
from repro.runtime.fault import FailureInjector  # noqa: E402
from repro.runtime.train_loop import Trainer, TrainerConfig  # noqa: E402
from repro.sharding.specs import make_topology  # noqa: E402

FAILURES = 0


def check(name: str, ok: bool) -> None:
    global FAILURES
    print(f"train_offload {name:38s} {'OK' if ok else 'FAIL'}")
    FAILURES += 0 if ok else 1


def _setup(mesh_shape, axis_names, *, batch=8, seq=32, seed=0):
    cfg = get_config("smollm_360m").reduced()
    api = build_model(cfg)
    shape = ShapeConfig("tiny", seq, batch, "train")
    data = batches(
        DataConfig(
            vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch,
            seed=seed,
        )
    )
    devs = np.array(jax.devices()[: int(np.prod(mesh_shape))])
    mesh = Mesh(devs.reshape(mesh_shape), axis_names)
    topo = make_topology(mesh)
    return api, topo, shape, data


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def bitwise_scenario(steps: int, bench_iters: int) -> None:
    """Engine-dispatched DP step vs the raw-lax reference, bit for bit."""
    api, topo, shape, data = _setup(_AXES, ("pod", "data"))
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100)
    eng = build_offload_engine(retune_on_remesh=False)

    raw_fn, _, _ = build_dp_train_step(api, topo, shape, opt, engine=None)
    off_fn, _, _ = build_dp_train_step(api, topo, shape, opt, engine=eng)

    # fresh (deterministic, identical) state per path: update_fn donates its
    # params/opt buffers, so state must never be shared across step builders
    def fresh_state():
        params = api.init(jax.random.key(0))
        return params, init_opt_state(params)

    p_raw, o_raw = fresh_state()
    p_off, o_off = fresh_state()
    bitwise = True
    step2_hit = True
    for s in range(steps):
        batch = next(data)
        misses0, hits0 = eng.telemetry.misses, eng.telemetry.hits
        p_off, o_off, m_off = off_fn(p_off, o_off, batch)
        p_raw, o_raw, m_raw = raw_fn(p_raw, o_raw, batch)
        d_miss = eng.telemetry.misses - misses0
        d_hit = eng.telemetry.hits - hits0
        same = (
            _tree_equal(p_off, p_raw)
            and np.array_equal(float(m_off["loss"]), float(m_raw["loss"]))
            and np.array_equal(
                float(m_off["grad_norm"]), float(m_raw["grad_norm"])
            )
        )
        bitwise &= same
        if s == 0:
            # step 1 must compile — but the plan-keyed cache may already
            # serve hits within the step: descriptors whose optimized
            # plans converge (e.g. the gradient and metric-mean ALLREDUCE
            # over the same axes) legitimately share one schedule
            check("step1 dispatches compile (miss)", d_miss > 0)
        else:
            step2_hit &= d_miss == 0 and d_hit > 0
        print(
            f"trainer_offload,step,{s + 1},misses,{d_miss},hits,{d_hit},"
            f"bitwise,{int(same)},loss,{float(m_off['loss']):.6f},"
            f"examples_seen,{float(m_off['examples_seen']):.0f}"
        )
    check("loss/grads/params bitwise == raw", bitwise)
    check("step2+ dispatch is a plan-cache hit", step2_hit)
    check(
        "examples_seen == global batch",
        float(m_off["examples_seen"]) == shape.global_batch,
    )

    if bench_iters > 0:
        rows = []
        for label, fn in (("raw_lax", raw_fn), ("offload_engine", off_fn)):
            p, o = fresh_state()
            batch = next(data)
            p, o, _ = fn(p, o, batch)  # warm the caches
            t0 = time.perf_counter()
            for _ in range(bench_iters):
                p, o, m = fn(p, o, batch)
            jax.block_until_ready(jax.tree.leaves(p)[0])
            dt = (time.perf_counter() - t0) / bench_iters
            rows.append(f"trainer_step,{label},{dt * 1e3:.1f}")
        for r in rows:
            print(r)
    snap = eng.telemetry.snapshot()
    print(
        f"trainer_offload_summary,bitwise_equal,{int(bitwise)},"
        f"step2_cache_hit,{int(step2_hit)},cache_size,{snap['cache_size']},"
        f"hit_rate,{snap['hit_rate']:.2f}"
    )


def recovery_scenario() -> None:
    """Injected failure under the offload trainer: planner-first remesh."""
    from repro.runtime.fault import plan_remesh

    api, topo, shape, data = _setup(_AXES, ("pod", "data"))
    eng = build_offload_engine(
        retune_on_remesh=True, remesh_tune_budget_s=0.2
    )
    try:
        with tempfile.TemporaryDirectory() as ckpt_dir:
            tr = Trainer(
                api, topo, shape, data,
                TrainerConfig(
                    ckpt_dir=ckpt_dir, ckpt_every=1, async_ckpt=False,
                    use_offload_engine=True,
                ),
                AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100),
                injector=FailureInjector(fail_at=(1,), lost_hosts=1),
                engine=eng,
            )
            params, opt_state = tr.init_state()
            _ = tr.run(params, opt_state, num_steps=3)
        ev = tr.remesh_events[-1]
        old_data = _AXES[1]
        want_plan = plan_remesh(old_data, _AXES[0], lost_hosts=1)
        adopted = dict(
            zip(tr.topo.mesh.axis_names, tr.topo.mesh.devices.shape)
        )
        check("remesh event records the plan", ev.get("plan") == want_plan)
        check(
            "adopted mesh == plan_remesh output",
            adopted["data"] == want_plan[0]
            and ev.get("adopted") == (_AXES[0], want_plan[0]),
        )
        # notify cleared the cache *after* rebuild; the next step's own
        # descriptors repopulated it on the surviving topology
        check("plan cache repopulated after remesh", eng.cache_size() > 0)
        check(
            "post-remesh steps keep dispatching",
            eng.telemetry.dispatches > 0 and eng.telemetry.errors == 0,
        )
    finally:
        detach_remesh_hook(eng)


def plan_not_halving_scenario() -> None:
    """data=4, lost_hosts=3: the planner says 1; naive halving said 2."""
    from repro.runtime.fault import plan_remesh

    api, topo, shape, data = _setup((4, 1), ("data", "model"), batch=8)
    eng = build_offload_engine(retune_on_remesh=True, remesh_tune_budget_s=0.2)
    try:
        with tempfile.TemporaryDirectory() as ckpt_dir:
            tr = Trainer(
                api, topo, shape, data,
                TrainerConfig(
                    ckpt_dir=ckpt_dir, ckpt_every=1, async_ckpt=False,
                    use_offload_engine=True,
                ),
                AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100),
                injector=FailureInjector(fail_at=(1,), lost_hosts=3),
                engine=eng,
            )
            params, opt_state = tr.init_state()
            _ = tr.run(params, opt_state, num_steps=3)
        want = plan_remesh(4, 1, lost_hosts=3)  # (1, 1) — not 4 // 2
        got = dict(zip(tr.topo.mesh.axis_names, tr.topo.mesh.devices.shape))
        check(
            "adopted plan beats naive halving",
            want == (1, 1) and got["data"] == 1 and got["data"] != 4 // 2,
        )
        check(
            "remesh event carries lost_hosts",
            tr.remesh_events[-1].get("lost_hosts") == 3,
        )
    finally:
        detach_remesh_hook(eng)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("axes", nargs="*", type=int, default=list(_AXES))
    ap.add_argument("--steps", type=int, default=2)
    ap.add_argument("--bench-iters", type=int, default=0)
    args = ap.parse_args()
    assert len(jax.devices()) == _NDEV, (len(jax.devices()), _NDEV)

    bitwise_scenario(max(2, args.steps), args.bench_iters)
    recovery_scenario()
    plan_not_halving_scenario()

    if FAILURES:
        print(f"FAILURES: {FAILURES}")
        sys.exit(1)
    print("ALL-OK")


if __name__ == "__main__":
    main()
