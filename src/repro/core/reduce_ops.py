"""The descriptor's other coll_types on the same schedule machinery:
MPI_Reduce / MPI_Allreduce / MPI_Barrier (the paper's companion collectives,
refs [6][7]) built from the identical backend abstraction — a reduce is a
scan whose result is read at the root; a barrier is a zero-byte allreduce.

Every schedule is written against the abstract :class:`~repro.core.algorithms.
Backend`, so the same code runs inside ``shard_map`` (``dist_*``) and on the
single-device simulator (``sim_*``) — which is what lets the offload engine
dispatch *all five* descriptor CollTypes through one code path and validate
them without a mesh.

These complete the CollectiveDescriptor's CollType coverage and give the
benchmark suite a like-for-like latency comparison across collectives.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import algorithms as alg
from repro.core.operators import AssocOp, get_operator

PyTree = Any


# ---------------------------------------------------------------------------
# Backend-generic schedules
# ---------------------------------------------------------------------------


def reduce_schedule(
    backend: alg.Backend, x: PyTree, op: AssocOp, *, root: int = 0,
    algorithm: str = "binomial_tree",
) -> PyTree:
    """MPI_Reduce: the full reduction lands on ``root``; other ranks receive
    the operator identity. Runs the scan schedule (rank p-1 holds the total)
    and ships it to root with one permute."""
    p = backend.p
    total = alg.get_algorithm(algorithm)(backend, x, op)
    if p == 1:
        return total
    rank = backend.rank()
    ident = op.identity_like(x)
    if root == p - 1:
        return alg._bwhere(rank == root, total, ident)
    moved = backend.permute(total, [(p - 1, root)])
    return alg._bwhere(rank == root, moved, ident)


def allreduce_schedule(
    backend: alg.Backend, x: PyTree, op: AssocOp, *,
    algorithm: str = "recursive_doubling",
) -> PyTree:
    """MPI_Allreduce (every rank ends with the total).

    Power-of-two sizes run the classic recursive-doubling butterfly with the
    combine *ordered by rank block* (received block precedes ours iff the
    partner is lower), which keeps the schedule correct for non-commutative
    operators such as SSD. Other sizes fall back to inclusive-scan +
    broadcast-from-last, correct for any p and operator. For ops with zero
    identity this is bitwise-equivalent to lax.psum's ring for 'sum'; the
    point is schedule control (the paper's [7])."""
    p = backend.p
    if p == 1:
        return x
    if p & (p - 1) == 0:
        rank = backend.rank()
        acc_v, acc_f = x, alg._ones_flag(backend)
        for k in range(alg.num_steps(p)):
            d = 1 << k
            perm = [(j, j ^ d) for j in range(p)]
            rv, rf = backend.permute((acc_v, acc_f), perm)
            partner_lower = (rank & d) != 0  # partner = rank ^ d < rank
            lo_v, lo_f = alg._combine_lr(op, rv, rf, acc_v, acc_f)
            hi_v, hi_f = alg._combine_lr(op, acc_v, acc_f, rv, rf)
            acc_v = alg._bwhere(partner_lower, lo_v, hi_v)
            acc_f = jnp.where(partner_lower, lo_f, hi_f)
        return acc_v
    total = alg.get_algorithm(algorithm)(backend, x, op)
    bcast = backend.permute(total, [(p - 1, j) for j in range(p - 1)])
    rank = backend.rank()
    return alg._bwhere(rank == p - 1, total, bcast)


def barrier_schedule(
    backend: alg.Backend, *, algorithm: str = "recursive_doubling"
) -> jax.Array:
    """MPI_Barrier (the authors' NetFPGA barrier, ref [6]): a minimal-payload
    allreduce; returns 1.0 per rank whose data dependency fences the program."""
    from repro.core.operators import MAX

    r = backend.rank()
    token = jnp.ones(jnp.shape(r), jnp.float32)
    return allreduce_schedule(backend, token, MAX, algorithm=algorithm)


# ---------------------------------------------------------------------------
# SPMD entry points (inside shard_map)
# ---------------------------------------------------------------------------


def dist_reduce(
    x: PyTree, op: "AssocOp | str", axis_name: str, *, root: int = 0,
    algorithm: str = "binomial_tree",
) -> PyTree:
    op = get_operator(op)
    backend = alg.SpmdBackend(axis_name)
    return reduce_schedule(backend, x, op, root=root, algorithm=algorithm)


def dist_allreduce(
    x: PyTree, op: "AssocOp | str", axis_name: str, *,
    algorithm: str = "recursive_doubling",
) -> PyTree:
    op = get_operator(op)
    backend = alg.SpmdBackend(axis_name)
    return allreduce_schedule(backend, x, op, algorithm=algorithm)


def dist_barrier(axis_name: str, *, algorithm: str = "recursive_doubling") -> jax.Array:
    backend = alg.SpmdBackend(axis_name)
    return barrier_schedule(backend, algorithm=algorithm)


# ---------------------------------------------------------------------------
# Simulator entry points (stacked leading rank axis, single device)
# ---------------------------------------------------------------------------


def sim_reduce(
    stacked: PyTree, op: "AssocOp | str", p: int, *, root: int = 0,
    algorithm: str = "binomial_tree",
) -> PyTree:
    op = get_operator(op)
    return reduce_schedule(
        alg.SimBackend(p), stacked, op, root=root, algorithm=algorithm
    )


def sim_allreduce(
    stacked: PyTree, op: "AssocOp | str", p: int, *,
    algorithm: str = "recursive_doubling",
) -> PyTree:
    op = get_operator(op)
    return allreduce_schedule(alg.SimBackend(p), stacked, op, algorithm=algorithm)


def sim_barrier(p: int, *, algorithm: str = "recursive_doubling") -> jax.Array:
    return barrier_schedule(alg.SimBackend(p), algorithm=algorithm)
