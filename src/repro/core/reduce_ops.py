"""The descriptor's other coll_types on the same schedule machinery:
MPI_Reduce / MPI_Allreduce / MPI_Barrier (the paper's companion collectives,
refs [6][7]) built from the identical backend abstraction — a reduce is a
scan whose result is read at the root; a barrier is a zero-byte allreduce.

These complete the CollectiveDescriptor's CollType coverage and give the
benchmark suite a like-for-like latency comparison across collectives.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import algorithms as alg
from repro.core.operators import AssocOp, get_operator

PyTree = Any


def dist_reduce(
    x: PyTree, op: "AssocOp | str", axis_name: str, *, root: int = 0,
    algorithm: str = "binomial_tree",
) -> PyTree:
    """MPI_Reduce: the full reduction lands on ``root``; other ranks receive
    the operator identity. Runs the scan schedule (rank p-1 holds the total)
    and ships it to root with one permute."""
    op = get_operator(op)
    backend = alg.SpmdBackend(axis_name)
    p = backend.p
    total = alg.get_algorithm(algorithm)(backend, x, op)
    if p == 1:
        return total
    rank = backend.rank()
    ident = op.identity_like(x)
    if root == p - 1:
        return alg._bwhere(rank == root, total, ident)
    moved = backend.permute(total, [(p - 1, root)])
    return alg._bwhere(rank == root, moved, ident)


def dist_allreduce(
    x: PyTree, op: "AssocOp | str", axis_name: str, *,
    algorithm: str = "recursive_doubling",
) -> PyTree:
    """MPI_Allreduce via the butterfly (every rank ends with the total).

    For ops with zero identity this is bitwise-equivalent to lax.psum's ring
    for 'sum'; the point is schedule control (the paper's [7])."""
    op = get_operator(op)
    backend = alg.SpmdBackend(axis_name)
    p = backend.p
    if p == 1:
        return x
    acc_v, acc_f = x, alg._ones_flag(backend)
    for k in range(alg.num_steps(p)):
        d = 1 << k
        perm = [(j, j ^ d) for j in range(p) if (j ^ d) < p]
        rv, rf = backend.permute((acc_v, acc_f), perm)
        acc_v, acc_f = alg._combine_lr(op, acc_v, acc_f, rv, rf)
    return acc_v


def dist_barrier(axis_name: str, *, algorithm: str = "recursive_doubling") -> jax.Array:
    """MPI_Barrier (the authors' NetFPGA barrier, ref [6]): a minimal-payload
    allreduce; returns a scalar 1.0 whose data dependency fences the program."""
    token = jnp.ones((), jnp.float32)
    from repro.core.operators import MAX

    return dist_allreduce(token, MAX, axis_name, algorithm=algorithm)
