"""Core: the paper's contribution — network-offloaded parallel prefix scan.

Public surface:
  dist_scan / dist_exscan / dist_scan_pair  — SPMD collectives (inside shard_map)
  sim_scan                                  — single-device schedule simulator
  host_scan                                 — host-orchestrated "software MPI" baseline
  AssocOp, SUM/MAX/MIN/PROD/SSD             — operator algebra
  select_algorithm / cost_table             — algo_type auto-selection
  CollectiveDescriptor                      — Fig. 1 offload packet analogue
"""

from repro.core.algorithms import (
    ALGORITHMS,
    SimBackend,
    SpmdBackend,
    algorithm_step_count,
)
from repro.core.host_scan import (
    host_scan,
    schedule_trace,
    time_host_scan,
    time_offloaded_scan,
)
from repro.core.operators import (
    MAX,
    MIN,
    PROD,
    SSD,
    SUM,
    AssocOp,
    get_operator,
    make_flash_op,
    register_operator,
    segmented_operator,
)
from repro.core.reduce_ops import (
    allreduce_schedule,
    barrier_schedule,
    dist_allreduce,
    dist_barrier,
    dist_reduce,
    reduce_schedule,
    sim_allreduce,
    sim_barrier,
    sim_reduce,
)
from repro.core.packet import (
    AlgoType,
    CollType,
    CollectiveDescriptor,
    MsgType,
    NodeType,
    WireDType,
    WireOp,
)
from repro.core.scan_collective import (
    dist_exscan,
    dist_scan,
    dist_scan_pair,
    sim_scan,
)
from repro.core.selector import (
    TPU_V5E,
    LinkModel,
    cost_features,
    cost_table,
    estimate_cost,
    get_active_tuning,
    select_algorithm,
    set_active_tuning,
)

__all__ = [
    "ALGORITHMS",
    "AssocOp",
    "AlgoType",
    "CollType",
    "CollectiveDescriptor",
    "LinkModel",
    "MAX",
    "MIN",
    "MsgType",
    "NodeType",
    "PROD",
    "SSD",
    "SUM",
    "SimBackend",
    "SpmdBackend",
    "TPU_V5E",
    "WireDType",
    "WireOp",
    "algorithm_step_count",
    "allreduce_schedule",
    "barrier_schedule",
    "cost_features",
    "cost_table",
    "dist_exscan",
    "dist_scan",
    "dist_scan_pair",
    "estimate_cost",
    "get_active_tuning",
    "get_operator",
    "host_scan",
    "make_flash_op",
    "register_operator",
    "reduce_schedule",
    "schedule_trace",
    "segmented_operator",
    "select_algorithm",
    "set_active_tuning",
    "dist_allreduce",
    "dist_barrier",
    "dist_reduce",
    "sim_allreduce",
    "sim_barrier",
    "sim_reduce",
    "sim_scan",
    "time_host_scan",
    "time_offloaded_scan",
]
