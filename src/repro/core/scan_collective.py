"""Public API of the offloaded scan collective: dist_scan / dist_exscan.

Call these *inside* an SPMD context (``shard_map``) over one mesh axis. The
entire schedule — every hop and every combine — lowers into the compiled XLA
program as collective-permutes, which is the TPU analogue of the paper's
one-descriptor-in, one-result-out NIC offload: the host dispatches a single
program; the network does the rest.

Exclusive scans come in two flavors, mirroring the paper:
  * structural: run the inclusive schedule on shifted inputs (one extra
    single-hop permute) — works for any operator;
  * inverse-op (``algo_type="invertible_doubling"`` or ``use_inverse=True``):
    recover exclusive from inclusive locally via the operator inverse — the
    Fig. 3 subtraction trick, zero extra communication.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import algorithms as alg
from repro.core.operators import AssocOp, get_operator
from repro.core.packet import CollectiveDescriptor
from repro.core.selector import select_algorithm

PyTree = Any


def _axis_size(axis_name: str) -> int:
    from repro.compat import axis_size

    return axis_size(axis_name)


def dist_scan(
    x: PyTree,
    op: "AssocOp | str",
    axis_name: str,
    *,
    algorithm: str = "auto",
    descriptor: Optional[CollectiveDescriptor] = None,
) -> PyTree:
    """Inclusive parallel prefix scan (MPI_Scan) across ``axis_name``.

    Args:
      x: per-rank pytree contribution (leaves may be any shape).
      op: an :class:`AssocOp` or registered name ("sum", "max", "ssd", ...).
      axis_name: mesh axis to scan over (must be an active SPMD axis).
      algorithm: one of ``core.algorithms.ALGORITHMS`` or "auto" to let the
        selector pick from (p, payload bytes) — the paper's runtime-side
        ``algo_type`` choice.
      descriptor: optional offload descriptor; when given, its ``algo_type``
        wins (the software layer pre-assigned roles, as in the paper).
    """
    op = get_operator(op)
    p = _axis_size(axis_name)
    if descriptor is not None:
        algorithm = descriptor.algo_type
    if algorithm == "auto":
        algorithm = select_algorithm(p, _payload_bytes(x), op)
    backend = alg.SpmdBackend(axis_name, p)
    return alg.get_algorithm(algorithm)(backend, x, op)


def dist_exscan(
    x: PyTree,
    op: "AssocOp | str",
    axis_name: str,
    *,
    algorithm: str = "auto",
    use_inverse: Optional[bool] = None,
    descriptor: Optional[CollectiveDescriptor] = None,
) -> PyTree:
    """Exclusive scan (MPI_Exscan): rank j gets x_0 (+) ... (+) x_{j-1}.

    Rank 0 receives the operator identity (MPI leaves it undefined; a defined
    identity is strictly more useful and is what our SSM/MoE layers need).
    """
    op = get_operator(op)
    p = _axis_size(axis_name)
    if descriptor is not None:
        algorithm = descriptor.algo_type
    if algorithm == "auto":
        algorithm = select_algorithm(p, _payload_bytes(x), op, coll="exscan")
    if use_inverse is None:
        use_inverse = algorithm == "invertible_doubling" and op.inverse is not None

    backend = alg.SpmdBackend(axis_name, p)
    identity = op.identity_like(x)
    if p == 1:
        return identity

    if use_inverse:
        if op.inverse is None:
            raise ValueError(f"op {op.name!r} has no inverse")
        inc = alg.get_algorithm(algorithm)(backend, x, op)
        # y_ex = inv(x) (+) y_inc  — valid because y_inc = x?  No: careful.
        # y_inc = y_ex (+) x  =>  for commutative ops y_ex = y_inc (+) inv(x);
        # for non-commutative ops we need a right-inverse form, so restrict.
        if not op.commutative:
            raise ValueError(
                "inverse-based exscan requires a commutative operator; "
                f"{op.name!r} is not"
            )
        ex = op.combine(inc, op.inverse(x))
        rank = backend.rank()
        return alg._bwhere(rank == 0, identity, ex)

    # Structural: shift contributions one rank to the right, then inclusive
    # scan; rank 0 holds the identity. One extra single-hop permute.
    shifted = backend.permute(x, [(i, i + 1) for i in range(p - 1)])
    rank = backend.rank()
    flag = jnp.where(rank == 0, 0.0, 1.0).astype(jnp.float32)
    if op.zero_identity:
        # zeros already are the identity; plain inclusive scan works.
        return alg.get_algorithm(algorithm)(backend, shifted, op)
    # For non-zero identities, rank 0's "contribution" must read as identity.
    shifted = alg._bwhere(flag > 0.5, shifted, identity)
    return alg.get_algorithm(algorithm)(backend, shifted, op)


def dist_scan_pair(
    x: PyTree,
    op: "AssocOp | str",
    axis_name: str,
    *,
    algorithm: str = "auto",
) -> tuple[PyTree, PyTree]:
    """Return (exclusive, inclusive) in one schedule run.

    The SSM sequence-parallel layer needs the exclusive scan (incoming state)
    but validating against the inclusive value is free: inc = ex (+) x.
    """
    op = get_operator(op)
    ex = dist_exscan(x, op, axis_name, algorithm=algorithm)
    return ex, op.combine(ex, x)


def _payload_bytes(x: PyTree) -> int:
    return sum(
        int(jnp.size(leaf)) * jnp.dtype(leaf.dtype).itemsize
        for leaf in jax.tree.leaves(x)
    )


# ---------------------------------------------------------------------------
# Simulator entry point (single device, stacked leading rank axis) — used by
# tests and the software-baseline benchmarks.
# ---------------------------------------------------------------------------


def sim_scan(
    stacked: PyTree,
    op: "AssocOp | str",
    p: int,
    *,
    algorithm: str,
    inclusive: bool = True,
    backend: "alg.Backend | None" = None,
) -> PyTree:
    """Run a schedule on stacked (p, ...) arrays without any mesh.

    ``backend`` overrides the default :class:`~repro.core.algorithms.
    SimBackend` — used by the traced plan interpreter to inject a
    round-counting wrapper; it must behave like a SimBackend of size ``p``.
    """
    op = get_operator(op)
    if backend is None:
        backend = alg.SimBackend(p)
    if inclusive:
        return alg.get_algorithm(algorithm)(backend, stacked, op)
    identity = op.identity_like(stacked)
    if p == 1:
        return identity
    rank = backend.rank()
    if (
        algorithm == "invertible_doubling"
        and op.inverse is not None
        and op.commutative
    ):
        # The Fig. 3 subtraction trick, mirrored from dist_exscan: recover the
        # exclusive value locally, skipping the structural shift permute.
        inc = alg.get_algorithm(algorithm)(backend, stacked, op)
        ex = op.combine(inc, op.inverse(stacked))
        return alg._bwhere(rank != 0, ex, identity)
    shifted = backend.permute(stacked, [(i, i + 1) for i in range(p - 1)])
    if not op.zero_identity:
        shifted = alg._bwhere(rank != 0, shifted, identity)
    out = alg.get_algorithm(algorithm)(backend, shifted, op)
    return alg._bwhere(rank != 0, out, identity)
