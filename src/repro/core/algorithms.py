"""Scan-collective schedules (the NetFPGA state machines, as ppermute programs).

Each algorithm from the paper is a *schedule*: a fixed sequence of
(permutation, combine) steps. On the NetFPGA these were hardware state machines
selected by the offload packet's ``algo_type`` field; here they are pure
functions over an abstract :class:`Backend`, so the identical schedule runs

  * inside ``shard_map`` via ``lax.ppermute`` (the *offloaded* path — the whole
    schedule compiles into the device program, no host involvement per step), or
  * on a stacked-array simulator (:class:`SimBackend`) used by the hypothesis
    property tests and by the host-orchestrated "software MPI" baseline.

All schedules carry ``(value, valid)`` pairs: ``ppermute`` delivers zeros on
ranks with no in-edge, so an arriving ``valid == 0`` marks "no message", which
makes every schedule correct for arbitrary operators and non-power-of-two rank
counts. For operators whose identity is the zero tree (``op.zero_identity``,
e.g. sum) the masking is skipped entirely — the compiled schedule is a bare
ppermute/add chain.

Fidelity notes (paper section III):
  * ``sequential``     — Open MPI's default; p-1 single-hop steps. The paper's
    NIC ACK protocol guards a single hardware buffer against back-to-back
    scans; in a compiled SPMD program ordering is structural, and each step
    keeps exactly one live carry (the same O(1) buffer bound).
  * ``recursive_doubling`` — MPICH's pairwise-exchange butterfly with the
    partner<j conditional accumulate (paper II-B2).
  * ``hillis_steele``  — the send-only distance-doubling variant.
  * ``binomial_tree``  — the two-phase up/down sweep (paper II-B3, III-D);
    out-of-range sends are dropped exactly as in the paper's schedule.
  * ``sklansky``       — log2(p) steps where one boundary rank *multicasts* to
    an entire half-block: a source may appear in multiple (src, dst) pairs of a
    single collective-permute, which is the ICI analogue of the paper's
    Ethernet multicast (Fig. 3).
  * ``invertible_doubling`` — hillis-steele whose *exclusive* form recovers
    the answer locally via the operator inverse (the paper's subtraction
    trick) instead of an extra shift step.
"""

from __future__ import annotations

import math
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.operators import AssocOp

PyTree = Any
Perm = List[Tuple[int, int]]

#: schedules whose chunked (pipelined) form is implemented round-by-round;
#: other algorithms chunk at whole-schedule granularity (chunk-major).
DOUBLING_ALGORITHMS = frozenset({"hillis_steele", "invertible_doubling"})

#: per-leaf byte ceiling for the contiguous-shift permute fast path. The
#: padded-copy realization moves the *whole* block (p rows) where the
#: dynamic-update-slice chain moves only the p-d shifted rows in place, so
#: pad wins while the per-op dispatch constant dominates (small blocks) and
#: loses once the copy is bandwidth-bound (big blocks) — measured crossover
#: on the sim backend sits near 64 KiB.
SHIFT_FAST_PATH_MAX_BYTES = 65536


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------


class Backend:
    """Minimal comm interface a schedule needs: rank id + permute."""

    p: int

    def rank(self):  # pragma: no cover - interface
        raise NotImplementedError

    def permute(self, tree: PyTree, perm: Perm) -> PyTree:  # pragma: no cover
        raise NotImplementedError


def split_multicast(perm: Perm) -> List[Perm]:
    """Split a one-to-many permutation into unique-source sub-permutations.

    ``jax.lax.ppermute`` requires unique sources AND destinations, so the
    paper's NIC-style hardware multicast (one payload, many receivers) cannot
    be expressed as a single collective-permute through JAX. We decompose:
    the i-th destination of each source lands in sub-permutation i. The
    sub-permutes are data-independent (XLA may run them concurrently) and each
    destination appears exactly once overall, so with ppermute's zero-fill
    semantics the receiver-side merge is a plain sum. Per-link traffic matches
    true multicast everywhere except the source's egress, which sends
    fanout copies — recorded as a hardware-adaptation delta in DESIGN.md.
    """
    buckets: List[Perm] = []
    seen: dict[int, int] = {}
    for src, dst in perm:
        i = seen.get(src, 0)
        seen[src] = i + 1
        while len(buckets) <= i:
            buckets.append([])
        buckets[i].append((src, dst))
    return buckets


class SpmdBackend(Backend):
    """Runs inside shard_map; permute lowers to XLA collective-permute."""

    def __init__(self, axis_name: str, axis_size: int | None = None):
        self.axis_name = axis_name
        if axis_size is None:
            from repro.compat import axis_size as _axis_size

            axis_size = _axis_size(axis_name)
        self.p = int(axis_size)

    def rank(self):
        return lax.axis_index(self.axis_name)

    def permute(self, tree: PyTree, perm: Perm) -> PyTree:
        if not perm:
            return jax.tree.map(jnp.zeros_like, tree)
        subperms = split_multicast(list(perm))
        if len(subperms) == 1:
            return jax.tree.map(
                lambda a: lax.ppermute(a, self.axis_name, subperms[0]), tree
            )
        parts = [
            jax.tree.map(lambda a, sp=sp: lax.ppermute(a, self.axis_name, sp), tree)
            for sp in subperms
        ]
        out = parts[0]
        for part in parts[1:]:
            out = jax.tree.map(jnp.add, out, part)
        return out


def as_contiguous_shift(perm: Perm, p: int) -> Optional[int]:
    """Recognize ``perm`` as a dense shift of the rank range.

    Returns ``d`` when ``perm`` is exactly ``[(i, i + d) for i in
    range(p - d)]`` (``d > 0``, shift toward higher ranks) or ``[(i, i + d)
    for i in range(-d, p)]`` (``d < 0``, shift toward lower ranks) in any
    pair order, else ``None``. Every doubling-schedule round and every
    structural EXSCAN shift is of this form.
    """
    if not perm:
        return None
    deltas = {dst - src for src, dst in perm}
    if len(deltas) != 1:
        return None
    d = deltas.pop()
    if d == 0:
        return None
    srcs = sorted(src for src, _ in perm)
    want = list(range(p - d)) if d > 0 else list(range(-d, p))
    if srcs != want or len(perm) != len(srcs):
        return None
    return d


class SimBackend(Backend):
    """Single-device simulator: every pytree leaf carries a leading rank axis.

    Semantically identical to SpmdBackend (missing in-edges deliver zeros);
    used by property tests and by the host-orchestrated baseline, where each
    ``permute`` models one host-driven message hop.

    Contiguous shifts (every doubling round, every structural EXSCAN shift)
    take a streaming fast path: one padded block copy instead of a chain of
    per-pair dynamic-update-slices — the software analogue of the NIC
    DMA-ing one contiguous segment. Values are identical either way (same
    permutation, same zero fill); the fast path is gated to small blocks
    (:data:`SHIFT_FAST_PATH_MAX_BYTES`) where the per-op constant, not the
    copy bandwidth, dominates.
    """

    def __init__(self, p: int):
        self.p = int(p)

    def rank(self):
        return jnp.arange(self.p, dtype=jnp.int32)

    def permute(self, tree: PyTree, perm: Perm) -> PyTree:
        d = as_contiguous_shift(list(perm), self.p)

        def shuffle(a):
            if (
                d is not None
                and a.size * a.dtype.itemsize <= SHIFT_FAST_PATH_MAX_BYTES
            ):
                tail = [(0, 0)] * (a.ndim - 1)
                if d > 0:
                    return jnp.pad(a[: self.p - d], [(d, 0)] + tail)
                return jnp.pad(a[-d:], [(0, -d)] + tail)
            out = jnp.zeros_like(a)
            for src, dst in perm:
                out = out.at[dst].set(a[src])
            return out

        return jax.tree.map(shuffle, tree)


# ---------------------------------------------------------------------------
# Masked combine plumbing
# ---------------------------------------------------------------------------


def _bwhere(cond, a, b):
    """tree-where with a rank-shaped (scalar or (p,)) condition broadcast."""

    def leaf(x, y):
        c = cond
        extra = x.ndim - c.ndim
        if extra > 0:
            c = c.reshape(c.shape + (1,) * extra)
        return jnp.where(c, x, y)

    return jax.tree.map(leaf, a, b)


def _combine_lr(op: AssocOp, lv, lval, rv, rval):
    """Masked combine with *l* the earlier-prefix operand.

    valid flags are float32 (0/1) so they travel through ppermute and arriving
    zero-fill naturally reads as "no message".
    """
    both = (lval > 0.5) & (rval > 0.5)
    merged = op.combine(lv, rv)
    keep_l = _bwhere(lval > 0.5, lv, rv)
    return _bwhere(both, merged, keep_l), jnp.maximum(lval, rval)


def _ones_flag(backend: Backend):
    r = backend.rank()
    return jnp.ones(jnp.shape(r), dtype=jnp.float32)


def num_steps(p: int) -> int:
    return max(0, math.ceil(math.log2(p))) if p > 1 else 0


def doubling_strides(p: int) -> Tuple[int, ...]:
    """Exchange distances (1, 2, 4, ...) of one distance-doubling schedule."""
    return tuple(1 << k for k in range(num_steps(p)))


def phase_round_count(kind: str, p: int, *, inclusive: bool = True) -> int:
    """Communication rounds a single-kernel (fused) lowering of one plan
    phase performs. Shared by the Pallas backend's kernels and the tracing
    layer's kernel-sourced round spans, so the declared round structure and
    the emitted spans can never drift apart.

    ``kind`` is a :class:`repro.offload.planner.PhaseKind` name. SCAN counts
    the structural entry shift of the exclusive form; FUSED_SCAN_TOTAL
    counts its entry (exclusive) or exit (inclusive) single-hop shift, i.e.
    :func:`scan_total_step_count`; TOTAL/BARRIER are the pow2 butterfly.
    """
    if p <= 1:
        return 0
    if kind == "SCAN":
        return num_steps(p) + (0 if inclusive else 1)
    if kind == "FUSED_SCAN_TOTAL":
        return num_steps(p) + 1
    if kind in ("TOTAL", "BARRIER"):
        return num_steps(p)
    return 0


# ---------------------------------------------------------------------------
# Schedules. Each returns the INCLUSIVE scan; exclusive handling lives in
# scan_collective (structural shift or inverse-op recovery).
# ---------------------------------------------------------------------------


def sequential(backend: Backend, x: PyTree, op: AssocOp) -> PyTree:
    """Open MPI's linear algorithm: p-1 steps, one single-hop message each.

    At step s, rank s-1's accumulator is a complete prefix and is handed to
    rank s. SPMD realization: every step performs one (s-1 -> s) permute and
    only the destination rank folds it in.
    """
    p = backend.p
    if p == 1:
        return x
    rank = backend.rank()
    acc = x
    for s in range(1, p):
        recv = backend.permute(acc, [(s - 1, s)])
        is_dst = rank == s
        merged = op.combine(recv, acc)
        acc = _bwhere(is_dst, merged, acc)
    return acc


def sequential_pipelined(backend: Backend, x: PyTree, op: AssocOp) -> PyTree:
    """Ring variant: every rank forwards every step (p-1 steps, stride-1).

    Raw contributions are *relayed* around the ring: at step s rank j receives
    x_{j-s}, which precedes its current window [j-s+1, j], so each step folds
    in exactly one new term (no window overlap). Same wire pattern as
    ``sequential`` but every link is busy every step — the bandwidth-friendly,
    torus-native form with a single static permute.
    """
    p = backend.p
    if p == 1:
        return x
    perm = [(i, i + 1) for i in range(p - 1)]
    if op.zero_identity:
        acc = x
        relay = x
        for _ in range(p - 1):
            relay = backend.permute(relay, perm)
            acc = op.combine(relay, acc)
        return acc
    acc_v, acc_f = x, _ones_flag(backend)
    rel_v, rel_f = x, acc_f
    for _ in range(p - 1):
        rel_v, rel_f = backend.permute((rel_v, rel_f), perm)
        acc_v, acc_f = _combine_lr(op, rel_v, rel_f, acc_v, acc_f)
    return acc_v


def hillis_steele(backend: Backend, x: PyTree, op: AssocOp) -> PyTree:
    """Distance-doubling send-only scan: ceil(log2 p) steps of stride 2^k."""
    p = backend.p
    if p == 1:
        return x
    if op.zero_identity:
        acc = x
        for k in range(num_steps(p)):
            d = 1 << k
            perm = [(i, i + d) for i in range(p - d)]
            recv = backend.permute(acc, perm)
            acc = op.combine(recv, acc)
        return acc
    acc_v, acc_f = x, _ones_flag(backend)
    for k in range(num_steps(p)):
        d = 1 << k
        perm = [(i, i + d) for i in range(p - d)]
        rv, rf = backend.permute((acc_v, acc_f), perm)
        acc_v, acc_f = _combine_lr(op, rv, rf, acc_v, acc_f)
    return acc_v


def recursive_doubling(backend: Backend, x: PyTree, op: AssocOp) -> PyTree:
    """MPICH's pairwise-exchange butterfly (paper II-B2).

    Maintains ``result`` (the answer) and ``partial`` (the running block
    total). Step k exchanges ``partial`` with partner j^2^k; ranks whose
    partner is lower fold the received block into both.
    """
    p = backend.p
    if p == 1:
        return x
    rank = backend.rank()
    one = _ones_flag(backend)
    res_v, res_f = x, one
    par_v, par_f = x, one
    for k in range(num_steps(p)):
        d = 1 << k
        perm = [(j, j ^ d) for j in range(p) if (j ^ d) < p]
        rv, rf = backend.permute((par_v, par_f), perm)
        partner_lower = (rank & d) != 0  # partner = rank ^ d < rank
        got = rf > 0.5
        # partner < j: received block precedes ours -> fold into result+partial
        fold = partner_lower & got
        nres_v, nres_f = _combine_lr(op, rv, rf, res_v, res_f)
        res_v = _bwhere(fold, nres_v, res_v)
        res_f = jnp.where(fold, nres_f, res_f)
        # partial always absorbs the partner block, ordered by rank
        lo_v, lo_f = _combine_lr(op, rv, rf, par_v, par_f)   # partner lower
        hi_v, hi_f = _combine_lr(op, par_v, par_f, rv, rf)   # partner higher
        par_v = _bwhere(partner_lower & got, lo_v, _bwhere(got, hi_v, par_v))
        par_f = jnp.where(got, jnp.maximum(par_f, rf), par_f)
        del nres_f, lo_f, hi_f
    return res_v


def binomial_tree(backend: Backend, x: PyTree, op: AssocOp) -> PyTree:
    """The paper's two-phase binomial/Brent-Kung schedule (II-B3, III-D).

    Up-phase: rank j with j & (2^(k+1)-1) == 2^(k+1)-1 receives from j-2^k and
    accumulates (the NIC caches children partials). Down-phase: complete ranks
    j & (2^k - 1) == 2^k - 1 send their inclusive prefix to j + 2^(k-1);
    out-of-range sends drop, exactly as in the paper's description.
    """
    p = backend.p
    if p == 1:
        return x
    K = num_steps(p)
    acc_v, acc_f = x, _ones_flag(backend)
    # Up-sweep.
    for k in range(K):
        mask = (1 << (k + 1)) - 1
        d = 1 << k
        perm = [
            (j - d, j)
            for j in range(p)
            if (j & mask) == mask and j - d >= 0
        ]
        if not perm:
            continue
        rv, rf = backend.permute((acc_v, acc_f), perm)
        got = rf > 0.5
        nv, nf = _combine_lr(op, rv, rf, acc_v, acc_f)
        acc_v = _bwhere(got, nv, acc_v)
        acc_f = jnp.where(got, nf, acc_f)
    # Down-sweep.
    for k in range(K, 0, -1):
        mask = (1 << k) - 1
        d = 1 << (k - 1)
        perm = [
            (j, j + d)
            for j in range(p)
            if (j & mask) == mask and j + d < p
        ]
        if not perm:
            continue
        rv, rf = backend.permute((acc_v, acc_f), perm)
        got = rf > 0.5
        nv, nf = _combine_lr(op, rv, rf, acc_v, acc_f)
        acc_v = _bwhere(got, nv, acc_v)
        acc_f = jnp.where(got, nf, acc_f)
    return acc_v


def sklansky(backend: Backend, x: PyTree, op: AssocOp) -> PyTree:
    """Sklansky's divide-and-conquer scan with one-to-many permutes.

    Step k: in each block of 2^(k+1), the last rank of the left half
    multicasts its inclusive prefix to every rank of the right half — a single
    collective-permute whose source appears in many (src, dst) pairs. This is
    the TPU/ICI realization of the paper's NIC multicast (Fig. 3): one message
    payload serves a whole receiver group.
    """
    p = backend.p
    if p == 1:
        return x
    acc_v, acc_f = x, _ones_flag(backend)
    for k in range(num_steps(p)):
        half = 1 << k
        block = half << 1
        perm: Perm = []
        for start in range(0, p, block):
            src = start + half - 1
            if src >= p:
                continue
            for dst in range(start + half, min(start + block, p)):
                perm.append((src, dst))
        if not perm:
            continue
        rv, rf = backend.permute((acc_v, acc_f), perm)
        got = rf > 0.5
        nv, nf = _combine_lr(op, rv, rf, acc_v, acc_f)
        acc_v = _bwhere(got, nv, acc_v)
        acc_f = jnp.where(got, nf, acc_f)
    return acc_v


def invertible_doubling(backend: Backend, x: PyTree, op: AssocOp) -> PyTree:
    """Inclusive form is hillis-steele; the payoff is in the exclusive form.

    ``scan_collective`` recognizes this algo_type and, given ``op.inverse``,
    derives MPI_Exscan locally as ``inv(x) (+) inclusive`` — zero extra
    communication, the compiled analogue of the paper's "receiver already
    caches its own contribution and subtracts it" (Fig. 3).
    """
    if op.inverse is None:
        raise ValueError(
            "invertible_doubling requires an operator with an inverse "
            f"(op={op.name!r} has none)"
        )
    return hillis_steele(backend, x, op)


def scan_total_schedule(
    backend: Backend, x: PyTree, op: AssocOp, *, inclusive: bool = True
) -> Tuple[PyTree, PyTree]:
    """Fused scan + total: ``(prefix scan of x, full reduction of x)`` from
    ONE schedule of ``ceil(log2 p) + 1`` rounds.

    This is the planner's ``FUSED_SCAN_TOTAL`` phase — the software analogue
    of the NetFPGA folding the scan's combine/forward/total steps into one
    pass over the wire instead of running a scan round followed by a separate
    allreduce round. Each doubling step carries two permutes in *opposite*
    directions (full-duplex links carry both at once, the same accounting as
    ``recursive_doubling``):

      * ``prefix``  extends left  — rank r accumulates x[l..r], l doubling
        toward 0 (plain hillis-steele invariant);
      * ``suffix``  extends right — rank r accumulates x[r..u], u doubling
        toward p-1 (the mirror image).

    After ceil(log2 p) steps every rank holds the complete prefix AND the
    complete suffix, so the total is one local combine away:
    ``total_r = prefix[0..r] (+) suffix[r+1..p-1]`` (inclusive form; one
    extra single-hop shift fetches suffix[r+1]) or
    ``total_r = prefix[0..r-1] (+) suffix[r..p-1]`` (exclusive form; the
    structural shift already happened on the way in, so no extra hop).
    Unfused, the same pair of outputs costs ``2*ceil(log2 p)`` rounds
    (scan + allreduce); fused it costs ``ceil(log2 p) + 1``.

    Correct for any associative operator (non-commutative included: windows
    only ever merge with *adjacent* windows, in rank order) and any p.
    """
    p = backend.p
    if p == 1:
        y = x if inclusive else op.identity_like(x)
        return y, x
    if op.zero_identity:
        # zero-fill *is* the identity: both streams run flag-free, exactly
        # like hillis_steele's fast path. Halves the wire payload (no flag
        # leaves) and drops every mask select from the compiled schedule.
        if inclusive:
            pre = x
        else:
            pre = backend.permute(x, [(i, i + 1) for i in range(p - 1)])
        suf = x
        for k in range(num_steps(p)):
            d = 1 << k
            rv = backend.permute(pre, [(i, i + d) for i in range(p - d)])
            pre = op.combine(rv, pre)
            sv = backend.permute(suf, [(i + d, i) for i in range(p - d)])
            suf = op.combine(suf, sv)
        if inclusive:
            sv = backend.permute(suf, [(i + 1, i) for i in range(p - 1)])
            return pre, op.combine(pre, sv)
        total = op.combine(pre, suf)
        rank = backend.rank()
        return _bwhere(rank != 0, pre, op.identity_like(x)), total
    one = _ones_flag(backend)
    if inclusive:
        pre_v, pre_f = x, one
    else:
        # structural shift: rank r starts from x_{r-1}; rank 0 starts empty
        pre_v, pre_f = backend.permute(
            (x, one), [(i, i + 1) for i in range(p - 1)]
        )
    suf_v, suf_f = x, one
    for k in range(num_steps(p)):
        d = 1 << k
        rv, rf = backend.permute(
            (pre_v, pre_f), [(i, i + d) for i in range(p - d)]
        )
        pre_v, pre_f = _combine_lr(op, rv, rf, pre_v, pre_f)
        sv, sf = backend.permute(
            (suf_v, suf_f), [(i + d, i) for i in range(p - d)]
        )
        suf_v, suf_f = _combine_lr(op, suf_v, suf_f, sv, sf)
    if inclusive:
        # total = prefix[0..r] (+) suffix[r+1..]; last rank keeps its prefix
        sv, sf = backend.permute(
            (suf_v, suf_f), [(i + 1, i) for i in range(p - 1)]
        )
        total, _ = _combine_lr(op, pre_v, pre_f, sv, sf)
        return pre_v, total
    # exclusive: prefix covers [0..r-1], same-rank suffix covers [r..p-1]
    total, _ = _combine_lr(op, pre_v, pre_f, suf_v, suf_f)
    rank = backend.rank()
    y = _bwhere(rank != 0, pre_v, op.identity_like(x))
    return y, total


def scan_total_step_count(p: int) -> int:
    """Rounds of the fused schedule (the planner's cost-model alpha term)."""
    return num_steps(p) + 1 if p > 1 else 0


# ---------------------------------------------------------------------------
# Chunked payload streaming: split the payload into C contiguous chunks and
# software-pipeline them across exchange steps. Chunk c runs round r at
# pipeline step t = c + r, so chunk k's round-r exchange is issued alongside
# chunk k-1's round-(r+1) combine — on a real backend (SPMD ppermutes) the
# independent per-chunk exchanges overlap; on the simulator the interleaved
# issue order is the rehearsal of the same pipeline. Each chunk runs the
# *identical* per-round schedule on its slice, and every registered operator
# combines elementwise across payload dims, so the concatenated chunked
# result is bitwise-equal to the unchunked schedule for any operator, any
# CollType, and any chunk count.
# ---------------------------------------------------------------------------


def chunk_bounds(n: int, chunks: int) -> List[int]:
    """Contiguous chunk boundaries: ``chunks + 1`` offsets into ``range(n)``."""
    return [n * c // chunks for c in range(chunks + 1)]


def chunkable(tree: PyTree, chunks: int, *, min_ndim: int = 1) -> bool:
    """True when every leaf can be split into ``chunks`` nonempty contiguous
    blocks along its last axis and all leaves agree on that axis size
    (keeps cross-leaf broadcasting in pytree operators aligned).

    ``min_ndim`` guards against chunking the wrong axis: the sim backend
    stacks a leading rank axis onto every leaf, so a scalar-per-rank payload
    is a 1-D leaf whose *last* axis is the rank axis — callers there pass
    ``min_ndim=2`` so such payloads fall back to the unchunked schedule.
    """
    if chunks <= 1:
        return False
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return False
    if any(leaf.ndim < min_ndim for leaf in leaves):
        return False
    lens = {leaf.shape[-1] for leaf in leaves}
    return len(lens) == 1 and lens.pop() >= chunks


def split_chunks(tree: PyTree, chunks: int) -> List[PyTree]:
    """Split every leaf along its last axis into ``chunks`` contiguous slices."""
    n = jax.tree.leaves(tree)[0].shape[-1]
    bounds = chunk_bounds(n, chunks)
    return [
        jax.tree.map(lambda a, c=c: a[..., bounds[c]:bounds[c + 1]], tree)
        for c in range(chunks)
    ]


def concat_chunks(parts: Sequence[PyTree]) -> PyTree:
    """Inverse of :func:`split_chunks`: concatenate along the last axis."""
    if len(parts) == 1:
        return parts[0]
    return jax.tree.map(
        lambda *leaves: jnp.concatenate(leaves, axis=-1), *parts
    )


def _set_chunk_context(backend: Backend, chunk: int, rnd: int) -> None:
    """Tag the backend's next permutes with (chunk, per-chunk round) — the
    tracing backend picks this up for per-(round, chunk) span attribution."""
    setter = getattr(backend, "set_chunk_context", None)
    if setter is not None:
        setter(chunk, rnd)


def _pipeline(
    backend: Backend,
    states: List[Any],
    round_fns: Sequence[Callable[[Any, int], Any]],
) -> List[Any]:
    """Run every chunk through ``round_fns`` in software-pipeline order.

    ``states[c]`` is chunk c's schedule state; ``round_fns[r](state, c)``
    advances one chunk by one round (issuing that round's exchanges). Step t
    serves chunk c at round ``t - c``: the first round of chunk c overlaps
    the later rounds of chunks ``< c``, including the entry/exit structural
    shifts that ride the round list like any other exchange.
    """
    chunks = len(states)
    rounds = len(round_fns)
    for t in range(rounds + chunks - 1):
        for c in range(max(0, t - rounds + 1), min(chunks, t + 1)):
            r = t - c
            _set_chunk_context(backend, c, r)
            states[c] = round_fns[r](states[c], c)
    _set_chunk_context(backend, -1, -1)
    return states


def chunked_scan_schedule(
    backend: Backend,
    x: PyTree,
    op: AssocOp,
    *,
    chunks: int,
    shift_first: bool = False,
    identity: Optional[PyTree] = None,
) -> PyTree:
    """Chunked, pipelined doubling scan (hillis_steele round structure).

    Runs the inclusive distance-doubling schedule per chunk; with
    ``shift_first`` the structural EXSCAN shift is the first pipelined round
    (chunk c's shift is issued alongside chunk c-1's first exchange, so the
    shift never costs a standalone step). ``identity`` (non-zero-identity
    operators only) replaces rank 0's shifted-in zeros before the doubling
    rounds, mirroring ``sim_scan``/``dist_exscan``. Callers apply any final
    rank-0 masking to the concatenated result — it is elementwise, so
    per-chunk and whole-payload application are bitwise-identical.
    """
    p = backend.p
    if p == 1 or chunks <= 1 or not chunkable(x, chunks):
        raise ValueError(
            "chunked_scan_schedule needs p > 1 and a chunkable payload; "
            "callers fall back to the unchunked schedule"
        )
    lg = num_steps(p)
    rank = backend.rank()
    masked = not op.zero_identity

    def shift_round(state, c):
        # mirrors sim_scan/dist_exscan: the structural shift moves the bare
        # value tree; masked ops then fill rank 0 with the identity and the
        # doubling rounds restart with all-ones flags (ident_parts is bound
        # by the time the pipeline calls this).
        perm = [(i, i + 1) for i in range(p - 1)]
        if not masked:
            return backend.permute(state, perm)
        val, flag = state
        val = backend.permute(val, perm)
        val = _bwhere(rank != 0, val, ident_parts[c])
        return val, flag

    def doubling(k: int):
        d = 1 << k
        perm = [(i, i + d) for i in range(p - d)]

        def rnd(state, c):
            if masked:
                rv, rf = backend.permute(state, perm)
                return _combine_lr(op, rv, rf, state[0], state[1])
            recv = backend.permute(state, perm)
            return op.combine(recv, state)

        return rnd

    if masked and shift_first and identity is None:
        raise ValueError(
            "non-zero-identity shift_first needs the identity tree to fill "
            "rank 0 (sim_scan always provides it)"
        )
    parts = split_chunks(x, chunks)
    ident_parts = (
        split_chunks(identity, chunks) if identity is not None else None
    )
    if masked:
        one = _ones_flag(backend)
        states: List[Any] = [(part, one) for part in parts]
    else:
        states = list(parts)
    round_fns: List[Callable[[Any, int], Any]] = []
    if shift_first:
        round_fns.append(shift_round)
    round_fns.extend(doubling(k) for k in range(lg))
    states = _pipeline(backend, states, round_fns)
    if masked:
        states = [v for v, _ in states]
    return concat_chunks(states)


def chunked_scan_total_schedule(
    backend: Backend,
    x: PyTree,
    op: AssocOp,
    *,
    chunks: int,
    inclusive: bool = True,
) -> Tuple[PyTree, PyTree]:
    """Chunked, pipelined form of :func:`scan_total_schedule`.

    Per chunk the round list is exactly the fused schedule's: the exclusive
    form's entry shift and the inclusive form's exit suffix-fetch are
    pipelined rounds, so they overlap neighboring chunks' doubling
    exchanges instead of serializing. Returns ``(scan, total)`` bitwise
    equal to the unchunked fused schedule.
    """
    p = backend.p
    if p == 1 or chunks <= 1 or not chunkable(x, chunks):
        raise ValueError(
            "chunked_scan_total_schedule needs p > 1 and a chunkable "
            "payload; callers fall back to the unchunked schedule"
        )
    lg = num_steps(p)
    rank = backend.rank()
    lean = op.zero_identity
    one = None if lean else _ones_flag(backend)

    # state per chunk: (prefix stream, suffix stream); each stream is a bare
    # tree (lean) or a (value, flag) pair (masked).
    def entry_shift(state, c):
        pre, suf = state
        perm = [(i, i + 1) for i in range(p - 1)]
        return backend.permute(pre, perm), suf

    def doubling(k: int):
        d = 1 << k
        up = [(i, i + d) for i in range(p - d)]
        down = [(i + d, i) for i in range(p - d)]

        def rnd(state, c):
            pre, suf = state
            if lean:
                pre = op.combine(backend.permute(pre, up), pre)
                suf = op.combine(suf, backend.permute(suf, down))
            else:
                rv, rf = backend.permute(pre, up)
                pre = _combine_lr(op, rv, rf, pre[0], pre[1])
                sv, sf = backend.permute(suf, down)
                suf = _combine_lr(op, suf[0], suf[1], sv, sf)
            return pre, suf

        return rnd

    def exit_fetch(state, c):
        # inclusive only: total_r = prefix[0..r] (+) suffix[r+1..]
        pre, suf = state
        perm = [(i + 1, i) for i in range(p - 1)]
        if lean:
            total = op.combine(pre, backend.permute(suf, perm))
        else:
            sv, sf = backend.permute(suf, perm)
            total, _ = _combine_lr(op, pre[0], pre[1], sv, sf)
        return pre, total

    parts = split_chunks(x, chunks)
    if lean:
        states: List[Any] = [(part, part) for part in parts]
    else:
        states = [((part, one), (part, one)) for part in parts]
    round_fns: List[Callable[[Any, int], Any]] = []
    if not inclusive:
        round_fns.append(entry_shift)
    round_fns.extend(doubling(k) for k in range(lg))
    if inclusive:
        round_fns.append(exit_fetch)
    states = _pipeline(backend, states, round_fns)

    if inclusive:
        if lean:
            scans = [pre for pre, _ in states]
        else:
            scans = [pre_vf[0] for pre_vf, _ in states]
        totals = [total for _, total in states]
        return concat_chunks(scans), concat_chunks(totals)
    # exclusive: prefix covers [0..r-1]; same-rank suffix covers [r..p-1]
    scans, totals = [], []
    for pre, suf in states:
        if lean:
            totals.append(op.combine(pre, suf))
            scans.append(pre)
        else:
            total, _ = _combine_lr(op, pre[0], pre[1], suf[0], suf[1])
            totals.append(total)
            scans.append(pre[0])
    scan = concat_chunks(scans)
    y = _bwhere(rank != 0, scan, op.identity_like(x))
    return y, concat_chunks(totals)


def run_chunked(
    fn: Callable[[PyTree], PyTree],
    tree: PyTree,
    chunks: int,
    *,
    min_ndim: int = 1,
) -> PyTree:
    """Chunk-major fallback: run a whole schedule per chunk and concatenate.

    Each chunk runs the identical schedule on its slice, so bitwise equality
    with the unchunked form holds for the same reason as the pipelined path
    — but the per-round constant is paid ``chunks`` times over, so the plan
    lowerings do *not* use this for phases without a pipelined variant (they
    run those phases whole); it exists for tests and host-side callers that
    want chunk-granular scheduling regardless.
    """
    if chunks <= 1 or not chunkable(tree, chunks, min_ndim=min_ndim):
        return fn(tree)
    return concat_chunks([fn(part) for part in split_chunks(tree, chunks)])


ALGORITHMS = {
    "sequential": sequential,
    "sequential_pipelined": sequential_pipelined,
    "hillis_steele": hillis_steele,
    "recursive_doubling": recursive_doubling,
    "binomial_tree": binomial_tree,
    "sklansky": sklansky,
    "invertible_doubling": invertible_doubling,
}


def get_algorithm(name: str):
    try:
        return ALGORITHMS[name]
    except KeyError:
        raise ValueError(
            f"unknown algo_type {name!r}; known: {sorted(ALGORITHMS)}"
        ) from None


def algorithm_step_count(name: str, p: int) -> int:
    """Latency in schedule steps — used by the selector's alpha term."""
    if p <= 1:
        return 0
    lg = num_steps(p)
    return {
        "sequential": p - 1,
        "sequential_pipelined": p - 1,
        "hillis_steele": lg,
        "recursive_doubling": lg,
        "binomial_tree": 2 * lg,
        "sklansky": lg,
        "invertible_doubling": lg,
    }[name]
