"""The offload descriptor — software analogue of the paper's Fig. 1 packet.

The NetFPGA consumed a UDP packet whose payload carried the collective
descriptor (comm_id, comm_size, coll_type, algo_type, node_type, msg_type,
rank, root, operation, data_type, count). The Ethernet/IP/UDP framing has no
TPU analogue (XLA owns transport); we keep the descriptor itself: it is how
the framework names, logs, and selects compiled collective schedules, and the
encode/decode round-trip keeps the format "self-describing" as the paper
intends. ``node_type`` is derived from (rank, comm_size) inside the SPMD
program — the hardware-side derivation the paper lists as future work is
trivial in software, so we do it.

Beyond the paper's single 8-host ring, the descriptor carries a topology
encoding: ``axes`` (per-mesh-axis sizes, outermost first, up to
:data:`MAX_AXES`) and ``split`` (the planner's chosen logical axis order, a
permutation of the axis indices). A multi-axis descriptor names a *planned*
hierarchical collective — the phase structure is derived from (coll_type,
axes, split) by ``repro.offload.planner`` — while keeping the wire contract:
the whole request, topology included, round-trips through ``encode``/
``decode`` and cache-keys the compiled schedule. The 16th word is the
schedule-flags word: bit 0 is the ``optimized`` flag (1 iff the
plan-optimizer pass pipeline in ``repro.offload.passes`` runs for this
request) and the remaining bits carry the lowering-backend id
(:data:`_WIRE_BACKENDS`; 0 = the mode default, so every pre-backend
encoding keeps its exact bytes), so brokered, cached, and remote
dispatches agree on the compiled schedule's shape. When chunked streaming
is requested (``chunks > 1``) a 17th word carries the payload chunk count;
unchunked descriptors keep the 16-word encoding unchanged. Legacy 10-word
descriptors (no topology) decode as single-axis requests; 15-word
descriptors (topology, pre-optimizer) decode with the flags off.
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
import math
import zlib

import numpy as np


class IntegrityError(RuntimeError):
    """A checksummed payload or descriptor failed verification.

    Raised by :func:`decode_checked` (descriptor wire words), by the
    broker's submit-time payload checksum (``repro.offload.reliability.
    verify_payload``), and by the chaos injector's modeled receiver CRC.
    ``request`` optionally names the poisoned broker request
    (``"tenant#seqno"``) when the failure is attributable to one — the
    broker's bisection path uses it to quarantine without retrying a
    payload that is corrupt *at rest*.
    """

    def __init__(self, message: str, *, request: "str | None" = None):
        super().__init__(message)
        self.request = request


class CollType(enum.IntEnum):
    SCAN = 0       # MPI_Scan
    EXSCAN = 1     # MPI_Exscan
    REDUCE = 2
    ALLREDUCE = 3
    BARRIER = 4


class AlgoType(enum.IntEnum):
    SEQUENTIAL = 0
    SEQUENTIAL_PIPELINED = 1
    HILLIS_STEELE = 2
    RECURSIVE_DOUBLING = 3
    BINOMIAL_TREE = 4
    SKLANSKY = 5
    INVERTIBLE_DOUBLING = 6


class NodeType(enum.IntEnum):
    LEAF = 0
    INTERNAL = 1
    ROOT = 2


class MsgType(enum.IntEnum):
    OFFLOAD_REQUEST = 0
    PARTIAL = 1
    RESULT = 2
    ACK = 3        # the paper's back-to-back flow-control packet


class WireOp(enum.IntEnum):
    SUM = 0
    PROD = 1
    MAX = 2
    MIN = 3
    SSD = 4
    FLASH = 5


class WireDType(enum.IntEnum):
    INT32 = 0
    FLOAT32 = 1
    BFLOAT16 = 2
    FLOAT16 = 3
    INT8 = 4


#: most mesh axes a descriptor can encode (inner, outer, pod)
MAX_AXES = 3

#: encoded word counts: legacy single-axis, topology-carrying, the
#: optimizer-flagged layout, and the chunked-streaming layout (each one
#: extra word; see ``encode``)
_LEGACY_WORDS = 10
_TOPO_WORDS = _LEGACY_WORDS + MAX_AXES + 2  # n_axes + sizes + split index
_OPT_WORDS = _TOPO_WORDS + 1                # + schedule-flags word
_CHUNK_WORDS = _OPT_WORDS + 1               # + payload chunk count word

#: lowering-backend names encodable in the schedule-flags word's high bits
#: (index = wire id). Id 0 is "" — "whatever the dispatch mode's default
#: backend is" — so descriptors that don't name a backend encode exactly as
#: they did before the registry existed. The wire table is append-only.
_WIRE_BACKENDS = ("", "pallas")


def split_index(order: "tuple[int, ...]") -> int:
    """Lexicographic rank of an axis-order permutation (wire encoding)."""
    n = len(order)
    perms = list(itertools.permutations(range(n)))
    try:
        return perms.index(tuple(order))
    except ValueError:
        raise ValueError(
            f"split {order!r} is not a permutation of range({n})"
        ) from None


def split_from_index(idx: int, n_axes: int) -> "tuple[int, ...]":
    """Inverse of :func:`split_index`."""
    perms = list(itertools.permutations(range(n_axes)))
    if not 0 <= idx < len(perms):
        raise ValueError(
            f"split index {idx} out of range for {n_axes} axes "
            f"({math.factorial(n_axes)} permutations)"
        )
    return perms[idx]


_ALGO_NAMES = {
    AlgoType.SEQUENTIAL: "sequential",
    AlgoType.SEQUENTIAL_PIPELINED: "sequential_pipelined",
    AlgoType.HILLIS_STEELE: "hillis_steele",
    AlgoType.RECURSIVE_DOUBLING: "recursive_doubling",
    AlgoType.BINOMIAL_TREE: "binomial_tree",
    AlgoType.SKLANSKY: "sklansky",
    AlgoType.INVERTIBLE_DOUBLING: "invertible_doubling",
}
_ALGO_IDS = {v: k for k, v in _ALGO_NAMES.items()}


@dataclasses.dataclass(frozen=True)
class CollectiveDescriptor:
    """Fig. 1 descriptor fields (transport framing dropped) + topology.

    ``axes`` is empty for single-axis (legacy) requests. When set, it holds
    the physical mesh-axis sizes outermost-first; ``prod(axes)`` must equal
    ``comm_size`` and ``split`` — a permutation of ``range(len(axes))`` —
    records which physical axis the planner placed at each logical level
    (level 0 outermost in global rank order, last level innermost).
    """

    comm_id: int = 0
    comm_size: int = 1
    coll_type: CollType = CollType.SCAN
    algo_type: str = "recursive_doubling"
    rank: int = 0
    root: int = 0
    operation: WireOp = WireOp.SUM
    data_type: WireDType = WireDType.FLOAT32
    count: int = 1
    msg_type: MsgType = MsgType.OFFLOAD_REQUEST
    axes: "tuple[int, ...]" = ()
    split: "tuple[int, ...]" = ()
    optimized: bool = False
    #: payload chunk count for chunked streaming (1 = whole-payload rounds;
    #: the wire layout only grows the extra word when chunks > 1, so every
    #: pre-chunking descriptor keeps its exact byte encoding)
    chunks: int = 1
    #: lowering-backend request ("" = the dispatch mode's default). Names
    #: must be wire-encodable (:data:`_WIRE_BACKENDS`); like ``optimized``
    #: it shapes the compiled schedule, so it is topology-only and travels
    #: in the schedule-flags word's high bits.
    backend: str = ""

    def __post_init__(self):
        if self.optimized and not self.axes:
            raise ValueError(
                "optimized flag requires a multi-axis topology (the plan "
                "optimizer runs on planned collectives only)"
            )
        if self.backend:
            if not self.axes:
                raise ValueError(
                    "backend request requires a multi-axis (planned) "
                    "topology; single-axis requests use the mode default"
                )
            if self.backend not in _WIRE_BACKENDS:
                raise ValueError(
                    f"backend {self.backend!r} is not wire-encodable; "
                    f"known: {', '.join(n or '<default>' for n in _WIRE_BACKENDS)}"
                )
        if self.chunks < 1:
            raise ValueError(f"chunks must be >= 1, got {self.chunks}")
        if self.chunks > 1 and not self.axes:
            raise ValueError(
                "chunked streaming requires a multi-axis (planned) "
                "descriptor; single-axis requests always run unchunked"
            )
        if self.axes:
            if len(self.axes) > MAX_AXES:
                raise ValueError(
                    f"at most {MAX_AXES} mesh axes encodable; got {self.axes}"
                )
            if math.prod(self.axes) != self.comm_size:
                raise ValueError(
                    f"axes {self.axes} do not factor comm_size="
                    f"{self.comm_size}"
                )
            split = self.split or tuple(range(len(self.axes)))
            if sorted(split) != list(range(len(self.axes))):
                raise ValueError(
                    f"split {split!r} is not a permutation of the "
                    f"{len(self.axes)} axes"
                )
            object.__setattr__(self, "split", tuple(split))
        elif self.split:
            raise ValueError("split given without axes")

    def normalized(self) -> "CollectiveDescriptor":
        """This request with the per-rank fields zeroed: every rank of a
        communicator, and every repeat request, shares one normalized form.
        Both the engine's schedule-cache key and the broker's coalescing
        group key derive from it — requests fuse iff they would share a
        compiled schedule."""
        return dataclasses.replace(
            self, rank=0, msg_type=MsgType.OFFLOAD_REQUEST
        )

    @property
    def node_type(self) -> NodeType:
        """Derived role in the binomial tree (paper left this to software)."""
        p, j = self.comm_size, self.rank
        if p <= 1:
            return NodeType.ROOT
        if j == p - 1:
            return NodeType.ROOT
        # leaf iff it never receives in the up-phase: lowest bit of j is 0
        return NodeType.LEAF if (j & 1) == 0 else NodeType.INTERNAL

    def encode(self) -> np.ndarray:
        """Pack to a uint32 word vector (round-trippable, logged by launch).

        Layout: the 10 legacy descriptor words, then [n_axes, size_0,
        size_1, size_2, split_index] (zero-padded past n_axes), then the
        schedule-flags word: bit 0 is the "optimized" flag (1 iff the
        plan-optimizer pass pipeline runs for this request) and the high
        bits the lowering-backend wire id — both shape the compiled
        schedule, so brokered and cached dispatches must agree on them and
        they travel on the wire like every other schedule-shaping field.
        Default-backend requests keep bit 1+ zero, i.e. their exact
        pre-registry bytes. When ``chunks > 1`` a 17th word carries the
        chunk count; unchunked requests keep the 16-word layout
        byte-for-byte, so existing logged and cached encodings stay valid.
        """
        sizes = list(self.axes) + [0] * (MAX_AXES - len(self.axes))
        split = split_index(self.split) if self.axes else 0
        flags = int(self.optimized) | (
            _WIRE_BACKENDS.index(self.backend) << 1
        )
        words = [
            self.comm_id,
            self.comm_size,
            int(self.coll_type),
            int(_ALGO_IDS[self.algo_type]),
            self.rank,
            self.root,
            int(self.operation),
            int(self.data_type),
            self.count,
            int(self.msg_type),
            len(self.axes),
            *sizes,
            split,
            flags,
        ]
        if self.chunks > 1:
            words.append(self.chunks)
        return np.asarray(words, dtype=np.uint32)

    @staticmethod
    def decode(words: np.ndarray) -> "CollectiveDescriptor":
        w = [int(v) for v in np.asarray(words, dtype=np.uint32)]
        if len(w) not in (_LEGACY_WORDS, _TOPO_WORDS, _OPT_WORDS,
                          _CHUNK_WORDS):
            raise ValueError(
                f"descriptor must be {_LEGACY_WORDS} (legacy), "
                f"{_TOPO_WORDS} (topology), {_OPT_WORDS} (optimizer "
                f"flag), or {_CHUNK_WORDS} (chunked) words; got {len(w)}"
            )
        axes: "tuple[int, ...]" = ()
        split: "tuple[int, ...]" = ()
        if len(w) >= _TOPO_WORDS and w[_LEGACY_WORDS]:
            n = w[_LEGACY_WORDS]
            axes = tuple(w[_LEGACY_WORDS + 1 : _LEGACY_WORDS + 1 + n])
            split = split_from_index(w[_LEGACY_WORDS + 1 + MAX_AXES], n)
        flags = w[_OPT_WORDS - 1] if len(w) >= _OPT_WORDS else 0
        optimized = bool(flags & 1)
        backend_id = flags >> 1
        if backend_id >= len(_WIRE_BACKENDS):
            raise ValueError(
                f"unknown lowering-backend wire id {backend_id} in the "
                f"schedule-flags word (know 0..{len(_WIRE_BACKENDS) - 1})"
            )
        chunks = max(1, w[_CHUNK_WORDS - 1]) if len(w) == _CHUNK_WORDS else 1
        return CollectiveDescriptor(
            comm_id=w[0],
            comm_size=w[1],
            coll_type=CollType(w[2]),
            algo_type=_ALGO_NAMES[AlgoType(w[3])],
            rank=w[4],
            root=w[5],
            operation=WireOp(w[6]),
            data_type=WireDType(w[7]),
            count=w[8],
            msg_type=MsgType(w[9]),
            axes=axes,
            split=split,
            optimized=optimized,
            chunks=chunks,
            backend=_WIRE_BACKENDS[backend_id],
        )


def wire_checksum(words: np.ndarray) -> int:
    """CRC32 over a descriptor word vector (the modeled frame FCS).

    The NetFPGA's Ethernet frames carried a hardware FCS; software
    transports that re-frame the descriptor (files, sockets, logs) lose
    it, so :func:`encode_checked` re-appends one as a trailing uint32
    word. Any single-bit flip over the checked words fails verification —
    which plain ``decode`` cannot promise, since flips in fields like
    ``comm_id`` or ``count`` decode silently into a different-but-valid
    descriptor.
    """
    w = np.ascontiguousarray(np.asarray(words, dtype=np.uint32))
    return zlib.crc32(w.tobytes()) & 0xFFFFFFFF


def encode_checked(desc: CollectiveDescriptor) -> np.ndarray:
    """``desc.encode()`` plus a trailing CRC32 word (see
    :func:`wire_checksum`)."""
    words = desc.encode()
    return np.concatenate(
        [words, np.asarray([wire_checksum(words)], dtype=np.uint32)]
    )


def decode_checked(words: np.ndarray) -> CollectiveDescriptor:
    """Verify and strip the trailing CRC32 word, then ``decode``.

    Raises :class:`IntegrityError` on checksum mismatch (corruption) and
    ``ValueError`` on structurally invalid remainders — never returns a
    descriptor that differs from the one originally encoded.
    """
    w = np.asarray(words, dtype=np.uint32)
    if w.size < _LEGACY_WORDS + 1:
        raise ValueError(
            f"checked descriptor needs at least {_LEGACY_WORDS + 1} words "
            f"(payload + CRC); got {w.size}"
        )
    payload, crc = w[:-1], int(w[-1])
    expect = wire_checksum(payload)
    if crc != expect:
        raise IntegrityError(
            f"descriptor wire checksum mismatch: got {crc:#010x}, "
            f"expected {expect:#010x} over {payload.size} words"
        )
    return CollectiveDescriptor.decode(payload)
