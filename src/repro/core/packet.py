"""The offload descriptor — software analogue of the paper's Fig. 1 packet.

The NetFPGA consumed a UDP packet whose payload carried the collective
descriptor (comm_id, comm_size, coll_type, algo_type, node_type, msg_type,
rank, root, operation, data_type, count). The Ethernet/IP/UDP framing has no
TPU analogue (XLA owns transport); we keep the descriptor itself: it is how
the framework names, logs, and selects compiled collective schedules, and the
encode/decode round-trip keeps the format "self-describing" as the paper
intends. ``node_type`` is derived from (rank, comm_size) inside the SPMD
program — the hardware-side derivation the paper lists as future work is
trivial in software, so we do it.
"""

from __future__ import annotations

import dataclasses
import enum

import numpy as np


class CollType(enum.IntEnum):
    SCAN = 0       # MPI_Scan
    EXSCAN = 1     # MPI_Exscan
    REDUCE = 2
    ALLREDUCE = 3
    BARRIER = 4


class AlgoType(enum.IntEnum):
    SEQUENTIAL = 0
    SEQUENTIAL_PIPELINED = 1
    HILLIS_STEELE = 2
    RECURSIVE_DOUBLING = 3
    BINOMIAL_TREE = 4
    SKLANSKY = 5
    INVERTIBLE_DOUBLING = 6


class NodeType(enum.IntEnum):
    LEAF = 0
    INTERNAL = 1
    ROOT = 2


class MsgType(enum.IntEnum):
    OFFLOAD_REQUEST = 0
    PARTIAL = 1
    RESULT = 2
    ACK = 3        # the paper's back-to-back flow-control packet


class WireOp(enum.IntEnum):
    SUM = 0
    PROD = 1
    MAX = 2
    MIN = 3
    SSD = 4
    FLASH = 5


class WireDType(enum.IntEnum):
    INT32 = 0
    FLOAT32 = 1
    BFLOAT16 = 2
    FLOAT16 = 3
    INT8 = 4


_ALGO_NAMES = {
    AlgoType.SEQUENTIAL: "sequential",
    AlgoType.SEQUENTIAL_PIPELINED: "sequential_pipelined",
    AlgoType.HILLIS_STEELE: "hillis_steele",
    AlgoType.RECURSIVE_DOUBLING: "recursive_doubling",
    AlgoType.BINOMIAL_TREE: "binomial_tree",
    AlgoType.SKLANSKY: "sklansky",
    AlgoType.INVERTIBLE_DOUBLING: "invertible_doubling",
}
_ALGO_IDS = {v: k for k, v in _ALGO_NAMES.items()}


@dataclasses.dataclass(frozen=True)
class CollectiveDescriptor:
    """Fig. 1 descriptor fields (transport framing dropped)."""

    comm_id: int = 0
    comm_size: int = 1
    coll_type: CollType = CollType.SCAN
    algo_type: str = "recursive_doubling"
    rank: int = 0
    root: int = 0
    operation: WireOp = WireOp.SUM
    data_type: WireDType = WireDType.FLOAT32
    count: int = 1
    msg_type: MsgType = MsgType.OFFLOAD_REQUEST

    @property
    def node_type(self) -> NodeType:
        """Derived role in the binomial tree (paper left this to software)."""
        p, j = self.comm_size, self.rank
        if p <= 1:
            return NodeType.ROOT
        if j == p - 1:
            return NodeType.ROOT
        # leaf iff it never receives in the up-phase: lowest bit of j is 0
        return NodeType.LEAF if (j & 1) == 0 else NodeType.INTERNAL

    def encode(self) -> np.ndarray:
        """Pack to a uint32 word vector (round-trippable, logged by launch)."""
        return np.asarray(
            [
                self.comm_id,
                self.comm_size,
                int(self.coll_type),
                int(_ALGO_IDS[self.algo_type]),
                self.rank,
                self.root,
                int(self.operation),
                int(self.data_type),
                self.count,
                int(self.msg_type),
            ],
            dtype=np.uint32,
        )

    @staticmethod
    def decode(words: np.ndarray) -> "CollectiveDescriptor":
        w = [int(v) for v in np.asarray(words, dtype=np.uint32)]
        return CollectiveDescriptor(
            comm_id=w[0],
            comm_size=w[1],
            coll_type=CollType(w[2]),
            algo_type=_ALGO_NAMES[AlgoType(w[3])],
            rank=w[4],
            root=w[5],
            operation=WireOp(w[6]),
            data_type=WireDType(w[7]),
            count=w[8],
            msg_type=MsgType(w[9]),
        )
