"""Host-orchestrated scan — the "software MPI" baseline.

The paper's comparison axis is *who drives the schedule*: software MPI has the
host CPU issue every send/recv (one kernel-launch-equivalent per hop, protocol
stack in the loop), while the offloaded version hands the NIC one descriptor
and receives one result.

The JAX analogue: the *offloaded* path compiles the entire schedule into one
XLA program (``dist_scan`` inside ``shard_map``); the *software* path below
re-enters Python between every schedule step — one jitted step per hop, with a
``block_until_ready`` modelling the host's synchronous involvement, exactly the
dispatch pattern an un-offloaded MPI progress engine exhibits. The benchmark
suite (paper Figs. 4-5) measures both over identical schedules and payloads.
"""

from __future__ import annotations

import time
from functools import partial
from typing import Any, Callable, List, Tuple

import jax
import jax.numpy as jnp

from repro.core import algorithms as alg
from repro.core.operators import AssocOp, get_operator

PyTree = Any


class _RecordingBackend(alg.SimBackend):
    """SimBackend that records the permutation of every schedule step."""

    def __init__(self, p: int):
        super().__init__(p)
        self.steps: List[alg.Perm] = []

    def permute(self, tree, perm):
        self.steps.append(list(perm))
        return super().permute(tree, perm)


def schedule_trace(algorithm: str, p: int) -> List[alg.Perm]:
    """Extract the hop list of a schedule (used by benches + latency model)."""
    backend = _RecordingBackend(p)
    op = get_operator("sum")
    x = jnp.zeros((p, 1), dtype=jnp.float32)
    alg.get_algorithm(algorithm)(backend, x, op)
    return backend.steps


def host_scan(
    stacked: PyTree,
    op: "AssocOp | str",
    p: int,
    *,
    algorithm: str,
) -> PyTree:
    """Run the schedule with the host in the loop (one dispatch per step).

    ``stacked`` carries a leading rank axis of size p on a single device —
    logically one buffer per rank, as on the paper's 8 hosts. Each step is an
    independently jitted program; the host synchronizes between steps. The
    result equals ``sim_scan`` / ``dist_scan`` bit-for-bit.
    """
    op = get_operator(op)
    backend = _HostSteppedBackend(p)
    out = alg.get_algorithm(algorithm)(backend, stacked, op)
    return jax.tree.map(lambda a: a.block_until_ready(), out)


class _HostSteppedBackend(alg.SimBackend):
    """Each permute is its own dispatch + host sync (the un-offloaded path)."""

    def permute(self, tree, perm):
        out = _jit_shuffle(tuple(perm), tree)
        jax.tree.map(lambda a: a.block_until_ready(), out)
        return out


@partial(jax.jit, static_argnums=0)
def _jit_shuffle(perm: Tuple[Tuple[int, int], ...], tree: PyTree) -> PyTree:
    def shuffle(a):
        out = jnp.zeros_like(a)
        for src, dst in perm:
            out = out.at[dst].set(a[src])
        return out

    return jax.tree.map(shuffle, tree)


def time_host_scan(
    stacked: PyTree, op, p: int, *, algorithm: str, iters: int = 20
) -> float:
    """Median wall-clock seconds per host-orchestrated scan."""
    host_scan(stacked, op, p, algorithm=algorithm)  # warm the per-step jits
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        host_scan(stacked, op, p, algorithm=algorithm)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def time_offloaded_scan(
    stacked: PyTree, op, p: int, *, algorithm: str, iters: int = 20
) -> float:
    """Median wall-clock seconds for the fused (single-program) schedule.

    Same simulator semantics, but the whole schedule is one jitted program —
    one dispatch total, like one offload packet.
    """
    from repro.core.scan_collective import sim_scan

    op = get_operator(op)
    fused = jax.jit(
        lambda s: sim_scan(s, op, p, algorithm=algorithm, inclusive=True)
    )
    out = fused(stacked)
    jax.tree.map(lambda a: a.block_until_ready(), out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fused(stacked)
        jax.tree.map(lambda a: a.block_until_ready(), out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]
