"""Associative operators for the offloaded scan collective.

The paper's offload packet carries an ``operation`` enum (MPI_SUM, MPI_MAX, ...)
and a ``data_type``; the NetFPGA state machine streams the combine at line rate.
Here the analogue is an :class:`AssocOp`: a named, pytree-valued associative
combine with an identity, an optional inverse (the paper's Fig. 3 "subtraction"
trick requires an invertible operator), and metadata the schedule generator uses
to pick fast paths (e.g. ``zero_identity`` lets ``ppermute``'s zero-fill act as
the identity, removing all masking selects from the compiled schedule).

Operators may act on arbitrary pytrees: the SSD operator used by the
sequence-parallel Mamba2 path combines ``(decay, state)`` pairs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AssocOp:
    """An associative binary operator over pytrees.

    Attributes:
      name: wire name (the ``operation`` field of the offload descriptor).
      combine: combine(left, right) with *left* the earlier-prefix operand.
        Must be associative; need not be commutative.
      identity_like: given an example pytree, return the identity element
        (same shapes/dtypes).
      inverse: optional. ``combine(inverse(a), combine(a, b)) == b`` and, when
        ``commutative``, ``combine(combine(b, a), inverse(a)) == b``. Enables
        the paper's multicast-subtraction optimization and zero-communication
        exclusive scans.
      commutative: whether operand order is irrelevant.
      zero_identity: True iff the identity element is all-zeros for every leaf;
        lets schedules skip (value, valid) masking because ``ppermute``
        delivers zeros on missing in-edges.
    """

    name: str
    combine: Callable[[PyTree, PyTree], PyTree]
    identity_like: Callable[[PyTree], PyTree]
    inverse: Optional[Callable[[PyTree], PyTree]] = None
    commutative: bool = False
    zero_identity: bool = False

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"AssocOp({self.name})"


def _tree_full_like(tree: PyTree, fill) -> PyTree:
    return jax.tree.map(lambda a: jnp.full_like(a, fill), tree)


SUM = AssocOp(
    name="sum",
    combine=lambda l, r: jax.tree.map(jnp.add, l, r),
    identity_like=lambda t: jax.tree.map(jnp.zeros_like, t),
    inverse=lambda t: jax.tree.map(jnp.negative, t),
    commutative=True,
    zero_identity=True,
)

PROD = AssocOp(
    name="prod",
    combine=lambda l, r: jax.tree.map(jnp.multiply, l, r),
    identity_like=lambda t: jax.tree.map(jnp.ones_like, t),
    # Inverse only valid away from zero; callers opt in.
    inverse=lambda t: jax.tree.map(lambda a: 1.0 / a, t),
    commutative=True,
)

MAX = AssocOp(
    name="max",
    combine=lambda l, r: jax.tree.map(jnp.maximum, l, r),
    identity_like=lambda t: jax.tree.map(
        lambda a: jnp.full_like(
            a,
            jnp.finfo(a.dtype).min if jnp.issubdtype(a.dtype, jnp.floating)
            else jnp.iinfo(a.dtype).min,
        ),
        t,
    ),
    commutative=True,
)

MIN = AssocOp(
    name="min",
    combine=lambda l, r: jax.tree.map(jnp.minimum, l, r),
    identity_like=lambda t: jax.tree.map(
        lambda a: jnp.full_like(
            a,
            jnp.finfo(a.dtype).max if jnp.issubdtype(a.dtype, jnp.floating)
            else jnp.iinfo(a.dtype).max,
        ),
        t,
    ),
    commutative=True,
)


def _ssd_combine(left: PyTree, right: PyTree) -> PyTree:
    """Combine for the linear recurrence h' = a*h + b.

    Elements are ``(a, b)`` tuples (decay, state contribution); ``a`` must be
    broadcast-compatible with ``b`` (the Mamba2 layer pre-expands decay dims).
    Applying ``left`` then ``right`` to an incoming state h gives
    ``aR*(aL*h + bL) + bR = (aR*aL)*h + (aR*bL + bR)``.
    """
    a_l, b_l = left
    a_r, b_r = right
    return (a_r * a_l, a_r * b_l + b_r)


SSD = AssocOp(
    name="ssd",
    combine=_ssd_combine,
    identity_like=lambda t: (jnp.ones_like(t[0]), jnp.zeros_like(t[1])),
    commutative=False,
)


def _flash_combine(left: PyTree, right: PyTree) -> PyTree:
    """Associative combine of flash-attention partial results.

    Elements are ``(m, l, o)``: running max of logits, sum of exp-weights, and
    the exp-weighted value accumulator. Commutative & associative; used by the
    KV-cache-sequence-sharded attention reduce.
    """
    m_l, l_l, o_l = left
    m_r, l_r, o_r = right
    m = jnp.maximum(m_l, m_r)
    c_l = jnp.exp(m_l - m)
    c_r = jnp.exp(m_r - m)
    return (m, l_l * c_l + l_r * c_r, o_l * c_l + o_r * c_r)


def make_flash_op(neg_inf: float = -1e30) -> AssocOp:
    return AssocOp(
        name="flash",
        combine=_flash_combine,
        identity_like=lambda t: (
            jnp.full_like(t[0], neg_inf),
            jnp.zeros_like(t[1]),
            jnp.zeros_like(t[2]),
        ),
        commutative=True,
    )


_REGISTRY = {
    "sum": SUM,
    "prod": PROD,
    "max": MAX,
    "min": MIN,
    "ssd": SSD,
    "flash": make_flash_op(),
}


def get_operator(op: "AssocOp | str") -> AssocOp:
    if isinstance(op, AssocOp):
        return op
    try:
        return _REGISTRY[op]
    except KeyError:
        raise ValueError(
            f"unknown operator {op!r}; known: {sorted(_REGISTRY)}"
        ) from None


def register_operator(op: AssocOp) -> None:
    _REGISTRY[op.name] = op


def segmented_operator(op: AssocOp) -> AssocOp:
    """Lift an operator to SEGMENTED scans (Blelloch — the paper's refs [8,9]).

    Elements are ``(value, start_flag)``: flag=1 marks a segment start and
    blocks accumulation across the boundary. The lifted combine

        (a, fa) (+) (b, fb) = (b if fb else a (+) b,  fa | fb)

    is associative whenever ``op`` is, so every schedule (and the offloaded
    SPMD path) works unchanged — this is how packed variable-length documents
    reset SSM state / packing offsets at document boundaries.
    """

    def combine(left: PyTree, right: PyTree) -> PyTree:
        (va, fa) = left
        (vb, fb) = right
        merged = op.combine(va, vb)
        keep_b = fb > 0.5

        def sel(m, b):
            c = keep_b
            extra = m.ndim - c.ndim
            if extra > 0:
                c = c.reshape(c.shape + (1,) * extra)
            return jnp.where(c, b, m)

        return (
            jax.tree.map(sel, merged, vb),
            jnp.maximum(fa, fb),
        )

    return AssocOp(
        name=f"segmented_{op.name}",
        combine=combine,
        identity_like=lambda t: (op.identity_like(t[0]), jnp.zeros_like(t[1])),
        commutative=False,  # segment boundaries impose order
        zero_identity=False,
    )
