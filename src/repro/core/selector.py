"""Algorithm selection — the paper's "MPI runtime can make an intelligent
selection of algorithms based on the underlying network topology".

The NetFPGA exposes ``algo_type`` in the offload packet and leaves the choice
to software. We implement the choice as an alpha-beta-gamma cost model over the
target interconnect:

    T(algo) = sum over steps of [ alpha + bytes_on_wire * beta + hops * gamma ]

with per-algorithm step counts and wire patterns. The model is linear in
(alpha, beta, gamma), exposed explicitly via :func:`cost_features`, so the
offload autotuner (``repro.offload.tuner``) can least-squares fit the constants
from measured latencies on whatever backend is actually running. Constants
default to TPU v5e ICI (the production target); when a tuning table is active
(:func:`set_active_tuning`) the selector consults its measured per-point
winners and fitted model before falling back to the static constants.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core.algorithms import ALGORITHMS, algorithm_step_count, num_steps
from repro.core.operators import AssocOp


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Interconnect constants.

    alpha: per-step launch latency (s) — collective-permute issue overhead.
    beta: seconds per byte per link (1 / link bandwidth).
    gamma: per-hop transit latency (s) on the torus.
    ring: ICI axes are rings; hop distance of a stride-s permute is
      min(s, p - s).
    """

    alpha: float = 1.0e-6
    beta: float = 1.0 / 50.0e9     # ~50 GB/s/link ICI
    gamma: float = 0.5e-6
    ring: bool = True


TPU_V5E = LinkModel()


def _hop(stride: int, p: int, ring: bool) -> int:
    return min(stride, p - stride) if ring else stride


def cost_features(
    algo: str, p: int, payload_bytes: int, ring: bool = True
) -> Tuple[float, float, float]:
    """(steps, bytes, hops) such that the predicted latency is their dot
    product with (alpha, beta, gamma).

    This is the design matrix row the autotuner fits against measured
    latencies; :func:`estimate_cost` is exactly ``features . constants``.
    """
    if p <= 1:
        return (0.0, 0.0, 0.0)
    m = float(payload_bytes)
    lg = num_steps(p)
    if algo in ("sequential", "sequential_pipelined"):
        # p-1 dependent single-hop steps. The pipelined form has identical
        # critical path; it differs in aggregate link traffic, not latency.
        return (float(p - 1), (p - 1) * m, float(p - 1))
    up_hops = float(sum(_hop(1 << k, p, ring) for k in range(lg)))
    if algo in (
        "hillis_steele",
        "invertible_doubling",
        # pairwise exchange: full duplex links carry both directions at once.
        "recursive_doubling",
        # multicast: one payload injected, fan-out handled by the fabric;
        # worst hop in step k is the half-block diameter.
        "sklansky",
    ):
        return (float(lg), lg * m, up_hops)
    if algo == "binomial_tree":
        down_hops = float(
            sum(_hop(1 << (k - 1), p, ring) for k in range(lg, 0, -1))
        )
        return (2.0 * lg, 2 * lg * m, up_hops + down_hops)
    raise ValueError(f"unknown algo {algo!r}")


def estimate_cost(
    algo: str, p: int, payload_bytes: int, model: LinkModel = TPU_V5E
) -> float:
    """Predicted completion latency of one scan with ``algo`` at size p."""
    steps, nbytes, hops = cost_features(algo, p, payload_bytes, model.ring)
    return steps * model.alpha + nbytes * model.beta + hops * model.gamma


def cost_table(
    p: int, payload_bytes: int, model: LinkModel = TPU_V5E
) -> Dict[str, float]:
    return {
        name: estimate_cost(name, p, payload_bytes, model)
        for name in ALGORITHMS
    }


# ---------------------------------------------------------------------------
# Tuning-table hook. ``repro.offload.tuning_cache`` registers the active table
# here (duck-typed so core never imports offload): anything with
# ``lookup(p, payload_bytes, coll) -> Optional[str]`` and
# ``fitted_model() -> Optional[LinkModel]``.
# ---------------------------------------------------------------------------

_ACTIVE_TUNING = None


def set_active_tuning(table) -> None:
    """Install (or, with None, clear) the tuning table ``select_algorithm``
    consults before the static constants."""
    global _ACTIVE_TUNING
    _ACTIVE_TUNING = table


def get_active_tuning():
    return _ACTIVE_TUNING


def _applicable(name: str, p: int, op: AssocOp) -> bool:
    if name not in ALGORITHMS:
        return False
    if name == "invertible_doubling" and (
        op.inverse is None or not op.commutative
    ):
        return False
    return True


def select_algorithm(
    p: int,
    payload_bytes: int,
    op: AssocOp,
    model: Optional[LinkModel] = None,
    coll: str = "scan",
) -> str:
    """Pick the cheapest *applicable* schedule.

    Resolution order when ``model`` is not given explicitly:
      1. an active tuning table's measured winner at/near (p, payload, coll);
      2. the tuning table's least-squares-fitted LinkModel;
      3. the static ``TPU_V5E`` constants.

    Applicability: invertible_doubling needs op.inverse (+ commutativity for
    its exscan payoff); everything else is generic. Ties break toward fewer
    steps, then lexicographic for determinism.
    """
    if model is None:
        if _ACTIVE_TUNING is not None:
            winner = _ACTIVE_TUNING.lookup(p, payload_bytes, coll)
            if winner is not None and _applicable(winner, p, op):
                return winner
            model = _ACTIVE_TUNING.fitted_model()
        if model is None:
            model = TPU_V5E
    costs = cost_table(p, payload_bytes, model)
    if op.inverse is None or not op.commutative:
        costs.pop("invertible_doubling", None)
    # sequential's O(p) critical path makes it a scalability trap (the paper's
    # own conclusion); keep it out of auto-selection beyond tiny axes.
    if p > 8:
        costs.pop("sequential", None)
        costs.pop("sequential_pipelined", None)
    return min(
        costs.items(),
        key=lambda kv: (kv[1], algorithm_step_count(kv[0], p), kv[0]),
    )[0]
