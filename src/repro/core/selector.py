"""Algorithm selection — the paper's "MPI runtime can make an intelligent
selection of algorithms based on the underlying network topology".

The NetFPGA exposes ``algo_type`` in the offload packet and leaves the choice
to software. We implement the choice as an alpha-beta-gamma cost model over the
target interconnect:

    T(algo) = sum over steps of [ alpha + bytes_on_wire * beta + hops * gamma ]

with per-algorithm step counts and wire patterns. Constants default to TPU
v5e ICI (the production target); the benchmark suite re-fits alpha/beta for
the CPU-simulated mesh so the selected crossovers can be validated in software.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.core.algorithms import ALGORITHMS, algorithm_step_count, num_steps
from repro.core.operators import AssocOp


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Interconnect constants.

    alpha: per-step launch latency (s) — collective-permute issue overhead.
    beta: seconds per byte per link (1 / link bandwidth).
    gamma: per-hop transit latency (s) on the torus.
    ring: ICI axes are rings; hop distance of a stride-s permute is
      min(s, p - s).
    """

    alpha: float = 1.0e-6
    beta: float = 1.0 / 50.0e9     # ~50 GB/s/link ICI
    gamma: float = 0.5e-6
    ring: bool = True


TPU_V5E = LinkModel()


def _hop(stride: int, p: int, ring: bool) -> int:
    return min(stride, p - stride) if ring else stride


def estimate_cost(
    algo: str, p: int, payload_bytes: int, model: LinkModel = TPU_V5E
) -> float:
    """Predicted completion latency of one scan with ``algo`` at size p."""
    if p <= 1:
        return 0.0
    m = payload_bytes
    a, b, g = model.alpha, model.beta, model.gamma
    lg = num_steps(p)
    if algo in ("sequential", "sequential_pipelined"):
        # p-1 dependent single-hop steps. The pipelined form has identical
        # critical path; it differs in aggregate link traffic, not latency.
        return (p - 1) * (a + m * b + g)
    if algo in ("hillis_steele", "invertible_doubling"):
        return sum(
            a + m * b + _hop(1 << k, p, model.ring) * g for k in range(lg)
        )
    if algo == "recursive_doubling":
        # pairwise exchange: full duplex links carry both directions at once.
        return sum(
            a + m * b + _hop(1 << k, p, model.ring) * g for k in range(lg)
        )
    if algo == "binomial_tree":
        up = sum(a + m * b + _hop(1 << k, p, model.ring) * g for k in range(lg))
        down = sum(
            a + m * b + _hop(1 << (k - 1), p, model.ring) * g
            for k in range(lg, 0, -1)
        )
        return up + down
    if algo == "sklansky":
        # multicast: one payload injected, fan-out handled by the fabric;
        # worst hop in step k is the half-block diameter.
        return sum(
            a + m * b + _hop(1 << k, p, model.ring) * g for k in range(lg)
        )
    raise ValueError(f"unknown algo {algo!r}")


def cost_table(
    p: int, payload_bytes: int, model: LinkModel = TPU_V5E
) -> Dict[str, float]:
    return {
        name: estimate_cost(name, p, payload_bytes, model)
        for name in ALGORITHMS
    }


def select_algorithm(
    p: int,
    payload_bytes: int,
    op: AssocOp,
    model: LinkModel = TPU_V5E,
) -> str:
    """Pick the cheapest *applicable* schedule.

    Applicability: invertible_doubling needs op.inverse (+ commutativity for
    its exscan payoff); everything else is generic. Ties break toward fewer
    steps, then lexicographic for determinism.
    """
    costs = cost_table(p, payload_bytes, model)
    if op.inverse is None or not op.commutative:
        costs.pop("invertible_doubling", None)
    # sequential's O(p) critical path makes it a scalability trap (the paper's
    # own conclusion); keep it out of auto-selection beyond tiny axes.
    if p > 8:
        costs.pop("sequential", None)
        costs.pop("sequential_pipelined", None)
    return min(
        costs.items(),
        key=lambda kv: (kv[1], algorithm_step_count(kv[0], p), kv[0]),
    )[0]
