"""Chrome/Perfetto trace export + host/device timeline merge.

Spans from :mod:`repro.obs.tracing` serialize to the Chrome trace-event JSON
format (``{"traceEvents": [...]}``) that both ``chrome://tracing`` and
https://ui.perfetto.dev open directly. Two extras beyond plain export:

  * **round-trip**: :func:`chrome_to_spans` reconstructs the span list from
    an exported trace (schema-tested), so traces are a faithful wire format
    for span data, not a lossy rendering;
  * **host+device merge**: :func:`merge_device_trace` folds the device-side
    executable-run events from the ``jax.profiler`` chrome trace that
    :mod:`repro.offload.profiling` already parses into the host span
    timeline — one trace showing the broker/engine/phase/round spans on the
    host track and the XLA executable executions on a device track. The two
    traces run on different clocks (host spans use ``perf_counter`` µs, the
    profiler uses its own epoch); alignment pins the profiler's
    ``TraceAnnotation`` event to the host-side span of the same name, which
    :func:`repro.offload.profiling.profile_offload` emits whenever a tracer
    is installed.

Event mapping: every span becomes one complete ("ph": "X") event whose
``args`` carry the span/parent ids, so parent links survive the round trip.
``pid`` 1 is the host process, ``pid`` 2 the device; thread-name metadata
events label the tracks.
"""

from __future__ import annotations

import gzip
import json
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.obs.tracing import Span

__all__ = [
    "HOST_PID",
    "DEVICE_PID",
    "chrome_to_spans",
    "load_chrome_trace",
    "merge_device_trace",
    "spans_to_chrome",
    "write_trace",
]

HOST_PID = 1
DEVICE_PID = 2


def spans_to_chrome(
    spans: Sequence[Span],
    *,
    process_name: str = "repro-host",
) -> Dict[str, Any]:
    """Serialize spans to a Chrome trace-event dict (Perfetto-openable)."""
    events: List[Dict[str, Any]] = [
        {
            "ph": "M",
            "pid": HOST_PID,
            "tid": 0,
            "name": "process_name",
            "args": {"name": process_name},
        }
    ]
    tids = sorted({s.tid for s in spans})
    tid_map = {t: i for i, t in enumerate(tids)}
    for t, i in tid_map.items():
        events.append(
            {
                "ph": "M",
                "pid": HOST_PID,
                "tid": i,
                "name": "thread_name",
                "args": {"name": f"host-thread-{i}"},
            }
        )
    for s in spans:
        args = dict(s.args)
        args["span_id"] = s.span_id
        if s.parent_id is not None:
            args["parent_id"] = s.parent_id
        args["host_tid"] = s.tid
        events.append(
            {
                "ph": "X",
                "pid": HOST_PID,
                "tid": tid_map.get(s.tid, 0),
                "name": s.name,
                "cat": s.cat,
                "ts": s.start_us,
                "dur": s.dur_us,
                "args": args,
            }
        )
    return {"traceEvents": events, "displayTimeUnit": "ns"}


def chrome_to_spans(trace: Dict[str, Any]) -> List[Span]:
    """Inverse of :func:`spans_to_chrome` for host span events."""
    spans: List[Span] = []
    for e in trace.get("traceEvents", []):
        if e.get("ph") != "X" or e.get("pid") != HOST_PID:
            continue
        args = dict(e.get("args", {}))
        span_id = args.pop("span_id", None)
        if span_id is None:
            continue
        parent_id = args.pop("parent_id", None)
        tid = args.pop("host_tid", e.get("tid", 0))
        spans.append(
            Span(
                name=str(e["name"]),
                cat=str(e.get("cat", "host")),
                start_us=float(e["ts"]),
                dur_us=float(e.get("dur", 0.0)),
                span_id=int(span_id),
                parent_id=None if parent_id is None else int(parent_id),
                tid=int(tid),
                args=args,
            )
        )
    return spans


def load_chrome_trace(path: "str | Path") -> Dict[str, Any]:
    """Read a chrome trace JSON, gzip-compressed or plain."""
    path = Path(path)
    raw = path.read_bytes()
    if raw[:2] == b"\x1f\x8b":
        raw = gzip.decompress(raw)
    return json.loads(raw)


def _device_events(
    trace: Dict[str, Any], device_event_re
) -> List[Dict[str, Any]]:
    out = []
    for e in trace.get("traceEvents", []):
        if e.get("ph") != "X":
            continue
        if device_event_re.search(str(e.get("name", ""))):
            out.append(e)
    return out


def _find_event(
    trace: Dict[str, Any], name: str
) -> Optional[Dict[str, Any]]:
    for e in trace.get("traceEvents", []):
        if e.get("ph") == "X" and e.get("name") == name:
            return e
    return None


def merge_device_trace(
    host_trace: Dict[str, Any],
    device_trace: "str | Path | Dict[str, Any]",
    *,
    align_on: Optional[str] = None,
) -> Dict[str, Any]:
    """Fold a ``jax.profiler`` chrome trace's device events into a host trace.

    ``align_on`` names an event present in *both* traces (the profiler's
    ``TraceAnnotation`` tag, which ``profile_offload`` mirrors as a host
    span); device timestamps are shifted so the two copies coincide. When
    ``align_on`` is None the first host event name that also appears in the
    device trace is used; with no common event the device events are
    appended unshifted (still viewable, on their own clock).

    Returns a new trace dict; inputs are not mutated. Device events keep
    their names, move to ``pid`` :data:`DEVICE_PID`, and gain
    ``args.source = "jax.profiler"``.

    A missing or unparseable device trace **degrades, never raises**: the
    profiler writing a truncated trace must not take down the tooling that
    wanted to decorate a perfectly good host trace. The merged result is
    then the host trace with ``deviceEventsMerged == 0`` and the reason in
    ``deviceMergeError`` (also recorded as a ``profiler_fallback`` flight
    event).
    """
    from repro.obs import events as obs_events
    from repro.offload.profiling import _DEVICE_EVENT_RE

    def degrade(reason: str, kind: str) -> Dict[str, Any]:
        obs_events.record("profiler_fallback", reason=kind)
        out = {
            **host_trace,
            "traceEvents": [
                dict(ev) for ev in host_trace.get("traceEvents", [])
            ],
        }
        out["deviceEventsMerged"] = 0
        out["deviceClockAligned"] = False
        out["deviceMergeError"] = reason
        return out

    if not isinstance(device_trace, dict):
        try:
            device_trace = load_chrome_trace(device_trace)
        except (OSError, ValueError) as e:
            return degrade(
                f"device trace unreadable: {e}", "merge_unreadable_trace"
            )
    if not isinstance(device_trace, dict):
        # a JSON file that parsed to a list/scalar — same degrade path
        return degrade(
            f"device trace malformed: expected an object, got "
            f"{type(device_trace).__name__}",
            "merge_malformed_trace",
        )

    host_events = [dict(e) for e in host_trace.get("traceEvents", [])]
    merged = {**host_trace, "traceEvents": host_events}

    # -- clock alignment ---------------------------------------------------
    offset = 0.0
    aligned = False
    candidates: List[str] = []
    if align_on is not None:
        candidates = [align_on]
    else:
        candidates = [
            str(e.get("name"))
            for e in host_events
            if e.get("ph") == "X"
        ]
    for name in candidates:
        dev_anchor = _find_event(device_trace, name)
        host_anchor = _find_event(merged, name)
        if dev_anchor is not None and host_anchor is not None:
            offset = float(host_anchor["ts"]) - float(dev_anchor["ts"])
            aligned = True
            break

    host_events.append(
        {
            "ph": "M",
            "pid": DEVICE_PID,
            "tid": 0,
            "name": "process_name",
            "args": {"name": "repro-device (jax.profiler)"},
        }
    )
    n = 0
    for e in _device_events(device_trace, _DEVICE_EVENT_RE):
        ev = dict(e)
        ev["pid"] = DEVICE_PID
        ev["tid"] = 0
        ev["ts"] = float(e.get("ts", 0.0)) + offset
        args = dict(ev.get("args") or {})
        args["source"] = "jax.profiler"
        args["aligned"] = aligned
        ev["args"] = args
        host_events.append(ev)
        n += 1
    merged["deviceEventsMerged"] = n
    merged["deviceClockAligned"] = aligned
    return merged


def write_trace(path: "str | Path", trace: Dict[str, Any]) -> Path:
    """Write a trace dict as (plain) JSON; returns the path. Open the file
    at https://ui.perfetto.dev or chrome://tracing."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(trace, indent=1, default=str) + "\n")
    return path
