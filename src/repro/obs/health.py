"""SLO health monitoring and per-link straggler attribution.

Two halves of "is the offload stack healthy?":

**SLOs.** :class:`SLO` declares an objective over a good/bad event ratio
(per-tenant latency under a target, deadline misses, schedule-cache
hits, lowering-backend fallbacks). :class:`HealthMonitor` ingests the
existing cumulative telemetry (:class:`~repro.service.telemetry.
ServiceTelemetry`, :class:`~repro.offload.engine.EngineTelemetry`),
converts counter snapshots to increments, and evaluates each SLO over
two sliding windows with **multi-window burn-rate alerting** (the SRE
playbook shape): burn rate is ``error_rate / error_budget`` and an
alert fires only when *both* the fast and the slow window burn faster
than ``burn_threshold`` — the fast window gives detection latency, the
slow window stops a single bad flush from paging. Alerts land in the
flight recorder (``slo_alert``), in Prometheus
(``repro_slo_alerts_total`` / ``repro_slo_burn_rate``), and in
:meth:`HealthMonitor.healthz` (the ``/healthz`` endpoint's payload).

**Per-link attribution.** ``runtime/straggler.py`` flags slow *steps* —
useful, but a remesh decision wants to know *which link* is slow.
:class:`LinkProbeBackend` decomposes each traced sim round's permute
into its individual (src, dst) messages — bitwise-identical merge, one
``link``-category span each — and :class:`LinkStragglerDetector` keeps
a per-(axis, src, dst) latency EWMA, compares each link against the
median of its same-axis peers (peer-relative, so a globally slow host
doesn't flag every link), and after ``report_after`` consecutive flags
names the slow link as a health event that remesh consumers
(``fault.notify_remesh`` listeners) can act on.
:class:`LinkDelayInjector` adds a synthetic per-link delay (sleep only
— values never change) so CI can prove the attribution finds the link
it planted (``repro.testing.health_check``).
"""

from __future__ import annotations

import collections
import dataclasses
import statistics
import threading
import time
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from repro.obs import events as obs_events
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing

__all__ = [
    "SLO",
    "Alert",
    "HealthMonitor",
    "LinkDelayInjector",
    "LinkProbeBackend",
    "LinkStragglerDetector",
    "default_slos",
]

LinkKey = Tuple[int, int, int]  # (axis/level, src, dst)


# ---------------------------------------------------------------------------
# SLO definitions + burn-rate evaluation
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SLO:
    """One declarative objective over a good/bad event ratio.

    ``objective`` is the target good fraction (0.99 -> 1% error budget).
    Evaluation is multi-window: an alert fires when the burn rate
    (window error rate / error budget) is at least ``burn_threshold`` on
    *both* the ``fast_window_s`` and ``slow_window_s`` windows, and each
    window saw at least ``min_events`` events (no data is not an alert).
    """

    name: str
    description: str = ""
    objective: float = 0.99
    fast_window_s: float = 60.0
    slow_window_s: float = 600.0
    burn_threshold: float = 1.0
    min_events: int = 1

    def __post_init__(self) -> None:
        if not 0.0 < self.objective < 1.0:
            raise ValueError(
                f"SLO {self.name!r}: objective must be in (0, 1), "
                f"got {self.objective}"
            )
        if self.fast_window_s > self.slow_window_s:
            raise ValueError(
                f"SLO {self.name!r}: fast window ({self.fast_window_s}s) "
                f"wider than slow window ({self.slow_window_s}s)"
            )

    @property
    def error_budget(self) -> float:
        return 1.0 - self.objective


@dataclasses.dataclass(frozen=True)
class Alert:
    """One firing SLO breach (both windows over the burn threshold)."""

    slo: str
    key: str  # e.g. tenant name; "" for global SLOs
    burn_fast: float
    burn_slow: float
    error_rate_fast: float
    error_rate_slow: float
    t: float

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


#: default latency target for the per-tenant latency SLO (microseconds)
DEFAULT_LATENCY_TARGET_US = 1e6


def default_slos() -> Tuple[SLO, ...]:
    """The stack's stock SLOs; pass your own tuple to tune any of them."""
    return (
        SLO(
            "tenant_latency",
            "fraction of tenant requests completing under the latency "
            "target (per-tenant; bucket-resolution good counts)",
            objective=0.99,
        ),
        SLO(
            "deadline_miss",
            "fraction of tenant completions that met their deadline "
            "(per-tenant)",
            objective=0.99,
        ),
        SLO(
            "cache_hit",
            "engine schedule-cache hit fraction",
            objective=0.50,
        ),
        SLO(
            "backend_fallback",
            "fraction of engine dispatches not hitting a lowering-backend "
            "fallback",
            objective=0.95,
        ),
    )


class HealthMonitor:
    """Sliding-window SLO evaluation over cumulative telemetry counters.

    Feed it either raw increments (:meth:`observe`) or whole telemetry
    objects/snapshots (:meth:`ingest`, which diffs against the previous
    ingest so cumulative counters become per-window increments). Then
    :meth:`evaluate` returns the currently-firing :class:`Alert` list;
    rising edges are recorded into the flight recorder and counted in
    ``repro_slo_alerts_total``. ``clock`` is injectable for tests.
    """

    #: retained (t, good, bad) entries per series — prune guard, not policy
    MAX_SERIES_LEN = 4096

    def __init__(
        self,
        slos: Optional[Tuple[SLO, ...]] = None,
        *,
        latency_target_us: float = DEFAULT_LATENCY_TARGET_US,
        link_detector: Optional["LinkStragglerDetector"] = None,
        breaker: Optional[Any] = None,
        recorder: Optional[obs_events.FlightRecorder] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        self._lock = threading.Lock()
        self._slos: Dict[str, SLO] = {
            s.name: s for s in (default_slos() if slos is None else slos)
        }
        self.latency_target_us = float(latency_target_us)
        self.link_detector = link_detector
        # a reliability CircuitBreaker (anything with .snapshot()); its
        # per-(backend, coll) state rides /healthz and an open circuit
        # flips overall status to "alert"
        self.breaker = breaker
        self._recorder = recorder
        self._clock = clock
        # (slo, key) -> deque[(t, good, bad)]
        self._series: Dict[Tuple[str, str], Deque[Tuple[float, float, float]]]
        self._series = {}
        # cumulative-counter memory for snapshot diffing
        self._last: Dict[Tuple[str, ...], float] = {}
        self._active: set = set()  # (slo, key) currently firing

    # -- configuration -----------------------------------------------------

    def add_slo(self, slo: SLO) -> None:
        with self._lock:
            self._slos[slo.name] = slo

    def slos(self) -> Tuple[SLO, ...]:
        with self._lock:
            return tuple(self._slos.values())

    @property
    def recorder(self) -> obs_events.FlightRecorder:
        # `is not None`, not `or`: an *empty* FlightRecorder is falsy
        if self._recorder is not None:
            return self._recorder
        return obs_events.get_recorder()

    # -- feeding -----------------------------------------------------------

    def observe(
        self,
        slo: str,
        *,
        key: str = "",
        good: float = 0.0,
        bad: float = 0.0,
        t: Optional[float] = None,
    ) -> None:
        """Add ``good``/``bad`` event increments to one SLO series."""
        if slo not in self._slos:
            raise KeyError(f"unknown SLO {slo!r}; add_slo() it first")
        if good <= 0.0 and bad <= 0.0:
            return
        t = self._clock() if t is None else t
        with self._lock:
            series = self._series.get((slo, key))
            if series is None:
                series = self._series[(slo, key)] = collections.deque(
                    maxlen=self.MAX_SERIES_LEN
                )
            series.append((t, max(0.0, good), max(0.0, bad)))

    def _delta(self, key: Tuple[str, ...], value: float) -> float:
        """Increment of a cumulative counter since the previous ingest.
        A counter going backwards (telemetry reset) re-bases at zero."""
        prev = self._last.get(key, 0.0)
        self._last[key] = value
        return value - prev if value >= prev else value

    @staticmethod
    def _snap(obj: Any) -> Dict[str, Any]:
        return obj.snapshot() if hasattr(obj, "snapshot") else dict(obj or {})

    def ingest(
        self,
        *,
        service: Any = None,
        engine: Any = None,
        t: Optional[float] = None,
    ) -> None:
        """Diff one round of telemetry into the SLO series.

        ``service``/``engine`` accept the live telemetry objects or their
        ``snapshot()`` dicts. The per-tenant latency SLO needs bucket
        counts, so it is only fed when ``service`` is a live
        :class:`ServiceTelemetry` (snapshots carry percentiles, not
        buckets); everything else works from either form.
        """
        t = self._clock() if t is None else t
        if service is not None:
            tenants = getattr(service, "tenants", None)
            snap = self._snap(service)
            for name, ts in (snap.get("tenants") or {}).items():
                done = self._delta(
                    ("svc", name, "done"),
                    float(ts.get("completed", 0) + ts.get("errors", 0)),
                )
                missed = self._delta(
                    ("svc", name, "missed"), float(ts.get("deadline_missed", 0))
                )
                if "deadline_miss" in self._slos:
                    self.observe(
                        "deadline_miss", key=name, t=t,
                        good=max(0.0, done - missed), bad=missed,
                    )
                if "tenant_latency" in self._slos and tenants is not None:
                    stats = tenants.get(name)
                    if stats is not None:
                        fast = self._delta(
                            ("svc", name, "lat_good"),
                            float(stats.latency.count_at_or_below(
                                self.latency_target_us
                            )),
                        )
                        total = self._delta(
                            ("svc", name, "lat_total"),
                            float(stats.latency.count),
                        )
                        self.observe(
                            "tenant_latency", key=name, t=t,
                            good=fast, bad=max(0.0, total - fast),
                        )
        if engine is not None:
            snap = self._snap(engine)
            hits = self._delta(("eng", "hits"), float(snap.get("hits", 0)))
            misses = self._delta(
                ("eng", "misses"), float(snap.get("misses", 0))
            )
            if "cache_hit" in self._slos:
                self.observe("cache_hit", t=t, good=hits, bad=misses)
            falls = self._delta(
                ("eng", "bfall"), float(snap.get("backend_fallbacks", 0))
            )
            disp = self._delta(
                ("eng", "disp"), float(snap.get("dispatches", 0))
            )
            if "backend_fallback" in self._slos:
                self.observe(
                    "backend_fallback", t=t,
                    good=max(0.0, disp - falls), bad=falls,
                )

    # -- evaluation --------------------------------------------------------

    def _window(
        self,
        series: List[Tuple[float, float, float]],
        window_s: float,
        now: float,
    ) -> Tuple[float, float]:
        cutoff = now - window_s
        good = bad = 0.0
        for t, g, b in reversed(series):
            if t < cutoff:
                break
            good += g
            bad += b
        return good, bad

    def evaluate(self, t: Optional[float] = None) -> List[Alert]:
        """The currently-firing alerts; publishes burn-rate gauges and
        records rising edges (flight recorder + alert counter)."""
        now = self._clock() if t is None else t
        reg = obs_metrics.get_registry()
        burn_gauge = reg.gauge(
            "repro_slo_burn_rate",
            "SLO burn rate (window error rate / error budget)",
            labelnames=("slo", "key", "window"),
        )
        alerts: List[Alert] = []
        firing: set = set()
        with self._lock:
            items = [
                (slo_key, self._slos[slo_key[0]], list(series))
                for slo_key, series in self._series.items()
                if slo_key[0] in self._slos
            ]
        for (slo_name, key), slo, series in items:
            gf, bf = self._window(series, slo.fast_window_s, now)
            gs, bs = self._window(series, slo.slow_window_s, now)
            tf, tsl = gf + bf, gs + bs
            if tf < slo.min_events or tsl < slo.min_events:
                continue
            erf = bf / tf if tf else 0.0
            ers = bs / tsl if tsl else 0.0
            burn_f = erf / slo.error_budget
            burn_s = ers / slo.error_budget
            burn_gauge.set(burn_f, slo=slo_name, key=key, window="fast")
            burn_gauge.set(burn_s, slo=slo_name, key=key, window="slow")
            if burn_f >= slo.burn_threshold and burn_s >= slo.burn_threshold:
                firing.add((slo_name, key))
                alerts.append(
                    Alert(
                        slo=slo_name, key=key,
                        burn_fast=burn_f, burn_slow=burn_s,
                        error_rate_fast=erf, error_rate_slow=ers, t=now,
                    )
                )
        with self._lock:
            new = firing - self._active
            self._active = firing
        for alert in alerts:
            if (alert.slo, alert.key) in new:
                self.recorder.record(
                    "slo_alert",
                    slo=alert.slo,
                    key=alert.key,
                    burn_fast=round(alert.burn_fast, 3),
                    burn_slow=round(alert.burn_slow, 3),
                )
                reg.counter(
                    "repro_slo_alerts_total",
                    "SLO burn-rate alerts (rising edges)",
                    labelnames=("slo", "key"),
                ).inc(slo=alert.slo, key=alert.key)
        return alerts

    def healthz(self, t: Optional[float] = None) -> Dict[str, Any]:
        """The ``/healthz`` payload: alerts, stragglers, breaker states.

        Any non-closed circuit breaker (open *or* half-open — a probing
        backend is not healthy yet) flips the status to "alert"."""
        alerts = self.evaluate(t)
        stragglers = (
            self.link_detector.reports() if self.link_detector else []
        )
        breakers = self.breaker.snapshot() if self.breaker else {}
        tripped = [
            k for k, v in breakers.items() if v.get("state") != "closed"
        ]
        return {
            "status": (
                "alert" if (alerts or stragglers or tripped) else "ok"
            ),
            "alerts": [a.as_dict() for a in alerts],
            "stragglers": stragglers,
            "breakers": breakers,
            "slos": [s.name for s in self.slos()],
        }


# ---------------------------------------------------------------------------
# Per-link straggler attribution
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _LinkStats:
    ewma_us: float = 0.0
    samples: int = 0
    consecutive: int = 0
    flags: int = 0


class LinkStragglerDetector:
    """Per-(axis, src, dst) latency EWMA with peer-relative flagging.

    Each observed link keeps its own EWMA of message latency (always
    updated — a slow link must *stay* visibly slow, unlike the step
    detector where a spike would poison its own baseline). A link is
    flagged when its EWMA exceeds ``threshold`` x the median EWMA of the
    *other* links on the same axis (peer-relative: a globally slow round
    moves every link and flags none). ``report_after`` consecutive flags
    promote the link to a report: recorded as a ``straggler_link``
    flight event, counted in ``repro_link_straggler_reports_total``, and
    handed to any :meth:`on_report` callbacks — the hook remesh
    consumers use.
    """

    def __init__(
        self,
        *,
        alpha: float = 0.25,
        threshold: float = 2.0,
        min_samples: int = 3,
        report_after: int = 3,
        recorder: Optional[obs_events.FlightRecorder] = None,
    ):
        self.alpha = float(alpha)
        self.threshold = float(threshold)
        self.min_samples = int(min_samples)
        self.report_after = int(report_after)
        self._recorder = recorder
        self._lock = threading.Lock()
        self._links: Dict[LinkKey, _LinkStats] = {}
        self._reports: Dict[LinkKey, Dict[str, Any]] = {}
        self._callbacks: List[Callable[[Dict[str, Any]], None]] = []

    @property
    def recorder(self) -> obs_events.FlightRecorder:
        # `is not None`, not `or`: an *empty* FlightRecorder is falsy
        if self._recorder is not None:
            return self._recorder
        return obs_events.get_recorder()

    def on_report(self, cb: Callable[[Dict[str, Any]], None]) -> None:
        """Register a callback for newly-reported straggler links."""
        self._callbacks.append(cb)

    def observe(
        self, axis: int, src: int, dst: int, dur_us: float
    ) -> Dict[str, Any]:
        """Feed one link-message latency; returns {flagged, report,
        ewma_us, peer_us} (the step detector's dict-contract shape)."""
        key: LinkKey = (int(axis), int(src), int(dst))
        with self._lock:
            st = self._links.get(key)
            if st is None:
                st = self._links[key] = _LinkStats()
            st.samples += 1
            st.ewma_us = (
                dur_us if st.samples == 1
                else (1 - self.alpha) * st.ewma_us + self.alpha * dur_us
            )
            peers = [
                o.ewma_us
                for k, o in self._links.items()
                if k[0] == key[0] and k != key and o.samples >= self.min_samples
            ]
            peer_us = statistics.median(peers) if peers else 0.0
            flagged = (
                st.samples >= self.min_samples
                and peer_us > 0.0
                and st.ewma_us > self.threshold * peer_us
            )
            if flagged:
                st.consecutive += 1
                st.flags += 1
            else:
                st.consecutive = 0
            new_report = flagged and st.consecutive == self.report_after
            report = flagged and st.consecutive >= self.report_after
            if report:
                self._reports[key] = {
                    "axis": key[0], "src": key[1], "dst": key[2],
                    "ewma_us": st.ewma_us, "peer_us": peer_us,
                    "consecutive": st.consecutive, "samples": st.samples,
                }
            rep = self._reports.get(key)
        if new_report and rep is not None:
            self.recorder.record("straggler_link", **rep)
            obs_metrics.get_registry().counter(
                "repro_link_straggler_reports_total",
                "per-link straggler reports (rising edges)",
                labelnames=("axis", "src", "dst"),
            ).inc(axis=str(key[0]), src=str(key[1]), dst=str(key[2]))
            for cb in self._callbacks:
                cb(dict(rep))
        return {
            "flagged": flagged,
            "report": report,
            "ewma_us": st.ewma_us,
            "peer_us": peer_us,
        }

    def observe_spans(self, spans: Any) -> int:
        """Feed every ``link``-category span (as emitted by
        :class:`LinkProbeBackend`); returns how many were consumed."""
        n = 0
        for s in spans:
            if getattr(s, "cat", None) != "link":
                continue
            a = s.args
            self.observe(a["axis"], a["src"], a["dst"], s.dur_us)
            n += 1
        return n

    def reports(self) -> List[Dict[str, Any]]:
        """All links ever promoted to a straggler report (worst first)."""
        with self._lock:
            reps = [dict(r) for r in self._reports.values()]
        reps.sort(key=lambda r: r["ewma_us"] / max(r["peer_us"], 1e-9),
                  reverse=True)
        return reps

    def straggler(self) -> Optional[Dict[str, Any]]:
        """The worst reported link, or None."""
        reps = self.reports()
        return reps[0] if reps else None

    def summary(self) -> List[Dict[str, Any]]:
        """Per-link EWMA table (sorted by axis then ewma, slowest first)."""
        with self._lock:
            rows = [
                {
                    "axis": k[0], "src": k[1], "dst": k[2],
                    "ewma_us": st.ewma_us, "samples": st.samples,
                    "consecutive": st.consecutive, "flags": st.flags,
                }
                for k, st in self._links.items()
            ]
        rows.sort(key=lambda r: (r["axis"], -r["ewma_us"]))
        return rows


class LinkDelayInjector:
    """Synthetic per-link delay table for fault-injection tests.

    ``delays`` maps (axis, src, dst) -> seconds of ``time.sleep`` added
    inside that link's probe span. Sleeping changes *timing only* — the
    permuted values are untouched, which is what lets the health check
    assert bitwise-identical results with the injector active.

    The general fault mechanism is ``repro.runtime.chaos.ChaosInjector``,
    which implements this exact ``delays``/``set_delay``/``delay``
    protocol (so it drops into ``Tracer(link_injector=...)`` unchanged)
    and adds seeded drop/duplicate/reorder/corrupt faults with rate
    schedules. This class stays as the dependency-free delay-only table
    (obs must not import the runtime package).
    """

    def __init__(self, delays: Optional[Dict[LinkKey, float]] = None):
        self.delays: Dict[LinkKey, float] = {
            (int(a), int(s), int(d)): float(v)
            for (a, s, d), v in (delays or {}).items()
        }

    def set_delay(self, axis: int, src: int, dst: int, seconds: float) -> None:
        self.delays[(int(axis), int(src), int(dst))] = float(seconds)

    def delay(self, axis: int, src: int, dst: int) -> float:
        return self.delays.get((int(axis), int(src), int(dst)), 0.0)


class LinkProbeBackend:
    """Decompose each sim round's permute into per-link probed messages.

    Sits *under* :class:`~repro.obs.tracing.TracingBackend` in the traced
    sim interpreter (round span parent, link spans children). The full
    permute ``[(s0,d0),(s1,d1),...]`` becomes one single-pair permute per
    message, each timed in a ``link``-category span carrying
    ``(axis, src, dst, round)``, then merged exactly: destination row
    ``d`` is *set* (not accumulated) from the pair result that carries
    it, which reproduces the vectorized permute bit-for-bit (same zero
    fill, same row writes — sign of zero included). Per-pair timing is
    what makes (axis, src, dst) attribution possible at all: the
    vectorized round is one XLA op covering every same-distance link
    simultaneously.

    Probing costs one dispatch per message instead of one per round, so
    it is opt-in via ``Tracer(link_probe=True)`` — a diagnosis mode, not
    the default traced path. ``injector`` adds synthetic delay;
    ``detector`` gets a live ``observe`` per message.
    """

    def __init__(
        self,
        inner: Any,
        tracer: Any,
        *,
        level: int = 0,
        injector: Optional[LinkDelayInjector] = None,
        detector: Optional[LinkStragglerDetector] = None,
    ):
        self.inner = inner
        self.tracer = tracer
        self.level = int(level)
        self.injector = injector
        self.detector = detector
        self.rounds = 0

    @property
    def p(self) -> int:
        return self.inner.p

    def rank(self):
        return self.inner.rank()

    def permute(self, tree: Any, perm: Any) -> Any:
        import jax

        pairs = [(int(s), int(d)) for s, d in perm]
        rnd = self.rounds
        self.rounds += 1
        if not pairs:
            return self.inner.permute(tree, perm)
        out = None
        for src, dst in pairs:
            delay_s = (
                self.injector.delay(self.level, src, dst)
                if self.injector is not None else 0.0
            )
            t0 = obs_tracing.now_us()
            with self.tracer.span(
                f"plan.link:L{self.level}:{src}->{dst}",
                "link",
                axis=self.level,
                src=src,
                dst=dst,
                round=rnd,
            ):
                if delay_s > 0.0:
                    time.sleep(delay_s)
                part = obs_tracing._block(
                    self.inner.permute(tree, [(src, dst)])
                )
            dur_us = obs_tracing.now_us() - t0
            if self.detector is not None:
                self.detector.observe(self.level, src, dst, dur_us)
            if out is None:
                out = part
            else:
                out = jax.tree.map(
                    lambda o, q, _d=dst: o.at[_d].set(q[_d]), out, part
                )
        return out
