"""Flight recorder: a bounded, always-on ring buffer of structured events.

Spans (:mod:`repro.obs.tracing`) answer "where did the time go?" for one
traced request; metrics (:mod:`repro.obs.metrics`) answer "how much, in
aggregate?". Neither helps when a process crashes or an SLO burns at
3am and the question is "what was the stack *doing* just before?" — the
tracer is off by default and metrics have no ordering. The flight
recorder fills that gap the way an aircraft FDR does: a fixed-size ring
of the last N structured events, recording **always**, cheap enough that
no one ever wants to turn it off, dumpable to JSON on demand and
automatically on crash/recovery.

Event kinds recorded by the stack (the open schema — extra fields are
free-form per kind, every event also carries ``seq``, ``t`` (epoch
seconds) and ``ts_us`` (perf_counter µs, same clock as spans)):

========================  ====================================================
kind                      fields
========================  ====================================================
``dispatch``              coll, cache ("hit"/"miss"), latency_us
``cache_miss``            coll, scope ("schedule"/"plan")
``backend_fallback``      coll, backend, reason
``profiler_fallback``     reason
``deadline_miss``         tenant, coll, group, queue_wait_s, overrun_s
``flush``                 reason, groups, requests
``remesh``                old_axes, new_axes
``recovery``              error, step
``retune``                axes, budget_s
``straggler_flag``        step, dt, ewma
``straggler_evict``       step, consecutive
``straggler_link``        axis, src, dst, ewma_us, peer_us, consecutive
``slo_alert``             slo, key, burn_fast, burn_slow
``dump``                  reason, path
``chaos_fault``           fault, axis, src, dst, msg, silent
``integrity_fail``        request, scope ("payload"/"wire")
``retry``                 backend, coll, attempt, error
``degrade``               coll, frm, to, error
``breaker_open``          backend, coll, consecutive
``breaker_half_open``     backend, coll, consecutive
``breaker_closed``        backend, coll, consecutive
``breaker_skip``          backend, coll, stage, of
``bisect``                coll, requests, error
``quarantine``            tenant, seqno, coll, error
========================  ====================================================

The recorder is process-global (:func:`get_recorder` /
:func:`record`), like the metrics registry. ``$REPRO_FLIGHT_RECORD``
(or :func:`set_auto_dump_path`) names a JSON file that
:func:`auto_dump` writes on crash/recovery paths — wired into
``runtime.fault.notify_remesh`` and the trainer's recovery loop — so a
post-mortem always has the last seconds of engine history.

Cost: ``record()`` is one lock acquire + deque append of a small tuple.
``benchmarks/obs_overhead.py`` measures the recorder-on vs recorder-off
dispatch path and CI gates the overhead (must stay ≤ 2%).
"""

from __future__ import annotations

import collections
import json
import os
import threading
import time
from pathlib import Path
from typing import Any, Deque, Dict, List, Optional, Tuple

__all__ = [
    "DEFAULT_CAPACITY",
    "FlightRecorder",
    "auto_dump",
    "auto_dump_path",
    "get_recorder",
    "record",
    "set_auto_dump_path",
    "set_recorder",
]

DEFAULT_CAPACITY = 4096

#: environment variable naming the auto-dump JSON file
AUTO_DUMP_ENV = "REPRO_FLIGHT_RECORD"


class FlightRecorder:
    """Bounded thread-safe ring buffer of ``(seq, t, ts_us, kind, fields)``.

    Always on: the hot path is one lock + one ``deque.append`` (the deque
    evicts the oldest event itself at capacity), so instrumented code
    calls :meth:`record` unconditionally. Reads (:meth:`events`,
    :meth:`snapshot`, :meth:`dump`) materialize dicts under the same lock.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.capacity = int(capacity)
        self._buf: Deque[Tuple[int, float, float, str, Dict[str, Any]]] = (
            collections.deque(maxlen=self.capacity)
        )
        self._lock = threading.Lock()
        self._seq = 0
        self._counts: Dict[str, int] = {}

    # -- hot path ----------------------------------------------------------

    def record(self, kind: str, **fields: Any) -> None:
        """Append one event. Cheap by design: no formatting, no I/O."""
        t = time.time()
        ts_us = time.perf_counter() * 1e6
        with self._lock:
            self._seq += 1
            self._counts[kind] = self._counts.get(kind, 0) + 1
            self._buf.append((self._seq, t, ts_us, kind, fields))

    # -- reading -----------------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)

    def events(
        self, kind: Optional[str] = None, limit: Optional[int] = None
    ) -> List[Dict[str, Any]]:
        """The retained events (oldest first) as dicts, optionally filtered
        by ``kind`` and truncated to the newest ``limit``."""
        with self._lock:
            raw = list(self._buf)
        out = [
            {"seq": seq, "t": t, "ts_us": ts_us, "kind": k, **f}
            for seq, t, ts_us, k, f in raw
            if kind is None or k == kind
        ]
        if limit is not None:
            out = out[-int(limit):]
        return out

    def counts(self) -> Dict[str, int]:
        """Total events recorded per kind (including evicted ones)."""
        with self._lock:
            return dict(self._counts)

    def snapshot(self, reason: str = "") -> Dict[str, Any]:
        """The full dump payload: config, per-kind totals, retained ring."""
        with self._lock:
            raw = list(self._buf)
            recorded = self._seq
            counts = dict(self._counts)
        return {
            "reason": reason,
            "wall_time": time.time(),
            "capacity": self.capacity,
            "recorded": recorded,
            "evicted": recorded - len(raw),
            "counts": counts,
            "events": [
                {"seq": seq, "t": t, "ts_us": ts_us, "kind": k, **f}
                for seq, t, ts_us, k, f in raw
            ],
        }

    def to_json(self, reason: str = "") -> str:
        return json.dumps(self.snapshot(reason), indent=1, default=str)

    def dump(
        self, path: Optional[os.PathLike] = None, reason: str = ""
    ) -> Dict[str, Any]:
        """Snapshot the ring; when ``path`` is given also write it as JSON.

        Never raises on I/O problems — a broken dump path must not take
        down the recovery path that asked for the dump; the failure is
        recorded into the ring instead.
        """
        snap = self.snapshot(reason)
        if path is not None:
            try:
                p = Path(path)
                if p.parent and not p.parent.exists():
                    p.parent.mkdir(parents=True, exist_ok=True)
                p.write_text(json.dumps(snap, indent=1, default=str))
                self.record("dump", reason=reason, path=str(p))
            except OSError as e:
                self.record(
                    "dump", reason=reason, path=str(path), error=str(e)
                )
        return snap

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self._counts.clear()
            self._seq = 0


# -- the process-wide recorder (always on) -----------------------------------

_recorder = FlightRecorder()
_auto_dump_path: Optional[Path] = None


def get_recorder() -> FlightRecorder:
    return _recorder


def set_recorder(rec: Optional[FlightRecorder]) -> FlightRecorder:
    """Install ``rec`` (None installs a fresh default); returns previous."""
    global _recorder
    prev = _recorder
    _recorder = FlightRecorder() if rec is None else rec
    return prev


def record(kind: str, **fields: Any) -> None:
    """Record one event into the process-wide flight recorder."""
    _recorder.record(kind, **fields)


def set_auto_dump_path(path: Optional[os.PathLike]) -> None:
    """Explicitly set (or clear) the crash/recovery auto-dump target;
    overrides ``$REPRO_FLIGHT_RECORD``."""
    global _auto_dump_path
    _auto_dump_path = None if path is None else Path(path)


def auto_dump_path() -> Optional[Path]:
    if _auto_dump_path is not None:
        return _auto_dump_path
    env = os.environ.get(AUTO_DUMP_ENV, "").strip()
    return Path(env) if env else None


def auto_dump(reason: str) -> Optional[Path]:
    """Dump the recorder to the configured path, if any. Called from
    crash/recovery paths (remesh notification, trainer recovery); a no-op
    when no path is configured so those paths stay dependency-free."""
    path = auto_dump_path()
    if path is None:
        return None
    _recorder.dump(path, reason=reason)
    return path
