"""Common metrics registry: counters, gauges, histograms, Prometheus text.

:class:`~repro.offload.engine.EngineTelemetry` and
:class:`~repro.service.telemetry.ServiceTelemetry` keep their existing
snapshot dicts untouched; this module gives them (and the tracing layer) a
*shared* registry to ALSO publish into, so one scrape shows the whole
stack. Metric names follow the Prometheus conventions
(``repro_<subsystem>_<thing>_<unit>``); the catalogue lives in README's
Observability section.

Key series:

  * ``repro_engine_dispatches_total{coll=...}`` / ``..._cache_hits_total``
    / ``..._compiles_total`` — the engine's NIC status registers;
  * ``repro_engine_profiler_fallbacks_total{reason=...}`` — every time a
    profiled dispatch degraded to the wall-clock source (alerting on
    profiler degradation instead of quietly trusting wall numbers);
  * ``repro_service_requests_total{tenant=..., outcome=...}`` and
    ``repro_service_request_latency_us{tenant=...}`` — the broker's
    per-tenant view;
  * ``repro_round_latency_us{coll=..., phase_kind=..., round_bucket=...}``
    — the per-round host-constant attribution from traced sim dispatches:
    round indices bucket as 0,1,2,3,"4-7","8-15",... so the label set
    stays bounded while still separating early rounds (where the fused
    schedule's extra payload lives) from the tail.

Everything is thread-safe (one lock per registry) and dependency-free.
:func:`render_prometheus` emits the text exposition format
(``# HELP`` / ``# TYPE`` + samples), suitable for a file-based scrape or a
trivial HTTP handler.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "ROUND_LATENCY_BUCKETS_US",
    "get_registry",
    "render_prometheus",
    "reset_registry",
    "round_bucket",
    "set_registry",
]

#: default histogram bucket upper bounds (microseconds; +Inf is implicit)
ROUND_LATENCY_BUCKETS_US: Tuple[float, ...] = (
    5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1e3, 2.5e3, 5e3, 1e4,
    5e4, 1e5,
)

LabelValues = Tuple[str, ...]


def round_bucket(index: int) -> str:
    """Bucket a round index for the ``round_bucket`` label: rounds 0-3 are
    individually labeled, then power-of-two ranges ("4-7", "8-15", ...)."""
    index = int(index)
    if index < 4:
        return str(index)
    lo = 1 << index.bit_length() - 1
    return f"{lo}-{2 * lo - 1}"


def _escape(value: str) -> str:
    return (
        str(value)
        .replace("\\", "\\\\")
        .replace("\n", "\\n")
        .replace('"', '\\"')
    )


def _fmt_labels(names: Sequence[str], values: LabelValues) -> str:
    if not names:
        return ""
    inner = ",".join(
        f'{n}="{_escape(v)}"' for n, v in zip(names, values)
    )
    return "{" + inner + "}"


class _Metric:
    """Shared labeled-series plumbing."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: Sequence[str]):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()

    def _key(self, labels: Dict[str, Any]) -> LabelValues:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: labels {sorted(labels)} != declared "
                f"{sorted(self.labelnames)}"
            )
        return tuple(str(labels[n]) for n in self.labelnames)


class Counter(_Metric):
    """Monotonically increasing per-label-set total."""

    kind = "counter"

    def __init__(self, name, help="", labelnames=()):
        super().__init__(name, help, labelnames)
        self._values: Dict[LabelValues, float] = {}

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels: Any) -> float:
        with self._lock:
            return self._values.get(self._key(labels), 0.0)

    def collect(self) -> Dict[LabelValues, float]:
        with self._lock:
            return dict(self._values)

    def render(self) -> List[str]:
        lines = []
        for key, v in sorted(self.collect().items()):
            lines.append(
                f"{self.name}{_fmt_labels(self.labelnames, key)} {_num(v)}"
            )
        return lines


class Gauge(_Metric):
    """Set-to-current-value metric."""

    kind = "gauge"

    def __init__(self, name, help="", labelnames=()):
        super().__init__(name, help, labelnames)
        self._values: Dict[LabelValues, float] = {}

    def set(self, value: float, **labels: Any) -> None:
        with self._lock:
            self._values[self._key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels: Any) -> float:
        with self._lock:
            return self._values.get(self._key(labels), 0.0)

    def collect(self) -> Dict[LabelValues, float]:
        with self._lock:
            return dict(self._values)

    def render(self) -> List[str]:
        return [
            f"{self.name}{_fmt_labels(self.labelnames, key)} {_num(v)}"
            for key, v in sorted(self.collect().items())
        ]


class Histogram(_Metric):
    """Cumulative-bucket histogram (Prometheus semantics: ``le`` buckets
    are cumulative, ``+Inf`` == count)."""

    kind = "histogram"

    def __init__(
        self,
        name,
        help="",
        labelnames=(),
        buckets: Sequence[float] = ROUND_LATENCY_BUCKETS_US,
    ):
        super().__init__(name, help, labelnames)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError(f"histogram {name} needs at least one bucket")
        self._counts: Dict[LabelValues, List[int]] = {}
        self._sums: Dict[LabelValues, float] = {}

    def observe(self, value: float, **labels: Any) -> None:
        key = self._key(labels)
        value = float(value)
        with self._lock:
            counts = self._counts.get(key)
            if counts is None:
                counts = self._counts[key] = [0] * (len(self.buckets) + 1)
            for i, edge in enumerate(self.buckets):
                if value <= edge:
                    counts[i] += 1
                    break
            else:
                counts[-1] += 1
            self._sums[key] = self._sums.get(key, 0.0) + value

    def collect(self) -> Dict[LabelValues, Dict[str, Any]]:
        with self._lock:
            out = {}
            for key, counts in self._counts.items():
                out[key] = {
                    "buckets": list(counts),
                    "sum": self._sums.get(key, 0.0),
                    "count": sum(counts),
                }
            return out

    def render(self) -> List[str]:
        lines = []
        for key, data in sorted(self.collect().items()):
            cum = 0
            for i, edge in enumerate(self.buckets):
                cum += data["buckets"][i]
                labels = dict(zip(self.labelnames, key))
                labels["le"] = _num(edge)
                names = tuple(self.labelnames) + ("le",)
                values = key + (_num(edge),)
                lines.append(
                    f"{self.name}_bucket{_fmt_labels(names, values)} {cum}"
                )
            names = tuple(self.labelnames) + ("le",)
            values = key + ("+Inf",)
            lines.append(
                f"{self.name}_bucket{_fmt_labels(names, values)} "
                f"{data['count']}"
            )
            lines.append(
                f"{self.name}_sum{_fmt_labels(self.labelnames, key)} "
                f"{_num(data['sum'])}"
            )
            lines.append(
                f"{self.name}_count{_fmt_labels(self.labelnames, key)} "
                f"{data['count']}"
            )
        return lines


def _num(v: float) -> str:
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


class MetricsRegistry:
    """Name -> metric map with get-or-create constructors.

    Re-registering the same name must agree on kind and label names (a
    mismatch raises — two subsystems silently sharing one series under
    different schemas is how dashboards lie).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}

    def _get_or_create(self, cls, name, help, labelnames, **kw):
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                if not isinstance(existing, cls) or tuple(
                    existing.labelnames
                ) != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{existing.kind}{existing.labelnames}; requested "
                        f"{cls.kind}{tuple(labelnames)}"
                    )
                return existing
            metric = cls(name, help, labelnames, **kw)
            self._metrics[name] = metric
            return metric

    def counter(self, name, help="", labelnames=()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name, help="", labelnames=()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(
        self, name, help="", labelnames=(),
        buckets: Sequence[float] = ROUND_LATENCY_BUCKETS_US,
    ) -> Histogram:
        return self._get_or_create(
            Histogram, name, help, labelnames, buckets=buckets
        )

    def metrics(self) -> Dict[str, _Metric]:
        with self._lock:
            return dict(self._metrics)

    def collect(self) -> Dict[str, Any]:
        """Structured snapshot of every registered series."""
        return {
            name: {
                "kind": m.kind,
                "help": m.help,
                "labelnames": list(m.labelnames),
                "series": {
                    ",".join(k) if k else "": v
                    for k, v in m.collect().items()
                },
            }
            for name, m in sorted(self.metrics().items())
        }

    def render(self) -> str:
        """Prometheus text exposition format."""
        out: List[str] = []
        for name, m in sorted(self.metrics().items()):
            if m.help:
                out.append(f"# HELP {name} {m.help}")
            out.append(f"# TYPE {name} {m.kind}")
            out.extend(m.render())
        return "\n".join(out) + ("\n" if out else "")


_default = MetricsRegistry()
_default_lock = threading.Lock()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry everything publishes into."""
    return _default


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Swap the default registry; returns the previous one."""
    global _default
    with _default_lock:
        prev = _default
        _default = registry
    return prev


def reset_registry() -> MetricsRegistry:
    """Install a fresh empty default registry (tests)."""
    return set_registry(MetricsRegistry()) and _default


def render_prometheus(registry: Optional[MetricsRegistry] = None) -> str:
    """Text exposition of ``registry`` (default: the process registry)."""
    return (get_registry() if registry is None else registry).render()


# -- canonical series helpers ------------------------------------------------


def observe_round(
    coll: str, phase_kind: str, round_index: int, dur_us: float,
    registry: Optional[MetricsRegistry] = None,
) -> None:
    """Record one traced communication round into the shared
    per-(coll, phase_kind, round-bucket) latency histogram."""
    reg = get_registry() if registry is None else registry
    reg.histogram(
        "repro_round_latency_us",
        "host-side latency of one traced communication round",
        labelnames=("coll", "phase_kind", "round_bucket"),
    ).observe(
        dur_us,
        coll=coll,
        phase_kind=phase_kind,
        round_bucket=round_bucket(round_index),
    )


def observe_phase(
    coll: str, phase_kind: str, dur_us: float,
    registry: Optional[MetricsRegistry] = None,
) -> None:
    """Record one traced plan phase's host-side latency."""
    reg = get_registry() if registry is None else registry
    reg.histogram(
        "repro_phase_latency_us",
        "host-side latency of one traced plan phase",
        labelnames=("coll", "phase_kind"),
    ).observe(dur_us, coll=coll, phase_kind=phase_kind)
