"""In-process span tracer: per-round latency attribution for the offload stack.

The paper's core evidence is a *measurement*: an on-NIC timer attributing
scan latency to the network device versus the host. Our software stack has
many more places for the time to hide — broker queue, coalescing window,
schedule-cache lookup, compilation, and the per-round host constant of the
sim interpreter — so this module provides lightweight host-side spans with
explicit parent links covering the full request lifecycle:

    service.submit  ->  broker.queue_wait  ->  broker.dispatch_group
      ->  engine.offload (cache hit/miss, engine.compile on miss)
        ->  plan.phase:<KIND>:L<level>   (one per PlanPhase)
          ->  plan.round:<i>             (one per communication round)

Span categories (``cat``): ``service``, ``broker``, ``engine``, ``phase``,
``round``, and — in link-probe mode (``Tracer(link_probe=True)``, see
:mod:`repro.obs.health`) — ``link``, one span per (src, dst) message of a
round. Timestamps are ``time.perf_counter()`` microseconds, one
monotonic clock for the whole process, so spans from every thread land on
one timeline; :mod:`repro.obs.export` serializes them to Chrome/Perfetto
trace JSON and can merge the device-side events a ``jax.profiler`` trace
records for the same dispatch.

**Tracing is off by default and zero-cost when off.** The module-level
tracer is a :class:`NoopTracer` whose ``span()`` returns one shared no-op
context manager — instrumented code paths pay a single attribute check.
Nothing about the dispatched computation changes either way: spans only
ever wrap *host-side* work. Jitted code paths (driver/spmd dispatch) get
spans around the dispatch, never inside traced computations; only the
eager sim interpreter (:func:`repro.offload.planner.lower_sim` with
``traced=True``) emits phase- and round-level spans, because there the
host genuinely pays a dispatch per round — exactly the constant the
ROADMAP wall-clock item needs attributed.

Usage::

    from repro.obs import tracing

    with tracing.tracing() as tracer:        # installs + restores
        engine.offload(desc, x)              # sim dispatch -> round spans
    spans = tracer.spans()
"""

from __future__ import annotations

import contextlib
import dataclasses
import itertools
import threading
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple

__all__ = [
    "NoopTracer",
    "Span",
    "Tracer",
    "TracingBackend",
    "add_kernel_round_spans",
    "get_tracer",
    "install_tracer",
    "now_us",
    "set_tracer",
    "tracing",
]


def now_us() -> float:
    """The tracer clock: ``perf_counter`` microseconds (process-monotonic)."""
    return time.perf_counter() * 1e6


@dataclasses.dataclass
class Span:
    """One closed span. ``start_us``/``dur_us`` are perf_counter µs."""

    name: str
    cat: str
    start_us: float
    dur_us: float
    span_id: int
    parent_id: Optional[int] = None
    tid: int = 0
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def end_us(self) -> float:
        return self.start_us + self.dur_us


class _OpenSpan:
    """Mutable in-flight span handle yielded by :meth:`Tracer.span`."""

    __slots__ = ("name", "cat", "span_id", "parent_id", "start_us", "args")

    def __init__(self, name, cat, span_id, parent_id, start_us, args):
        self.name = name
        self.cat = cat
        self.span_id = span_id
        self.parent_id = parent_id
        self.start_us = start_us
        self.args = args

    def set(self, **kw: Any) -> None:
        """Attach/overwrite span args while the span is open."""
        self.args.update(kw)


class _NullSpan:
    """The disabled tracer's span handle/context manager: does nothing."""

    __slots__ = ()
    span_id = None
    parent_id = None

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> None:
        return None

    def set(self, **kw: Any) -> None:
        return None


_NULL_SPAN = _NullSpan()


class NoopTracer:
    """The default tracer: disabled, allocation-free on the hot path."""

    enabled = False

    def span(self, name: str, cat: str = "host", **args: Any) -> _NullSpan:
        return _NULL_SPAN

    def add_span(self, *a: Any, **kw: Any) -> None:
        return None

    def spans(self) -> Tuple[Span, ...]:
        return ()

    def clear(self) -> None:
        return None

    def current_span_id(self) -> Optional[int]:
        return None


class Tracer:
    """Collecting tracer: thread-safe append, per-thread parent stacks.

    Parent links resolve from context-manager nesting on each thread; spans
    that cross threads (e.g. ``broker.queue_wait``, which starts on the
    client thread and ends on the dispatch thread) are recorded after the
    fact via :meth:`add_span` with an explicit ``parent_id``.
    """

    enabled = True

    def __init__(
        self,
        *,
        max_spans: int = 200_000,
        link_probe: bool = False,
        link_injector: Optional[Any] = None,
        link_detector: Optional[Any] = None,
    ):
        self._lock = threading.Lock()
        self._spans: List[Span] = []
        self._ids = itertools.count(1)
        self._tls = threading.local()
        self.max_spans = int(max_spans)
        self.dropped = 0
        # Link-probe mode (see repro.obs.health.LinkProbeBackend): when
        # set, the traced sim interpreter decomposes each round's permute
        # into per-(src, dst) messages and emits one "link"-category child
        # span per message — the data source for per-link straggler
        # attribution. Off by default: probing costs one dispatch per
        # message instead of one per round.
        self.link_probe = bool(link_probe)
        self.link_injector = link_injector
        self.link_detector = link_detector

    # -- recording ---------------------------------------------------------

    def _stack(self) -> List[int]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def current_span_id(self) -> Optional[int]:
        stack = self._stack()
        return stack[-1] if stack else None

    @contextlib.contextmanager
    def span(
        self,
        name: str,
        cat: str = "host",
        *,
        parent_id: Optional[int] = None,
        **args: Any,
    ) -> Iterator[_OpenSpan]:
        stack = self._stack()
        if parent_id is None and stack:
            parent_id = stack[-1]
        handle = _OpenSpan(
            name, cat, next(self._ids), parent_id, now_us(), dict(args)
        )
        stack.append(handle.span_id)
        try:
            yield handle
        finally:
            stack.pop()
            self._append(
                Span(
                    name=handle.name,
                    cat=handle.cat,
                    start_us=handle.start_us,
                    dur_us=now_us() - handle.start_us,
                    span_id=handle.span_id,
                    parent_id=handle.parent_id,
                    tid=threading.get_ident(),
                    args=handle.args,
                )
            )

    def add_span(
        self,
        name: str,
        cat: str,
        start_us: float,
        end_us: float,
        *,
        parent_id: Optional[int] = None,
        tid: Optional[int] = None,
        **args: Any,
    ) -> Optional[int]:
        """Record a span whose bounds were measured elsewhere (cross-thread
        waits, retroactive attribution). Returns the new span id."""
        span = Span(
            name=name,
            cat=cat,
            start_us=float(start_us),
            dur_us=max(0.0, float(end_us) - float(start_us)),
            span_id=next(self._ids),
            parent_id=parent_id,
            tid=threading.get_ident() if tid is None else tid,
            args=dict(args),
        )
        self._append(span)
        return span.span_id

    def _append(self, span: Span) -> None:
        with self._lock:
            if len(self._spans) >= self.max_spans:
                self.dropped += 1
                return
            self._spans.append(span)

    # -- reading -----------------------------------------------------------

    def spans(self) -> Tuple[Span, ...]:
        with self._lock:
            return tuple(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self.dropped = 0


# -- the process-wide active tracer (default: disabled) ----------------------

NOOP = NoopTracer()
_active: "Tracer | NoopTracer" = NOOP
_active_lock = threading.Lock()


def get_tracer() -> "Tracer | NoopTracer":
    """The active tracer. Instrumented code calls this per operation; with
    the default :data:`NOOP` installed the whole call chain is a couple of
    attribute reads."""
    return _active


def set_tracer(tracer: "Tracer | NoopTracer | None") -> "Tracer | NoopTracer":
    """Install ``tracer`` (None restores the no-op); returns the previous."""
    global _active
    with _active_lock:
        prev = _active
        _active = NOOP if tracer is None else tracer
    return prev


def install_tracer(**kw: Any) -> Tracer:
    """Install and return a fresh collecting tracer."""
    tracer = Tracer(**kw)
    set_tracer(tracer)
    return tracer


@contextlib.contextmanager
def tracing(tracer: Optional[Tracer] = None) -> Iterator[Tracer]:
    """Context manager: install a (fresh by default) tracer, restore the
    previous one on exit."""
    tracer = Tracer() if tracer is None else tracer
    prev = set_tracer(tracer)
    try:
        yield tracer
    finally:
        set_tracer(prev)


class TracingBackend:
    """Wrap a schedule backend so every ``permute`` is one ``round`` span.

    A communication *round* in every schedule in :mod:`repro.core.algorithms`
    is exactly one ``backend.permute`` call (opposite-direction permutes of
    the fused schedule count as one full-duplex round each — they appear as
    two adjacent spans sharing a round index only when the schedule really
    issues two permutes). The wrapper blocks on the permuted result so the
    span's duration is the *host-side cost of that round* — dispatch,
    transfer, sync — the per-round constant the ROADMAP wall-clock item
    wants attributed. Only meaningful on the eager sim backend: inside jit
    there is no per-round host work to measure, and this wrapper must never
    be used there.

    Chunked-streaming schedules (:func:`repro.core.algorithms._pipeline`)
    announce the (chunk, schedule-round) coordinates of each pipeline slot
    via :meth:`set_chunk_context` before issuing its permute; while set,
    round spans carry ``chunk`` and ``chunk_round`` args, so the per-round
    cost table can attribute time per (round, chunk) cell. Unchunked
    schedules never call it and their spans are arg-for-arg what they were
    before chunking existed.
    """

    def __init__(
        self,
        inner: Any,
        tracer: "Tracer | NoopTracer",
        *,
        phase: str = "",
        on_round: Optional[Any] = None,
    ):
        self.inner = inner
        self.tracer = tracer
        self.phase = phase
        self.on_round = on_round
        self.rounds = 0
        self._chunk = -1
        self._chunk_round = -1

    @property
    def p(self) -> int:
        return self.inner.p

    def rank(self):
        return self.inner.rank()

    def set_chunk_context(self, chunk: int, rnd: int) -> None:
        """Label subsequent rounds with pipeline coordinates (-1 clears)."""
        self._chunk = int(chunk)
        self._chunk_round = int(rnd)

    def permute(self, tree: Any, perm: Any) -> Any:
        idx = self.rounds
        self.rounds += 1
        extra: Dict[str, Any] = {}
        if self._chunk >= 0:
            extra = {"chunk": self._chunk, "chunk_round": self._chunk_round}
        t0 = now_us()
        with self.tracer.span(
            f"plan.round:{idx}",
            "round",
            round=idx,
            phase=self.phase,
            messages=len(perm),
            **extra,
        ):
            out = self.inner.permute(tree, perm)
            out = _block(out)
        if self.on_round is not None:
            self.on_round(idx, now_us() - t0)
        return out


def add_kernel_round_spans(
    tracer: "Tracer | NoopTracer",
    *,
    phase: str,
    coll: str,
    rounds: int,
    start_us: float,
    end_us: float,
) -> Optional[int]:
    """Record phase + round spans for a *fused-kernel* phase after the fact.

    The pallas backend runs every exchange round of a phase inside one
    kernel, so there is no host-side per-round boundary to wrap a span
    around — the only measurable quantity is the whole kernel's wall time.
    This helper keeps the trace schema uniform anyway: one ``phase``-category
    span over ``[start_us, end_us]`` plus ``rounds`` contiguous child
    ``round`` spans splitting the interval evenly, all tagged
    ``source="pallas"`` and ``attribution="uniform"`` so downstream
    consumers (the per-round cost table, trace exports) can tell a measured
    host round from a kernel-amortized estimate. Returns the phase span id
    (None on the no-op tracer).
    """
    if not tracer.enabled:
        return None
    n = max(0, int(rounds))
    phase_id = tracer.add_span(
        f"plan.phase:{phase}",
        "phase",
        start_us,
        end_us,
        parent_id=tracer.current_span_id(),
        coll=coll,
        rounds=n,
        source="pallas",
    )
    if n:
        step = (float(end_us) - float(start_us)) / n
        for i in range(n):
            tracer.add_span(
                f"plan.round:{i}",
                "round",
                start_us + i * step,
                start_us + (i + 1) * step,
                parent_id=phase_id,
                round=i,
                phase=phase,
                source="pallas",
                attribution="uniform",
            )
    return phase_id


def _block(tree: Any) -> Any:
    import jax

    return jax.tree.map(
        lambda a: a.block_until_ready() if hasattr(a, "block_until_ready")
        else a,
        tree,
    )
