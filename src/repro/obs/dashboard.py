"""Operator dashboard: text rendering + a stdlib HTTP scrape endpoint.

The last mile of the observability stack: everything the flight
recorder, metrics registry, health monitor, and telemetry snapshots
already know, in two operator-consumable forms —

* :func:`render_dashboard` — a fixed-width text panel (engine cache and
  latency, per-tenant service table, SLO burn rates, straggler links,
  the tail of the flight recorder). ``repro.launch.offload_runtime
  --dashboard`` prints it after a run.
* :func:`start_http_server` — a ``http.server`` daemon thread serving

  ============  ==========================================================
  endpoint      payload
  ============  ==========================================================
  ``/healthz``  :meth:`HealthMonitor.healthz` JSON; HTTP 200 when ``ok``,
                503 while any SLO alert or straggler report is active
  ``/metrics``  Prometheus text exposition (the existing
                :func:`repro.obs.metrics.render_prometheus`)
  ``/events``   flight-recorder ring as JSON (``?kind=`` filter,
                ``?limit=`` newest-N)
  ``/``         the text dashboard
  ============  ==========================================================

Stdlib only (``http.server`` + ``threading``): no new dependencies, and
binding port 0 lets tests grab an ephemeral port.
"""

from __future__ import annotations

import json
import threading
import urllib.parse
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, List, Optional

from repro.obs import events as obs_events
from repro.obs import metrics as obs_metrics

__all__ = [
    "DashboardServer",
    "render_dashboard",
    "start_http_server",
]


def _rule(title: str, width: int) -> str:
    pad = max(0, width - len(title) - 4)
    return f"-- {title} " + "-" * pad


def _table(rows: List[List[str]], header: List[str]) -> List[str]:
    cols = [header] + rows
    widths = [max(len(str(r[i])) for r in cols) for i in range(len(header))]
    out = ["  ".join(str(h).ljust(w) for h, w in zip(header, widths))]
    for r in rows:
        out.append("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    return out


def render_dashboard(
    *,
    engine: Any = None,
    broker: Any = None,
    monitor: Any = None,
    recorder: Optional[obs_events.FlightRecorder] = None,
    events_tail: int = 12,
    width: int = 76,
) -> str:
    """One text panel over whatever subset of the stack is wired in.

    ``engine``/``broker`` are the live objects (their ``telemetry``
    attributes are snapshotted); every argument is optional and a
    missing one just drops its section.
    """
    # `is None` check, not `or`: an *empty* FlightRecorder is falsy
    if recorder is None:
        recorder = obs_events.get_recorder()
    lines: List[str] = ["=" * width, "offload stack dashboard".center(width),
                        "=" * width]
    if engine is not None:
        t = engine.telemetry.snapshot()
        lines.append(_rule("engine", width))
        lines.append(
            f"dispatches {t['dispatches']}  cache {t['hits']}h/"
            f"{t['misses']}m (hit rate {t['hit_rate']:.2f})  "
            f"size {t['cache_size']}  compiles {t['compiles']}  "
            f"errors {t['errors']}"
        )
        lines.append(
            f"backend fallbacks {t['backend_fallbacks']}  "
            f"profiler fallbacks {t['profiler_fallbacks']}"
        )
        if t["latency_by_coll_us"]:
            rows = [
                [coll, f"{us:.0f}",
                 f"{t['device_latency_by_coll_us'].get(coll, 0.0):.0f}",
                 t["latency_source_by_coll"].get(coll, "-")]
                for coll, us in sorted(t["latency_by_coll_us"].items())
            ]
            lines += _table(rows, ["coll", "wall_us", "device_us", "source"])
    if broker is not None:
        t = broker.telemetry.snapshot()
        lines.append(_rule("service", width))
        lines.append(
            f"flushes {t['flushes']} (deadline {t['deadline_flushes']})  "
            f"coalesce {t['coalesce_factor']:.2f} "
            f"({t['fused_requests']} req / {t['fused_dispatches']} disp)"
        )
        rows = [
            [name, ts["submitted"], ts["completed"], ts["rejected"],
             ts["errors"], ts["deadline_missed"],
             f"{ts['latency']['p50_us']:.0f}",
             f"{ts['latency']['p99_us']:.0f}"]
            for name, ts in sorted(t["tenants"].items())
        ]
        if rows:
            lines += _table(
                rows,
                ["tenant", "sub", "done", "rej", "err", "miss",
                 "p50_us", "p99_us"],
            )
    if monitor is not None:
        hz = monitor.healthz()
        lines.append(_rule(f"health: {hz['status'].upper()}", width))
        for a in hz["alerts"]:
            lines.append(
                f"ALERT {a['slo']}[{a['key']}] burn "
                f"fast={a['burn_fast']:.1f}x slow={a['burn_slow']:.1f}x"
            )
        for s in hz["stragglers"]:
            lines.append(
                f"STRAGGLER link axis={s['axis']} {s['src']}->{s['dst']} "
                f"ewma {s['ewma_us']:.0f}us vs peers {s['peer_us']:.0f}us"
            )
        if not hz["alerts"] and not hz["stragglers"]:
            lines.append(f"all {len(hz['slos'])} SLOs within budget")
    lines.append(_rule("flight recorder", width))
    counts = recorder.counts()
    lines.append(
        f"{len(recorder)}/{recorder.capacity} events retained; totals: "
        + (", ".join(f"{k}={v}" for k, v in sorted(counts.items())) or "none")
    )
    for e in recorder.events(limit=events_tail):
        extras = {
            k: v for k, v in e.items()
            if k not in ("seq", "t", "ts_us", "kind")
        }
        body = " ".join(f"{k}={v}" for k, v in extras.items())
        lines.append(f"  [{e['seq']:>6}] {e['kind']:<18} {body}"[:width])
    lines.append("=" * width)
    return "\n".join(lines)


class DashboardServer:
    """A running scrape endpoint; ``close()`` (or context-exit) stops it."""

    def __init__(self, server: ThreadingHTTPServer, thread: threading.Thread):
        self.server = server
        self.thread = thread

    @property
    def port(self) -> int:
        return self.server.server_address[1]

    @property
    def url(self) -> str:
        host = self.server.server_address[0]
        return f"http://{host}:{self.port}"

    def close(self) -> None:
        self.server.shutdown()
        self.server.server_close()
        self.thread.join(timeout=5.0)

    def __enter__(self) -> "DashboardServer":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


def start_http_server(
    host: str = "127.0.0.1",
    port: int = 0,
    *,
    engine: Any = None,
    broker: Any = None,
    monitor: Any = None,
    recorder: Optional[obs_events.FlightRecorder] = None,
    registry: Optional[obs_metrics.MetricsRegistry] = None,
) -> DashboardServer:
    """Serve ``/healthz`` + ``/metrics`` + ``/events`` + the dashboard on a
    daemon thread. ``port=0`` binds an ephemeral port (see ``.url``)."""

    # `is None` check, not `or`: an *empty* FlightRecorder is falsy
    rec = recorder if recorder is not None else obs_events.get_recorder()

    class Handler(BaseHTTPRequestHandler):
        def log_message(self, *a: Any) -> None:  # keep test output clean
            return None

        def _send(
            self, body: str, status: int = 200,
            ctype: str = "application/json",
        ) -> None:
            data = body.encode()
            self.send_response(status)
            self.send_header("Content-Type", f"{ctype}; charset=utf-8")
            self.send_header("Content-Length", str(len(data)))
            self.end_headers()
            self.wfile.write(data)

        def do_GET(self) -> None:  # noqa: N802 - http.server contract
            parsed = urllib.parse.urlparse(self.path)
            q = urllib.parse.parse_qs(parsed.query)
            try:
                if parsed.path == "/healthz":
                    hz: Dict[str, Any] = (
                        monitor.healthz() if monitor is not None
                        else {"status": "ok", "alerts": [], "stragglers": []}
                    )
                    self._send(
                        json.dumps(hz, default=str),
                        status=200 if hz["status"] == "ok" else 503,
                    )
                elif parsed.path == "/metrics":
                    self._send(
                        obs_metrics.render_prometheus(
                            registry or obs_metrics.get_registry()
                        ),
                        ctype="text/plain",
                    )
                elif parsed.path == "/events":
                    kind = q.get("kind", [None])[0]
                    limit = q.get("limit", [None])[0]
                    self._send(
                        json.dumps(
                            {
                                "counts": rec.counts(),
                                "events": rec.events(
                                    kind=kind,
                                    limit=int(limit) if limit else None,
                                ),
                            },
                            default=str,
                        )
                    )
                elif parsed.path in ("/", "/dashboard"):
                    self._send(
                        render_dashboard(
                            engine=engine, broker=broker, monitor=monitor,
                            recorder=rec,
                        ),
                        ctype="text/plain",
                    )
                else:
                    self._send(json.dumps({"error": "not found"}), status=404)
            except Exception as e:  # surface handler bugs to the scraper
                self._send(json.dumps({"error": str(e)}), status=500)

    server = ThreadingHTTPServer((host, port), Handler)
    server.daemon_threads = True
    thread = threading.Thread(
        target=server.serve_forever, name="repro-dashboard", daemon=True
    )
    thread.start()
    return DashboardServer(server, thread)
