"""Unified observability: spans, Chrome/Perfetto export, metrics registry.

Three pieces, one subsystem:

* :mod:`repro.obs.tracing` — in-process spans with parent links covering
  service submit -> broker -> engine dispatch -> plan phase -> comm round;
  a no-op tracer is installed by default so the instrumented hot paths are
  zero-cost until :func:`~repro.obs.tracing.install_tracer` (or the
  :func:`~repro.obs.tracing.tracing` context manager) enables collection.
* :mod:`repro.obs.export` — spans -> Chrome trace JSON (Perfetto-openable)
  and the host+device merge with ``jax.profiler`` traces.
* :mod:`repro.obs.metrics` — counter/gauge/histogram registry with
  Prometheus text exposition; engine/service telemetry publish here in
  addition to their existing snapshot dicts.

Plus the operational layer on top:

* :mod:`repro.obs.events` — the always-on bounded flight recorder of
  structured events (dispatch, cache miss, deadline miss, remesh, ...),
  dumpable to JSON on demand and automatically on crash/recovery.
* :mod:`repro.obs.health` — declarative SLOs with multi-window
  burn-rate alerting, and per-link straggler attribution over the
  round-span tracer's link probes.
* :mod:`repro.obs.dashboard` — text dashboard + stdlib HTTP endpoint
  (``/healthz``, ``/metrics``, ``/events``).
"""

from repro.obs.dashboard import render_dashboard, start_http_server
from repro.obs.events import (
    FlightRecorder,
    auto_dump,
    get_recorder,
    record,
    set_recorder,
)
from repro.obs.export import (
    chrome_to_spans,
    load_chrome_trace,
    merge_device_trace,
    spans_to_chrome,
    write_trace,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_registry,
    render_prometheus,
    reset_registry,
    round_bucket,
    set_registry,
)
from repro.obs.health import (
    SLO,
    HealthMonitor,
    LinkDelayInjector,
    LinkProbeBackend,
    LinkStragglerDetector,
    default_slos,
)
# NB: the submodules are the package attributes ``tracing`` / ``metrics`` /
# ``export``; the tracing() context manager is deliberately NOT re-exported
# here (it would shadow the submodule) — use ``repro.obs.tracing.tracing``.
from repro.obs.tracing import (
    NoopTracer,
    Span,
    Tracer,
    TracingBackend,
    get_tracer,
    install_tracer,
    now_us,
    set_tracer,
)

__all__ = [
    "Counter",
    "FlightRecorder",
    "Gauge",
    "HealthMonitor",
    "Histogram",
    "LinkDelayInjector",
    "LinkProbeBackend",
    "LinkStragglerDetector",
    "MetricsRegistry",
    "NoopTracer",
    "SLO",
    "Span",
    "Tracer",
    "TracingBackend",
    "auto_dump",
    "chrome_to_spans",
    "default_slos",
    "get_recorder",
    "get_registry",
    "get_tracer",
    "install_tracer",
    "load_chrome_trace",
    "merge_device_trace",
    "now_us",
    "record",
    "render_dashboard",
    "render_prometheus",
    "reset_registry",
    "round_bucket",
    "set_recorder",
    "set_registry",
    "set_tracer",
    "spans_to_chrome",
    "start_http_server",
    "write_trace",
]
