"""Unified observability: spans, Chrome/Perfetto export, metrics registry.

Three pieces, one subsystem:

* :mod:`repro.obs.tracing` — in-process spans with parent links covering
  service submit -> broker -> engine dispatch -> plan phase -> comm round;
  a no-op tracer is installed by default so the instrumented hot paths are
  zero-cost until :func:`~repro.obs.tracing.install_tracer` (or the
  :func:`~repro.obs.tracing.tracing` context manager) enables collection.
* :mod:`repro.obs.export` — spans -> Chrome trace JSON (Perfetto-openable)
  and the host+device merge with ``jax.profiler`` traces.
* :mod:`repro.obs.metrics` — counter/gauge/histogram registry with
  Prometheus text exposition; engine/service telemetry publish here in
  addition to their existing snapshot dicts.
"""

from repro.obs.export import (
    chrome_to_spans,
    load_chrome_trace,
    merge_device_trace,
    spans_to_chrome,
    write_trace,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_registry,
    render_prometheus,
    reset_registry,
    round_bucket,
    set_registry,
)
# NB: the submodules are the package attributes ``tracing`` / ``metrics`` /
# ``export``; the tracing() context manager is deliberately NOT re-exported
# here (it would shadow the submodule) — use ``repro.obs.tracing.tracing``.
from repro.obs.tracing import (
    NoopTracer,
    Span,
    Tracer,
    TracingBackend,
    get_tracer,
    install_tracer,
    now_us,
    set_tracer,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NoopTracer",
    "Span",
    "Tracer",
    "TracingBackend",
    "chrome_to_spans",
    "get_registry",
    "get_tracer",
    "install_tracer",
    "load_chrome_trace",
    "merge_device_trace",
    "now_us",
    "render_prometheus",
    "reset_registry",
    "round_bucket",
    "set_registry",
    "set_tracer",
    "spans_to_chrome",
    "write_trace",
]
