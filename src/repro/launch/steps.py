"""Step builders: jitted train_step / prefill / decode with full shardings.

These are shared by the real launchers (train.py / serve.py) and the dry-run:
the dry-run lowers exactly what the launcher would execute.

Two gradient-collective paths exist for training:

  * the default jitted step, where GSPMD derives the gradient reductions from
    the in/out shardings (raw XLA collectives); and
  * :func:`build_dp_train_step` — the *offloaded* path: the step runs under
    ``shard_map`` over the data-parallel mesh axes and every collective the
    application issues (gradient allreduce, metric means, the scan-shaped
    per-rank example offset) is an explicit
    :class:`~repro.core.packet.CollectiveDescriptor` dispatched through
    :class:`~repro.offload.OffloadEngine` — the paper's contract, with the
    *training step's own collectives* as the offloaded schedule rather than a
    side benchmark. Built with ``engine=None`` the same step body runs its
    collectives as raw per-axis ``lax`` reductions in the identical logical
    order, giving a bitwise reference for the engine path.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.packet import CollType
from repro.models import ModelApi, input_specs
from repro.offload import planner
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.sharding.rules import batch_specs, cache_specs, param_specs, zero1_specs
from repro.sharding.specs import Topology, plan_spec, use_topology


def _sharding(topo: Topology, spec_tree):
    if topo.mesh is None:
        return None
    return jax.tree.map(
        lambda s: NamedSharding(topo.mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )


def build_train_step(
    api: ModelApi,
    topo: Topology,
    shape: ShapeConfig,
    opt_cfg: Optional[AdamWConfig] = None,
    *,
    use_offload_engine: bool = False,
    engine: Any = None,
):
    """Returns (step_fn, arg_shapes, shardings) for one optimizer step.

    With ``use_offload_engine=True`` (and a mesh), the step is built by
    :func:`build_dp_train_step`: gradient/metric collectives dispatch through
    the given :class:`~repro.offload.OffloadEngine` as planned descriptors
    instead of GSPMD-derived reductions. Without a mesh the flag is a no-op
    (there is nothing to reduce over).
    """
    opt_cfg = opt_cfg or AdamWConfig()
    if use_offload_engine and topo.mesh is not None:
        if engine is None:
            raise ValueError(
                "use_offload_engine=True requires an OffloadEngine "
                "(see repro.launch.offload_runtime.build_offload_engine)"
            )
        return build_dp_train_step(api, topo, shape, opt_cfg, engine=engine)
    cfg = api.cfg

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(api.loss, has_aux=True)(
            params, batch
        )
        new_params, new_opt, stats = adamw_update(
            grads, opt_state, params, opt_cfg
        )
        out = {"loss": loss, **metrics, **stats}
        return new_params, new_opt, out

    pshapes = api.param_shapes()
    oshapes = jax.eval_shape(init_opt_state, pshapes)
    bshapes = input_specs(cfg, shape)

    pspec = param_specs(pshapes, cfg, topo)
    ospec = {
        "m": zero1_specs(pspec, pshapes, topo),
        "v": zero1_specs(pspec, pshapes, topo),
        "master": zero1_specs(pspec, pshapes, topo),
        "count": jax.sharding.PartitionSpec(),
    }
    bspec = batch_specs(bshapes, topo)

    jitted = jax.jit(
        train_step,
        in_shardings=(
            _sharding(topo, pspec),
            _sharding(topo, ospec),
            _sharding(topo, bspec),
        ),
        out_shardings=(
            _sharding(topo, pspec),
            _sharding(topo, ospec),
            None,
        ),
        donate_argnums=(0, 1),
    )
    return jitted, (pshapes, oshapes, bshapes), (pspec, ospec, bspec)


def _null_topo() -> Topology:
    # model-internal shard() annotations are global-sharding constraints;
    # inside shard_map's manual context they must be no-ops
    return Topology(mesh=None, batch_axes=("data",), model_axis=None)


def build_dp_train_step(
    api: ModelApi,
    topo: Topology,
    shape: ShapeConfig,
    opt_cfg: Optional[AdamWConfig] = None,
    *,
    engine: Any = None,
):
    """Data-parallel train step with application-issued collectives.

    The step body runs under ``shard_map`` over the topology's DP axes with
    params/optimizer state replicated and the batch sharded in the *plan's
    logical rank order* (``plan_spec``), so a tuned non-identity axis split
    needs no hand layout. Per step it issues four collectives:

      1. ALLREDUCE(sum) of the gradient pytree over the DP axes,
      2. ALLREDUCE(sum) of the loss/metric stack,
      3. EXSCAN(sum) of the per-rank example count — each rank's global
         example offset, the paper's primitive on the training path,
      4. ALLREDUCE(max) of offset+count — total examples seen this step.

    The step is three programs, the paper's host/NIC split:

      * ``local``  — jitted shard_map: per-rank fwd/bwd, emits the stacked
        ``(p, ...)`` contribution pytree (leading axis in the collective
        plan's logical rank order, sharded by ``plan_spec``);
      * ``collectives`` — with ``engine`` set, each collective is an encoded
        CollectiveDescriptor dispatched *per step* through
        ``OffloadEngine.offload`` in driver mode (planned multi-axis
        descriptors when the DP span is 2-3 mesh axes): step 1 compiles and
        caches the schedule programs, every later step is a plan-cache hit,
        and a remesh-cleared cache repopulates from these same descriptors
        on the next step. With ``engine=None`` a single prebuilt shard_map
        program runs raw per-axis ``lax`` reductions chained
        innermost-logical-level first — exactly the planned ALLREDUCE phase
        order — making the two paths bitwise comparable;
      * ``update`` — jitted AdamW on the reduced gradients.

    Requires a pure-DP mesh (``model_size == 1``): with tensor parallelism
    the gradient reductions are interleaved with the model's own collectives
    and belong to the GSPMD path.
    """
    opt_cfg = opt_cfg or AdamWConfig()
    mesh = topo.mesh
    if mesh is None:
        raise ValueError("build_dp_train_step requires a mesh topology")
    if topo.model_size > 1:
        raise ValueError(
            "the offload-engine train step is data-parallel only; "
            f"model axis has size {topo.model_size} (use the GSPMD path)"
        )
    cfg = api.cfg
    # size-1 axes carry no collective traffic; drop them from the DP span
    dp_names = tuple(
        a for a in topo.batch_axes if int(mesh.shape[a]) > 1
    )
    dp_sizes = tuple(int(mesh.shape[a]) for a in dp_names)
    dp = int(np.prod(dp_sizes)) if dp_sizes else 1
    k = len(dp_names)

    pshapes = api.param_shapes()
    oshapes = jax.eval_shape(init_opt_state, pshapes)
    bshapes = input_specs(cfg, shape)
    grad_bytes = sum(
        int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
        for l in jax.tree.leaves(pshapes)
    )
    loss_s, aux_s = jax.eval_shape(api.loss, pshapes, bshapes)
    metric_bytes = 4 * (1 + len(jax.tree.leaves(aux_s)))

    # the gradient allreduce dominates the payload, so its tuned split
    # decides the step's logical axis order — and thereby the data layout
    # every other collective (and the batch sharding) follows
    order = (
        planner.plan_axis_order(CollType.ALLREDUCE, dp_sizes, grad_bytes)
        if k > 1
        else tuple(range(k))
    )
    layout = planner.PlanLayout(sizes=dp_sizes, order=order) if k else None
    names_l = layout.spec_axes(dp_names) if k else ()
    sizes_l = layout.logical_sizes if k else ()

    rep = lambda tree: jax.tree.map(lambda _: P(), tree)  # noqa: E731
    stacked = P(names_l[0] if k == 1 else names_l) if k else P()

    def bspec_one(leaf):
        nd = len(leaf.shape)
        if k and nd >= 1 and leaf.shape[0] % dp == 0 and leaf.shape[0] > 1:
            return plan_spec(layout, dp_names, ndim=nd)
        return P(*([None] * nd))

    bspec = jax.tree.map(bspec_one, bshapes)
    pspec, ospec = rep(pshapes), rep(oshapes)

    # --- program 1: per-rank fwd/bwd, stacked contributions out ----------
    def local_body(params, batch):
        with use_topology(_null_topo()):
            (loss, metrics), grads = jax.value_and_grad(
                api.loss, has_aux=True
            )(params, batch)
        count = jnp.asarray(batch["tokens"].shape[0], jnp.float32)
        stack = {
            "grads": grads,
            "metrics": {"loss": loss, **metrics},
            "count": count,
        }
        return jax.tree.map(lambda a: jnp.asarray(a)[None], stack)

    local_fn = jax.jit(
        shard_map(
            local_body,
            mesh=mesh,
            in_specs=(pspec, bspec),
            out_specs=stacked,
            check_vma=False,
        )
    )

    # --- program 2: the collectives the application issues ---------------
    if engine is not None and k > 0:
        if k > 1:
            mk = partial(engine.make_descriptor, axes=dp_sizes, split=order)
        else:
            mk = partial(engine.make_descriptor, p=dp)
        grad_desc = mk("ALLREDUCE", payload_bytes=grad_bytes, op="sum")
        metric_desc = mk(
            "ALLREDUCE", payload_bytes=metric_bytes, op="sum", comm_id=1
        )
        offset_desc = mk("EXSCAN", payload_bytes=4, op="sum", comm_id=2)
        seen_desc = mk("ALLREDUCE", payload_bytes=4, op="max", comm_id=3)
        axis_arg = dp_names if k > 1 else dp_names[0]

        def collectives(stack):
            off = partial(engine.offload, axis_name=axis_arg, mesh=mesh)
            gsum = off(grad_desc, stack["grads"])
            msum = off(metric_desc, stack["metrics"])
            offset = off(offset_desc, stack["count"])
            seen = off(seen_desc, offset + stack["count"])
            return gsum, msum, seen

    elif k > 0:

        def _chain(tree, reduce_fn):
            # innermost logical level first — the planned ALLREDUCE phase
            # order, so raw and engine paths associate identically
            for name in reversed(names_l):
                tree = jax.tree.map(lambda g, n=name: reduce_fn(g, n), tree)
            return tree

        def raw_body(stack):
            stack = jax.tree.map(lambda a: a[0], stack)
            gsum = _chain(stack["grads"], lax.psum)
            msum = _chain(stack["metrics"], lax.psum)
            rank = jnp.int32(0)
            for name, size in zip(names_l, sizes_l):
                rank = rank * size + lax.axis_index(name)
            count = stack["count"]
            offset = count * rank.astype(count.dtype)  # equal per-rank counts
            seen = _chain(offset + count, lax.pmax)
            return jax.tree.map(
                lambda a: jnp.asarray(a)[None], (gsum, msum, seen)
            )

        raw_fn = jax.jit(
            shard_map(
                raw_body,
                mesh=mesh,
                in_specs=(stacked,),
                out_specs=stacked,
                check_vma=False,
            )
        )

        def collectives(stack):
            return raw_fn(stack)

    else:

        def collectives(stack):
            return stack["grads"], stack["metrics"], stack["count"]

    # --- program 3: optimizer update on the reduced gradients ------------
    def update_body(params, opt_state, gsum, msum, seen):
        grads = jax.tree.map(
            lambda a: (a[0] / dp).astype(a.dtype), gsum
        )
        mstack = jax.tree.map(lambda a: a[0] / dp, msum)
        new_params, new_opt, stats = adamw_update(
            grads, opt_state, params, opt_cfg
        )
        out = {**mstack, **stats, "examples_seen": seen[0]}
        return new_params, new_opt, out

    # donate params/opt like the GSPMD path does — the update consumes them
    update_fn = jax.jit(update_body, donate_argnums=(0, 1))

    def step_fn(params, opt_state, batch):
        stack = local_fn(params, batch)
        gsum, msum, seen = collectives(stack)
        return update_fn(params, opt_state, gsum, msum, seen)

    return step_fn, (pshapes, oshapes, bshapes), (pspec, ospec, bspec)


def build_prefill_step(api: ModelApi, topo: Topology, shape: ShapeConfig):
    cfg = api.cfg
    bshapes = input_specs(cfg, shape)
    pshapes = api.param_shapes()
    pspec = param_specs(pshapes, cfg, topo)
    bspec = batch_specs(bshapes, topo)

    def prefill(params, batch):
        return api.prefill(params, batch)

    # output cache sharding: same rules as decode caches
    cshapes = jax.eval_shape(
        lambda p, b: api.prefill(p, b)[1], pshapes, bshapes
    )
    cspec = cache_specs(cshapes, cfg, topo)
    lspec = batch_specs(
        jax.eval_shape(lambda p, b: api.prefill(p, b)[0], pshapes, bshapes),
        topo,
    )
    jitted = jax.jit(
        prefill,
        in_shardings=(_sharding(topo, pspec), _sharding(topo, bspec)),
        out_shardings=(_sharding(topo, lspec), _sharding(topo, cspec)),
    )
    return jitted, (pshapes, bshapes), (pspec, bspec)


def build_decode_step(api: ModelApi, topo: Topology, shape: ShapeConfig):
    cfg = api.cfg
    bshapes = input_specs(cfg, shape)  # {token, cache, cache_len}
    pshapes = api.param_shapes()
    pspec = param_specs(pshapes, cfg, topo)
    cspec = cache_specs(bshapes["cache"], cfg, topo)
    tspec = batch_specs(bshapes["token"], topo)

    def decode(params, token, cache, cache_len):
        return api.decode_step(params, token, cache, cache_len)

    jitted = jax.jit(
        decode,
        in_shardings=(
            _sharding(topo, pspec),
            _sharding(topo, tspec),
            _sharding(topo, cspec),
            None,
        ),
        out_shardings=(
            _sharding(topo, tspec),
            _sharding(topo, cspec),
        ),
        donate_argnums=(2,),
    )
    return jitted, (pshapes, bshapes), (pspec, cspec)
