"""Step builders: jitted train_step / prefill / decode with full shardings.

These are shared by the real launchers (train.py / serve.py) and the dry-run:
the dry-run lowers exactly what the launcher would execute.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import ModelApi, input_specs
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.sharding.rules import batch_specs, cache_specs, param_specs, zero1_specs
from repro.sharding.specs import Topology


def _sharding(topo: Topology, spec_tree):
    if topo.mesh is None:
        return None
    return jax.tree.map(
        lambda s: NamedSharding(topo.mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )


def build_train_step(
    api: ModelApi,
    topo: Topology,
    shape: ShapeConfig,
    opt_cfg: Optional[AdamWConfig] = None,
):
    """Returns (jitted_step, arg_shapes, shardings) for one optimizer step."""
    opt_cfg = opt_cfg or AdamWConfig()
    cfg = api.cfg

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(api.loss, has_aux=True)(
            params, batch
        )
        new_params, new_opt, stats = adamw_update(
            grads, opt_state, params, opt_cfg
        )
        out = {"loss": loss, **metrics, **stats}
        return new_params, new_opt, out

    pshapes = api.param_shapes()
    oshapes = jax.eval_shape(init_opt_state, pshapes)
    bshapes = input_specs(cfg, shape)

    pspec = param_specs(pshapes, cfg, topo)
    ospec = {
        "m": zero1_specs(pspec, pshapes, topo),
        "v": zero1_specs(pspec, pshapes, topo),
        "master": zero1_specs(pspec, pshapes, topo),
        "count": jax.sharding.PartitionSpec(),
    }
    bspec = batch_specs(bshapes, topo)

    jitted = jax.jit(
        train_step,
        in_shardings=(
            _sharding(topo, pspec),
            _sharding(topo, ospec),
            _sharding(topo, bspec),
        ),
        out_shardings=(
            _sharding(topo, pspec),
            _sharding(topo, ospec),
            None,
        ),
        donate_argnums=(0, 1),
    )
    return jitted, (pshapes, oshapes, bshapes), (pspec, ospec, bspec)


def build_prefill_step(api: ModelApi, topo: Topology, shape: ShapeConfig):
    cfg = api.cfg
    bshapes = input_specs(cfg, shape)
    pshapes = api.param_shapes()
    pspec = param_specs(pshapes, cfg, topo)
    bspec = batch_specs(bshapes, topo)

    def prefill(params, batch):
        return api.prefill(params, batch)

    # output cache sharding: same rules as decode caches
    cshapes = jax.eval_shape(
        lambda p, b: api.prefill(p, b)[1], pshapes, bshapes
    )
    cspec = cache_specs(cshapes, cfg, topo)
    lspec = batch_specs(
        jax.eval_shape(lambda p, b: api.prefill(p, b)[0], pshapes, bshapes),
        topo,
    )
    jitted = jax.jit(
        prefill,
        in_shardings=(_sharding(topo, pspec), _sharding(topo, bspec)),
        out_shardings=(_sharding(topo, lspec), _sharding(topo, cspec)),
    )
    return jitted, (pshapes, bshapes), (pspec, bspec)


def build_decode_step(api: ModelApi, topo: Topology, shape: ShapeConfig):
    cfg = api.cfg
    bshapes = input_specs(cfg, shape)  # {token, cache, cache_len}
    pshapes = api.param_shapes()
    pspec = param_specs(pshapes, cfg, topo)
    cspec = cache_specs(bshapes["cache"], cfg, topo)
    tspec = batch_specs(bshapes["token"], topo)

    def decode(params, token, cache, cache_len):
        return api.decode_step(params, token, cache, cache_len)

    jitted = jax.jit(
        decode,
        in_shardings=(
            _sharding(topo, pspec),
            _sharding(topo, tspec),
            _sharding(topo, cspec),
            None,
        ),
        out_shardings=(
            _sharding(topo, tspec),
            _sharding(topo, cspec),
        ),
        donate_argnums=(2,),
    )
    return jitted, (pshapes, bshapes), (pspec, cspec)
