"""Launch-time construction of the offload engine + tuning-table plumbing.

Every launcher that issues collective descriptors goes through here:

  * :func:`build_offload_engine` loads (or, on request, generates) the tuning
    table for the current backend, activates it underneath
    ``select_algorithm``, and returns a ready :class:`OffloadEngine` — the
    process-wide "NIC". Ambient tables (``$REPRO_TUNING_TABLE`` or the
    default cache path) are backend-fingerprint-checked and ignored with a
    warning on mismatch; an explicitly passed path is trusted verbatim.
  * The engine is wired to ``runtime.fault``: when a shrunken mesh is
    *adopted* (the trainer's recovery path fires ``fault.notify_remesh``),
    the registered listener clears the engine's compiled-plan cache (plans
    key on axis sizes) and runs a budgeted re-tune
    (``autotune(time_budget_s=...)``) on the surviving topology, hot-swapping
    the active tuning table. Disable with ``retune_on_remesh=False``; detach
    a built engine's hook with :func:`detach_remesh_hook`.
  * :func:`build_offload_service` stacks the multi-tenant
    :class:`~repro.service.DescriptorBroker` on top of the engine (service
    mode): many client streams, coalesced dispatches, per-tenant telemetry,
    and a shared tuning-table registry — a fresh tune (or an ambient table)
    is *published* to the registry so every worker pointing at the same
    registry directory (``$REPRO_TUNING_REGISTRY`` / ``--registry``)
    inherits the merged winners instead of re-measuring.
  * ``python -m repro.launch.offload_runtime --tune`` is the operator-facing
    way to produce a tuning table once (including the planner's axis-split
    winners via ``--splits``) and reuse it across launches via
    ``$REPRO_TUNING_TABLE``; add ``--registry DIR`` to also merge it into a
    shared registry keyed by backend fingerprint.
  * Observability: ``build_offload_engine(tracing=True)`` (or
    ``$REPRO_TRACE=1``) installs a collecting span tracer
    (:mod:`repro.obs.tracing`) before the engine is built, so every
    dispatch in the launch emits broker/engine/phase/round spans; and
    ``python -m repro.launch.offload_runtime --trace OUT.json`` runs one
    traced+profiled smoke dispatch and writes the merged host+device
    Perfetto trace — the quickest way to *see* where a round's time goes
    (open the file at https://ui.perfetto.dev).
  * Operations: ``--dashboard`` runs a smoke dispatch through
    engine+broker+health monitor and prints the text dashboard
    (:mod:`repro.obs.dashboard`); ``--serve PORT`` exposes ``/healthz``,
    ``/metrics``, ``/events`` over HTTP; ``--flight-record OUT.json``
    dumps the always-on flight recorder (:mod:`repro.obs.events`) at run
    end and arms the crash/recovery auto-dump.
"""

from __future__ import annotations

import argparse
import os
import weakref
from pathlib import Path
from typing import List, Optional, Tuple

from repro.obs import events as obs_events
from repro.offload import (
    TUNING_TABLE_ENV,
    OffloadEngine,
    TuningCache,
    autotune,
    tune_splits,
)
from repro.runtime import fault

DEFAULT_TABLE_PATH = Path(
    os.environ.get("REPRO_CACHE_DIR", os.path.expanduser("~/.cache/repro"))
) / "tuning_table.json"

_ENGINE: Optional[OffloadEngine] = None


def _remesh_ps(new_axes: Tuple[int, ...]) -> Tuple[int, ...]:
    """The (p) grid worth re-measuring after a re-mesh: every surviving axis
    size plus the flat total, doubles included up to the total."""
    total = 1
    for s in new_axes:
        total *= max(1, int(s))
    ps = {int(s) for s in new_axes if int(s) > 1}
    p = 2
    while p <= total:
        ps.add(p)
        p *= 2
    if total > 1:
        ps.add(total)
    return tuple(sorted(ps)) or (2,)


# One module-level listener serves every engine: a re-mesh clears each live
# engine's plan cache but runs the budgeted re-tune exactly once (the tuning
# table is process-global state), under the largest budget any live engine
# asked for. Engines are held by weakref so subscribing never extends their
# lifetime.
_HOOKED_ENGINES: List[Tuple["weakref.ref[OffloadEngine]", float]] = []


def _on_remesh(old_axes, new_axes):
    alive = []
    for ref, budget_s in _HOOKED_ENGINES:
        engine = ref()
        if engine is not None:
            # stale on two levels: compiled plans key on the old axis sizes,
            # and the active table was measured on the old (p, payload) grid
            engine.clear()
            alive.append((ref, budget_s))
    _HOOKED_ENGINES[:] = alive
    if not alive:
        fault.unregister_remesh_listener(_on_remesh)
        return
    budget_s = max(b for _, b in alive)
    obs_events.record(
        "retune", axes=tuple(int(a) for a in new_axes), budget_s=budget_s
    )
    cache = autotune(
        ps=_remesh_ps(tuple(new_axes)),
        payloads=(1024, 65536),
        iters=2,
        time_budget_s=budget_s,
    )
    cache.activate()


def _attach_remesh_hook(
    engine: OffloadEngine, tune_budget_s: float
) -> OffloadEngine:
    if not _HOOKED_ENGINES:
        fault.register_remesh_listener(_on_remesh)
    else:  # drop entries for engines that were garbage-collected
        _HOOKED_ENGINES[:] = [
            (ref, b) for ref, b in _HOOKED_ENGINES if ref() is not None
        ]
    _HOOKED_ENGINES.append((weakref.ref(engine), float(tune_budget_s)))
    return engine


def detach_remesh_hook(engine: OffloadEngine) -> None:
    """Unsubscribe an engine built with ``retune_on_remesh=True``."""
    _HOOKED_ENGINES[:] = [
        (ref, b) for ref, b in _HOOKED_ENGINES
        if ref() is not None and ref() is not engine
    ]
    if not _HOOKED_ENGINES:
        fault.unregister_remesh_listener(_on_remesh)


TRACE_ENV = "REPRO_TRACE"


def build_offload_engine(
    *,
    tuning_table: "str | Path | None" = None,
    autotune_if_missing: bool = False,
    tune_budget_s: float = 30.0,
    retune_on_remesh: bool = True,
    remesh_tune_budget_s: float = 5.0,
    tracing: Optional[bool] = None,
) -> OffloadEngine:
    """Construct the launch's engine, with the tuning table resolved from
    (in order): the explicit argument, ``$REPRO_TUNING_TABLE``, the default
    cache path, or — when ``autotune_if_missing`` — a fresh budgeted tuning
    run persisted to the default path for the next launch.

    ``tracing=True`` (default: on when ``$REPRO_TRACE`` is a non-empty
    value other than ``0``) installs a process-wide collecting span tracer
    before the engine is built; read it back with
    :func:`repro.obs.tracing.get_tracer` and export via
    :mod:`repro.obs.export`. The default no-op tracer costs nothing.
    """
    if tracing is None:
        tracing = os.environ.get(TRACE_ENV, "") not in ("", "0", "false")
    if tracing:
        from repro.obs import tracing as obs_tracing

        if not obs_tracing.get_tracer().enabled:
            obs_tracing.install_tracer()
    cache: Optional[TuningCache] = None
    if tuning_table:
        # An explicitly named table must exist: silently falling through to
        # a different (or no) table would tune against the wrong cost model.
        if not Path(tuning_table).exists():
            raise FileNotFoundError(
                f"tuning table {str(tuning_table)!r} does not exist"
            )
        cache = TuningCache.load(tuning_table)
    elif os.environ.get(TUNING_TABLE_ENV):
        env_path = os.environ[TUNING_TABLE_ENV]
        if not Path(env_path).exists():
            raise FileNotFoundError(
                f"tuning table {env_path!r} (from ${TUNING_TABLE_ENV}) "
                "does not exist"
            )
        cache = TuningCache.load_compatible(env_path)
    elif DEFAULT_TABLE_PATH.exists():
        cache = TuningCache.load_compatible(DEFAULT_TABLE_PATH)
    if cache is None and autotune_if_missing:
        # also the recovery path for an ambient table the fingerprint check
        # rejected: the caller asked for a usable table, so measure one
        cache = autotune(
            ps=(2, 4, 8),
            payloads=(1024, 65536),
            iters=3,
            time_budget_s=tune_budget_s,
        )
        cache.save(DEFAULT_TABLE_PATH)
    if cache is not None:
        cache.activate()
    engine = OffloadEngine()
    if retune_on_remesh:
        _attach_remesh_hook(engine, remesh_tune_budget_s)
    return engine


def get_engine() -> OffloadEngine:
    """Process-wide engine singleton (built lazily on first use)."""
    global _ENGINE
    if _ENGINE is None:
        _ENGINE = build_offload_engine()
    return _ENGINE


DEFAULT_REGISTRY_DIR = Path(
    os.environ.get("REPRO_CACHE_DIR", os.path.expanduser("~/.cache/repro"))
) / "tuning_registry"

_SERVICE = None


def build_offload_service(
    *,
    axis_name=None,
    mesh=None,
    registry: "object | str | Path | None" = None,
    publish_active_table: bool = True,
    flush_interval_s: float = 0.002,
    max_coalesce: int = 64,
    max_pending: int = 1024,
    max_tenants: int = 64,
    start: bool = True,
    **engine_kw,
):
    """Service mode: a started :class:`~repro.service.DescriptorBroker`
    front end over a freshly built engine.

    The registry resolves from (in order): the explicit argument (a registry
    object or a directory path), ``$REPRO_TUNING_REGISTRY``, the default
    cache-dir registry. The broker fetches the registry's merged table for
    this backend and activates it; when ``publish_active_table`` and this
    process also tuned (or loaded) its own table, that table is merged back
    in, so workers converge on one pod-wide table instead of each keeping a
    private one.
    """
    from repro.core.selector import get_active_tuning
    from repro.service import DescriptorBroker, FileTuningRegistry
    from repro.service.registry import default_registry

    if registry is None:
        registry = default_registry() or FileTuningRegistry(
            DEFAULT_REGISTRY_DIR
        )
    elif isinstance(registry, (str, Path)):
        registry = FileTuningRegistry(registry)
    engine = build_offload_engine(**engine_kw)
    active = get_active_tuning()
    if publish_active_table and isinstance(active, TuningCache):
        registry.publish(active)
    broker = DescriptorBroker(
        engine,
        axis_name=axis_name,
        mesh=mesh,
        flush_interval_s=flush_interval_s,
        max_coalesce=max_coalesce,
        max_pending=max_pending,
        max_tenants=max_tenants,
        registry=registry,
    )
    return broker.start() if start else broker


def get_service():
    """Process-wide broker singleton (sim-mode engine, default registry)."""
    global _SERVICE
    if _SERVICE is None:
        _SERVICE = build_offload_service()
    return _SERVICE


def write_traced_smoke_trace(
    out: "str | Path",
    *,
    axes: Tuple[int, ...] = (2, 4),
    payload_floats: int = 256,
    coll: str = "scan",
) -> Path:
    """Run one traced + profiled smoke dispatch and write the merged
    host+device Perfetto trace to ``out``. The attribution workflow's
    one-command entry point (see README's Observability section)."""
    import math as _math
    import tempfile

    import jax.numpy as jnp

    from repro.obs import export as obs_export
    from repro.obs import tracing as obs_tracing

    engine = OffloadEngine()
    desc = engine.make_descriptor(
        coll, axes=tuple(axes), payload_bytes=payload_floats * 4, op="sum"
    )
    p = _math.prod(axes)
    x = jnp.arange(p * payload_floats, dtype=jnp.float32).reshape(
        p, payload_floats
    )
    with tempfile.TemporaryDirectory() as td:
        with obs_tracing.tracing() as tracer:
            timing = engine.profile_offload(desc, x, trace_dir=td)
        host = obs_export.spans_to_chrome(tracer.spans())
        if timing.trace_path is not None:
            merged = obs_export.merge_device_trace(host, timing.trace_path)
        else:
            merged = host
        path = obs_export.write_trace(out, merged)
    n_spans = len(tracer.spans())
    print(
        f"traced {coll} over {tuple(axes)}: {n_spans} host spans, "
        f"{merged.get('deviceEventsMerged', 0)} device events "
        f"(aligned={merged.get('deviceClockAligned', False)}, "
        f"device source={timing.source})"
    )
    print(f"merged trace written to {path} — open at https://ui.perfetto.dev")
    return path


def run_dashboard_smoke(
    *, axes: Tuple[int, ...] = (2, 4), payload_floats: int = 256
) -> None:
    """Drive a few dispatches through an engine + broker + health monitor
    and print the text dashboard — the ``--dashboard`` entry point."""
    import jax.numpy as jnp

    from repro.obs import dashboard as obs_dashboard
    from repro.obs import health as obs_health
    from repro.service import DescriptorBroker

    engine = build_offload_engine(retune_on_remesh=False)
    broker = DescriptorBroker(engine).start()
    monitor = obs_health.HealthMonitor()
    p = 1
    for a in axes:
        p *= int(a)
    x = jnp.arange(p * payload_floats, dtype=jnp.float32).reshape(
        p, payload_floats
    )
    try:
        client = broker.client("dashboard")
        desc = engine.make_descriptor(
            "scan", axes=tuple(axes), payload_bytes=payload_floats * 4,
            op="sum",
        )
        for _ in range(4):
            client.submit(desc, x).result(timeout=60.0)
    finally:
        broker.stop()
    monitor.ingest(service=broker.telemetry, engine=engine.telemetry)
    monitor.evaluate()
    print(
        obs_dashboard.render_dashboard(
            engine=engine, broker=broker, monitor=monitor
        )
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tune", action="store_true", help="run the autotuner")
    ap.add_argument(
        "--dashboard",
        action="store_true",
        help="run a smoke dispatch through engine+broker+health monitor "
        "and print the text dashboard",
    )
    ap.add_argument(
        "--serve",
        metavar="PORT",
        type=int,
        default=None,
        help="after other actions, serve /healthz, /metrics, /events and "
        "the dashboard over HTTP on PORT until interrupted",
    )
    ap.add_argument(
        "--flight-record",
        metavar="OUT.json",
        default=None,
        help="dump the flight recorder's event ring to OUT.json when the "
        "run ends (and automatically on crash/recovery paths)",
    )
    ap.add_argument(
        "--trace",
        metavar="OUT.json",
        default=None,
        help="run one traced smoke dispatch and write the merged "
        "host+device Perfetto trace",
    )
    ap.add_argument(
        "--trace-axes",
        default="2,4",
        help="mesh axes for --trace (comma-separated, default 2,4)",
    )
    ap.add_argument(
        "--splits",
        action="store_true",
        help="also measure planner axis-split winners per mesh shape",
    )
    ap.add_argument(
        "--fusion",
        action="store_true",
        help="also measure plan-optimizer fused-vs-unfused winners per "
        "mesh shape (feeds make_descriptor's optimize='auto')",
    )
    ap.add_argument(
        "--chunks",
        metavar="C,C,...",
        default=None,
        help="with --fusion, widen the measured grid to these chunked-"
        "streaming chunk counts per (fused, unfused) schedule (e.g. "
        "1,2,4,8 — feeds make_descriptor's chunks='auto')",
    )
    ap.add_argument(
        "--backend",
        metavar="NAME,NAME,...",
        default=None,
        help="with --fusion, race each schedule variant across these "
        "lowering backends ('' or 'default' = the op-per-round default, "
        "'pallas' = the fused-kernel lowering; e.g. default,pallas — "
        "feeds make_descriptor's backend='auto'). Variants outside a "
        "named backend's capabilities are skipped, not mis-measured",
    )
    ap.add_argument("--out", default=str(DEFAULT_TABLE_PATH))
    ap.add_argument("--budget-s", type=float, default=60.0)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument(
        "--registry",
        metavar="DIR",
        default=None,
        help="also merge the tuned table into a shared file registry "
        "(keyed by backend fingerprint) so other workers inherit it",
    )
    args = ap.parse_args()
    if not (
        args.tune or args.trace or args.dashboard or args.serve is not None
    ):
        ap.error(
            "nothing to do; pass --tune, --trace, --dashboard, or --serve"
        )
    if args.chunks and not args.fusion:
        ap.error("--chunks widens the --fusion grid; pass --fusion too")
    if args.backend and not args.fusion:
        ap.error("--backend races the --fusion grid; pass --fusion too")
    if args.flight_record:
        # also arms the crash/recovery auto-dump for the rest of the run
        obs_events.set_auto_dump_path(args.flight_record)
    if args.trace:
        axes = tuple(int(a) for a in args.trace_axes.split(","))
        write_traced_smoke_trace(args.trace, axes=axes)
    if args.dashboard:
        run_dashboard_smoke()
    if args.tune:
        _run_tune(args)
    if args.serve is not None:
        from repro.obs import dashboard as obs_dashboard

        server = obs_dashboard.start_http_server(port=args.serve)
        print(
            f"serving /healthz /metrics /events and the dashboard at "
            f"{server.url} (Ctrl-C to stop)"
        )
        try:
            server.thread.join()
        except KeyboardInterrupt:
            server.close()
    if args.flight_record:
        snap = obs_events.get_recorder().dump(
            args.flight_record, reason="run_end"
        )
        print(
            f"flight recorder: {len(snap['events'])} events "
            f"({snap['recorded']} recorded) -> {args.flight_record}"
        )


def _run_tune(args) -> None:
    cache = autotune(
        iters=args.iters, time_budget_s=args.budget_s, verbose=True
    )
    if args.splits:
        tune_splits(
            iters=args.iters,
            time_budget_s=args.budget_s,
            cache=cache,
            verbose=True,
        )
    if args.fusion:
        from repro.offload import tune_schedule

        chunk_grid = (
            tuple(int(c) for c in args.chunks.split(","))
            if args.chunks
            else (1,)
        )
        backend_grid = (
            tuple(
                "" if b in ("", "default") else b
                for b in args.backend.split(",")
            )
            if args.backend
            else ("",)
        )
        tune_schedule(
            chunks=chunk_grid,
            backends=backend_grid,
            iters=args.iters,
            time_budget_s=args.budget_s,
            cache=cache,
            verbose=True,
        )
    if args.registry:
        from repro.service import FileTuningRegistry

        merged = FileTuningRegistry(args.registry).publish(cache)
        print(
            f"merged into registry {args.registry} "
            f"[{cache.backend}]: {len(merged.measurements)} measurements, "
            f"{len(merged.split_measurements)} split samples"
        )
    out = cache.save(args.out)
    fitted = cache.fitted_model()
    print(f"tuning table written to {out}")
    if fitted is not None:
        print(
            f"fitted LinkModel: alpha={fitted.alpha:.3e}s "
            f"beta={fitted.beta:.3e}s/B gamma={fitted.gamma:.3e}s"
        )
    if cache.split_winners:
        print(f"axis-split winners: {len(cache.split_winners)} shapes")
    if cache.fusion_winners:
        print(f"fusion winners: {len(cache.fusion_winners)} shapes")
        chunked = sum(
            1 for _opt, c in cache.schedule_winners.values() if c > 1
        )
        if chunked:
            print(f"chunked-streaming winners: {chunked} grid points")
    if cache.backend_winners:
        print(
            f"lowering-backend winners: {len(cache.backend_winners)} "
            f"grid points"
        )
    print(f"export {TUNING_TABLE_ENV}={out}  # to use it in later launches")


if __name__ == "__main__":
    main()
