"""Launch-time construction of the offload engine + tuning-table plumbing.

Every launcher that issues collective descriptors goes through here:

  * :func:`build_offload_engine` loads (or, on request, generates) the tuning
    table for the current backend, activates it underneath
    ``select_algorithm``, and returns a ready :class:`OffloadEngine` — the
    process-wide "NIC".
  * ``python -m repro.launch.offload_runtime --tune`` is the operator-facing
    way to produce a tuning table once and reuse it across launches via
    ``$REPRO_TUNING_TABLE``.
"""

from __future__ import annotations

import argparse
import os
from pathlib import Path
from typing import Optional

from repro.offload import (
    TUNING_TABLE_ENV,
    OffloadEngine,
    TuningCache,
    autotune,
)

DEFAULT_TABLE_PATH = Path(
    os.environ.get("REPRO_CACHE_DIR", os.path.expanduser("~/.cache/repro"))
) / "tuning_table.json"

_ENGINE: Optional[OffloadEngine] = None


def build_offload_engine(
    *,
    tuning_table: "str | Path | None" = None,
    autotune_if_missing: bool = False,
    tune_budget_s: float = 30.0,
) -> OffloadEngine:
    """Construct the launch's engine, with the tuning table resolved from
    (in order): the explicit argument, ``$REPRO_TUNING_TABLE``, the default
    cache path, or — when ``autotune_if_missing`` — a fresh budgeted tuning
    run persisted to the default path for the next launch."""
    path = tuning_table or os.environ.get(TUNING_TABLE_ENV)
    cache: Optional[TuningCache] = None
    if path:
        # An explicitly named table must exist: silently falling through to
        # a different (or no) table would tune against the wrong cost model.
        if not Path(path).exists():
            raise FileNotFoundError(
                f"tuning table {path!r} (from argument or "
                f"${TUNING_TABLE_ENV}) does not exist"
            )
        cache = TuningCache.load(path)
    elif DEFAULT_TABLE_PATH.exists():
        cache = TuningCache.load(DEFAULT_TABLE_PATH)
    elif autotune_if_missing:
        cache = autotune(
            ps=(2, 4, 8),
            payloads=(1024, 65536),
            iters=3,
            time_budget_s=tune_budget_s,
        )
        cache.save(DEFAULT_TABLE_PATH)
    if cache is not None:
        cache.activate()
    return OffloadEngine()


def get_engine() -> OffloadEngine:
    """Process-wide engine singleton (built lazily on first use)."""
    global _ENGINE
    if _ENGINE is None:
        _ENGINE = build_offload_engine()
    return _ENGINE


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tune", action="store_true", help="run the autotuner")
    ap.add_argument("--out", default=str(DEFAULT_TABLE_PATH))
    ap.add_argument("--budget-s", type=float, default=60.0)
    ap.add_argument("--iters", type=int, default=5)
    args = ap.parse_args()
    if not args.tune:
        ap.error("nothing to do; pass --tune")
    cache = autotune(
        iters=args.iters, time_budget_s=args.budget_s, verbose=True
    )
    out = cache.save(args.out)
    fitted = cache.fitted_model()
    print(f"tuning table written to {out}")
    if fitted is not None:
        print(
            f"fitted LinkModel: alpha={fitted.alpha:.3e}s "
            f"beta={fitted.beta:.3e}s/B gamma={fitted.gamma:.3e}s"
        )
    print(f"export {TUNING_TABLE_ENV}={out}  # to use it in later launches")


if __name__ == "__main__":
    main()
