"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --steps 100 --batch 8 --seq 128 [--reduced] [--ckpt-dir /tmp/ckpt]

On a real pod this runs under one process per host with jax.distributed
initialized by the cluster runtime; the mesh/topology code is identical.
On this container it runs the reduced config on the local device.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro import perf_flags
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, batches
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.runtime.fault import FailureInjector
from repro.runtime.train_loop import Trainer, TrainerConfig
from repro.sharding.specs import Topology, make_topology


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", choices=["none", "production"], default="none")
    ap.add_argument(
        "--offload-engine", action="store_true",
        help="dispatch the step's gradient/metric collectives through the "
             "offload engine as planned descriptors (pure-DP meshes)",
    )
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject simulated failures at these steps")
    ap.add_argument("--opt", default="", help="perf flags k=v,...")
    args = ap.parse_args()
    perf_flags.parse_opt_string(args.opt)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = build_model(cfg)
    if args.mesh == "production":
        from repro.launch.mesh import make_production_mesh
        topo = make_topology(make_production_mesh())
    else:
        topo = Topology(mesh=None)

    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    data = batches(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch,
    ))
    tr = Trainer(
        api, topo, shape, data,
        TrainerConfig(
            ckpt_dir=args.ckpt_dir, ckpt_every=25,
            use_offload_engine=args.offload_engine,
        ),
        AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps),
        injector=FailureInjector(fail_at=tuple(args.fail_at)),
    )
    params, opt = tr.init_state()
    start, params, opt = tr.maybe_restore(params, opt)
    if start:
        print(f"resumed from checkpoint at step {start}")
    params, opt, hist = tr.run(params, opt, args.steps, start_step=start)
    for h in hist[:: max(1, len(hist) // 12)]:
        print(f"step {h['step']:5d} loss {h['loss']:.4f} "
              f"gnorm {h['grad_norm']:.2f} {h['step_time_s']*1e3:.0f}ms")
    print(f"final loss: {hist[-1]['loss']:.4f}; "
          f"remesh events: {len(tr.remesh_events)}; "
          f"straggler flags: {len(tr.straggler.events)}")


if __name__ == "__main__":
    main()
