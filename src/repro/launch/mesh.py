"""Production mesh definitions.

A v5e pod is 16x16 = 256 chips; the multi-pod config stacks 2 pods on a
'pod' axis (DCN-connected). Defined as FUNCTIONS so importing this module
never touches jax device state (device count is locked at first use).
"""

from __future__ import annotations

import jax

from repro.sharding.specs import Topology, make_topology


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """Whatever devices exist, all on the data axis (CPU tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def production_topology(*, multi_pod: bool = False) -> Topology:
    return make_topology(make_production_mesh(multi_pod=multi_pod))
