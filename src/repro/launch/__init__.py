from repro.launch.mesh import make_production_mesh, make_smoke_mesh, production_topology
from repro.launch.offload_runtime import build_offload_engine, get_engine
