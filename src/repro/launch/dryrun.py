import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices stand in for 2 pods x 256 chips, the
full production sharding lowers, XLA compiles it, and we record
memory_analysis (fits-per-device), cost_analysis (FLOPs/bytes) and the
collective schedule (parsed from optimized HLO) into a JSON artifact per cell.

Usage:
  python -m repro.launch.dryrun --arch mamba2-130m --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--skip-existing]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from repro import perf_flags  # noqa: E402
from repro.configs import SHAPES, applicable_shapes, get_config  # noqa: E402
from repro.configs.base import ARCH_IDS  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (  # noqa: E402
    build_decode_step,
    build_prefill_step,
    build_train_step,
)
from repro.models import build_model, input_specs  # noqa: E402
from repro.roofline.analysis import analyze_hlo, model_flops  # noqa: E402
from repro.sharding.specs import make_topology, use_topology  # noqa: E402

ART_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    topo = make_topology(mesh)
    n_chips = mesh.devices.size
    api = build_model(cfg)

    t0 = time.time()
    with use_topology(topo):
        if shape.kind == "train":
            step, shapes, _ = build_train_step(api, topo, shape)
            lowered = step.lower(*shapes[:3])
        elif shape.kind == "prefill":
            step, shapes, _ = build_prefill_step(api, topo, shape)
            lowered = step.lower(*shapes)
        else:  # decode
            step, (pshapes, bshapes), _ = build_decode_step(api, topo, shape)
            lowered = step.lower(
                pshapes, bshapes["token"], bshapes["cache"], bshapes["cache_len"]
            )
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    roof, coll = analyze_hlo(hlo, n_chips, default_group=topo.model_size)
    mf = model_flops(cfg, shape, shape.kind)
    hlo_flops_total = roof.flops_per_device * n_chips
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "n_chips": n_chips,
        "kind": shape.kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes": getattr(
                mem, "generated_code_size_in_bytes", None
            ),
        },
        "roofline": roof.as_dict(),
        "collectives": {
            "counts": coll.coll_counts,
            "wire_bytes": coll.coll_bytes,
        },
        "raw_cost_analysis": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "model_flops_total": mf,
        "useful_flops_ratio": (mf / hlo_flops_total) if hlo_flops_total else None,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }
    record["opt"] = dataclasses_asdict(perf_flags.FLAGS)
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
    out_path.write_text(json.dumps(record, indent=2))
    return record


def dataclasses_asdict(obj):
    import dataclasses
    return dataclasses.asdict(obj)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str)
    ap.add_argument("--shape", type=str)
    ap.add_argument("--mesh", type=str, default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", type=str, default=str(ART_DIR))
    ap.add_argument("--opt", type=str, default="",
                    help="perf flags, e.g. seq_shard_attn=1,remat_policy=save_block_outputs")
    ap.add_argument("--tag", type=str, default="",
                    help="artifact filename suffix for perf iterations")
    args = ap.parse_args()
    perf_flags.parse_opt_string(args.opt)
    out_dir = Path(args.out)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in applicable_shapes(get_config(arch)):
                for m in meshes:
                    cells.append((arch, shape, m))
    else:
        cells = [(args.arch, args.shape, m) for m in meshes]

    failures = 0
    for arch, shape, m in cells:
        tag = f"{arch} x {shape} x {m}"
        path = out_dir / f"{arch}__{shape}__{m}.json"
        if args.skip_existing and path.exists():
            print(f"[skip] {tag}")
            continue
        try:
            rec = run_cell(arch, shape, m, out_dir, tag=args.tag)
            r = rec["roofline"]
            print(
                f"[ok]   {tag}: compile={rec['compile_s']}s "
                f"flops/dev={r['flops_per_device']:.3e} "
                f"bytes/dev={r['bytes_per_device']:.3e} "
                f"coll={r['collective_bytes_per_device']:.3e}B "
                f"bottleneck={r['bottleneck']}"
            )
        except Exception:
            failures += 1
            print(f"[FAIL] {tag}")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
