"""Serving launcher: batched greedy decoding over a prompt file or synthetic
requests.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import Request, ServeEngine
from repro.sharding.specs import Topology


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    eng = ServeEngine(
        api, params, Topology(mesh=None),
        batch_size=args.batch_size, max_len=args.max_len,
    )
    rng = np.random.default_rng(0)
    reqs = []
    for rid in range(args.requests):
        plen = int(rng.integers(4, 24))
        r = Request(
            rid=rid,
            prompt=rng.integers(2, cfg.vocab_size, size=plen).astype(np.int32),
            max_new_tokens=args.max_new,
        )
        reqs.append(r)
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run_until_drained()
    dt = time.perf_counter() - t0
    total = sum(len(r.generated) for r in reqs)
    print(f"served {len(reqs)} requests / {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s batched greedy)")
    for r in reqs[:4]:
        print(f"  req {r.rid}: {len(r.generated)} tokens {r.generated[:8]}...")


if __name__ == "__main__":
    main()
