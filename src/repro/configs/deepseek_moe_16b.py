"""DeepSeekMoE-16B [arXiv:2401.06066; hf] — fine-grained MoE, 2 shared + 64 routed top-6."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,            # per-expert hidden (fine-grained experts)
    vocab_size=102400,
    head_dim=128,
    moe_num_experts=64,
    moe_top_k=6,
    moe_num_shared=2,
    rope_theta=1e4,
)
