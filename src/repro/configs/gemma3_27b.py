"""Gemma3-27B [hf:google/gemma-3; unverified] — 5:1 local:global sliding window, 128k."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    d_ff=21504,
    vocab_size=262144,
    head_dim=128,
    sliding_window=1024,
    local_global_ratio=5,   # 5 local layers per 1 global
    rope_theta=1e6,
    act="gelu",
)
