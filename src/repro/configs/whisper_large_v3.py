"""Whisper large-v3 [arXiv:2212.04356; unverified] — enc-dec; conv frontend stubbed.

The modality frontend (log-mel + conv downsampling) is a STUB: input_specs()
provides precomputed frame embeddings (B, 1500, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,           # decoder layers
    encoder_layers=32,
    encoder_frames=1500,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    head_dim=64,
    act="gelu",
    norm="layernorm",
    gated_mlp=False,
)
