"""Jamba-v0.1-52B [arXiv:2403.19887; hf] — Mamba+attention 1:7, MoE 16e top-2.

Period of 8 layers: 1 attention + 7 Mamba; MoE replaces the dense FFN on
every 2nd layer (16 MoE layers total).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    moe_num_experts=16,
    moe_top_k=2,
    moe_every=2,
    attn_every=8,          # 1 attention layer per period of 8
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
)
