"""Config system: model architecture + input-shape + run configs.

Every assigned architecture is a ``ModelConfig`` in its own module
(``repro/configs/<id>.py``); the registry maps ``--arch`` ids to configs and
owns the official input-shape set. ``reduced()`` derives the family-preserving
tiny config used by the per-arch CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # --- MoE ---
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_num_shared: int = 0
    moe_every: int = 1             # apply MoE every k-th FFN (jamba: 2)
    capacity_factor: float = 1.25
    # --- attention pattern ---
    sliding_window: int = 0        # >0: local-attention window size
    local_global_ratio: int = 0    # gemma3: 5 local per 1 global
    qkv_bias: bool = False         # qwen2/2.5
    # --- ssm / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    attn_every: int = 0            # jamba: 1 attention layer per 8 (period)
    # --- encoder-decoder (audio) ---
    encoder_layers: int = 0
    encoder_frames: int = 1500     # whisper stub: precomputed frame embeddings
    # --- vlm ---
    mrope: bool = False
    vision_patches: int = 1024     # stub: precomputed patch embeddings
    # --- misc ---
    norm: str = "rmsnorm"
    act: str = "silu"
    gated_mlp: bool = True
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        if self.num_heads == 0:
            return 0
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a 128 multiple so the vocab dim shards cleanly."""
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / windowed attention)."""
        return self.family in ("ssm", "hybrid") or self.local_global_ratio > 0

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), used by roofline."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        q = d * self.num_heads * hd + (0 if not self.qkv_bias else self.num_heads * hd)
        kv = 2 * (d * self.num_kv_heads * hd + (0 if not self.qkv_bias else self.num_kv_heads * hd))
        o = self.num_heads * hd * d
        attn = q + kv + o
        nmat = 3 if self.gated_mlp else 2
        dense_mlp = nmat * d * ff  # gated (w_in, w_gate, w_out) or plain (w_in, w_out)
        moe_mlp = 0
        if self.moe_num_experts:
            expert = nmat * d * ff
            moe_mlp = self.moe_num_experts * expert + d * self.moe_num_experts
            moe_mlp += self.moe_num_shared * expert
        ssm = 0
        if self.ssm_state:
            di, N, H = self.ssm_d_inner, self.ssm_state, self.ssm_num_heads
            ssm = d * (2 * di + 2 * N + H) + di * d + di + 2 * H  # in/out proj, B,C, dt, A, D

        def block_cost(has_attn: bool, has_moe: bool, has_ssm: bool) -> int:
            c = 2 * d  # norms
            if has_attn:
                c += attn
            if has_ssm:
                c += ssm
            c += moe_mlp if has_moe else dense_mlp
            return c

        total = V * d  # embedding
        if not self.tie_embeddings:
            total += V * d
        L = self.num_layers
        if self.family == "ssm":
            total += L * (ssm + 2 * d) + d
            return total
        if self.family == "hybrid":
            period = self.attn_every or 8
            n_attn = L // period
            n_ssm = L - n_attn
            n_moe = L // max(self.moe_every, 1) if self.moe_num_experts else 0
            total += n_attn * (attn + 2 * d) + n_ssm * (ssm + 2 * d)
            total += n_moe * moe_mlp + (L - n_moe) * dense_mlp
            return total + d
        if self.encoder_layers:
            total += self.encoder_layers * (attn + dense_mlp + 2 * d)
            total += L * (2 * attn + dense_mlp + 3 * d)  # self+cross attn
            return total + 2 * d
        if self.moe_num_experts:
            total += L * (attn + moe_mlp + 2 * d)
            return total + d
        total += L * block_cost(True, False, False)
        return total + d

    def active_param_count(self) -> int:
        """Active-per-token params (MoE top-k) for MODEL_FLOPS = 6*N_active*D."""
        if not self.moe_num_experts:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        expert = (3 if self.gated_mlp else 2) * d * ff
        inert = (self.moe_num_experts - self.moe_top_k) * expert
        n_moe_layers = (
            self.num_layers // max(self.moe_every, 1)
            if self.family != "hybrid"
            else self.num_layers // max(self.moe_every, 1)
        )
        return self.param_count() - n_moe_layers * inert

    def reduced(self) -> "ModelConfig":
        """Family-preserving tiny config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            num_layers=max(2, min(4, self.num_layers)),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(4, max(1, self.num_kv_heads // max(1, self.num_heads // 4))),
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            moe_num_experts=8 if self.moe_num_experts else 0,
            moe_top_k=min(2, self.moe_top_k) if self.moe_top_k else 0,
            moe_num_shared=min(1, self.moe_num_shared),
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=16,
            sliding_window=32 if self.sliding_window else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_frames=24 if self.encoder_layers else 1500,
            vision_patches=16,
            attn_every=4 if self.attn_every else 0,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = (
    "deepseek_moe_16b",
    "olmoe_1b_7b",
    "whisper_large_v3",
    "smollm_360m",
    "granite_20b",
    "qwen25_14b",
    "gemma3_27b",
    "jamba_v01_52b",
    "qwen2_vl_7b",
    "mamba2_130m",
)

# canonical --arch spellings (hyphens) map to module names
_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
_ALIASES.update(
    {
        "deepseek-moe-16b": "deepseek_moe_16b",
        "olmoe-1b-7b": "olmoe_1b_7b",
        "whisper-large-v3": "whisper_large_v3",
        "smollm-360m": "smollm_360m",
        "granite-20b": "granite_20b",
        "qwen2.5-14b": "qwen25_14b",
        "gemma3-27b": "gemma3_27b",
        "jamba-v0.1-52b": "jamba_v01_52b",
        "qwen2-vl-7b": "qwen2_vl_7b",
        "mamba2-130m": "mamba2_130m",
    }
)


def get_config(arch: str) -> ModelConfig:
    mod_name = _ALIASES.get(arch, arch).replace("-", "_").replace(".", "")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def applicable_shapes(cfg: ModelConfig) -> Tuple[str, ...]:
    """The official (arch x shape) cells. long_500k only for sub-quadratic."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")
    return tuple(names)


def all_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            yield arch, shape
