"""Granite-20B code [arXiv:2405.04324; hf] — MQA (kv=1), wide FFN."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    gated_mlp=False,
)
