"""The paper's own experimental configuration (section IV): 8 NetFPGA nodes,
Intel i5-2400 hosts, directly-connected 1GbE testbed, OSU-microbenchmark-style
back-to-back MPI_Scan at small message sizes.

Used by the benchmark suite (benchmarks/scan_latency.py mirrors these
parameters) and by examples/quickstart.py.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperSetup:
    n_ranks: int = 8
    msg_bytes: tuple = (4, 16, 64, 256, 1024)
    algorithms: tuple = (
        "sequential",            # Open MPI default (paper II-B1)
        "recursive_doubling",    # MPICH (paper II-B2)
        "binomial_tree",         # paper II-B3
    )
    iters: int = 10_000_000      # paper: 10M back-to-back calls
    link_gbps: float = 1.0       # 1GbE
    nic_clock_mhz: float = 125.0  # 8ns timer resolution


CONFIG = PaperSetup()
