"""Qwen2-VL-7B [arXiv:2409.12191; hf] — M-RoPE; vision frontend stubbed.

The dynamic-resolution ViT frontend is a STUB: input_specs() provides
precomputed patch embeddings prepended to the token sequence; M-RoPE position
ids (temporal/height/width) arrive as inputs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    mrope=True,
    vision_patches=1024,
    rope_theta=1e6,
)
