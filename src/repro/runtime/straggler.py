"""Straggler detection: per-step wall-time EWMA with deviation triggers.

On a real pod a straggling host shows up as a slow step for EVERYONE (SPMD
collectives synchronize), so detection is local: track the step-time EWMA and
flag steps beyond ``threshold`` x the running mean. The trainer's response
policy, in order: log -> skip non-critical work (eval/checkpoint deferral) ->
after ``evict_after`` consecutive flags, report the host for eviction (which
triggers the elastic re-mesh path in fault.py).

Flag and evict events are routed through the flight recorder
(:mod:`repro.obs.events`, kinds ``straggler_flag`` / ``straggler_evict``)
so they survive into crash dumps; ``events`` keeps a *bounded* local ring
(the newest ``max_events``) for direct inspection. For naming *which link*
is slow rather than which step, see
:class:`repro.obs.health.LinkStragglerDetector`.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Optional

from repro.obs import events as obs_events

#: retained flag events per detector — diagnosis ring, not a history
MAX_EVENTS = 256


@dataclasses.dataclass
class StragglerDetector:
    alpha: float = 0.1          # EWMA weight
    threshold: float = 2.5      # x mean -> flagged
    evict_after: int = 5        # consecutive flags -> evict recommendation
    warmup: int = 3             # ignore first steps (compile, cache warm)
    max_events: int = MAX_EVENTS

    _ewma: Optional[float] = None
    _seen: int = 0
    _consecutive: int = 0
    events: Deque[dict] = dataclasses.field(default=None)  # set post-init

    def __post_init__(self) -> None:
        if self.events is None:
            self.events = collections.deque(maxlen=int(self.max_events))

    def observe(self, step: int, dt: float) -> dict:
        """Feed one step duration; returns {flagged, evict, ewma}."""
        self._seen += 1
        if self._seen <= self.warmup:
            return {"flagged": False, "evict": False, "ewma": dt}
        if self._ewma is None:
            self._ewma = dt
        flagged = dt > self.threshold * self._ewma
        if flagged:
            self._consecutive += 1
            self.events.append({"step": step, "dt": dt, "ewma": self._ewma})
            obs_events.record(
                "straggler_flag", step=int(step), dt=round(dt, 6),
                ewma=round(self._ewma, 6),
            )
        else:
            self._consecutive = 0
            self._ewma = (1 - self.alpha) * self._ewma + self.alpha * dt
        evict = self._consecutive >= self.evict_after
        if evict and self._consecutive == self.evict_after:
            obs_events.record(
                "straggler_evict", step=int(step),
                consecutive=self._consecutive,
            )
        return {
            "flagged": flagged,
            "evict": evict,
            "ewma": self._ewma,
        }
