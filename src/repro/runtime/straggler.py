"""Straggler detection: per-step wall-time EWMA with deviation triggers.

On a real pod a straggling host shows up as a slow step for EVERYONE (SPMD
collectives synchronize), so detection is local: track the step-time EWMA and
flag steps beyond ``threshold`` x the running mean. The trainer's response
policy, in order: log -> skip non-critical work (eval/checkpoint deferral) ->
after ``evict_after`` consecutive flags, report the host for eviction (which
triggers the elastic re-mesh path in fault.py).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass
class StragglerDetector:
    alpha: float = 0.1          # EWMA weight
    threshold: float = 2.5      # x mean -> flagged
    evict_after: int = 5        # consecutive flags -> evict recommendation
    warmup: int = 3             # ignore first steps (compile, cache warm)

    _ewma: Optional[float] = None
    _seen: int = 0
    _consecutive: int = 0
    events: List[dict] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> dict:
        """Feed one step duration; returns {flagged, evict, ewma}."""
        self._seen += 1
        if self._seen <= self.warmup:
            return {"flagged": False, "evict": False, "ewma": dt}
        if self._ewma is None:
            self._ewma = dt
        flagged = dt > self.threshold * self._ewma
        if flagged:
            self._consecutive += 1
            self.events.append({"step": step, "dt": dt, "ewma": self._ewma})
        else:
            self._consecutive = 0
            self._ewma = (1 - self.alpha) * self._ewma + self.alpha * dt
        return {
            "flagged": flagged,
            "evict": self._consecutive >= self.evict_after,
            "ewma": self._ewma,
        }
