"""Failure injection, detection, and elastic re-mesh planning.

Real clusters lose hosts; the contract here is:
  * any step may raise (SimulatedFailure stands in for a dead host / ICI
    timeout / preemption);
  * the trainer catches, consults ``plan_remesh`` for a degraded-but-valid
    mesh (shrink the data axis — TP degree is fixed by the model's layout),
  * rebuilds jitted steps on the new topology and restores the latest
    checkpoint with the NEW shardings (CheckpointManager.restore handles the
    re-layout), then continues.

A re-mesh also invalidates everything the offload subsystem derived from the
old topology: cached collective plans key on axis sizes, and the tuning
table's (p, payload) grid no longer matches the surviving mesh. Interested
parties (``launch.offload_runtime`` wires the engine + a budgeted re-tune)
subscribe with :func:`register_remesh_listener`; whoever *adopts* a new
topology (the trainer's recovery path) fires :func:`notify_remesh` with the
applied axis sizes — ``plan_remesh`` itself is a pure feasibility query.
Listeners must never block recovery — exceptions are swallowed into
:data:`remesh_listener_errors`.

Straggler mitigation lives in runtime/straggler.py; here we only decide
membership.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

from repro.obs import events as obs_events

RemeshListener = Callable[[Tuple[int, ...], Tuple[int, ...]], None]

_REMESH_LISTENERS: List[RemeshListener] = []

#: (listener, exception) pairs from listeners that raised during notify
remesh_listener_errors: List[Tuple[RemeshListener, Exception]] = []


def register_remesh_listener(fn: RemeshListener) -> RemeshListener:
    """Subscribe ``fn(old_axes, new_axes)`` to re-mesh plans; returns ``fn``
    so it can be handed back to :func:`unregister_remesh_listener`."""
    _REMESH_LISTENERS.append(fn)
    return fn


def unregister_remesh_listener(fn: RemeshListener) -> None:
    try:
        _REMESH_LISTENERS.remove(fn)
    except ValueError:
        pass


def notify_remesh(
    old_axes: Tuple[int, ...], new_axes: Tuple[int, ...]
) -> None:
    """Fire every registered listener; a failing listener is recorded in
    ``remesh_listener_errors`` and never interrupts recovery.

    The event lands in the flight recorder, and — since a re-mesh means a
    recovery is in progress — the recorder auto-dumps its ring to
    ``$REPRO_FLIGHT_RECORD`` (if set) *before* listeners run, so even a
    listener wedging the process leaves a post-mortem on disk."""
    obs_events.record(
        "remesh", old_axes=tuple(old_axes), new_axes=tuple(new_axes)
    )
    obs_events.auto_dump("remesh")
    for fn in list(_REMESH_LISTENERS):
        try:
            fn(old_axes, new_axes)
        except Exception as e:  # pragma: no cover - defensive
            remesh_listener_errors.append((fn, e))


class SimulatedFailure(RuntimeError):
    """Stands in for a lost host / hung collective.

    ``lost_hosts`` is the failure-detector's estimate of how many hosts the
    event took out — the recovery path feeds it to :func:`plan_remesh` so the
    feasibility query is about the *actual* surviving capacity.
    """

    lost_hosts: int = 1


def _collective_error_types() -> Tuple[type, ...]:
    """The runtime-error family a dead host surfaces as through jax.

    A hung or torn collective does not raise SimulatedFailure — it comes back
    as the XLA runtime error wrapping the failed all-reduce/ppermute. Both
    spellings (jax.errors.JaxRuntimeError and the older
    jaxlib XlaRuntimeError) are included when present.
    """
    errs: List[type] = [SimulatedFailure]
    try:
        import jax.errors as _je

        errs.append(_je.JaxRuntimeError)
    except (ImportError, AttributeError):  # pragma: no cover - old jax
        pass
    try:
        from jaxlib.xla_extension import XlaRuntimeError as _Xla

        if not any(issubclass(_Xla, e) or issubclass(e, _Xla) for e in errs):
            errs.append(_Xla)
    except ImportError:  # pragma: no cover - jaxlib layout drift
        pass
    return tuple(errs)


#: exception types the trainer's recovery loop treats as a host failure
RECOVERABLE_ERRORS: Tuple[type, ...] = _collective_error_types()

#: XLA status codes that signal a caller bug or resource problem, not a
#: dead host — a runtime error carrying one must propagate, never remesh
_NON_FAILURE_CODES = (
    "RESOURCE_EXHAUSTED",
    "INVALID_ARGUMENT",
    "NOT_FOUND",
    "ALREADY_EXISTS",
    "UNIMPLEMENTED",
    "PERMISSION_DENIED",
    "OUT_OF_RANGE",
)


def is_recoverable(err: BaseException) -> bool:
    """Whether the recovery loop should treat ``err`` as a host failure.

    SimulatedFailure always is. A jax/XLA runtime error is, *unless* its
    status code marks a non-transient caller problem (OOM, shape bugs, ...)
    — shrinking the mesh and rolling back a checkpoint would mask those.
    """
    if isinstance(err, SimulatedFailure):
        return True
    if not isinstance(err, RECOVERABLE_ERRORS):
        return False
    msg = str(err)
    return not any(code in msg for code in _NON_FAILURE_CODES)


@dataclasses.dataclass
class FailureInjector:
    """Raises at configured step numbers (once each).

    ``lost_hosts`` stamps the raised SimulatedFailure; ``exc_factory``
    substitutes an arbitrary exception (e.g. a JaxRuntimeError) to exercise
    the collective-error recovery path.
    """

    fail_at: Tuple[int, ...] = ()
    lost_hosts: int = 1
    exc_factory: Optional[Callable[[int], BaseException]] = None
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            if self.exc_factory is not None:
                raise self.exc_factory(step)
            err = SimulatedFailure(f"injected failure at step {step}")
            err.lost_hosts = self.lost_hosts
            raise err


def plan_remesh(
    data_axis: int, model_axis: int, lost_hosts: int, hosts_per_slice: int = 1
) -> Optional[Tuple[int, int]]:
    """New (data, model) axis sizes after losing hosts.

    The model axis is load-bearing (parameter layout); we only shrink the
    data axis, to the largest power-of-two that the surviving hosts support.
    Returns None when no valid mesh remains. Pure: planning is a feasibility
    query — whoever *adopts* a plan calls :func:`notify_remesh` with the
    applied topology (the trainer's recovery path does).
    """
    surviving = data_axis - lost_hosts * hosts_per_slice
    if surviving < 1:
        return None
    new_data = 1 << (surviving.bit_length() - 1)  # floor pow2
    return (new_data, model_axis)


def rescale_batch(global_batch: int, old_data: int, new_data: int) -> int:
    """Keep per-replica batch fixed; the global batch shrinks with the mesh.

    (Alternative — fixed global batch with more grad accumulation — is a
    config flag in the trainer.)
    """
    per = global_batch // old_data
    return per * new_data
