"""Failure injection, detection, and elastic re-mesh planning.

Real clusters lose hosts; the contract here is:
  * any step may raise (SimulatedFailure stands in for a dead host / ICI
    timeout / preemption);
  * the trainer catches, consults ``plan_remesh`` for a degraded-but-valid
    mesh (shrink the data axis — TP degree is fixed by the model's layout),
  * rebuilds jitted steps on the new topology and restores the latest
    checkpoint with the NEW shardings (CheckpointManager.restore handles the
    re-layout), then continues.

A re-mesh also invalidates everything the offload subsystem derived from the
old topology: cached collective plans key on axis sizes, and the tuning
table's (p, payload) grid no longer matches the surviving mesh. Interested
parties (``launch.offload_runtime`` wires the engine + a budgeted re-tune)
subscribe with :func:`register_remesh_listener`; whoever *adopts* a new
topology (the trainer's recovery path) fires :func:`notify_remesh` with the
applied axis sizes — ``plan_remesh`` itself is a pure feasibility query.
Listeners must never block recovery — exceptions are swallowed into
:data:`remesh_listener_errors`.

Straggler mitigation lives in runtime/straggler.py; here we only decide
membership.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.core.packet import IntegrityError
from repro.obs import events as obs_events
from repro.runtime.chaos import TransportError

RemeshListener = Callable[[Tuple[int, ...], Tuple[int, ...]], None]

_REMESH_LISTENERS: List[RemeshListener] = []

#: (listener, exception) pairs from listeners that raised during notify
remesh_listener_errors: List[Tuple[RemeshListener, Exception]] = []


def register_remesh_listener(fn: RemeshListener) -> RemeshListener:
    """Subscribe ``fn(old_axes, new_axes)`` to re-mesh plans; returns ``fn``
    so it can be handed back to :func:`unregister_remesh_listener`."""
    _REMESH_LISTENERS.append(fn)
    return fn


def unregister_remesh_listener(fn: RemeshListener) -> None:
    try:
        _REMESH_LISTENERS.remove(fn)
    except ValueError:
        pass


def notify_remesh(
    old_axes: Tuple[int, ...], new_axes: Tuple[int, ...]
) -> None:
    """Fire every registered listener; a failing listener is recorded in
    ``remesh_listener_errors`` and never interrupts recovery.

    The event lands in the flight recorder, and — since a re-mesh means a
    recovery is in progress — the recorder auto-dumps its ring to
    ``$REPRO_FLIGHT_RECORD`` (if set) *before* listeners run, so even a
    listener wedging the process leaves a post-mortem on disk."""
    obs_events.record(
        "remesh", old_axes=tuple(old_axes), new_axes=tuple(new_axes)
    )
    obs_events.auto_dump("remesh")
    for fn in list(_REMESH_LISTENERS):
        try:
            fn(old_axes, new_axes)
        except Exception as e:  # pragma: no cover - defensive
            remesh_listener_errors.append((fn, e))


class SimulatedFailure(RuntimeError):
    """Stands in for a lost host / hung collective.

    ``lost_hosts`` is the failure-detector's estimate of how many hosts the
    event took out — the recovery path feeds it to :func:`plan_remesh` so the
    feasibility query is about the *actual* surviving capacity.
    """

    lost_hosts: int = 1


def _collective_error_types() -> Tuple[type, ...]:
    """The runtime-error family a dead host surfaces as through jax.

    A hung or torn collective does not raise SimulatedFailure — it comes back
    as the XLA runtime error wrapping the failed all-reduce/ppermute. Both
    spellings (jax.errors.JaxRuntimeError and the older
    jaxlib XlaRuntimeError) are included when present.
    """
    errs: List[type] = [SimulatedFailure]
    try:
        import jax.errors as _je

        errs.append(_je.JaxRuntimeError)
    except (ImportError, AttributeError):  # pragma: no cover - old jax
        pass
    try:
        from jaxlib.xla_extension import XlaRuntimeError as _Xla

        if not any(issubclass(_Xla, e) or issubclass(e, _Xla) for e in errs):
            errs.append(_Xla)
    except ImportError:  # pragma: no cover - jaxlib layout drift
        pass
    return tuple(errs)


#: exception types the trainer's recovery loop treats as a host failure
RECOVERABLE_ERRORS: Tuple[type, ...] = _collective_error_types()

#: XLA status codes that signal a caller bug or resource problem, not a
#: dead host — a runtime error carrying one must propagate, never remesh
_NON_FAILURE_CODES = (
    "RESOURCE_EXHAUSTED",
    "INVALID_ARGUMENT",
    "NOT_FOUND",
    "ALREADY_EXISTS",
    "UNIMPLEMENTED",
    "PERMISSION_DENIED",
    "OUT_OF_RANGE",
)

#: reliability-layer faults are *transport/data* problems the dispatch
#: layer owns (retry, degrade, quarantine) — never host failures. A remesh
#: would roll back a checkpoint to "fix" a corrupt payload. These are
#: checked both as types and as message markers (for wrapped runtime
#: errors that only carry the upstream error's text).
_NON_RECOVERABLE_TYPES: Tuple[type, ...] = (IntegrityError, TransportError)

_NON_RECOVERABLE_MARKERS = (
    "IntegrityError",
    "TransportError",
    "RetryExhausted",
    "CircuitOpen",
    "checksum mismatch",
)

_REL_ERRORS: Optional[Tuple[type, ...]] = None


def _reliability_error_types() -> Tuple[type, ...]:
    """RetryExhaustedError/CircuitOpenError, imported lazily: fault.py
    loads at ``repro.runtime`` init, before ``repro.offload`` may exist."""
    global _REL_ERRORS
    if _REL_ERRORS is None:
        try:
            from repro.offload.reliability import (
                CircuitOpenError,
                RetryExhaustedError,
            )

            _REL_ERRORS = (RetryExhaustedError, CircuitOpenError)
        except Exception:  # pragma: no cover - partial-import window
            return ()
    return _REL_ERRORS


def is_recoverable(err: BaseException) -> bool:
    """Whether the recovery loop should treat ``err`` as a host failure.

    SimulatedFailure always is. Reliability-layer faults — IntegrityError,
    TransportError, retry exhaustion, open circuits — never are: they are
    per-request dispatch problems with their own handling (retry /
    degrade / quarantine), and swallowing them as remesh triggers would
    shrink the mesh over a corrupt payload. A jax/XLA runtime error is
    recoverable *unless* its status code (or wrapped message) marks a
    non-transient caller problem (OOM, shape bugs, ...) or a wrapped
    reliability fault.
    """
    if isinstance(err, SimulatedFailure):
        return True
    if isinstance(err, _NON_RECOVERABLE_TYPES):
        return False
    if isinstance(err, _reliability_error_types()):
        return False
    if not isinstance(err, RECOVERABLE_ERRORS):
        return False
    msg = str(err)
    if any(marker in msg for marker in _NON_RECOVERABLE_MARKERS):
        return False
    return not any(code in msg for code in _NON_FAILURE_CODES)


@dataclasses.dataclass
class FailureInjector:
    """Raises at configured step numbers (once each) and, optionally,
    probabilistically per dispatch.

    ``lost_hosts`` stamps the raised SimulatedFailure; ``exc_factory``
    substitutes an arbitrary exception (e.g. a JaxRuntimeError, or a
    TransportError to exercise the dispatch layer's retry path) to
    exercise the matching recovery path.

    ``rate``/``seed`` enable the sub-step-granular mode: the reliable
    dispatcher calls :meth:`check_dispatch` before every dispatch attempt,
    and each call draws a deterministic seeded verdict keyed by ``(seed,
    dispatch_index)`` — the same injector config always fails the same
    dispatches, so chaos runs are reproducible.
    """

    fail_at: Tuple[int, ...] = ()
    lost_hosts: int = 1
    exc_factory: Optional[Callable[[int], BaseException]] = None
    rate: float = 0.0
    seed: int = 0
    _fired: set = dataclasses.field(default_factory=set)
    _dispatches: int = 0

    def check(self, step: int) -> None:
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            if self.exc_factory is not None:
                raise self.exc_factory(step)
            err = SimulatedFailure(f"injected failure at step {step}")
            err.lost_hosts = self.lost_hosts
            raise err

    def check_dispatch(self) -> None:
        """Probabilistic per-dispatch injection (seeded, deterministic).

        Advances the dispatch counter on every call — retried attempts
        draw fresh verdicts, exactly like real transient faults.
        """
        if self.rate <= 0.0:
            return
        n = self._dispatches
        self._dispatches += 1
        u = np.random.default_rng((int(self.seed), n)).random()
        if u < self.rate:
            obs_events.record("chaos_fault", fault="dispatch", msg=n)
            if self.exc_factory is not None:
                raise self.exc_factory(n)
            err = SimulatedFailure(f"injected dispatch failure (#{n})")
            err.lost_hosts = self.lost_hosts
            raise err


def plan_remesh(
    data_axis: int, model_axis: int, lost_hosts: int, hosts_per_slice: int = 1
) -> Optional[Tuple[int, int]]:
    """New (data, model) axis sizes after losing hosts.

    The model axis is load-bearing (parameter layout); we only shrink the
    data axis, to the largest power-of-two that the surviving hosts support.
    Returns None when no valid mesh remains. Pure: planning is a feasibility
    query — whoever *adopts* a plan calls :func:`notify_remesh` with the
    applied topology (the trainer's recovery path does).
    """
    surviving = data_axis - lost_hosts * hosts_per_slice
    if surviving < 1:
        return None
    new_data = 1 << (surviving.bit_length() - 1)  # floor pow2
    return (new_data, model_axis)


def rescale_batch(global_batch: int, old_data: int, new_data: int) -> int:
    """Keep per-replica batch fixed; the global batch shrinks with the mesh.

    (Alternative — fixed global batch with more grad accumulation — is a
    config flag in the trainer.)
    """
    per = global_batch // old_data
    return per * new_data
