"""Failure injection, detection, and elastic re-mesh planning.

Real clusters lose hosts; the contract here is:
  * any step may raise (SimulatedFailure stands in for a dead host / ICI
    timeout / preemption);
  * the trainer catches, consults ``plan_remesh`` for a degraded-but-valid
    mesh (shrink the data axis — TP degree is fixed by the model's layout),
  * rebuilds jitted steps on the new topology and restores the latest
    checkpoint with the NEW shardings (CheckpointManager.restore handles the
    re-layout), then continues.

Straggler mitigation lives in runtime/straggler.py; here we only decide
membership.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple


class SimulatedFailure(RuntimeError):
    """Stands in for a lost host / hung collective."""


@dataclasses.dataclass
class FailureInjector:
    """Raises at configured step numbers (once each)."""

    fail_at: Tuple[int, ...] = ()
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


def plan_remesh(
    data_axis: int, model_axis: int, lost_hosts: int, hosts_per_slice: int = 1
) -> Optional[Tuple[int, int]]:
    """New (data, model) axis sizes after losing hosts.

    The model axis is load-bearing (parameter layout); we only shrink the
    data axis, to the largest power-of-two that the surviving hosts support.
    Returns None when no valid mesh remains.
    """
    surviving = data_axis - lost_hosts * hosts_per_slice
    if surviving < 1:
        return None
    new_data = 1 << (surviving.bit_length() - 1)  # floor pow2
    return (new_data, model_axis)


def rescale_batch(global_batch: int, old_data: int, new_data: int) -> int:
    """Keep per-replica batch fixed; the global batch shrinks with the mesh.

    (Alternative — fixed global batch with more grad accumulation — is a
    config flag in the trainer.)
    """
    per = global_batch // old_data
    return per * new_data
