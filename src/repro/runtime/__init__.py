from repro.runtime.fault import FailureInjector, SimulatedFailure, plan_remesh, rescale_batch
from repro.runtime.straggler import StragglerDetector
from repro.runtime.train_loop import Trainer, TrainerConfig
