"""Message-level chaos injection for the sim backend's eager interpreter.

The paper's NetFPGA moves descriptors and partial sums over raw Ethernet
media-access frames — a medium that drops, duplicates, reorders, corrupts,
and delays packets. The stack's reliability layer (`repro.offload.
reliability`, the broker's retry/bisection path) claims to survive that;
this module is the adversary that keeps the claim honest.

:class:`ChaosInjector` perturbs *individual messages* — one (src, dst)
pair of one communication round — on the sim backend's eager/traced
interpreter path (``repro.offload.planner.lower_sim(traced=True)``; the
engine routes planned sim dispatches through it whenever an injector
scope is active, under the same eager cache key the tracer uses). Five
fault kinds, each with an independent seeded rate (a float or a
:class:`RateSchedule` over the injector's global message counter):

``drop``       the message never arrives. Unless ``silent``, the sender's
               delivery timeout surfaces as :class:`TransportError` — the
               software analogue of a NIC ACK/retransmit protocol
               declaring the link dead (PAPERS.md, cs/0402027). Silent
               drops deliver the permute's zero fill (exactly what a lost
               ppermute in-edge looks like).
``duplicate``  the message is delivered twice. Benign by construction:
               the sim permute's per-destination row *set* is idempotent,
               which is the receiver-side dedup contract.
``reorder``    messages within the round are delivered in reversed
               order. Benign: a round's destinations are unique, so
               delivery order cannot change the merged result.
``corrupt``    one bit of the payload row flips in flight. Unless
               ``silent``, the modeled receiver-side CRC rejects the
               message as :class:`~repro.core.packet.IntegrityError`.
               Silent corruption actually flips the delivered bit — the
               demonstration of why the broker checksums payloads.
``delay``      ``delay_s`` seconds of extra latency (plus any per-link
               ``delays`` table entry — the delay table *is* the old
               ``repro.obs.health.LinkDelayInjector`` contract, so a
               ChaosInjector drops into ``Tracer(link_injector=...)`` and
               every other place the delay-only injector was used).

Faults are deterministic: each message's decision derives from
``(seed, message_index, axis, src, dst)``, so a run either always passes
or always fails for a given seed and dispatch order — chaos tests are
reproducible, never flaky. A retry naturally advances the message
counter, so a retried dispatch draws fresh (usually clean) decisions:
that is what lets the CI gate demand *bitwise* recovery under sustained
fault rates.

Every injected fault is recorded in the flight recorder (``chaos_fault``
events) and counted in ``repro_chaos_faults_total{fault=...}``.

Scope: install with ``with injector.scope(): ...`` (or
:func:`set_injector` for manual control). The scope is process-global,
like the tracer — the broker's flush thread must see the injector the
test thread installed.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.packet import IntegrityError
from repro.obs import events as obs_events
from repro.obs import metrics as obs_metrics

__all__ = [
    "ChaosBackend",
    "ChaosInjector",
    "RateSchedule",
    "TransportError",
    "active",
    "get_injector",
    "set_injector",
]

LinkKey = Tuple[int, int, int]  # (axis/level, src, dst)


class TransportError(RuntimeError):
    """A message was lost in flight (modeled NIC delivery timeout).

    Raised by :class:`ChaosBackend` for non-silent drops; the reliability
    layer treats it as retryable (a retransmit fixes a lost frame) and the
    recovery loop treats it as **non**-recoverable (losing a message is
    not losing a host — see ``repro.runtime.fault.is_recoverable``).
    """


class RateSchedule:
    """A fault rate as a function of the injector's message counter.

    Plain floats are constant rates; schedules let a test script a fault
    *storm* (e.g. 100% drop for the first N messages, then clean) so
    breaker trip/recovery cycles are driven deterministically.
    """

    def __init__(self, fn: Callable[[int], float]):
        self._fn = fn

    def __call__(self, n: int) -> float:
        return float(self._fn(n))

    @classmethod
    def constant(cls, rate: float) -> "RateSchedule":
        r = float(rate)
        return cls(lambda _n: r)

    @classmethod
    def burst(cls, rate: float, until: int) -> "RateSchedule":
        """``rate`` for the first ``until`` messages, 0 afterwards."""
        r, u = float(rate), int(until)
        return cls(lambda n: r if n < u else 0.0)

    @classmethod
    def steps(cls, steps: List[Tuple[int, float]]) -> "RateSchedule":
        """Piecewise-constant: ``[(until_n, rate), ...]`` in order; a
        message index past every step gets rate 0."""
        table = [(int(u), float(r)) for u, r in steps]

        def fn(n: int) -> float:
            for until, rate in table:
                if n < until:
                    return rate
            return 0.0

        return cls(fn)


def _as_rate(r: "float | RateSchedule | Callable[[int], float]") -> RateSchedule:
    if isinstance(r, RateSchedule):
        return r
    if callable(r):
        return RateSchedule(r)
    return RateSchedule.constant(float(r))


@dataclasses.dataclass
class FaultDecision:
    """The seeded verdict for one message."""

    drop: bool = False
    duplicate: bool = False
    reorder: bool = False
    corrupt: bool = False
    corrupt_bit: int = 0
    delay_s: float = 0.0

    @property
    def any(self) -> bool:
        return (
            self.drop or self.duplicate or self.reorder or self.corrupt
            or self.delay_s > 0.0
        )


class ChaosInjector:
    """Deterministic seeded per-message fault source (see module doc).

    Rates accept floats or :class:`RateSchedule`; ``links`` optionally
    restricts faults to a set of (axis, src, dst) keys. ``delays`` is the
    per-link delay table absorbed from ``LinkDelayInjector`` (same
    ``delay``/``set_delay`` protocol), applied *on top of* the rate-based
    ``delay`` fault.
    """

    def __init__(
        self,
        seed: int = 0,
        *,
        drop: "float | RateSchedule" = 0.0,
        duplicate: "float | RateSchedule" = 0.0,
        reorder: "float | RateSchedule" = 0.0,
        corrupt: "float | RateSchedule" = 0.0,
        delay: "float | RateSchedule" = 0.0,
        delay_s: float = 0.001,
        delays: Optional[Dict[LinkKey, float]] = None,
        links: Optional[Any] = None,
        silent: bool = False,
        recorder: Optional[obs_events.FlightRecorder] = None,
    ):
        self.seed = int(seed)
        self.rates: Dict[str, RateSchedule] = {
            "drop": _as_rate(drop),
            "duplicate": _as_rate(duplicate),
            "reorder": _as_rate(reorder),
            "corrupt": _as_rate(corrupt),
            "delay": _as_rate(delay),
        }
        self.delay_fault_s = float(delay_s)
        self.delays: Dict[LinkKey, float] = {
            (int(a), int(s), int(d)): float(v)
            for (a, s, d), v in (delays or {}).items()
        }
        self.links = (
            None if links is None
            else {(int(a), int(s), int(d)) for a, s, d in links}
        )
        self.silent = bool(silent)
        self._recorder = recorder
        self._lock = threading.Lock()
        self.messages = 0
        self.counts: Dict[str, int] = {}

    # -- LinkDelayInjector protocol (absorbed) ----------------------------

    def set_delay(self, axis: int, src: int, dst: int, seconds: float) -> None:
        self.delays[(int(axis), int(src), int(dst))] = float(seconds)

    def delay(self, axis: int, src: int, dst: int) -> float:
        return self.delays.get((int(axis), int(src), int(dst)), 0.0)

    # -- decisions ---------------------------------------------------------

    @property
    def recorder(self) -> obs_events.FlightRecorder:
        if self._recorder is not None:
            return self._recorder
        return obs_events.get_recorder()

    def faults_injected(self) -> int:
        with self._lock:
            return sum(self.counts.values())

    def reset_counts(self) -> None:
        with self._lock:
            self.counts.clear()
            self.messages = 0

    def decide(self, axis: int, src: int, dst: int) -> FaultDecision:
        """The (deterministic) fault verdict for the next message on the
        given link; advances the global message counter."""
        key: LinkKey = (int(axis), int(src), int(dst))
        with self._lock:
            n = self.messages
            self.messages += 1
        if self.links is not None and key not in self.links:
            return FaultDecision()
        rng = np.random.default_rng((self.seed, n) + key)
        u = rng.random(5)
        dec = FaultDecision(
            drop=bool(u[0] < self.rates["drop"](n)),
            duplicate=bool(u[1] < self.rates["duplicate"](n)),
            reorder=bool(u[2] < self.rates["reorder"](n)),
            corrupt=bool(u[3] < self.rates["corrupt"](n)),
            corrupt_bit=int(rng.integers(0, 64)),
            delay_s=(
                self.delay_fault_s
                if u[4] < self.rates["delay"](n) else 0.0
            ),
        )
        if dec.any:
            self._note(dec, key, n)
        return dec

    def _note(self, dec: FaultDecision, key: LinkKey, n: int) -> None:
        counter = obs_metrics.get_registry().counter(
            "repro_chaos_faults_total",
            "chaos-injected message faults",
            labelnames=("fault",),
        )
        kinds = [
            k for k in ("drop", "duplicate", "reorder", "corrupt")
            if getattr(dec, k)
        ]
        if dec.delay_s > 0.0:
            kinds.append("delay")
        with self._lock:
            for k in kinds:
                self.counts[k] = self.counts.get(k, 0) + 1
        for k in kinds:
            counter.inc(fault=k)
            self.recorder.record(
                "chaos_fault",
                fault=k,
                axis=key[0],
                src=key[1],
                dst=key[2],
                msg=n,
                silent=self.silent,
            )

    # -- scope -------------------------------------------------------------

    @contextlib.contextmanager
    def scope(self) -> Iterator["ChaosInjector"]:
        """Install this injector process-globally for the block."""
        prev = set_injector(self)
        try:
            yield self
        finally:
            set_injector(prev)


_ACTIVE: Optional[ChaosInjector] = None


def set_injector(inj: Optional[ChaosInjector]) -> Optional[ChaosInjector]:
    """Install (or clear, with None) the global injector; returns the
    previous one so callers can restore it."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, inj
    return prev


def get_injector() -> Optional[ChaosInjector]:
    return _ACTIVE


def active() -> bool:
    """Whether a chaos scope is currently installed (the engine checks
    this to route planned sim dispatches onto the eager interpreter)."""
    return _ACTIVE is not None


# ---------------------------------------------------------------------------
# The lossy backend wrapper
# ---------------------------------------------------------------------------


def _flip_row_bit(tree: Any, dst: int, bit: int) -> Any:
    """Flip one bit of every leaf's ``dst`` row (bit index taken modulo
    the leaf's element width) — the silent-corruption payload mutation."""
    import jax
    import jax.numpy as jnp

    def leaf(a):
        nbits = a.dtype.itemsize * 8
        uint = jnp.dtype(f"uint{nbits}")
        iv = jax.lax.bitcast_convert_type(a[dst], uint)
        flipped = jnp.bitwise_xor(
            iv, jnp.asarray(np.uint64(1 << (bit % nbits))).astype(uint)
        )
        return a.at[dst].set(jax.lax.bitcast_convert_type(flipped, a.dtype))

    return jax.tree.map(leaf, tree)


class ChaosBackend:
    """Wrap a schedule :class:`~repro.core.algorithms.Backend` with the
    injector's per-message faults.

    Sits directly over ``SimBackend`` in the eager interpreter (under any
    tracing/link-probe wrappers, so probed single-pair permutes still get
    per-message decisions). A round raises at most once: the first
    non-silent drop wins (:class:`TransportError`), then the first
    non-silent corruption (:class:`IntegrityError`) — every message of
    the round still *draws* its decision first, so the seeded stream
    stays aligned across retries regardless of which fault fired.
    """

    def __init__(self, inner: Any, injector: ChaosInjector, *, level: int = 0):
        self.inner = inner
        self.injector = injector
        self.level = int(level)

    @property
    def p(self) -> int:
        return self.inner.p

    def rank(self):
        return self.inner.rank()

    def permute(self, tree: Any, perm: Any) -> Any:
        pairs = [(int(s), int(d)) for s, d in perm]
        if not pairs:
            return self.inner.permute(tree, perm)
        inj = self.injector
        decisions = [inj.decide(self.level, s, d) for s, d in pairs]
        total_delay = sum(f.delay_s for f in decisions) + sum(
            inj.delay(self.level, s, d) for s, d in pairs
        )
        if total_delay > 0.0:
            time.sleep(total_delay)
        if not inj.silent:
            for (s, d), f in zip(pairs, decisions):
                if f.drop:
                    raise TransportError(
                        f"chaos: message L{self.level} {s}->{d} dropped "
                        f"(delivery timeout; retransmit required)"
                    )
            for (s, d), f in zip(pairs, decisions):
                if f.corrupt:
                    raise IntegrityError(
                        f"chaos: message L{self.level} {s}->{d} failed "
                        f"receiver CRC (bit flip in flight)"
                    )
        kept = [
            p for p, f in zip(pairs, decisions) if not (f.drop and inj.silent)
        ]
        kept += [
            p
            for p, f in zip(pairs, decisions)
            if f.duplicate and not f.drop
        ]
        if any(f.reorder for f in decisions):
            kept = kept[::-1]
        out = self.inner.permute(tree, kept)
        if inj.silent:
            for (s, d), f in zip(pairs, decisions):
                if f.corrupt and not f.drop:
                    out = _flip_row_bit(out, d, f.corrupt_bit)
        return out
