"""Fault-tolerant training driver.

Checkpoint/restart + failure handling + elastic re-mesh + straggler watch,
composed over the pure step builders in launch/steps.py. The loop's contract:

  1. every ``ckpt_every`` steps: atomic async checkpoint (params+opt+step);
  2. a step raising SimulatedFailure (or any collective error) triggers:
     detect -> plan_remesh (shrink data axis) -> rebuild jitted step on the
     surviving topology -> restore latest checkpoint with NEW shardings ->
     continue (bounded retries);
  3. StragglerDetector watches step wall-times; eviction recommendations
     feed the same re-mesh path.

Works identically on the 1-device CPU smoke mesh and on a real pod — the
fault-injection integration test (tests/test_fault_tolerance.py) runs the
whole recovery path on CPU.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.steps import build_train_step
from repro.models import ModelApi, build_model
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.runtime import fault as fault_mod
from repro.runtime.fault import FailureInjector, SimulatedFailure, plan_remesh
from repro.runtime.straggler import StragglerDetector
from repro.sharding.specs import Topology, make_topology, use_topology


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep_ckpts: int = 3
    max_retries: int = 3
    log_every: int = 10
    async_ckpt: bool = True


class Trainer:
    def __init__(
        self,
        api: ModelApi,
        topo: Topology,
        shape: ShapeConfig,
        data_iter: Iterator[Dict[str, np.ndarray]],
        tcfg: TrainerConfig,
        opt_cfg: Optional[AdamWConfig] = None,
        injector: Optional[FailureInjector] = None,
    ):
        self.api = api
        self.topo = topo
        self.shape = shape
        self.data_iter = data_iter
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg or AdamWConfig()
        self.injector = injector
        self.ckpt = CheckpointManager(
            tcfg.ckpt_dir, keep=tcfg.keep_ckpts, async_write=tcfg.async_ckpt
        )
        self.straggler = StragglerDetector()
        self.remesh_events: list = []
        self._build()

    def _build(self):
        self.step_fn, _, self.specs = build_train_step(
            self.api, self.topo, self.shape, self.opt_cfg
        )

    def init_state(self, seed: int = 0):
        with use_topology(self.topo):
            params = self.api.init(jax.random.key(seed))
            opt_state = init_opt_state(params)
        return params, opt_state

    def maybe_restore(self, params, opt_state):
        latest = self.ckpt.latest_step()
        if latest is None:
            return 0, params, opt_state
        _, blob = self.ckpt.restore(
            {"params": params, "opt": opt_state}, step=latest
        )
        return latest, blob["params"], blob["opt"]

    # ------------------------------------------------------------------ run
    def run(self, params, opt_state, num_steps: int, start_step: int = 0):
        """Returns (final_params, final_opt, history). Fault-tolerant."""
        history = []
        step = start_step
        retries = 0
        while step < num_steps:
            batch = next(self.data_iter)
            t0 = time.perf_counter()
            try:
                if self.injector is not None:
                    self.injector.check(step)
                with use_topology(self.topo):
                    params, opt_state, metrics = self.step_fn(
                        params, opt_state, batch
                    )
                    metrics = jax.tree.map(float, metrics)
            except SimulatedFailure as e:
                retries += 1
                if retries > self.tcfg.max_retries:
                    raise
                self._recover(e)
                step, params, opt_state = self._restore_after_failure(
                    params, opt_state
                )
                continue
            dt = time.perf_counter() - t0
            verdict = self.straggler.observe(step, dt)
            metrics["step_time_s"] = dt
            metrics["straggler_flagged"] = verdict["flagged"]
            history.append({"step": step, **metrics})
            step += 1
            if step % self.tcfg.ckpt_every == 0 or step == num_steps:
                self.ckpt.save(
                    step, {"params": params, "opt": opt_state},
                    block=(step == num_steps),
                )
        self.ckpt.wait()
        return params, opt_state, history

    # ------------------------------------------------------------- recovery
    def _recover(self, err: Exception) -> None:
        """Shrink the data axis and rebuild the jitted step (elastic)."""
        mesh = self.topo.mesh
        if mesh is None:
            self.remesh_events.append({"err": str(err), "action": "none"})
            return
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        old_data = sizes.get("data", 1)
        model = sizes.get("model", 1)
        plan = plan_remesh(old_data, model, lost_hosts=0)
        new_data = max(1, old_data // 2) if old_data > 1 else 1
        if new_data != old_data:
            # the adopted topology invalidates offload plan caches and the
            # active tuning grid — fire the fault-layer listeners
            fault_mod.notify_remesh((old_data, model), (new_data, model))
        n_needed = new_data * sizes.get("model", 1)
        devices = np.asarray(mesh.devices).reshape(-1)[:n_needed]
        new_mesh = jax.sharding.Mesh(
            devices.reshape(new_data, sizes.get("model", 1)),
            ("data", "model"),
        )
        self.topo = make_topology(new_mesh)
        self.remesh_events.append(
            {"err": str(err), "old_data": old_data, "new_data": new_data,
             "plan": plan}
        )
        self._build()

    def _restore_after_failure(self, params, opt_state):
        self.ckpt.wait()
        latest = self.ckpt.latest_step()
        if latest is None:
            with use_topology(self.topo):
                params = self.api.init(jax.random.key(0))
                opt_state = init_opt_state(params)
            return 0, params, opt_state
        host_params = jax.tree.map(np.asarray, params)
        host_opt = jax.tree.map(np.asarray, opt_state)
        _, blob = self.ckpt.restore(
            {"params": host_params, "opt": host_opt}, step=latest
        )
        return latest, blob["params"], blob["opt"]
