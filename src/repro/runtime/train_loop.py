"""Fault-tolerant training driver.

Checkpoint/restart + failure handling + elastic re-mesh + straggler watch,
composed over the pure step builders in launch/steps.py. The loop's contract:

  1. every ``ckpt_every`` steps: atomic async checkpoint (params+opt+step);
  2. a step raising SimulatedFailure — or any error in the jax collective
     runtime-error family (``fault.RECOVERABLE_ERRORS``) — triggers the
     planner-first recovery sequence:
     fail -> ``plan_remesh`` (lost_hosts derived from the failure) ->
     **adopt the planned sizes** -> rebuild the step on the surviving
     topology -> ``notify_remesh`` (offload listeners clear plan caches and
     re-tune against the adopted mesh) -> restore the latest checkpoint with
     NEW shardings -> continue (bounded retries). The offload engine's
     cleared cache then repopulates from the trainer's own descriptors on
     the next step.
  3. StragglerDetector watches step wall-times; eviction recommendations
     feed the same re-mesh path.

With ``TrainerConfig.use_offload_engine`` the step's gradient/metric
collectives dispatch through an :class:`~repro.offload.OffloadEngine`
(see ``launch.steps.build_dp_train_step``); otherwise GSPMD derives them.

Works identically on the 1-device CPU smoke mesh and on a real pod — the
fault-injection integration test (tests/test_fault_tolerance.py) runs the
whole recovery path on CPU.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.steps import build_train_step
from repro.models import ModelApi, build_model
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.runtime import fault as fault_mod
from repro.runtime.fault import (
    RECOVERABLE_ERRORS,
    FailureInjector,
    SimulatedFailure,
    is_recoverable,
    plan_remesh,
)
from repro.runtime.straggler import StragglerDetector
from repro.sharding.specs import Topology, make_topology, use_topology


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep_ckpts: int = 3
    max_retries: int = 3
    log_every: int = 10
    async_ckpt: bool = True
    #: route the step's gradient/metric collectives through the offload
    #: engine as planned descriptors (requires a pure-DP mesh; a no-op
    #: without a mesh)
    use_offload_engine: bool = False


class Trainer:
    def __init__(
        self,
        api: ModelApi,
        topo: Topology,
        shape: ShapeConfig,
        data_iter: Iterator[Dict[str, np.ndarray]],
        tcfg: TrainerConfig,
        opt_cfg: Optional[AdamWConfig] = None,
        injector: Optional[FailureInjector] = None,
        engine: Any = None,
    ):
        self.api = api
        self.topo = topo
        self.shape = shape
        self.data_iter = data_iter
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg or AdamWConfig()
        self.injector = injector
        self.engine = engine
        self.ckpt = CheckpointManager(
            tcfg.ckpt_dir, keep=tcfg.keep_ckpts, async_write=tcfg.async_ckpt
        )
        self.straggler = StragglerDetector()
        self.remesh_events: list = []
        self._build()

    def _build(self):
        use_engine = (
            self.tcfg.use_offload_engine and self.topo.mesh is not None
        )
        if use_engine and self.engine is None:
            from repro.launch.offload_runtime import build_offload_engine

            self.engine = build_offload_engine()
        self.step_fn, _, self.specs = build_train_step(
            self.api, self.topo, self.shape, self.opt_cfg,
            use_offload_engine=use_engine,
            engine=self.engine if use_engine else None,
        )

    def init_state(self, seed: int = 0):
        with use_topology(self.topo):
            params = self.api.init(jax.random.key(seed))
            opt_state = init_opt_state(params)
        return params, opt_state

    def maybe_restore(self, params, opt_state):
        latest = self.ckpt.latest_step()
        if latest is None:
            return 0, params, opt_state
        _, blob = self.ckpt.restore(
            {"params": params, "opt": opt_state}, step=latest
        )
        return latest, blob["params"], blob["opt"]

    # ------------------------------------------------------------------ run
    def run(self, params, opt_state, num_steps: int, start_step: int = 0):
        """Returns (final_params, final_opt, history). Fault-tolerant."""
        history = []
        step = start_step
        retries = 0
        while step < num_steps:
            batch = next(self.data_iter)
            t0 = time.perf_counter()
            try:
                if self.injector is not None:
                    self.injector.check(step)
                with use_topology(self.topo):
                    params, opt_state, metrics = self.step_fn(
                        params, opt_state, batch
                    )
                    metrics = jax.tree.map(float, metrics)
            except RECOVERABLE_ERRORS as e:
                if not is_recoverable(e):
                    raise  # OOM / shape bugs: remeshing would mask them
                retries += 1
                if retries > self.tcfg.max_retries:
                    raise
                self._recover(e)
                step, params, opt_state = self._restore_after_failure(
                    params, opt_state
                )
                continue
            dt = time.perf_counter() - t0
            verdict = self.straggler.observe(step, dt)
            metrics["step_time_s"] = dt
            metrics["straggler_flagged"] = verdict["flagged"]
            history.append({"step": step, **metrics})
            step += 1
            if step % self.tcfg.ckpt_every == 0 or step == num_steps:
                self.ckpt.save(
                    step, {"params": params, "opt": opt_state},
                    block=(step == num_steps),
                )
        self.ckpt.wait()
        return params, opt_state, history

    # ------------------------------------------------------------- recovery
    def _recover(self, err: Exception) -> None:
        """Planner-first elastic re-mesh: adopt what ``plan_remesh`` returns.

        Sequence: derive ``lost_hosts`` from the failure -> ``plan_remesh``
        -> adopt the planned data-axis size (every other axis is
        load-bearing and kept) -> rebuild the step on the adopted topology
        -> ``notify_remesh`` so offload listeners invalidate plan caches and
        re-tune against the mesh that was *actually* adopted. Notify fires
        only after adopt+rebuild: listeners re-tune on the new topology, and
        the engine's cleared cache repopulates from the rebuilt step's own
        descriptors on the next step.
        """
        from repro.obs import events as obs_events

        obs_events.record("recovery", error=str(err)[:200])
        obs_events.auto_dump("recovery")
        mesh = self.topo.mesh
        if mesh is None:
            self.remesh_events.append({"err": str(err), "action": "none"})
            return
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        old_data = int(sizes.get("data", 1))
        rest = int(np.prod([s for a, s in sizes.items() if a != "data"]))
        lost = max(1, int(getattr(err, "lost_hosts", 1)))
        plan = plan_remesh(old_data, rest, lost_hosts=lost)
        if plan is None:
            # the data axis cannot absorb the loss (e.g. TP-only mesh, or
            # lost_hosts was a pessimistic estimate): keep the topology and
            # retry from the checkpoint — run()'s max_retries bounds this
            self.remesh_events.append(
                {"err": str(err), "action": "infeasible", "lost_hosts": lost}
            )
            return
        new_data = int(plan[0])
        old_axes = tuple(int(s) for s in mesh.devices.shape)
        new_sizes = {**sizes, "data": new_data}
        new_shape = tuple(int(new_sizes[a]) for a in mesh.axis_names)
        n_needed = int(np.prod(new_shape))
        devices = np.asarray(mesh.devices).reshape(-1)[:n_needed]
        new_mesh = jax.sharding.Mesh(
            devices.reshape(new_shape), mesh.axis_names
        )
        self.topo = make_topology(new_mesh)
        self._build()
        # adopt + rebuild first, *then* tell the offload layer: plan caches
        # and the tuning grid are invalidated against the adopted topology
        fault_mod.notify_remesh(old_axes, new_shape)
        self.remesh_events.append(
            {"err": str(err), "old_data": old_data, "new_data": new_data,
             "plan": plan, "adopted": new_shape, "lost_hosts": lost}
        )

    def _restore_after_failure(self, params, opt_state):
        self.ckpt.wait()
        latest = self.ckpt.latest_step()
        if latest is None:
            with use_topology(self.topo):
                params = self.api.init(jax.random.key(0))
                opt_state = init_opt_state(params)
            return 0, params, opt_state
        host_params = jax.tree.map(np.asarray, params)
        host_opt = jax.tree.map(np.asarray, opt_state)
        _, blob = self.ckpt.restore(
            {"params": host_params, "opt": host_opt}, step=latest
        )
        return latest, blob["params"], blob["opt"]
