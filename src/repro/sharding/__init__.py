from repro.sharding.specs import (
    Topology,
    current_topology,
    shard,
    use_topology,
)

__all__ = ["Topology", "current_topology", "shard", "use_topology"]
