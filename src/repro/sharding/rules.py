"""Parameter & input PartitionSpec rules per architecture family.

Rules are name+shape based, applied over the param pytree with key paths.
The same rules produce:
  * param specs (TP layout over the 'model' axis),
  * ZeRO-1 optimizer-state specs (param spec + an extra 'data' sharding on
    the first divisible unsharded dim),
  * batch input specs.

Non-divisible dims (whisper's 20 heads on a 16-way axis, ...) degrade to
replicated for that dim — the model code made the same fallback in its
activation annotations, so layouts agree.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.sharding.specs import Topology


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _leaf_spec(path: str, shape: tuple, cfg, msize: int) -> P:
    """TP spec for one (unstacked: trailing dims) param leaf."""
    nd = len(shape)

    def pad(*tail):
        return P(*([None] * (nd - len(tail)) + list(tail)))

    d = cfg.d_model
    if "embed" in path or "lm_head" in path:
        # (V, d) table / (d, V) head: shard the vocab dim
        if shape[-1] == cfg.padded_vocab and _div(cfg.padded_vocab, msize):
            return pad(None, "model")
        if nd >= 2 and shape[-2] == cfg.padded_vocab and _div(cfg.padded_vocab, msize):
            return pad("model", None)
        return P(*([None] * nd))
    if "attn" in path or "cross" in path:
        from repro import perf_flags
        if perf_flags.FLAGS.attn_seq_over_tp:
            return P(*([None] * nd))  # replicated projections (seq-sharded attn)
        if path.endswith("wq"):
            return pad(None, "model", None) if _div(cfg.num_heads, msize) else P(*([None] * nd))
        if path.endswith("wk") or path.endswith("wv"):
            return pad(None, "model", None) if _div(cfg.num_kv_heads, msize) else P(*([None] * nd))
        if path.endswith("wo"):
            return pad("model", None, None) if _div(cfg.num_heads, msize) else P(*([None] * nd))
        if path.endswith("bq"):
            return pad("model", None) if _div(cfg.num_heads, msize) else P(*([None] * nd))
        if path.endswith("bk") or path.endswith("bv"):
            return pad("model", None) if _div(cfg.num_kv_heads, msize) else P(*([None] * nd))
    if "moe" in path and ("w_in" in path or "w_gate" in path or "w_out" in path) and "shared" not in path:
        # expert-parallel: experts over 'model'
        return pad("model", None, None) if _div(cfg.moe_num_experts, msize) else P(*([None] * nd))
    if "router" in path:
        return P(*([None] * nd))
    if path.endswith("w_in") or path.endswith("w_gate"):
        return pad(None, "model") if _div(shape[-1], msize) else P(*([None] * nd))
    if path.endswith("w_out") and nd >= 2 and shape[-2] != cfg.ssm_d_inner:
        return pad("model", None) if _div(shape[-2], msize) else P(*([None] * nd))
    # --- mamba ---
    if "mamba" in path:
        if cfg.family == "ssm":
            return P(*([None] * nd))  # SP mode: weights replicated
        di, H = cfg.ssm_d_inner, cfg.ssm_num_heads
        if path.endswith("w_z") or path.endswith("w_x"):
            return pad(None, "model") if _div(di, msize) else P(*([None] * nd))
        if path.endswith("w_dt"):
            return pad(None, "model") if _div(H, msize) else P(*([None] * nd))
        if path.endswith("conv_w_x"):
            return pad(None, "model") if _div(di, msize) else P(*([None] * nd))
        if path.endswith("conv_b_x") or path.endswith("norm_scale"):
            return pad("model") if _div(di, msize) else P(*([None] * nd))
        if path.endswith("A_log") or path.endswith("D") or path.endswith("dt_bias"):
            return pad("model") if _div(H, msize) else P(*([None] * nd))
        if path.endswith("w_out"):
            return pad("model", None) if _div(di, msize) else P(*([None] * nd))
        return P(*([None] * nd))
    return P(*([None] * nd))


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _stack_depth(path_s: str) -> int:
    """Stacked layer collections carry leading scan dims the rules skip."""
    if "periods" in path_s:
        return 1
    if "blocks" in path_s:
        return 1
    return 0


def param_specs(param_shapes: Any, cfg, topo: Topology) -> Any:
    """PartitionSpec pytree matching the params tree."""
    msize = topo.model_size

    def one(path, leaf):
        path_s = _path_str(path)
        nd_extra = _stack_depth(path_s)
        shape = tuple(leaf.shape)
        spec = _leaf_spec(path_s, shape[nd_extra:], cfg, msize)
        return P(*([None] * nd_extra + list(spec)))

    return jax.tree_util.tree_map_with_path(one, param_shapes)


def zero1_specs(param_specs_tree: Any, param_shapes: Any, topo: Topology) -> Any:
    """Optimizer-state specs: param spec + extra 'data' sharding (ZeRO-1).

    The first dim that is unsharded and divisible by the data-axis size gets
    the DP axes. Scalars and tiny leaves stay as-is.
    """
    dp = topo.batch_axes
    dp_size = topo.dp_size
    dp_entry = dp[0] if len(dp) == 1 else tuple(dp)

    def one(spec: P, leaf) -> P:
        shape = tuple(leaf.shape)
        if len(shape) == 0 or int(np.prod(shape)) < 65536 or dp_size <= 1:
            return spec
        entries = list(spec) + [None] * (len(shape) - len(spec))
        for i, (dim, e) in enumerate(zip(shape, entries)):
            if e is None and dim % dp_size == 0:
                entries[i] = dp_entry
                return P(*entries)
        return spec

    return jax.tree.map(one, param_specs_tree, param_shapes)


def batch_specs(batch_shapes: Dict[str, Any], topo: Topology) -> Dict[str, Any]:
    """Batch dims over DP axes; everything else replicated."""
    dp = topo.batch_axes
    dp_entry = dp[0] if len(dp) == 1 else tuple(dp)
    dp_size = topo.dp_size

    def one(leaf):
        if leaf.ndim == 0:
            return P()
        if leaf.shape[0] % dp_size == 0 and leaf.shape[0] > 1:
            return P(*([dp_entry] + [None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree.map(one, batch_shapes)


def cache_specs(cache_shapes: Any, cfg, topo: Topology) -> Any:
    """Decode-cache specs: batch over DP; KV heads over 'model' when they
    divide, else cache SEQUENCE over 'model' (the kv_seq decode mode)."""
    msize = topo.model_size
    dp = topo.batch_axes
    dp_entry = dp[0] if len(dp) == 1 else tuple(dp)
    dp_size = topo.dp_size
    kv_heads_ok = _div(cfg.num_kv_heads, msize)

    def one(path, leaf):
        path_s = _path_str(path)
        shape = tuple(leaf.shape)
        nd = len(shape)
        # leading dim is the stacked layer/period dim for k/v/mamba caches
        entries: list = [None] * nd
        # find batch dim: first dim equal to a multiple of dp that's not the
        # layer dim — by construction caches are (L, B, S, Kh, D) or
        # mamba (L, [7,] B, ...)
        if path_s.startswith("k") or path_s.startswith("v") or path_s.startswith("x"):
            # (L, B, S, Kh, D)
            if shape[1] % dp_size == 0 and shape[1] > 1:
                entries[1] = dp_entry
            if kv_heads_ok:
                entries[3] = "model"
            elif shape[2] % msize == 0 and shape[2] > 1:
                entries[2] = "model"
        elif "mamba" in path_s:
            bdim = 1 if cfg.family == "ssm" else 2
            if nd > bdim and shape[bdim] % dp_size == 0 and shape[bdim] > 1:
                entries[bdim] = dp_entry
        return P(*entries)

    return jax.tree_util.tree_map_with_path(one, cache_shapes)
