"""Topology context: logical parallelism axes -> physical mesh axes.

Model code names *logical* axes ("batch", "model", "seq", "expert", "vocab");
the topology maps them onto whatever mesh is active — single-pod
(data, model), multi-pod (pod, data, model), a 1-device smoke mesh, or no mesh
at all (plain CPU tests, where every annotation is a no-op).

DP spans (pod, data); TP/EP/SP all live on the "model" axis (the standard
megatron-style layout at 256 chips/pod: one fast axis for intra-layer
parallelism, everything else data-parallel).
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Topology:
    mesh: Optional[Mesh]
    batch_axes: Tuple[str, ...] = ("data",)   # DP axes (pod folded in)
    model_axis: Optional[str] = "model"       # TP / EP / SP axis

    @property
    def dp(self):
        return self.batch_axes if len(self.batch_axes) > 1 else self.batch_axes[0]

    @property
    def model_size(self) -> int:
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n

    def spec(self, *logical: Optional[str]) -> P:
        """Translate logical axis names to a PartitionSpec."""
        out = []
        for name in logical:
            if name is None:
                out.append(None)
            elif name == "batch":
                out.append(self.dp)
            elif name in ("model", "seq", "expert", "vocab", "ff", "heads"):
                out.append(self.model_axis)
            else:
                raise ValueError(f"unknown logical axis {name!r}")
        return P(*out)


def _null_topology() -> Topology:
    return Topology(mesh=None, batch_axes=("data",), model_axis=None)


_current: contextvars.ContextVar[Topology] = contextvars.ContextVar(
    "repro_topology", default=_null_topology()
)


def current_topology() -> Topology:
    return _current.get()


@contextlib.contextmanager
def use_topology(topo: Topology):
    token = _current.set(topo)
    try:
        if topo.mesh is not None:
            with topo.mesh:
                yield topo
        else:
            yield topo
    finally:
        _current.reset(token)


def make_topology(mesh: Optional[Mesh]) -> Topology:
    if mesh is None:
        return _null_topology()
    names = mesh.axis_names
    if "pod" in names:
        # pure-DP pod meshes (pod, data) carry no model axis
        model = "model" if "model" in names else None
        batch = ("pod", "data") if "data" in names else ("pod",)
        return Topology(mesh=mesh, batch_axes=batch, model_axis=model)
    if "model" in names:
        return Topology(mesh=mesh, batch_axes=("data",), model_axis="model")
    return Topology(mesh=mesh, batch_axes=tuple(names), model_axis=None)


def plan_spec(layout, axis_names, ndim: int = 1) -> P:
    """PartitionSpec realizing a collective plan's data layout.

    ``layout`` is anything with an ``order`` attribute (a
    :class:`repro.offload.planner.PlanLayout` or ``CollectivePlan``).
    Dim 0 of the array is sharded across the physical mesh axes named in
    ``axis_names`` *in the plan's logical order*: block ``i`` of a flat
    logical-rank-ordered array then lands exactly on the device whose
    logical rank is ``i``, so callers feed logical-order data straight into
    ``shard_map`` and never permute by hand (the spec-level twin of
    ``PlanLayout.to_physical``)."""
    order = tuple(layout.order)
    if len(order) != len(axis_names):
        raise ValueError(
            f"layout order {order!r} does not cover axes "
            f"{tuple(axis_names)!r}"
        )
    names = tuple(axis_names[i] for i in order)
    entry = names[0] if len(names) == 1 else names
    return P(entry, *([None] * (max(ndim, 1) - 1)))


def shard(x, *logical: Optional[str]):
    """with_sharding_constraint in logical axes; no-op without a mesh.

    Axis names that don't divide the corresponding dim (e.g. 20 whisper heads
    on a 16-way model axis) fall back to unsharded for that dim.
    """
    topo = current_topology()
    if topo.mesh is None:
        return x
    fixed = []
    for dim, name in enumerate(logical):
        if name is None or name == "batch":
            fixed.append(name)
            continue
        size = topo.model_size
        if size and x.shape[dim] % size != 0:
            fixed.append(None)
        else:
            fixed.append(name)
    spec = topo.spec(*fixed)
    return jax.lax.with_sharding_constraint(x, spec)
