"""Flash-attention Pallas kernel (TPU target; interpret-validated on CPU).

Online-softmax attention over (BH, S, D) operands with VMEM-blocked tiles:
grid (batch*heads, q_blocks, kv_blocks), kv innermost (sequential on the
TensorCore) so the running (m, l, acc) statistics live in VMEM scratch across
kv steps — the same carry-in-scratch pattern as the prefix-scan kernel, which
is exactly the flash recurrence: an associative (max, sum, weighted-sum)
scan over KV blocks (core.operators.make_flash_op is its algebra).

Causal and sliding-window masks are computed from grid indices; ``q_offset``
supports decode/sharded-query positions. Q/KV tiles are MXU-aligned
(multiples of 128 on the matmul dims via the ops.py wrapper's padding).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, block_q: int, block_kv: int, nkv: int, causal: bool,
    window: int, q_offset: int, kv_len: int, scale: float,
):
    jq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (bq, D)
    k = k_ref[0]                                   # (bkv, D)
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                      # (bq, bkv)

    qpos = q_offset + jq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0
    )
    kpos = jk * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1
    )
    mask = kpos < kv_len
    if causal:
        mask = mask & (qpos >= kpos)
    if window > 0:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_old = m_ref[:, 0]                            # (bq,)
    m_new = jnp.maximum(m_old, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_old - m_new)
    l_new = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[:, 0] = m_new
    l_ref[:, 0] = l_new

    @pl.when(jk == nkv - 1)
    def _finish():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,          # (BH, Sq, D)
    k: jax.Array,          # (BH, Skv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    kv_len: int | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    BH, Sq, D = q.shape
    _, Skv, _ = k.shape
    if kv_len is None:
        kv_len = Skv
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    assert Sq % block_q == 0 and Skv % block_kv == 0, (q.shape, k.shape)
    nkv = Skv // block_kv
    grid = (BH, Sq // block_q, nkv)
    kernel = functools.partial(
        _flash_kernel,
        block_q=block_q, block_kv=block_kv, nkv=nkv,
        causal=causal, window=window, q_offset=q_offset,
        kv_len=kv_len, scale=1.0 / (D ** 0.5),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),   # running max
            pltpu.VMEM((block_q, 128), jnp.float32),   # running denom
            pltpu.VMEM((block_q, D), jnp.float32),     # weighted accumulator
        ],
        interpret=interpret,
    )(q, k, v)
