from repro.kernels.ops import flash_attention, prefix_scan, ssd_scan

_PALLAS_COLLECTIVE = (
    "lower_pallas", "supports_plan", "kernel_round_structure", "on_tpu",
)


def __getattr__(name):
    # Lazy: pallas_collective imports repro.offload.planner, and the offload
    # package imports repro.kernels through the lowering registry — deferring
    # the submodule import keeps the cycle unwound regardless of which
    # package loads first. import_module, not a from-import: the latter
    # re-enters this __getattr__ through _handle_fromlist before the
    # submodule is bound on the package.
    if name in _PALLAS_COLLECTIVE or name == "pallas_collective":
        import importlib

        module = importlib.import_module("repro.kernels.pallas_collective")
        if name == "pallas_collective":
            return module
        return getattr(module, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
