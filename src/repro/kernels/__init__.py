from repro.kernels.ops import flash_attention, prefix_scan, ssd_scan
