"""Fused Pallas "NIC" kernels: one kernel per schedule, not one op per round.

The source paper's thesis is that MPI_Scan wins when the whole collective
runs inside the network device. ``lower_sim``/``lower_spmd`` are the
host-driven op-per-round baseline; this module is the offloaded analogue —
each communication phase of a :class:`~repro.offload.planner.CollectivePlan`
lowers to a *single* Pallas kernel that executes every exchange round
internally with RDMA-style ``make_async_remote_copy`` sends and per-slot DMA
semaphore waits between rounds (the NIC-triggered-operation model of the
Quadrics barrier and sPIN handler papers in PAPERS.md).

Two forms share the same round structure:

* **spmd form** (``axis_names`` given): a per-rank kernel run under
  ``shard_map`` over ONE named mesh axis. Round ``k`` posts a remote copy of
  the accumulator into the partner's double-buffered receive slot
  (``recv[k % 2]``), blocks on that slot's DMA semaphores, masks the cyclic
  wrap back to ``ppermute``'s zero-fill, and folds with the exact operand
  order of the op-per-round schedule — outputs are bitwise identical to
  ``lower_spmd``.
* **sim form** (no ``axis_names``): the single-device rehearsal over stacked
  ``(p, ...)`` leaves; the same rounds run as local ``make_async_copy``
  row-block shifts. This is the variant the autotuner races against the
  op-per-round interpreter and the engine's sim mode dispatches.

Where no TPU is attached the kernels run in Pallas interpret mode, which
fully discharges the DMAs — CI exercises the real send/wait structure on
CPU. The interpreter's remote-DMA discharge requires a *scalar* logical
``device_id``, exactly one named mesh axis in scope, and rounds that are
full permutations; the kernels honor all three (cyclic sends + receiver
masking reproduce the partial-permutation zero-fill), and plans outside the
supported set (multi-axis under shard_map, chunked C > 1, non-doubling scan
algorithms, non-pow2 butterflies) are reported by :func:`supports_plan` so
the lowering registry can fall back to the op-per-round default.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import algorithms as alg
from repro.core.operators import MAX, AssocOp, get_operator
from repro.core.packet import CollType
from repro.core.reduce_ops import allreduce_schedule, reduce_schedule
from repro.core.scan_collective import sim_scan
from repro.offload.planner import (
    CollectivePlan,
    PhaseKind,
    _along_axis,
    _zero_coord_mask,
)

PyTree = Any

#: phase kinds the fused kernels implement on the active (size > 1) level
_COMM_KINDS = (
    PhaseKind.SCAN,
    PhaseKind.FUSED_SCAN_TOTAL,
    PhaseKind.TOTAL,
    PhaseKind.BARRIER,
)


def on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover - no backend at all
        return False


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    return (not on_tpu()) if interpret is None else bool(interpret)


def active_level(plan: CollectivePlan) -> Optional[int]:
    """The single logical level with size > 1, or None if the plan is not
    effectively single-axis (zero or several non-trivial levels)."""
    active = [lv for lv, s in enumerate(plan.logical_sizes) if s > 1]
    return active[0] if len(active) == 1 else None


def supports_plan(
    plan: CollectivePlan, axis_names: Optional[Sequence[str]] = None
) -> Tuple[bool, str]:
    """Can the fused-kernel backend lower ``plan``? Returns ``(ok, reason)``
    with a stable reason token for telemetry when it can't.

    Supported: effectively single-axis plans (one logical level of size
    > 1; size-1 levels run the identical local shortcuts), whole payloads
    (chunking == 1), hillis-steele SCAN / FUSED_SCAN_TOTAL over a
    zero-identity operator, and pow2 TOTAL/BARRIER butterflies. The spmd
    form additionally requires exactly one named mesh axis (the interpret
    remote-DMA discharge supports no more).
    """
    if plan.chunking > 1:
        return False, "chunked"
    if axis_names is not None and (
        len(axis_names) != 1 or len(plan.sizes) != 1
    ):
        return False, "multi_axis_mesh"
    lv = active_level(plan)
    if lv is None:
        return False, "not_single_axis"
    p = plan.logical_sizes[lv]
    op = get_operator(plan.op_name)
    for ph in plan.phases:
        if ph.kind in (PhaseKind.COMBINE, PhaseKind.IDENTITY):
            continue
        if ph.level != lv:
            continue  # size-1 level: local shortcut, no kernel needed
        if ph.kind == PhaseKind.SCAN:
            if ph.algorithm != "hillis_steele":
                return False, f"algorithm:{ph.algorithm}"
            if not op.zero_identity:
                return False, "op_flags"
        elif ph.kind == PhaseKind.FUSED_SCAN_TOTAL:
            if not op.zero_identity:
                return False, "op_flags"
        elif ph.kind in (PhaseKind.TOTAL, PhaseKind.BARRIER):
            if p & (p - 1):
                return False, "non_pow2_butterfly"
        else:
            return False, f"phase:{ph.kind.name.lower()}"
    return True, ""


def kernel_round_structure(
    plan: CollectivePlan,
) -> Tuple[Tuple[str, int], ...]:
    """``(phase_kind_name, rounds)`` per fused comm phase, in plan order —
    the round structure the kernels execute internally, consumed by
    :func:`repro.obs.tracing.add_kernel_round_spans`."""
    lv = active_level(plan)
    out = []
    if lv is None:
        return ()
    p = plan.logical_sizes[lv]
    for ph in plan.phases:
        if ph.kind in _COMM_KINDS and ph.level == lv:
            out.append(
                (
                    ph.kind.name,
                    alg.phase_round_count(
                        ph.kind.name, p, inclusive=ph.inclusive
                    ),
                )
            )
    return tuple(out)


# ---------------------------------------------------------------------------
# spmd form: per-rank kernels under shard_map, remote DMA rounds
# ---------------------------------------------------------------------------
#
# Every round is issued as a *full* permutation (cyclic shift by +/-d, or the
# XOR butterfly) so each rank receives exactly one incoming copy per round —
# the invariant the interpret discharge rule needs — and the receiver masks
# wrapped rows back to zero, reproducing the op-per-round ``ppermute``
# zero-fill bit for bit.


def _start_rounds(copies):
    """Issue all of a round's DMAs before waiting on any (full duplex)."""
    for c in copies:
        c.start()
    for c in copies:
        c.wait()


def _masked(tree_leaves, mask):
    return [jnp.where(mask, v, jnp.zeros_like(v)) for v in tree_leaves]


def _spmd_comm_kernel(
    kind: PhaseKind,
    p: int,
    axis_name: str,
    op: AssocOp,
    *,
    inclusive: bool = True,
    interpret: bool = True,
):
    """Build ``fn(tree) -> tree`` (or ``(tree, tree)`` for FUSED_SCAN_TOTAL)
    running one whole comm phase as a single per-rank Pallas kernel."""

    def run(x: PyTree):
        leaves, treedef = jax.tree.flatten(x)
        # scalar leaves (the barrier token) ride as (1, 1) blocks
        shapes = [l.shape for l in leaves]
        leaves = [l.reshape((1, 1)) if l.ndim == 0 else l for l in leaves]
        L = len(leaves)
        nsteps = alg.num_steps(p)
        fused = kind == PhaseKind.FUSED_SCAN_TOTAL
        streams = 2 if fused else 1

        def combine(lhs_leaves, rhs_leaves):
            merged = op.combine(
                jax.tree.unflatten(treedef, lhs_leaves),
                jax.tree.unflatten(treedef, rhs_leaves),
            )
            return jax.tree.flatten(merged)[0]

        def body(*refs):
            ins = refs[:L]
            outs = refs[L : L * (1 + streams)]
            scratch = refs[L * (1 + streams):]
            acc = scratch[: L * streams]
            recv = scratch[L * streams : 2 * L * streams]
            send_sem, recv_sem = scratch[2 * L * streams :]
            rank = lax.axis_index(axis_name)
            step = 0

            def exchange(dst_rank, stream):
                """One full-permutation round: remote-copy every leaf of one
                stream's accumulator into the partner's recv slot. Returns
                ``(copies, read)``; ``read()`` loads the received leaves and
                must only run after the copies' ``wait`` (via
                ``_start_rounds``)."""
                nonlocal step
                slot = step & 1
                step += 1
                copies = []
                for li in range(L):
                    si = stream * L + li
                    copies.append(
                        pltpu.make_async_remote_copy(
                            src_ref=acc[si],
                            dst_ref=recv[si].at[slot],
                            send_sem=send_sem.at[slot, si],
                            recv_sem=recv_sem.at[slot, si],
                            device_id=dst_rank,
                            device_id_type=pltpu.DeviceIdType.LOGICAL,
                        )
                    )
                read = lambda: [  # noqa: E731
                    recv[stream * L + li][slot] for li in range(L)
                ]
                return copies, read

            def set_acc(stream, vals):
                for li in range(L):
                    acc[stream * L + li][...] = vals[li]

            def get_acc(stream):
                return [acc[stream * L + li][...] for li in range(L)]

            if kind in (PhaseKind.TOTAL, PhaseKind.BARRIER):
                # pow2 recursive-doubling butterfly; the XOR rounds are full
                # permutations so the flag stream of allreduce_schedule is
                # constantly 1 and _combine_lr reduces to a plain combine.
                set_acc(0, [r[...] for r in ins])
                for k in range(nsteps):
                    d = 1 << k
                    copies, read = exchange(rank ^ d, 0)
                    _start_rounds(copies)
                    rv = read()
                    partner_lower = (rank & d) != 0
                    lo = combine(rv, get_acc(0))
                    hi = combine(get_acc(0), rv)
                    set_acc(
                        0,
                        [
                            jnp.where(partner_lower, l, h)
                            for l, h in zip(lo, hi)
                        ],
                    )
                for li in range(L):
                    outs[li][...] = acc[li][...]
                return

            # doubling scans: stream 0 = prefix, stream 1 = suffix (fused)
            set_acc(0, [r[...] for r in ins])
            if not inclusive:
                # structural entry shift: rank r starts from x_{r-1}
                copies, read = exchange(lax.rem(rank + 1, p), 0)
                _start_rounds(copies)
                set_acc(0, _masked(read(), rank >= 1))
            if fused:
                set_acc(1, [r[...] for r in ins])
            for k in range(nsteps):
                d = 1 << k
                pre_copies, pre_read = exchange(lax.rem(rank + d, p), 0)
                if fused:
                    suf_copies, suf_read = exchange(
                        lax.rem(rank - d + p, p), 1
                    )
                    # full duplex: both directions' sends in flight at once
                    _start_rounds(pre_copies + suf_copies)
                else:
                    _start_rounds(pre_copies)
                pre = combine(_masked(pre_read(), rank >= d), get_acc(0))
                if fused:
                    set_acc(
                        1,
                        combine(
                            get_acc(1), _masked(suf_read(), rank < p - d)
                        ),
                    )
                set_acc(0, pre)
            if not fused:
                for li in range(L):
                    outs[li][...] = acc[li][...]
                return
            # fused exits (same arithmetic as alg.scan_total_schedule)
            if inclusive:
                copies, read = exchange(lax.rem(rank - 1 + p, p), 1)
                _start_rounds(copies)
                total = combine(get_acc(0), _masked(read(), rank < p - 1))
                y = get_acc(0)
            else:
                total = combine(get_acc(0), get_acc(1))
                y = _masked(get_acc(0), rank != 0)
            for li in range(L):
                outs[li][...] = y[li]
                outs[L + li][...] = total[li]

        out_shape = [
            jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves
        ] * streams
        scratch_shapes = (
            [pltpu.VMEM(l.shape, l.dtype) for l in leaves] * streams
            + [pltpu.VMEM((2,) + l.shape, l.dtype) for l in leaves] * streams
            + [
                pltpu.SemaphoreType.DMA((2, L * streams)),
                pltpu.SemaphoreType.DMA((2, L * streams)),
            ]
        )
        outs = pl.pallas_call(
            body,
            out_shape=out_shape,
            scratch_shapes=scratch_shapes,
            interpret=interpret,
        )(*leaves)
        if streams == 1:
            outs = [o.reshape(s) for o, s in zip(outs, shapes)]
            return jax.tree.unflatten(treedef, outs)
        y = [o.reshape(s) for o, s in zip(outs[:L], shapes)]
        t = [o.reshape(s) for o, s in zip(outs[L:], shapes)]
        return (
            jax.tree.unflatten(treedef, y),
            jax.tree.unflatten(treedef, t),
        )

    return run


# ---------------------------------------------------------------------------
# sim form: whole-mesh (p, ...) kernels, local DMA row-block shifts
# ---------------------------------------------------------------------------


def _row_iota(shape) -> jnp.ndarray:
    return lax.broadcasted_iota(jnp.int32, shape, 0)


def _sim_comm_kernel(
    kind: PhaseKind,
    p: int,
    op: AssocOp,
    *,
    inclusive: bool = True,
    interpret: bool = True,
):
    """Build the single-device form over stacked ``(p, ...)`` leaves: the
    same rounds as the spmd kernel, realized as local ``make_async_copy``
    row-block shifts (rank r's row moves to row r+d), with the uncovered
    rows masked to zero exactly like ``ppermute``'s zero-fill."""

    def run(x: PyTree):
        leaves, treedef = jax.tree.flatten(x)
        L = len(leaves)
        nsteps = alg.num_steps(p)
        fused = kind == PhaseKind.FUSED_SCAN_TOTAL
        streams = 2 if fused else 1

        def combine(lhs_leaves, rhs_leaves):
            merged = op.combine(
                jax.tree.unflatten(treedef, lhs_leaves),
                jax.tree.unflatten(treedef, rhs_leaves),
            )
            return jax.tree.flatten(merged)[0]

        def body(*refs):
            ins = refs[:L]
            outs = refs[L : L * (1 + streams)]
            scratch = refs[L * (1 + streams):]
            acc = scratch[: L * streams]
            recv = scratch[L * streams : 2 * L * streams]
            sem = scratch[-1]
            step = 0

            def shift(srcs, d, stream):
                """One round: every leaf's rows move by ``d`` (+d = toward
                higher ranks) into this round's recv slot; rows with no
                sender are masked to zero by the caller via the row mask."""
                nonlocal step
                slot = step & 1
                step += 1
                for li in range(L):
                    si = stream * L + li
                    src = srcs[li] if srcs is not None else acc[si]
                    if d > 0:
                        copy = pltpu.make_async_copy(
                            src.at[pl.ds(0, p - d)],
                            recv[si].at[slot, pl.ds(d, p - d)],
                            sem.at[slot, si],
                        )
                    else:
                        copy = pltpu.make_async_copy(
                            src.at[pl.ds(-d, p + d)],
                            recv[si].at[slot, pl.ds(0, p + d)],
                            sem.at[slot, si],
                        )
                    copy.start()
                    copy.wait()
                return [recv[stream * L + li][slot] for li in range(L)]

            def bfly(d, stream):
                """XOR-partner round as 2*(p / 2d) block swaps (full perm)."""
                nonlocal step
                slot = step & 1
                step += 1
                for li in range(L):
                    si = stream * L + li
                    for base in range(0, p, 2 * d):
                        for (a, b) in ((base, base + d), (base + d, base)):
                            copy = pltpu.make_async_copy(
                                acc[si].at[pl.ds(a, d)],
                                recv[si].at[slot, pl.ds(b, d)],
                                sem.at[slot, si],
                            )
                            copy.start()
                            copy.wait()
                return [recv[stream * L + li][slot] for li in range(L)]

            def mask_rows(vals, keep):
                return [
                    jnp.where(
                        keep(_row_iota(v.shape)), v, jnp.zeros_like(v)
                    )
                    for v in vals
                ]

            def set_acc(stream, vals):
                for li in range(L):
                    acc[stream * L + li][...] = vals[li]

            def get_acc(stream):
                return [acc[stream * L + li][...] for li in range(L)]

            if kind in (PhaseKind.TOTAL, PhaseKind.BARRIER):
                set_acc(0, [r[...] for r in ins])
                for k in range(nsteps):
                    d = 1 << k
                    rv = bfly(d, 0)
                    partner_lower = (_row_iota((p,)) & d) != 0
                    lo = combine(rv, get_acc(0))
                    hi = combine(get_acc(0), rv)
                    set_acc(
                        0,
                        [
                            jnp.where(
                                partner_lower.reshape(
                                    (p,) + (1,) * (l.ndim - 1)
                                ),
                                l,
                                h,
                            )
                            for l, h in zip(lo, hi)
                        ],
                    )
                for li in range(L):
                    outs[li][...] = acc[li][...]
                return

            if inclusive:
                set_acc(0, [r[...] for r in ins])
            else:
                rv = shift(ins, 1, 0)
                set_acc(0, mask_rows(rv, lambda r: r >= 1))
            if fused:
                set_acc(1, [r[...] for r in ins])
            for k in range(nsteps):
                d = 1 << k
                rv = shift(None, d, 0)
                pre = combine(
                    mask_rows(rv, lambda r, _d=d: r >= _d), get_acc(0)
                )
                if fused:
                    sv = shift(None, -d, 1)
                    set_acc(
                        1,
                        combine(
                            get_acc(1),
                            mask_rows(sv, lambda r, _d=d: r < p - _d),
                        ),
                    )
                set_acc(0, pre)
            if not fused:
                for li in range(L):
                    outs[li][...] = acc[li][...]
                return
            if inclusive:
                sv = shift(None, -1, 1)
                total = combine(
                    get_acc(0), mask_rows(sv, lambda r: r < p - 1)
                )
                y = get_acc(0)
            else:
                total = combine(get_acc(0), get_acc(1))
                y = mask_rows(get_acc(0), lambda r: r != 0)
            for li in range(L):
                outs[li][...] = y[li]
                outs[L + li][...] = total[li]

        out_shape = [
            jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves
        ] * streams
        scratch_shapes = (
            [pltpu.VMEM(l.shape, l.dtype) for l in leaves] * streams
            + [pltpu.VMEM((2,) + l.shape, l.dtype) for l in leaves] * streams
            + [pltpu.SemaphoreType.DMA((2, L * streams))]
        )
        outs = pl.pallas_call(
            body,
            out_shape=out_shape,
            scratch_shapes=scratch_shapes,
            interpret=interpret,
        )(*leaves)
        if streams == 1:
            return jax.tree.unflatten(treedef, list(outs))
        return (
            jax.tree.unflatten(treedef, list(outs[:L])),
            jax.tree.unflatten(treedef, list(outs[L:])),
        )

    return run


# ---------------------------------------------------------------------------
# Plan lowering: the same phase loop as lower_sim/lower_spmd, with every
# comm phase on the active level replaced by one fused kernel
# ---------------------------------------------------------------------------


def _sim_fallback_fn(ph, op, chunks_backend):
    """The op-per-round functions lower_sim uses — only reached for size-1
    levels, where they are communication-free local shortcuts."""
    if ph.kind == PhaseKind.SCAN:
        return lambda t: sim_scan(
            t, op, chunks_backend.p, algorithm=ph.algorithm,
            inclusive=ph.inclusive, backend=chunks_backend,
        )
    if ph.kind == PhaseKind.FUSED_SCAN_TOTAL:
        return lambda t: alg.scan_total_schedule(
            chunks_backend, t, op, inclusive=ph.inclusive
        )
    if ph.kind == PhaseKind.TOTAL:
        return lambda t: allreduce_schedule(
            chunks_backend, t, op, algorithm=ph.algorithm
        )
    if ph.kind == PhaseKind.REDUCE:
        return lambda t: reduce_schedule(
            chunks_backend, t, op, root=ph.root, algorithm=ph.algorithm
        )
    if ph.kind == PhaseKind.BARRIER:
        return lambda t: allreduce_schedule(
            chunks_backend, t, MAX, algorithm=ph.algorithm
        )
    raise ValueError(f"unknown phase kind {ph.kind!r}")


def _lower_pallas_sim(
    plan: CollectivePlan, op: AssocOp, interpret: bool, traced: bool
):
    logical = plan.logical_sizes
    k = len(logical)
    p_total = plan.p
    lv_active = active_level(plan)
    coll_name = plan.coll.name.lower()

    def to_mesh(tree: PyTree) -> PyTree:
        return jax.tree.map(lambda a: a.reshape(logical + a.shape[1:]), tree)

    def to_flat(tree: PyTree) -> PyTree:
        return jax.tree.map(
            lambda a: a.reshape((p_total,) + a.shape[k:]), tree
        )

    def run(x: Optional[PyTree]) -> PyTree:
        regs = {}
        if plan.coll == CollType.BARRIER:
            regs["x"] = jnp.ones(logical, jnp.float32)
        else:
            regs["x"] = to_mesh(x)
        tracer = None
        if traced:
            from repro.obs import tracing as obs_tracing

            tracer = obs_tracing.get_tracer()
        for ph in plan.phases:
            if ph.kind == PhaseKind.COMBINE:
                merged = op.combine(regs[ph.src[0]], regs[ph.src[1]])
                if ph.guard_levels:
                    mask = _zero_coord_mask(logical, ph.guard_levels)
                    merged = alg._bwhere(mask, regs[ph.src[1]], merged)
                regs[ph.dst] = merged
                continue
            if ph.kind == PhaseKind.IDENTITY:
                regs[ph.dst] = op.identity_like(regs[ph.src[0]])
                continue
            p_axis = logical[ph.level]
            phase_op = MAX if ph.kind == PhaseKind.BARRIER else op
            if ph.level == lv_active and ph.kind in _COMM_KINDS:
                fn = _sim_comm_kernel(
                    ph.kind, p_axis, phase_op,
                    inclusive=ph.inclusive, interpret=interpret,
                )
                rounds = alg.phase_round_count(
                    ph.kind.name, p_axis, inclusive=ph.inclusive
                )
            else:
                fn = _sim_fallback_fn(ph, op, alg.SimBackend(p_axis))
                rounds = 0
            if tracer is not None and rounds:
                from repro.obs import tracing as obs_tracing

                t0 = obs_tracing.now_us()
                out = jax.block_until_ready(
                    _along_axis(regs[ph.src[0]], ph.level, fn)
                )
                obs_tracing.add_kernel_round_spans(
                    tracer,
                    phase=f"{ph.kind.name}:L{ph.level}",
                    coll=coll_name,
                    rounds=rounds,
                    start_us=t0,
                    end_us=obs_tracing.now_us(),
                )
            else:
                out = _along_axis(regs[ph.src[0]], ph.level, fn)
            if ph.kind == PhaseKind.FUSED_SCAN_TOTAL:
                regs[ph.dst], regs[ph.dst2] = out
            else:
                regs[ph.dst] = out
        return to_flat(regs[plan.result])

    return run


def _lower_pallas_spmd(
    plan: CollectivePlan,
    op: AssocOp,
    axis_names: Sequence[str],
    interpret: bool,
):
    name = axis_names[plan.order[0]]
    p = plan.sizes[0]

    def run(x: Optional[PyTree] = None) -> PyTree:
        regs = {}
        if plan.coll == CollType.BARRIER:
            regs["x"] = jnp.ones((), jnp.float32)
        else:
            regs["x"] = x
        for ph in plan.phases:
            if ph.kind == PhaseKind.COMBINE:
                merged = op.combine(regs[ph.src[0]], regs[ph.src[1]])
                if ph.guard_levels:
                    keep = jnp.asarray(True)
                    for lv in ph.guard_levels:
                        keep = keep & (lax.axis_index(name) == 0)
                    merged = alg._bwhere(keep, regs[ph.src[1]], merged)
                regs[ph.dst] = merged
                continue
            if ph.kind == PhaseKind.IDENTITY:
                regs[ph.dst] = op.identity_like(regs[ph.src[0]])
                continue
            phase_op = MAX if ph.kind == PhaseKind.BARRIER else op
            fn = _spmd_comm_kernel(
                ph.kind, p, name, phase_op,
                inclusive=ph.inclusive, interpret=interpret,
            )
            out = fn(regs[ph.src[0]])
            if ph.kind == PhaseKind.FUSED_SCAN_TOTAL:
                regs[ph.dst], regs[ph.dst2] = out
            else:
                regs[ph.dst] = out
        return regs[plan.result]

    return run


def lower_pallas(
    plan: CollectivePlan,
    op: "AssocOp | str | None" = None,
    *,
    axis_names: Optional[Sequence[str]] = None,
    interpret: Optional[bool] = None,
    traced: bool = False,
):
    """Compile a supported plan to fused-Pallas-kernel schedules.

    Mirrors the :func:`repro.offload.planner.lower_sim` /
    :func:`~repro.offload.planner.lower_spmd` calling conventions — with
    ``axis_names`` the result runs per-rank inside ``shard_map`` over one
    named axis; without, it runs over flat stacked ``(p, ...)`` leaves on a
    single device. Outputs are bitwise identical to the op-per-round
    lowerings (same arithmetic, same operand order, same zero-fills).

    ``interpret=None`` auto-selects Pallas interpret mode off-TPU so CI
    exercises the DMA structure on CPU. Raises ``ValueError`` for plans
    outside :func:`supports_plan`; callers wanting a soft fallback go
    through the lowering registry (``repro.offload.backends``).
    """
    op = get_operator(plan.op_name if op is None else op)
    ok, reason = supports_plan(plan, axis_names)
    if not ok:
        raise ValueError(
            f"plan not supported by the pallas backend ({reason}); "
            f"use the registry default lowering"
        )
    inter = _resolve_interpret(interpret)
    if axis_names is None:
        return _lower_pallas_sim(plan, op, inter, traced)
    return _lower_pallas_spmd(plan, op, axis_names, inter)
