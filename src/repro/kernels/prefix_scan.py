"""Blocked prefix-scan Pallas kernel (the on-chip half of the paper's scan).

The paper offloads the *inter-node* scan to the NIC; the *intra-node* combine
ran in the NetFPGA datapath at line rate. On TPU the intra-device analogue is
this kernel: a VMEM-blocked scan along the last axis that streams HBM tiles
through VMEM exactly once, carrying the running prefix in a VMEM scratch
across sequential grid steps (the TPU grid's innermost dimension executes in
order on the TensorCore, so the scratch acts as the NIC's "partial sum
register").

Layout: rows are blocked to sublane multiples (8 for f32), the scan axis to
lane multiples (128). Each grid step loads one (BR, BL) tile, does an in-tile
associative scan on the VPU, folds in the carry, and updates the carry with
the tile's last column — one HBM read + one HBM write per element, the memory
roofline for a scan.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_IDENT = {
    "add": lambda dt: jnp.zeros((), dt),
    "max": lambda dt: (
        jnp.array(jnp.finfo(dt).min, dt)
        if jnp.issubdtype(dt, jnp.floating)
        else jnp.array(jnp.iinfo(dt).min, dt)
    ),
    "mul": lambda dt: jnp.ones((), dt),
}

_COMBINE = {
    "add": jnp.add,
    "max": jnp.maximum,
    "mul": jnp.multiply,
}


def _scan_kernel(x_ref, o_ref, carry_ref, *, op: str):
    """One (BR, BL) tile: local scan + carry fold, carry update."""
    j = pl.program_id(1)
    combine = _COMBINE[op]
    ident = _IDENT[op](x_ref.dtype)

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = jnp.full_like(carry_ref, ident)

    x = x_ref[...]
    local = lax.associative_scan(combine, x, axis=1)
    carry = carry_ref[:, :1]  # (BR, 1) broadcasts over the tile
    out = combine(carry, local)
    o_ref[...] = out
    carry_ref[:, :1] = out[:, -1:]


def prefix_scan_pallas(
    x: jax.Array,
    *,
    op: str = "add",
    block_rows: int = 256,
    block_len: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Inclusive scan along axis -1 of a 2D (R, L) array.

    R must divide by block_rows and L by block_len (ops.py pads).
    """
    if x.ndim != 2:
        raise ValueError(f"expected 2D (rows, length), got {x.shape}")
    rows, length = x.shape
    block_rows = min(block_rows, rows)
    block_len = min(block_len, length)
    if rows % block_rows or length % block_len:
        raise ValueError(
            f"shape {x.shape} not divisible by blocks ({block_rows},{block_len})"
        )
    grid = (rows // block_rows, length // block_len)
    kernel = functools.partial(_scan_kernel, op=op)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_len), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_len), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((block_rows, 128), x.dtype)],
        interpret=interpret,
    )(x)
