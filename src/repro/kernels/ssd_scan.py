"""Chunked diagonal-SSM scan Pallas kernel (Mamba2 SSD intra-chunk engine).

Computes h_t = a_t * h_{t-1} + b_t along time for (B, T, D) operands, blocked
over (batch-rows, time). The (decay-product, state) pair carry lives in VMEM
scratch across the sequential time-block grid steps — the same
carry-in-a-register structure the NetFPGA used to stream partial sums, and the
intra-device complement of ``core.dist_scan``'s inter-device SSD operator: the
model layer computes chunk-local trajectories with this kernel, then stitches
chunks across devices with the offloaded scan collective.

Time is mapped to the TPU *lane* axis within a tile (contiguous, 128-aligned)
and the (batch×feature) rows to sublanes; the in-tile pair scan is a
log2(tile) shift/multiply ladder on the VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pair_combine(left, right):
    al, bl = left
    ar, br = right
    return ar * al, ar * bl + br


def _ssd_kernel(a_ref, b_ref, h_ref, acc_ref, *, nblocks: int):
    """One (BR, BT) tile of rows x time. acc holds (a_prod, h) carries."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[0, ...] = jnp.ones_like(acc_ref[0])
        acc_ref[1, ...] = jnp.zeros_like(acc_ref[1])

    a = a_ref[...]
    b = b_ref[...]
    # In-tile inclusive pair scan along time (axis 1).
    A, B = lax.associative_scan(_pair_combine, (a, b), axis=1)
    a_in = acc_ref[0, :, :1]
    h_in = acc_ref[1, :, :1]
    # Fold in carry: h_t = B_t + A_t * h_in ; decay product also accumulates.
    h = B + A * h_in
    h_ref[...] = h
    acc_ref[0, :, :1] = A[:, -1:] * a_in
    acc_ref[1, :, :1] = h[:, -1:]
    del nblocks


def ssd_scan_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    block_rows: int = 256,
    block_time: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Diagonal recurrence along axis -1 of 2D (rows, T) operands.

    Returns (h, h_last). rows = flattened (batch x feature); callers reshape.
    """
    if a.shape != b.shape or a.ndim != 2:
        raise ValueError(f"expected matching 2D shapes, got {a.shape} {b.shape}")
    rows, t = a.shape
    block_rows = min(block_rows, rows)
    block_time = min(block_time, t)
    if rows % block_rows or t % block_time:
        raise ValueError(
            f"shape {a.shape} not divisible by blocks ({block_rows},{block_time})"
        )
    grid = (rows // block_rows, t // block_time)
    kernel = functools.partial(_ssd_kernel, nblocks=grid[1])
    h = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_time), lambda i, j: (i, j)),
            pl.BlockSpec((block_rows, block_time), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_time), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(a.shape, b.dtype),
        scratch_shapes=[pltpu.VMEM((2, block_rows, 128), b.dtype)],
        interpret=interpret,
    )(a, b)
    return h, h[:, -1]
