"""Public jit'd wrappers over the Pallas kernels.

Dispatch policy: on TPU the Pallas kernels run natively; elsewhere (this CPU
container) the default is the jnp reference (identical semantics & FLOPs) so
that full-model compiles stay tractable, and ``force_pallas=True`` (or env
REPRO_FORCE_PALLAS=1) routes through the kernels in interpret mode — that is
how the kernel test-suite executes them.

The wrappers own the ugly parts: shape flattening, padding to tile multiples,
and exclusive-shift handling, so kernels stay minimal.
"""

from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.prefix_scan import prefix_scan_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

_IDENT_VAL = {"add": 0, "max": None, "mul": 1}  # max identity filled per-dtype


def _use_pallas(force_pallas: bool | None) -> tuple[bool, bool]:
    """(use_pallas, interpret)."""
    if force_pallas is None:
        force_pallas = os.environ.get("REPRO_FORCE_PALLAS", "0") == "1"
    on_tpu = jax.default_backend() == "tpu"
    if on_tpu:
        return True, False
    return (True, True) if force_pallas else (False, True)


def _pad_to(x: jax.Array, mult: int, axis: int, fill) -> tuple[jax.Array, int]:
    n = x.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return x, n
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=fill), n


@partial(jax.jit, static_argnames=("op", "exclusive", "force_pallas", "block_rows", "block_len"))
def prefix_scan(
    x: jax.Array,
    *,
    op: str = "add",
    exclusive: bool = False,
    force_pallas: bool | None = None,
    block_rows: int = 256,
    block_len: int = 512,
) -> jax.Array:
    """Prefix scan along the last axis of an arbitrary-rank array."""
    use, interpret = _use_pallas(force_pallas)
    if not use:
        return ref.ref_prefix_scan(x, op, exclusive=exclusive)

    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    if op == "max":
        fill = (
            jnp.finfo(x.dtype).min
            if jnp.issubdtype(x.dtype, jnp.floating)
            else jnp.iinfo(x.dtype).min
        )
    else:
        fill = _IDENT_VAL[op]
    # rows pad with identity (harmless), length pad with identity (trimmed)
    sub = 8 if x.dtype != jnp.int8 else 32
    flat, rows = _pad_to(flat, min(block_rows, max(sub, 1)), 0, fill)
    flat, length = _pad_to(flat, 128, 1, fill)
    br = min(block_rows, flat.shape[0])
    bl = min(block_len, flat.shape[1])
    # shrink blocks to divisors
    while flat.shape[0] % br:
        br //= 2
    while flat.shape[1] % bl:
        bl //= 2
    out = prefix_scan_pallas(
        flat, op=op, block_rows=br, block_len=bl, interpret=interpret
    )
    out = out[:rows, :length].reshape(shape)
    if exclusive:
        ident = fill
        pad = jnp.full_like(out[..., :1], ident)
        out = jnp.concatenate([pad, out[..., :-1]], axis=-1)
    return out


@partial(jax.jit, static_argnames=("force_pallas", "block_rows", "block_time"))
def ssd_scan(
    a: jax.Array,
    b: jax.Array,
    h0: jax.Array | None = None,
    *,
    force_pallas: bool | None = None,
    block_rows: int = 256,
    block_time: int = 512,
) -> tuple[jax.Array, jax.Array]:
    """Diagonal recurrence h_t = a_t h_{t-1} + b_t along axis -2 of (..., T, D).

    Returns (h, h_last) with h: (..., T, D), h_last: (..., D).
    """
    use, interpret = _use_pallas(force_pallas)
    if not use:
        return ref.ref_ssd_scan(a, b, h0)

    shape = a.shape
    t, d = shape[-2], shape[-1]
    # kernel wants (rows, T): move time last, flatten the rest
    a2 = jnp.moveaxis(a, -2, -1).reshape(-1, t)
    b2 = jnp.moveaxis(b, -2, -1).reshape(-1, t)
    a2, rows = _pad_to(a2, 8, 0, 1.0)   # identity decay
    b2, _ = _pad_to(b2, 8, 0, 0.0)
    a2, tlen = _pad_to(a2, 128, 1, 1.0)
    b2, _ = _pad_to(b2, 128, 1, 0.0)
    br = min(block_rows, a2.shape[0])
    bt = min(block_time, a2.shape[1])
    while a2.shape[0] % br:
        br //= 2
    while a2.shape[1] % bt:
        bt //= 2
    h2, _ = ssd_scan_pallas(
        a2, b2, block_rows=br, block_time=bt, interpret=interpret
    )
    h2 = h2[:rows, :tlen]
    h = jnp.moveaxis(h2.reshape(shape[:-2] + (d, t)), -1, -2)
    if h0 is not None:
        # fold initial state: h_t += A_t * h0 with A_t the running decay prod
        A2 = prefix_scan(
            jnp.moveaxis(a, -2, -1), op="mul", force_pallas=force_pallas
        )
        A = jnp.moveaxis(A2, -1, -2)
        h = h + A * h0[..., None, :]
    return h, h[..., -1, :]


@partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                   "force_pallas", "block_q", "block_kv"))
def flash_attention(
    q: jax.Array,      # (BH, Sq, D)
    k: jax.Array,      # (BH, Skv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    force_pallas: bool | None = None,
    block_q: int = 128,
    block_kv: int = 128,
) -> jax.Array:
    """Flash attention over flattened (batch*heads, seq, head_dim) operands.

    Pads seq dims to block multiples; padded KV columns are masked via
    kv_len, padded queries are trimmed.
    """
    use, interpret = _use_pallas(force_pallas)
    if not use:
        return ref.ref_flash_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset
        )
    BH, Sq, D = q.shape
    _, Skv, _ = k.shape
    qp, _ = _pad_to(q, min(block_q, max(Sq, 1)), 1, 0)
    kp, _ = _pad_to(k, min(block_kv, max(Skv, 1)), 1, 0)
    vp, _ = _pad_to(v, min(block_kv, max(Skv, 1)), 1, 0)
    bq = min(block_q, qp.shape[1])
    bkv = min(block_kv, kp.shape[1])
    while qp.shape[1] % bq:
        bq //= 2
    while kp.shape[1] % bkv:
        bkv //= 2
    out = flash_attention_pallas(
        qp, kp, vp, causal=causal, window=window, q_offset=q_offset,
        kv_len=Skv, block_q=bq, block_kv=bkv, interpret=interpret,
    )
    return out[:, :Sq]
