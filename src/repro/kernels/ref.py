"""Pure-jnp oracles for every Pallas kernel.

These are the semantic ground truth: kernel tests sweep shapes/dtypes and
assert_allclose against these, and the CPU runtime path (this container) uses
them directly so compiled programs have kernel-equivalent FLOPs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ref_prefix_scan(x: jax.Array, op: str = "add", *, exclusive: bool = False) -> jax.Array:
    """Prefix scan along the LAST axis. op in {add, max, mul}."""
    if op == "add":
        out = jnp.cumsum(x, axis=-1)
        ident = 0
    elif op == "max":
        out = lax.cummax(x, axis=x.ndim - 1)
        ident = (
            jnp.finfo(x.dtype).min
            if jnp.issubdtype(x.dtype, jnp.floating)
            else jnp.iinfo(x.dtype).min
        )
    elif op == "mul":
        out = jnp.cumprod(x, axis=-1)
        ident = 1
    else:
        raise ValueError(f"unknown op {op!r}")
    if exclusive:
        pad = jnp.full_like(x[..., :1], ident)
        out = jnp.concatenate([pad, out[..., :-1]], axis=-1)
    return out


def ref_ssd_scan(
    a: jax.Array, b: jax.Array, h0: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """Diagonal linear recurrence h_t = a_t * h_{t-1} + b_t along axis -2.

    a, b: (..., T, D); h0: (..., D) initial state (zeros if None).
    Returns (h, h_last): the full state trajectory and the final state.
    """
    if h0 is None:
        h0 = jnp.zeros(a.shape[:-2] + a.shape[-1:], dtype=b.dtype)

    def combine(left, right):
        al, bl = left
        ar, br = right
        return (ar * al, ar * bl + br)

    A, B = lax.associative_scan(combine, (a, b), axis=a.ndim - 2)
    # fold in the initial state: h_t = B_t + A_t * h0
    h = B + A * h0[..., None, :]
    return h, h[..., -1, :]


def ref_chunk_state(
    a_cum_last: jax.Array, x_decay: jax.Array, B_blk: jax.Array
) -> jax.Array:
    """Oracle for the SSD chunk-state matmul: state = (decayed x)^T @ B.

    x_decay: (..., T, P) inputs pre-scaled by a_cum_last/a_cum_t;
    B_blk: (..., T, N). Returns (..., P, N).
    """
    del a_cum_last
    return jnp.einsum("...tp,...tn->...pn", x_decay, B_blk)


def ref_flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, window: int = 0, q_offset: int = 0,
    kv_len: int | None = None,
) -> jax.Array:
    """Plain softmax attention oracle for the flash kernel. (BH, S, D)."""
    BH, Sq, D = q.shape
    _, Skv, _ = k.shape
    if kv_len is None:
        kv_len = Skv
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (D ** 0.5)
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = kpos < kv_len
    if causal:
        mask = mask & (qpos >= kpos)
    if window > 0:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)
