"""Unified model API: build_model(cfg) -> ModelApi.

One object per architecture exposing init / loss / prefill / decode_step /
init_cache / input_specs, so the launcher, trainer, server, dry-run and tests
all speak one interface regardless of family.

``input_specs`` returns ShapeDtypeStructs (weak-type-correct, shardable, no
device allocation) — the dry-run lowers against these directly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as ED
from repro.models import transformer as T

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init: Callable[[jax.Array], Params]
    loss: Callable[[Params, Dict[str, jax.Array]], Any]
    forward: Callable[..., Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    init_cache: Callable[[int, int], Params]

    def param_shapes(self) -> Params:
        return jax.eval_shape(self.init, jax.random.key(0))


def build_model(cfg: ModelConfig) -> ModelApi:
    if cfg.family == "audio":
        return ModelApi(
            cfg=cfg,
            init=lambda key: ED.init_encdec(key, cfg),
            loss=lambda p, b: ED.encdec_loss(p, b, cfg),
            forward=lambda p, b: ED.encdec_forward(
                p, b["tokens"], b["frames"], cfg
            ),
            prefill=lambda p, b: ED.encdec_prefill(
                p, b["tokens"], b["frames"], cfg
            ),
            decode_step=lambda p, tok, cache, clen: ED.encdec_decode_step(
                p, tok, cache, clen, cfg
            ),
            init_cache=lambda batch, seq: T.init_decode_cache(cfg, batch, seq),
        )

    return ModelApi(
        cfg=cfg,
        init=lambda key: T.init_lm(key, cfg),
        loss=lambda p, b: T.lm_loss(p, b, cfg),
        forward=lambda p, b: T.lm_forward(
            p,
            b["tokens"],
            cfg,
            vision_embeds=b.get("vision_embeds"),
            positions3=b.get("positions3"),
        ),
        prefill=lambda p, b: T.lm_prefill(
            p,
            b["tokens"],
            cfg,
            vision_embeds=b.get("vision_embeds"),
            positions3=b.get("positions3"),
        ),
        decode_step=lambda p, tok, cache, clen: T.lm_decode_step(
            p, tok, cache, clen, cfg
        ),
        init_cache=lambda batch, seq: T.init_decode_cache(cfg, batch, seq),
    )


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a given shape.

    train/prefill: token batches (+ stub frontend embeddings for audio/vlm).
    decode: one new token + the full decode cache + cache_len scalar.
    """
    B, S = shape.global_batch, shape.seq_len
    act_dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    i32 = jnp.int32
    d = cfg.d_model

    if shape.kind in ("train", "prefill"):
        batch: Dict[str, Any] = {"tokens": _sds((B, S), i32)}
        if shape.kind == "train":
            batch["labels"] = _sds((B, S), i32)
        if cfg.family == "audio":
            batch["frames"] = _sds((B, cfg.encoder_frames, d), act_dt)
        if cfg.family == "vlm":
            batch["vision_embeds"] = _sds((B, cfg.vision_patches, d), act_dt)
            batch["positions3"] = _sds((B, S, 3), i32)
        return batch

    # decode: cache laid out for context length S
    api = build_model(cfg)
    cache = jax.eval_shape(lambda: api.init_cache(B, S))
    return {
        "token": _sds((B, 1), i32),
        "cache": cache,
        "cache_len": _sds((), i32),
    }
