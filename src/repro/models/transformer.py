"""Decoder-only LM assembly: dense / MoE / SSM / hybrid / VLM families.

Layer stacks lower as ``lax.scan`` over stacked params (compile-tractable at
62 layers on a 1-core container; identical HLO shape on TPU). Heterogeneous
patterns are handled without breaking the scan:
  * gemma3's 5:1 local:global attention — a per-layer scanned flag array
    selects the sliding-window width inside the layer body;
  * jamba's 1-attention-per-8 + MoE-every-2 — the scan runs over *periods*
    whose 8 sublayers are unrolled with distinct param subtrees.

Every layer body is rematerialized (jax.checkpoint) for training.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import perf_flags
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.sharding import current_topology, shard

Params = Dict[str, Any]


def _remat(fn):
    """Layer remat honoring the perf flag: save_block_outputs keeps the
    post-TP-collective tensors (named 'block_out') so backward does not
    re-run forward all-reduces."""
    if perf_flags.FLAGS.remat_policy == "save_block_outputs":
        policy = jax.checkpoint_policies.save_only_these_names("block_out")
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _name_out(x):
    if perf_flags.FLAGS.remat_policy == "save_block_outputs":
        from jax.ad_checkpoint import checkpoint_name
        return checkpoint_name(x, "block_out")
    return x


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(key, cfg, kind: str, use_moe: bool, dtype) -> Params:
    """kind: 'attn' | 'mamba'."""
    k1, k2 = jax.random.split(key)
    p: Params = {"norm1": L.init_norm(cfg.d_model, cfg.norm, dtype)}
    if kind == "attn":
        p["attn"] = L.init_attention(k1, cfg, dtype)
    else:
        p["mamba"] = M.init_mamba(k1, cfg, dtype)
    if use_moe:
        p["norm2"] = L.init_norm(cfg.d_model, cfg.norm, dtype)
        p["moe"] = MOE.init_moe(k2, cfg, dtype)
    elif cfg.d_ff > 0:
        p["norm2"] = L.init_norm(cfg.d_model, cfg.norm, dtype)
        p["mlp"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype, cfg.gated_mlp)
    return p


def init_lm(key, cfg) -> Params:
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    Vp, d = cfg.padded_vocab, cfg.d_model
    kE, kB, kH, kO = jax.random.split(key, 4)
    params: Params = {
        "embed": jax.random.normal(kE, (Vp, d), dtype) * 0.02,
        "final_norm": L.init_norm(d, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(kH, (d, Vp), dtype) / math.sqrt(d)

    Lnum = cfg.num_layers
    if cfg.family == "hybrid":
        period = cfg.attn_every or 8
        n_periods = Lnum // period
        keys = jax.random.split(kB, n_periods)

        def init_period(k):
            ks = jax.random.split(k, period)
            sub = {}
            for i in range(period):
                kind = "attn" if i == 0 else "mamba"
                use_moe = cfg.moe_num_experts > 0 and (i % cfg.moe_every == 1)
                sub[f"sub_{i}"] = _init_block(ks[i], cfg, kind, use_moe, dtype)
            return sub

        params["periods"] = jax.vmap(init_period)(keys)
        return params

    kind = "mamba" if cfg.family == "ssm" else "attn"
    use_moe = cfg.moe_num_experts > 0 and cfg.family in ("moe",)
    keys = jax.random.split(kB, Lnum)
    params["blocks"] = jax.vmap(
        lambda k: _init_block(k, cfg, kind, use_moe, dtype)
    )(keys)
    return params


def _layer_flags(cfg) -> jnp.ndarray:
    """Per-layer is_global flags (gemma3's r local : 1 global pattern).

    Derived from config, NOT stored in params (non-trainable ints would
    break grad and the optimizer)."""
    if cfg.local_global_ratio:
        r = cfg.local_global_ratio
        return jnp.array(
            [1 if (i % (r + 1)) == r else 0 for i in range(cfg.num_layers)],
            jnp.int32,
        )
    return jnp.zeros((cfg.num_layers,), jnp.int32)


# ---------------------------------------------------------------------------
# blocks (forward)
# ---------------------------------------------------------------------------


def _ffn(p: Params, x: jax.Array, cfg):
    if "moe" in p:
        y, aux = MOE.moe_block(p["moe"], x, cfg, act=cfg.act)
        return y, aux["load_balance"], aux["router_z"]
    return L.mlp_block(p["mlp"], x, cfg.act), jnp.zeros(()), jnp.zeros(())


def _maybe_ffn(p: Params, x: jax.Array, cfg):
    """Norm + FFN residual, skipped entirely for FFN-less blocks (mamba2)."""
    if "moe" not in p and "mlp" not in p:
        return x, jnp.zeros(()), jnp.zeros(())
    h = L.norm(p["norm2"], x, cfg.norm)
    f, lb, z = _ffn(p, h, cfg)
    return x + _name_out(f), lb, z


def _attn_block_fwd(
    p, x, positions, cfg, window, positions3=None, causal=True, collect=False
):
    h = L.norm(p["norm1"], x, cfg.norm)
    a = L.attention_block(
        p["attn"], h, positions, cfg,
        causal=causal, window=window, positions3=positions3,
        return_kv=collect,
    )
    kv = None
    if collect:
        a, kv = a
    x = x + _name_out(a)
    x, lb, z = _maybe_ffn(p, x, cfg)
    return x, lb, z, kv


def _mamba_block_fwd(p, x, cfg, seq_parallel):
    h = L.norm(p["norm1"], x, cfg.norm)
    a, cache = M.mamba_mixer(p["mamba"], h, cfg, seq_parallel=seq_parallel)
    x = x + _name_out(a)
    x, lb, z = _maybe_ffn(p, x, cfg)
    return x, lb, z, cache


# ---------------------------------------------------------------------------
# full forward (train / prefill)
# ---------------------------------------------------------------------------


def _window_for(cfg, is_global):
    if cfg.local_global_ratio:
        return jnp.where(is_global > 0, 0, cfg.sliding_window)
    return cfg.sliding_window


def lm_forward(
    params: Params,
    tokens: jax.Array,
    cfg,
    *,
    vision_embeds: Optional[jax.Array] = None,
    positions3: Optional[jax.Array] = None,
    collect_cache: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """tokens (B, S) -> logits (B, S, Vp). Returns (logits, aux)."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    x = shard(x, "batch", None, None)
    if vision_embeds is not None:
        nv = vision_embeds.shape[1]
        x = lax.dynamic_update_slice(
            x, vision_embeds.astype(x.dtype), (0, 0, 0)
        )
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    lb_sum = jnp.zeros(())
    z_sum = jnp.zeros(())

    seq_par = cfg.family == "ssm"  # mamba2: sequence-parallel SSD

    caches = None
    if cfg.family == "hybrid":
        period = cfg.attn_every or 8
        sub_keys = sorted(
            params["periods"].keys(), key=lambda s: int(s.split("_")[1])
        )

        def period_fwd_inner(x, pp):
            lbs = jnp.zeros(())
            zs = jnp.zeros(())
            kv = None
            mcaches = []
            for i, sk in enumerate(sub_keys):
                p = pp[sk]
                if i == 0:
                    x, lb, z, kv = _attn_block_fwd(
                        p, x, positions, cfg, cfg.sliding_window,
                        collect=collect_cache,
                    )
                else:
                    x, lb, z, mc = _mamba_block_fwd(p, x, cfg, False)
                    mcaches.append(mc)
                lbs, zs = lbs + lb, zs + z
            cache = None
            if collect_cache:
                cache = {
                    "k": kv[0],
                    "v": kv[1],
                    "mamba": jax.tree.map(lambda *a: jnp.stack(a, 0), *mcaches),
                }
            return x, (lbs, zs), cache

        period_fwd = _remat(period_fwd_inner)

        def scan_body(carry, pp):
            x, lbs, zs = carry
            x, (lb, z), cache = period_fwd(x, pp)
            return (x, lbs + lb, zs + z), cache

        (x, lb_sum, z_sum), caches = lax.scan(
            scan_body, (x, lb_sum, z_sum), params["periods"]
        )
    else:
        blocks = params["blocks"]
        flags = _layer_flags(cfg)

        if cfg.family == "ssm":

            def layer_fwd_inner(x, p, flag):
                x, lb, z, mc = _mamba_block_fwd(p, x, cfg, seq_par)
                return x, lb, z, ({"mamba": mc} if collect_cache else None)
        else:

            def layer_fwd_inner(x, p, flag):
                window = _window_for(cfg, flag)
                x, lb, z, kv = _attn_block_fwd(
                    p, x, positions, cfg, window, positions3=positions3,
                    collect=collect_cache,
                )
                return x, lb, z, ({"k": kv[0], "v": kv[1]} if collect_cache else None)

        layer_fwd = _remat(layer_fwd_inner)

        def scan_body(carry, inp):
            x, lbs, zs = carry
            p, flag = inp
            x, lb, z, cache = layer_fwd(x, p, flag)
            return (x, lbs + lb, zs + z), cache

        (x, lb_sum, z_sum), caches = lax.scan(
            scan_body, (x, lb_sum, z_sum), (blocks, flags)
        )

    x = L.norm(params["final_norm"], x, cfg.norm)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = shard(logits, "batch", None, "vocab")
    aux = {"load_balance": lb_sum, "router_z": z_sum}
    if collect_cache:
        return logits, aux, caches
    return logits, aux


def lm_loss(params, batch, cfg) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """batch: {tokens (B,S), labels (B,S), [vision_embeds, positions3]}."""
    logits, aux = lm_forward(
        params,
        batch["tokens"],
        cfg,
        vision_embeds=batch.get("vision_embeds"),
        positions3=batch.get("positions3"),
    )
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1
    )[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    xent = jnp.sum((lse - ll) * mask) / jnp.maximum(mask.sum(), 1.0)
    loss = xent + 0.01 * aux["load_balance"] + 0.001 * aux["router_z"]
    metrics = {"xent": xent, **aux}
    return loss, metrics


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------


def init_decode_cache(cfg, batch: int, seq_len: int, topo=None) -> Params:
    """KV / SSM caches for one-token decode against a seq_len context."""
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    hd = cfg.resolved_head_dim
    if cfg.family == "ssm":
        state = M.init_mamba_state(cfg, batch)
        return {"mamba": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape), state
        )}
    if cfg.family == "hybrid":
        period = cfg.attn_every or 8
        n_p = cfg.num_layers // period
        state = M.init_mamba_state(cfg, batch)
        return {
            "k": jnp.zeros((n_p, batch, seq_len, cfg.num_kv_heads, hd), dt),
            "v": jnp.zeros((n_p, batch, seq_len, cfg.num_kv_heads, hd), dt),
            "mamba": jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (n_p, period - 1) + a.shape
                ),
                state,
            ),
        }
    Lnum = cfg.num_layers
    cache = {
        "k": jnp.zeros((Lnum, batch, seq_len, cfg.num_kv_heads, hd), dt),
        "v": jnp.zeros((Lnum, batch, seq_len, cfg.num_kv_heads, hd), dt),
    }
    if cfg.encoder_layers:
        cache["xk"] = jnp.zeros(
            (Lnum, batch, cfg.encoder_frames, cfg.num_kv_heads, hd), dt
        )
        cache["xv"] = jnp.zeros(
            (Lnum, batch, cfg.encoder_frames, cfg.num_kv_heads, hd), dt
        )
    return cache


def lm_decode_step(
    params: Params,
    token: jax.Array,          # (B, 1) int32
    cache: Params,
    cache_len: jax.Array,      # scalar int32: current context length
    cfg,
) -> Tuple[jax.Array, Params]:
    """One greedy decode step. Returns (next_token (B,1), new_cache)."""
    kv_mode = L.decode_kv_mode(cfg)
    B = token.shape[0]
    x = params["embed"][token]

    if cfg.family == "ssm":

        def body(x, pm):
            p, st = pm
            h = L.norm(p["norm1"], x, cfg.norm)
            a, st = M.mamba_decode(p["mamba"], h, st, cfg)
            x = x + a
            x, _, _ = _maybe_ffn(p, x, cfg)
            return x, st

        def scan_body(x, pm):
            x, st = body(x, pm)
            return x, st

        x, new_states = lax.scan(
            scan_body, x, (params["blocks"], cache["mamba"])
        )
        new_cache = {"mamba": new_states}
    elif cfg.family == "hybrid":
        period = cfg.attn_every or 8
        sub_keys = sorted(
            params["periods"].keys(), key=lambda s: int(s.split("_")[1])
        )

        def period_step(x, inp):
            pp, kc, vc, mstates = inp
            new_m = []
            for i, sk in enumerate(sub_keys):
                p = pp[sk]
                h = L.norm(p["norm1"], x, cfg.norm)
                if i == 0:
                    a, kc, vc = L.cached_attention(
                        p["attn"], h, kc, vc, cache_len, cfg, kv_mode=kv_mode
                    )
                    x = x + a
                else:
                    st = jax.tree.map(lambda a, i=i: a[i - 1], mstates)
                    a, st = M.mamba_decode(p["mamba"], h, st, cfg)
                    new_m.append(st)
                    x = x + a
                x, _, _ = _maybe_ffn(p, x, cfg)
            stacked_m = jax.tree.map(
                lambda *xs: jnp.stack(xs, 0), *new_m
            )
            return x, (kc, vc, stacked_m)

        def scan_body(x, inp):
            x, out = period_step(x, inp)
            return x, out

        x, (nk, nv, nm) = lax.scan(
            scan_body,
            x,
            (params["periods"], cache["k"], cache["v"], cache["mamba"]),
        )
        new_cache = {"k": nk, "v": nv, "mamba": nm}
    else:
        flags = _layer_flags(cfg)

        def scan_body(x, inp):
            p, kc, vc, flag = inp
            h = L.norm(p["norm1"], x, cfg.norm)
            window = _window_for(cfg, flag)
            # window must be a static python int for decode masks; use the
            # traced flag to select between two static computations
            a, kc, vc = L.cached_attention(
                p["attn"], h, kc, vc, cache_len, cfg,
                window=window, kv_mode=kv_mode,
            )
            x = x + a
            x, _, _ = _maybe_ffn(p, x, cfg)
            return x, (kc, vc)

        x, (nk, nv) = lax.scan(
            scan_body, x, (params["blocks"], cache["k"], cache["v"], flags)
        )
        new_cache = {"k": nk, "v": nv}

    x = L.norm(params["final_norm"], x, cfg.norm)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    return next_tok, new_cache


def lm_prefill(
    params: Params,
    tokens: jax.Array,
    cfg,
    *,
    vision_embeds: Optional[jax.Array] = None,
    positions3: Optional[jax.Array] = None,
):
    """Prefill: full forward collecting decode-ready caches.

    Returns (last_logits (B,1,Vp), caches). Cache layout matches
    init_decode_cache so the serving engine can continue decoding.
    """
    logits, _aux, caches = lm_forward(
        params, tokens, cfg,
        vision_embeds=vision_embeds, positions3=positions3,
        collect_cache=True,
    )
    return logits[:, -1:], caches
