"""Whisper-style encoder-decoder backbone.

The audio frontend (log-mel + conv downsampling) is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings (B, frames, d_model).
The encoder is bidirectional; the decoder has causal self-attention plus
cross-attention into the encoder output. Positions use RoPE on self-attention
(hardware-adaptation: whisper's learned absolute embeddings add a (max_pos, d)
table with no structural consequence; noted in DESIGN.md) and no rotation on
cross-attention, matching whisper's structure.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.sharding import shard

Params = Dict[str, Any]


def init_encdec(key, cfg) -> Params:
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    Vp, d = cfg.padded_vocab, cfg.d_model
    kE, kEnc, kDec, kH = jax.random.split(key, 4)

    def init_enc_block(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": L.init_norm(d, cfg.norm, dtype),
            "attn": L.init_attention(k1, cfg, dtype),
            "norm2": L.init_norm(d, cfg.norm, dtype),
            "mlp": L.init_mlp(k2, d, cfg.d_ff, dtype, cfg.gated_mlp),
        }

    def init_dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "norm1": L.init_norm(d, cfg.norm, dtype),
            "attn": L.init_attention(k1, cfg, dtype),
            "norm_c": L.init_norm(d, cfg.norm, dtype),
            "cross": L.init_attention(k2, cfg, dtype),
            "norm2": L.init_norm(d, cfg.norm, dtype),
            "mlp": L.init_mlp(k3, d, cfg.d_ff, dtype, cfg.gated_mlp),
        }

    return {
        "embed": jax.random.normal(kE, (Vp, d), dtype) * 0.02,
        "enc_blocks": jax.vmap(init_enc_block)(
            jax.random.split(kEnc, cfg.encoder_layers)
        ),
        "dec_blocks": jax.vmap(init_dec_block)(
            jax.random.split(kDec, cfg.num_layers)
        ),
        "enc_norm": L.init_norm(d, cfg.norm, dtype),
        "final_norm": L.init_norm(d, cfg.norm, dtype),
        "lm_head": jax.random.normal(kH, (d, Vp), dtype) / math.sqrt(d),
    }


def encoder_forward(params: Params, frames: jax.Array, cfg) -> jax.Array:
    """frames: (B, F, d) stub embeddings -> encoder states (B, F, d)."""
    B, F, _ = frames.shape
    x = shard(frames, "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (B, F))

    @jax.checkpoint
    def layer(x, p):
        h = L.norm(p["norm1"], x, cfg.norm)
        a = L.attention_block(p["attn"], h, positions, cfg, causal=False)
        x = x + a
        h = L.norm(p["norm2"], x, cfg.norm)
        return x + L.mlp_block(p["mlp"], h, cfg.act)

    def body(x, p):
        return layer(x, p), None

    x, _ = lax.scan(body, x, params["enc_blocks"])
    return L.norm(params["enc_norm"], x, cfg.norm)


def encdec_forward(
    params: Params, tokens: jax.Array, frames: jax.Array, cfg,
    *, collect_cache: bool = False,
):
    """tokens (B, S), frames (B, F, d) -> logits (B, S, Vp)."""
    enc = encoder_forward(params, frames, cfg)
    B, S = tokens.shape
    x = params["embed"][tokens]
    x = shard(x, "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    @jax.checkpoint
    def layer(x, p):
        h = L.norm(p["norm1"], x, cfg.norm)
        a = L.attention_block(
            p["attn"], h, positions, cfg, causal=True, return_kv=collect_cache
        )
        kv = None
        if collect_cache:
            a, kv = a
        x = x + a
        h = L.norm(p["norm_c"], x, cfg.norm)
        x = x + L.attention_block(p["cross"], h, positions, cfg, causal=False, xkv=enc)
        h = L.norm(p["norm2"], x, cfg.norm)
        return x + L.mlp_block(p["mlp"], h, cfg.act), kv

    def body(x, p):
        x, kv = layer(x, p)
        return x, kv

    x, kvs = lax.scan(body, x, params["dec_blocks"])
    x = L.norm(params["final_norm"], x, cfg.norm)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    logits = shard(logits, "batch", None, "vocab")
    if collect_cache:
        xk, xv = make_cross_caches(params, enc, cfg)
        caches = {"k": kvs[0], "v": kvs[1], "xk": xk, "xv": xv}
        return logits, caches
    return logits


def encdec_loss(params, batch, cfg):
    logits = encdec_forward(params, batch["tokens"], batch["frames"], cfg)
    if isinstance(logits, tuple):
        logits = logits[0]
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1
    )[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    xent = jnp.sum((lse - ll) * mask) / jnp.maximum(mask.sum(), 1.0)
    return xent, {"xent": xent}


def make_cross_caches(params: Params, enc: jax.Array, cfg):
    """Precompute per-decoder-layer cross K/V from encoder states (prefill)."""

    def one(p):
        k = jnp.einsum("bsd,dhk->bshk", enc, p["cross"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc, p["cross"]["wv"])
        if "bk" in p["cross"]:
            k = k + p["cross"]["bk"]
            v = v + p["cross"]["bv"]
        return k, v

    def body(_, p):
        return None, one(p)

    _, (xk, xv) = lax.scan(body, None, params["dec_blocks"])
    return xk, xv  # (L, B, F, Kh, D)


def _cross_attn_decode(p, x, xk, xv):
    """Single-token cross attention over fixed encoder K/V (no rope)."""
    B, F, Kh, D = xk.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    H = q.shape[2]
    G = H // Kh
    qh = (q * (1.0 / math.sqrt(D))).reshape(B, Kh, G, D)
    s = jnp.einsum(
        "bhgd,bshd->bhgs", qh, xk, preferred_element_type=jnp.float32
    )
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bhgs,bshd->bhgd", w.astype(xv.dtype), xv,
        preferred_element_type=jnp.float32,
    )
    o = o.reshape(B, 1, H, D).astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def encdec_decode_step(
    params: Params,
    token: jax.Array,
    cache: Params,
    cache_len: jax.Array,
    cfg,
) -> Tuple[jax.Array, Params]:
    """One greedy decoder step. cache: {k, v, xk, xv} stacked over layers."""
    x = params["embed"][token]
    kv_mode = L.decode_kv_mode(cfg)

    def scan_body(x, inp):
        p, kc, vc, xk, xv = inp
        h = L.norm(p["norm1"], x, cfg.norm)
        a, kc, vc = L.cached_attention(
            p["attn"], h, kc, vc, cache_len, cfg, kv_mode=kv_mode
        )
        x = x + a
        h = L.norm(p["norm_c"], x, cfg.norm)
        x = x + _cross_attn_decode(p["cross"], h, xk, xv)
        h = L.norm(p["norm2"], x, cfg.norm)
        x = x + L.mlp_block(p["mlp"], h, cfg.act)
        return x, (kc, vc)

    x, (nk, nv) = lax.scan(
        scan_body,
        x,
        (params["dec_blocks"], cache["k"], cache["v"], cache["xk"], cache["xv"]),
    )
    x = L.norm(params["final_norm"], x, cfg.norm)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    new_cache = {"k": nk, "v": nv, "xk": cache["xk"], "xv": cache["xv"]}
    return next_tok, new_cache


def encdec_prefill(params, tokens, frames, cfg):
    """Prefill (encoder + decoder prompt). Returns (last_logits, caches)."""
    logits, caches = encdec_forward(
        params, tokens, frames, cfg, collect_cache=True
    )
    return logits[:, -1:], caches
