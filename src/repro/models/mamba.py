"""Mamba2 (SSD) mixer with sequence-parallel inter-chunk scan.

TPU-native adaptation of SSD (state-space duality, arXiv:2405.21060):
  * intra-chunk work is the matmul ("attention-like") form — MXU-aligned
    einsums over (Q x Q) chunk score matrices;
  * within-chunk cumulative log-decays go through the Pallas prefix-scan
    kernel path (kernels.ops.prefix_scan);
  * inter-chunk state propagation h' = A*h + B is an associative scan:
    locally a lax.associative_scan over the chunk axis, and ACROSS DEVICES —
    when the sequence is sharded (seq_parallel) — the paper's offloaded scan
    collective ``core.dist_exscan`` with the SSD operator;
  * the causal depthwise conv's cross-shard halo is a single neighbor
    ppermute (rank 0's zero-fill is exactly causal padding).

Projections are stored per-segment (z, x, BC, dt) instead of one fused
in_proj so tensor-parallel sharding is clean: z/x shard the inner dim, dt the
head dim, BC stays replicated (it is tiny and shared across heads).

Modes:
  seq_parallel=True  — sequence sharded over the model axis (mamba2-130m;
                       weights replicated, the scan collective carries state).
  seq_parallel=False — heads sharded over the model axis (jamba-52b TP;
                       full sequence per device, scan stays local).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import perf_flags
from repro.compat import axis_size as compat_axis_size, shard_map
from repro.core import SSD, dist_exscan
from repro.kernels.ops import prefix_scan
from repro.sharding import current_topology, shard

Params = Dict[str, Any]

_CONV_WIDTH = 4


def init_mamba(key, cfg, dtype) -> Params:
    d = cfg.d_model
    di = cfg.ssm_d_inner
    N = cfg.ssm_state
    H = cfg.ssm_num_heads
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    return {
        "w_z": jax.random.normal(ks[0], (d, di), dtype) * s,
        "w_x": jax.random.normal(ks[1], (d, di), dtype) * s,
        "w_bc": jax.random.normal(ks[2], (d, 2 * N), dtype) * s,
        "w_dt": jax.random.normal(ks[3], (d, H), dtype) * s,
        "conv_w_x": jax.random.normal(ks[4], (_CONV_WIDTH, di), dtype) * 0.5,
        "conv_b_x": jnp.zeros((di,), dtype),
        "conv_w_bc": jax.random.normal(ks[5], (_CONV_WIDTH, 2 * N), dtype) * 0.5,
        "conv_b_bc": jnp.zeros((2 * N,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), math.log(math.e - 1), jnp.float32),
        "norm_scale": jnp.zeros((di,), dtype),
        "w_out": jax.random.normal(ks[0], (di, d), dtype) / math.sqrt(di),
    }


def _conv1d(x: jax.Array, w: jax.Array, b: jax.Array, halo: Optional[jax.Array]):
    """Depthwise causal conv width 4 + silu. halo: (B, 3, C) left context."""
    B, S, C = x.shape
    if halo is None:
        halo = jnp.zeros((B, _CONV_WIDTH - 1, C), x.dtype)
    ext = jnp.concatenate([halo, x], axis=1)
    out = jnp.zeros_like(x)
    for wi in range(_CONV_WIDTH):
        out = out + ext[:, wi : wi + S] * w[wi]
    return jax.nn.silu(out + b)


def _gated_rmsnorm(scale: jax.Array, y: jax.Array, z: jax.Array) -> jax.Array:
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * lax.rsqrt(var + 1e-6) * (1.0 + scale.astype(jnp.float32))).astype(y.dtype)


def _ssd_chunked(
    xs: jax.Array,     # (B, S, H, P) conv'd inputs
    Bc: jax.Array,     # (B, S, N)
    Cc: jax.Array,     # (B, S, N)
    dA: jax.Array,     # (B, S, H) log-decay increments (<= 0)
    dt: jax.Array,     # (B, S, H) softplus'd step sizes
    chunk: int,
    state_in: Optional[Tuple[jax.Array, jax.Array]] = None,
):
    """Chunked SSD. Returns (y, (A_tot, S_tot), extras) where extras enable a
    cheap post-hoc fold of a device-incoming state (SP mode)."""
    B, S, H, Pd = xs.shape
    N = Bc.shape[-1]
    Q = min(chunk, S)
    nc = S // Q
    assert S % Q == 0, (S, Q)

    xb = (xs * dt[..., None]).astype(xs.dtype)       # dt-scaled inputs
    xbc_ = xb.reshape(B, nc, Q, H, Pd)
    Bcc = Bc.reshape(B, nc, Q, N)
    Ccc = Cc.reshape(B, nc, Q, N)
    dAc = dA.reshape(B, nc, Q, H)

    # within-chunk cumulative log decay — Pallas prefix-scan path
    seg = prefix_scan(
        jnp.moveaxis(dAc, 2, 3).astype(jnp.float32)  # (B,nc,H,Q)
    )
    seg = jnp.moveaxis(seg, 3, 2)                    # (B,nc,Q,H)

    @jax.checkpoint
    def intra(Ccc, Bcc, seg, xbc_):
        scores = jnp.einsum("bcin,bcjn->bcij", Ccc, Bcc)
        Lmat = jnp.exp(
            jnp.clip(seg[:, :, :, None, :] - seg[:, :, None, :, :], -60.0, 0.0)
        )  # (B,c,i,j,H)
        ii = jnp.arange(Q)
        causal = (ii[:, None] >= ii[None, :]).astype(scores.dtype)
        W = scores[..., None] * Lmat * causal[None, None, :, :, None]
        return jnp.einsum("bcijh,bcjhp->bcihp", W, xbc_)

    y_intra = intra(Ccc, Bcc, seg, xbc_)

    # chunk summary states: S_c = sum_j decay_to_end_j * xb_j (x) B_j
    decay_end = jnp.exp(seg[:, :, -1:, :] - seg)     # (B,c,Q,H)
    S_c = jnp.einsum("bcjhp,bcjn->bchpn", xbc_ * decay_end[..., None], Bcc)
    A_c = jnp.exp(seg[:, :, -1, :])                  # (B,c,H)

    # local inclusive scan over the chunk axis
    def comb(l, r):
        al, sl = l
        ar, sr = r
        return ar * al, ar[..., None, None] * sl + sr

    A_inc, S_inc = lax.associative_scan(comb, (A_c, S_c), axis=1)
    A_exc = jnp.concatenate([jnp.ones_like(A_inc[:, :1]), A_inc[:, :-1]], axis=1)
    S_exc = jnp.concatenate([jnp.zeros_like(S_inc[:, :1]), S_inc[:, :-1]], axis=1)
    if state_in is not None:
        a_in, s_in = state_in                        # (B,H), (B,H,P,N)
        S_exc = A_exc[..., None, None] * s_in[:, None] + S_exc
        A_exc = A_exc * a_in[:, None]

    y_inter = jnp.einsum("bcin,bchpn->bcihp", Ccc, S_exc) * jnp.exp(seg)[..., None]
    y = (y_intra + y_inter).reshape(B, S, H, Pd)
    A_tot, S_tot = A_inc[:, -1], S_inc[:, -1]        # device totals
    if state_in is not None:
        S_tot = A_inc[:, -1][..., None, None] * state_in[1] + S_tot
        A_tot = A_tot * state_in[0]
    extras = (Ccc, seg, A_exc)
    return y, (A_tot, S_tot), extras


def _project(p: Params, x: jax.Array, cfg, halo_x: Optional[jax.Array], tp: bool):
    """proj + conv. Returns (z, xs, Bc, Cc, dtp, dA)."""
    B, S, _ = x.shape
    di, N, H = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads
    Pd = cfg.ssm_head_dim
    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    x_in = jnp.einsum("bsd,de->bse", x, p["w_x"])
    bc = jnp.einsum("bsd,de->bse", x, p["w_bc"])
    dt = jnp.einsum("bsd,de->bse", x, p["w_dt"])
    if tp:
        z = shard(z, "batch", None, "model")
        x_in = shard(x_in, "batch", None, "model")
        dt = shard(dt, "batch", None, "heads")

    halo_xin = halo_bc = None
    if halo_x is not None:
        halo_xin = jnp.einsum("bsd,de->bse", halo_x, p["w_x"])
        halo_bc = jnp.einsum("bsd,de->bse", halo_x, p["w_bc"])
    tails = (x_in[:, -(_CONV_WIDTH - 1):], bc[:, -(_CONV_WIDTH - 1):])
    x_in = _conv1d(x_in, p["conv_w_x"], p["conv_b_x"], halo_xin)
    bc = _conv1d(bc, p["conv_w_bc"], p["conv_b_bc"], halo_bc)
    xs = x_in.reshape(B, S, H, Pd)
    Bc, Cc = bc[..., :N], bc[..., N:]
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    dA = dtp * A
    return z, xs, Bc, Cc, dtp, dA, tails


def _mixer_core(p: Params, x: jax.Array, cfg, halo_x, state_in, seq_axis, tp):
    B, S, _ = x.shape
    di = cfg.ssm_d_inner
    H, Pd = cfg.ssm_num_heads, cfg.ssm_head_dim
    chunk = perf_flags.FLAGS.ssm_chunk or cfg.ssm_chunk
    z, xs, Bc, Cc, dtp, dA, tails = _project(p, x, cfg, halo_x, tp)

    if seq_axis is not None:
        y, (A_tot, S_tot), (Ccc, seg, A_exc) = _ssd_chunked(
            xs, Bc, Cc, dA, dtp, chunk
        )
        # cross-device incoming state via the offloaded scan collective
        payload = (A_tot[..., None, None], S_tot)
        if perf_flags.FLAGS.scan_payload_bf16:
            # barrier pins the narrow dtype so converts can't hoist across
            # the ppermutes (wire payload stays bf16)
            payload = lax.optimization_barrier(
                jax.tree.map(lambda t: t.astype(jnp.bfloat16), payload)
            )
        a_in, s_in = dist_exscan(
            payload, SSD, seq_axis,
            algorithm=perf_flags.FLAGS.scan_algorithm,
        )
        a_in = a_in[..., 0, 0].astype(A_tot.dtype)   # (B,H)
        s_in = s_in.astype(S_tot.dtype)
        y_add = jnp.einsum(
            "bcin,bch,bhpn->bcihp", Ccc, A_exc, s_in
        ) * jnp.exp(seg)[..., None]
        y = y + y_add.reshape(B, S, H, Pd)
        S_tot = A_tot[..., None, None] * s_in + S_tot
        A_tot = A_tot * a_in
    else:
        y, (A_tot, S_tot), _ = _ssd_chunked(
            xs, Bc, Cc, dA, dtp, chunk, state_in=state_in
        )

    y = y + p["D"][None, None, :, None].astype(y.dtype) * xs.astype(y.dtype)
    y = y.reshape(B, S, di)
    y = _gated_rmsnorm(p["norm_scale"], y.astype(x.dtype), z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"]).astype(x.dtype)
    if tp:
        out = shard(out, "batch", None, None)
    cache = {
        "ssm": S_tot.astype(jnp.float32),
        "conv_x": tails[0],
        "conv_bc": tails[1],
    }
    if seq_axis is not None:
        # decode cache is global: take the LAST sequence shard's values
        psize = compat_axis_size(seq_axis)
        last = lax.axis_index(seq_axis) == psize - 1
        cache = jax.tree.map(
            lambda a: lax.psum(jnp.where(last, a, jnp.zeros_like(a)), seq_axis),
            cache,
        )
    return out, cache


def mamba_mixer(
    p: Params,
    x: jax.Array,
    cfg,
    *,
    seq_parallel: bool = False,
    state_in: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence SSD mixer (train / prefill).

    Returns (y, cache) where cache = {ssm, conv_x, conv_bc} is decode-ready
    (the final SSD state and the conv-input tails)."""
    topo = current_topology()
    B, S, _ = x.shape
    sp_ok = (
        seq_parallel
        and topo.mesh is not None
        and topo.model_size > 1
        and S % topo.model_size == 0
        and (S // topo.model_size) >= _CONV_WIDTH
    )
    if not sp_ok:
        tp = topo.mesh is not None and not seq_parallel
        return _mixer_core(p, x, cfg, None, state_in, None, tp)

    axis = topo.model_axis
    dp = topo.batch_axes
    dpspec = dp[0] if len(dp) == 1 else dp
    x_spec = P(dpspec, axis, None)
    wspecs = jax.tree.map(lambda _: P(), p)

    def region(p_l, x_l):
        # conv halo: last 3 raw tokens from the left sequence shard (rank 0
        # receives ppermute zero-fill == causal zero padding)
        psize = compat_axis_size(axis)
        tail = x_l[:, -(_CONV_WIDTH - 1):, :]
        halo_x = lax.ppermute(tail, axis, [(i, i + 1) for i in range(psize - 1)])
        return _mixer_core(p_l, x_l, cfg, halo_x, None, axis, False)

    cache_specs = {
        "ssm": P(dpspec, None, None, None),
        "conv_x": P(dpspec, None, None),
        "conv_bc": P(dpspec, None, None),
    }
    mapped = shard_map(
        region,
        mesh=topo.mesh,
        in_specs=(wspecs, x_spec),
        out_specs=(x_spec, cache_specs),
        check_vma=False,
    )
    return mapped(p, x)


def init_mamba_state(cfg, batch: int, dtype=jnp.float32):
    H, Pd, N = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, H, Pd, N), dtype),
        "conv_x": jnp.zeros((batch, _CONV_WIDTH - 1, cfg.ssm_d_inner), dtype),
        "conv_bc": jnp.zeros((batch, _CONV_WIDTH - 1, 2 * N), dtype),
    }


def mamba_decode(
    p: Params, x: jax.Array, state: Dict[str, jax.Array], cfg
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token SSD step. x: (B, 1, d); state: {ssm, conv_x, conv_bc}."""
    B = x.shape[0]
    di, N, H = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads
    Pd = cfg.ssm_head_dim
    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    x_in = jnp.einsum("bsd,de->bse", x, p["w_x"])
    bc = jnp.einsum("bsd,de->bse", x, p["w_bc"])
    dt = jnp.einsum("bsd,de->bse", x, p["w_dt"])

    ext_x = jnp.concatenate([state["conv_x"], x_in], axis=1)    # (B, W, di)
    ext_bc = jnp.concatenate([state["conv_bc"], bc], axis=1)
    cx = jax.nn.silu(jnp.einsum("bwc,wc->bc", ext_x, p["conv_w_x"]) + p["conv_b_x"])
    cbc = jax.nn.silu(jnp.einsum("bwc,wc->bc", ext_bc, p["conv_w_bc"]) + p["conv_b_bc"])

    xs = cx.reshape(B, H, Pd)
    Bc, Cc = cbc[..., :N], cbc[..., N:]
    dtp = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dtp * A)                          # (B,H)
    h = state["ssm"]
    h = (
        decay[..., None, None] * h
        + (dtp[..., None] * xs.astype(jnp.float32))[..., None]
        * Bc.astype(jnp.float32)[:, None, None, :]
    )
    y = jnp.einsum("bhpn,bn->bhp", h, Cc.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = _gated_rmsnorm(p["norm_scale"], y, z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"]).astype(x.dtype)
    return out, {"ssm": h, "conv_x": ext_x[:, 1:], "conv_bc": ext_bc[:, 1:]}
