"""Transformer substrate: norms, RoPE/M-RoPE, GQA attention (flash-blocked,
sliding-window, KV-cache decode, KV-sequence-sharded decode), gated MLP.

Everything is a pure function over explicit param pytrees (dicts of arrays);
activation sharding is expressed through ``repro.sharding.shard`` logical
annotations so the same code runs unsharded on 1 CPU device and fully sharded
on the production mesh.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import shard_map
from repro.perf_flags import FLAGS as _DEFAULT_FLAGS
from repro import perf_flags
from repro.sharding import current_topology, shard

Params = Dict[str, Any]


def tp_out_einsum(spec: str, a, b):
    """Projection einsum whose output crosses a TP psum.

    With tp_reduce_bf16, the dot emits bf16 directly so the partitioner's
    all-reduce carries bf16 (half the wire bytes); otherwise XLA's f32-accum
    lowering leaves the psum payload in f32 on this backend."""
    if perf_flags.FLAGS.tp_reduce_bf16:
        return jnp.einsum(
            spec, a.astype(jnp.bfloat16), b.astype(jnp.bfloat16),
            preferred_element_type=jnp.bfloat16,
        )
    return jnp.einsum(spec, a, b)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(w: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return ((xf * lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))).astype(dt)


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


def norm(p, x, kind: str = "rmsnorm"):
    if kind == "rmsnorm":
        return rmsnorm(p["scale"], x)
    return layernorm(p, x)


def init_norm(d: int, kind: str, dtype) -> Params:
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def _rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float = 1e4
) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = _rope_freqs(d, theta)  # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, d/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope_sections(head_dim: int) -> tuple:
    """Qwen2-VL splits head_dim/2 freq slots 1:1.5:1.5 over (t, h, w) —
    (16, 24, 24) at head_dim=128; scaled proportionally otherwise."""
    half = head_dim // 2
    t = max(1, half // 4)
    h = (half - t) // 2
    return (t, h, half - t - h)


def apply_mrope(
    x: jax.Array,
    positions3: jax.Array,
    theta: float = 1e4,
    sections: tuple = None,
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: positions3 (B, S, 3) = (t, h, w) streams.

    head_dim/2 frequency slots are split across the three position streams
    (sections sum to head_dim/2); text tokens carry t==h==w so M-RoPE reduces
    to 1-D RoPE for them.
    """
    d = x.shape[-1]
    if sections is None:
        sections = mrope_sections(d)
    assert sum(sections) == d // 2, (sections, d)
    freqs = _rope_freqs(d, theta)  # (d/2,)
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        f = freqs[start : start + sec]
        ang = positions3[..., i][..., None].astype(jnp.float32) * f
        parts.append(ang)
        start += sec
    angles = jnp.concatenate(parts, axis=-1)  # (B, S, d/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg, dtype) -> Params:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    h, kh = cfg.num_heads, cfg.num_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": jax.random.normal(k1, (d, h, hd), dtype) * s,
        "wk": jax.random.normal(k2, (d, kh, hd), dtype) * s,
        "wv": jax.random.normal(k3, (d, kh, hd), dtype) * s,
        "wo": jax.random.normal(k4, (h, hd, d), dtype) * s,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kh, hd), dtype)
        p["bv"] = jnp.zeros((kh, hd), dtype)
    return p


def _qkv(p: Params, x: jax.Array, xkv: Optional[jax.Array] = None):
    xkv = x if xkv is None else xkv
    q = tp_out_einsum("bsd,dhk->bshk", x, p["wq"])
    k = tp_out_einsum("bsd,dhk->bshk", xkv, p["wk"])
    v = tp_out_einsum("bsd,dhk->bshk", xkv, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: jax.Array | int = 0,
    q_offset: jax.Array | int = 0,
    q_block: int = 1024,
    kv_block: int = 1024,
    seq_shard: bool = False,
) -> jax.Array:
    """Memory-safe blocked attention (jnp flash): scan over KV blocks.

    q: (B, Sq, H, D); k/v: (B, Sk, Kh, D) with H = G*Kh (GQA). ``window`` > 0
    masks keys older than ``window`` positions (sliding-window attention);
    pass a traced scalar to select local/global per scanned layer.
    ``q_offset`` is the absolute position of q[0] (decode / sharded-sequence).

    The per-(q-block, kv-block) body is checkpointed so the backward pass
    recomputes scores instead of storing (B, H, Sq, Sk).
    """
    B, Sq, H, D = q.shape
    _, Sk, Kh, _ = k.shape
    G = H // Kh
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    nq = -(-Sq // q_block)
    nk = -(-Sk // kv_block)
    # pad to block multiples
    qp = jnp.pad(q, ((0, 0), (0, nq * q_block - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * kv_block - Sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kv_block - Sk), (0, 0), (0, 0)))
    scale = 1.0 / math.sqrt(D)
    qp = (qp * scale).reshape(B, nq, q_block, Kh, G, D)
    kp = kp.reshape(B, nk, kv_block, Kh, D)
    vp = vp.reshape(B, nk, kv_block, Kh, D)

    if seq_shard:
        # shard the query-block dim over the model axis: each device scores
        # q_block/msize queries against the (gathered) KV — removes the
        # replicated-attention waste when heads don't divide the axis
        qp = shard(qp, "batch", None, "seq", None, None, None)

    q_pos = q_offset + jnp.arange(nq * q_block).reshape(nq, q_block)
    k_pos = jnp.arange(nk * kv_block).reshape(nk, kv_block)
    k_valid = (jnp.arange(nk * kv_block) < Sk).reshape(nk, kv_block)

    @jax.checkpoint
    def block(qb, qpos, kb, vb, kpos, kval):
        # qb: (B, q_block, Kh, G, D); kb/vb: (B, kv_block, Kh, D)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb).astype(jnp.float32)
        mask = kval[None, None, None, None, :]
        if causal is not None and causal:
            mask = mask & (qpos[:, None] >= kpos[None, :])[None, None, None]
        w = window if isinstance(window, jax.Array) else jnp.array(window)
        win_mask = (qpos[:, None] - kpos[None, :]) < w
        mask = mask & jnp.where(w > 0, win_mask, True)[None, None, None]
        s = jnp.where(mask, s, -1e30)
        m = jnp.max(s, axis=-1)                          # (B,h,g,q)
        probs = jnp.exp(s - m[..., None])
        l = jnp.sum(probs, axis=-1)
        if perf_flags.FLAGS.attn_probs_bf16:
            o = jnp.einsum(
                "bhgqk,bkhd->bhgqd",
                probs.astype(jnp.bfloat16), vb.astype(jnp.bfloat16),
                preferred_element_type=jnp.float32,
            )
        else:
            o = jnp.einsum("bhgqk,bkhd->bhgqd", probs, vb.astype(jnp.float32))
        return m, l, o

    def q_loop(_, qi):
        qb, qpos = qi

        def kv_loop(carry, ki):
            m, l, o = carry
            kb, vb, kpos, kval = ki
            mb, lb, ob = block(qb, qpos, kb, vb, kpos, kval)
            mn = jnp.maximum(m, mb)
            c1 = jnp.exp(m - mn)
            c2 = jnp.exp(mb - mn)
            return (
                mn,
                l * c1 + lb * c2,
                o * c1[..., None] + ob * c2[..., None],
            ), None

        m0 = jnp.full((B, Kh, G, q_block), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Kh, G, q_block), jnp.float32)
        o0 = jnp.zeros((B, Kh, G, q_block, D), jnp.float32)
        (m, l, o), _ = lax.scan(
            kv_loop,
            (m0, l0, o0),
            (
                jnp.moveaxis(kp, 1, 0),
                jnp.moveaxis(vp, 1, 0),
                k_pos,
                k_valid,
            ),
        )
        out = o / jnp.maximum(l, 1e-30)[..., None]       # (B,h,g,q,D)
        return None, out

    _, outs = lax.scan(q_loop, None, (jnp.moveaxis(qp, 1, 0), q_pos))
    if seq_shard:
        outs = shard(outs, None, "batch", None, None, "seq", None)
    # outs: (nq, B, Kh, G, q_block, D) -> (B, Sq, H, D)
    out = jnp.moveaxis(outs, 0, 3).reshape(B, Kh, G, nq * q_block, D)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, nq * q_block, H, D)
    return out[:, :Sq].astype(q.dtype)


def attention_block(
    p: Params,
    x: jax.Array,
    positions: jax.Array,
    cfg,
    *,
    causal: bool = True,
    window: jax.Array | int = 0,
    xkv: Optional[jax.Array] = None,
    positions3: Optional[jax.Array] = None,
    return_kv: bool = False,
):
    """Full-sequence attention (train / prefill). TP over heads when they
    divide the model axis, else sequence stays sharded and XLA gathers KV.

    With return_kv=True also returns the (roped-k, v) pair for decode caches.
    """
    topo = current_topology()
    seq_over_tp = perf_flags.FLAGS.attn_seq_over_tp
    if not seq_over_tp and _tp_ready(topo, cfg.num_heads):
        q, k, v = explicit_tp_qkv(p, x, xkv, topo)
    else:
        q, k, v = _qkv(p, x, xkv)
    if xkv is None:  # self-attention: rotate both q and k
        if positions3 is not None and cfg.mrope:
            q = apply_mrope(q, positions3, cfg.rope_theta)
            k = apply_mrope(k, positions3, cfg.rope_theta)
        elif cfg.rope_theta > 0:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    msize = topo.model_size
    heads_ok = msize <= 1 or (q.shape[2] % msize == 0)
    blocks_ok = min(1024, q.shape[1]) % max(msize, 1) == 0
    seq_shard = (
        (perf_flags.FLAGS.seq_shard_attn and not heads_ok) or seq_over_tp
    ) and blocks_ok
    if not (seq_over_tp and blocks_ok):
        q = shard(q, "batch", None, "heads", None)
        k = shard(k, "batch", None, "heads", None)
        v = shard(v, "batch", None, "heads", None)
    out = flash_attention(
        q, k, v, causal=causal, window=window, seq_shard=seq_shard,
        kv_block=perf_flags.FLAGS.attn_kv_block,
    )
    if not seq_over_tp and _tp_ready(topo, cfg.num_heads):
        out = explicit_tp_wo(out, p["wo"], topo)
    else:
        out = tp_out_einsum("bshk,hkd->bsd", out, p["wo"])
    out = shard(out, "batch", None, None)
    if return_kv:
        return out, (k, v)
    return out


def decode_attention(
    p: Params,
    x: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    cfg,
    *,
    window: int = 0,
    update_cache: bool = True,
    positions3: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode vs a (B, S_max, Kh, D) KV cache.

    Returns (out, new_k_cache, new_v_cache). The new token is written at
    ``cache_len``. For cross-attention pass update_cache=False.
    """
    B, S_max, Kh, D = k_cache.shape
    q, k, v = _qkv(p, x)
    pos = jnp.full((B, 1), cache_len, jnp.int32)
    if positions3 is not None and cfg.mrope:
        q = apply_mrope(q, positions3, cfg.rope_theta)
        k = apply_mrope(k, positions3, cfg.rope_theta)
    elif cfg.rope_theta > 0:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    if update_cache:
        k_cache = lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (0, cache_len, 0, 0)
        )
        v_cache = lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (0, cache_len, 0, 0)
        )
    H = cfg.num_heads
    G = H // Kh
    scale = 1.0 / math.sqrt(D)
    qh = (q * scale).reshape(B, Kh, G, D)
    s = jnp.einsum(
        "bhgd,bshd->bhgs", qh, k_cache,
        preferred_element_type=jnp.float32,
    )
    kpos = jnp.arange(S_max)
    valid = kpos[None, None, None, :] <= cache_len
    w = window if isinstance(window, jax.Array) else jnp.array(window)
    win_ok = cache_len - kpos[None, None, None, :] < w
    valid = valid & jnp.where(w > 0, win_ok, True)
    s = jnp.where(valid, s, -1e30)
    attn_w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bhgs,bshd->bhgd", attn_w.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    o = o.reshape(B, 1, H, D).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, k_cache, v_cache


def seq_sharded_decode_attention_core(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    cfg,
    *,
    axis_name: str,
    window: jax.Array | int = 0,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Decode attention with the KV cache sharded along SEQUENCE over
    ``axis_name`` (for archs whose kv-head count can't split the model axis:
    MQA granite, qwen2.5, jamba...).

    Runs inside shard_map; q/k/v are precomputed (and roped) outside so the
    projection weights keep their TP sharding. Each device scores its cache
    shard and the partial (m, l, o) triplets are merged with the associative
    flash combine via pmax/psum — the same operator algebra as the scan
    collective (core.operators.make_flash_op). The new token is written by
    the owner shard only. Returns the merged per-head outputs (B, 1, H, D);
    the wo projection happens outside.
    """
    B, S_shard, Kh, D = k_cache.shape
    idx = lax.axis_index(axis_name)
    # owner shard writes the new kv
    local_start = idx * S_shard
    off = cache_len - local_start
    owner = (off >= 0) & (off < S_shard)
    safe_off = jnp.clip(off, 0, S_shard - 1)
    new_k = lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, safe_off, 0, 0)
    )
    new_v = lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, safe_off, 0, 0)
    )
    k_cache = jnp.where(owner, new_k, k_cache)
    v_cache = jnp.where(owner, new_v, v_cache)

    H = cfg.num_heads
    G = H // Kh
    scale = 1.0 / math.sqrt(D)
    qh = (q * scale).reshape(B, Kh, G, D)
    s = jnp.einsum(
        "bhgd,bshd->bhgs", qh, k_cache,
        preferred_element_type=jnp.float32,
    )
    kpos = local_start + jnp.arange(S_shard)
    valid = kpos[None, None, None, :] <= cache_len
    w = window if isinstance(window, jax.Array) else jnp.array(window)
    win_ok = cache_len - kpos[None, None, None, :] < w
    valid = valid & jnp.where(w > 0, win_ok, True)
    s = jnp.where(valid, s, -1e30)
    m = jnp.max(s, axis=-1)
    l = jnp.sum(jnp.exp(s - m[..., None]), axis=-1)
    o = jnp.einsum(
        "bhgs,bshd->bhgd",
        jnp.exp(s - m[..., None]).astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    # associative flash merge across shards
    mg = lax.pmax(m, axis_name)
    c = jnp.exp(m - mg)
    lg = lax.psum(l * c, axis_name)
    og = lax.psum(o * c[..., None], axis_name)
    o = (og / jnp.maximum(lg, 1e-30)[..., None]).reshape(B, 1, H, D)
    return o.astype(q.dtype), k_cache, v_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

_ACT = {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True), "relu": jax.nn.relu}


def init_mlp(key, d: int, ff: int, dtype, gated: bool = True) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(ff)
    p = {
        "w_in": jax.random.normal(k1, (d, ff), dtype) * s_in,
        "w_out": jax.random.normal(k2, (ff, d), dtype) * s_out,
    }
    if gated:
        p["w_gate"] = jax.random.normal(k3, (d, ff), dtype) * s_in
    return p


def mlp_block(p: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    topo = current_topology()
    ff = p["w_in"].shape[-1]
    if _tp_ready(topo, ff):
        return explicit_tp_mlp(p, x, act, topo)
    a = _ACT[act]
    h = tp_out_einsum("bsd,df->bsf", x, p["w_in"])
    if "w_gate" in p:
        g = tp_out_einsum("bsd,df->bsf", x, p["w_gate"])
        h = a(g) * h
    else:
        h = a(h)
    h = shard(h, "batch", None, "ff")
    out = tp_out_einsum("bsf,fd->bsd", h, p["w_out"])
    return shard(out, "batch", None, None)


def decode_kv_mode(cfg) -> str:
    """Cache layout for decode: 'heads' when kv heads divide the model axis,
    'seq' (sequence-sharded cache + LSE psum merge) otherwise, 'local' off-mesh."""
    topo = current_topology()
    if topo.mesh is None or topo.model_size <= 1:
        return "local"
    return "heads" if cfg.num_kv_heads % topo.model_size == 0 else "seq"


def cached_attention(p, x, kc, vc, cache_len, cfg, *, window=0, kv_mode="local"):
    """One-token attention against a KV cache, dispatching on cache layout."""
    if kv_mode == "seq":
        from jax.sharding import PartitionSpec as P

        topo = current_topology()
        axis = topo.model_axis
        dp = topo.batch_axes
        B = x.shape[0]
        dpspec = dp[0] if len(dp) == 1 else dp
        bspec = dpspec if (B % topo.dp_size == 0 and B > 1) else None
        q, k, v = _qkv(p, x)
        pos = jnp.full((B, 1), cache_len, jnp.int32)
        if cfg.rope_theta > 0:
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k, pos, cfg.rope_theta)

        def region(q, k, v, kc, vc, clen, win):
            return seq_sharded_decode_attention_core(
                q, k, v, kc, vc, clen, cfg, axis_name=axis, window=win
            )

        cspec = P(bspec, axis, None, None)
        rspec = P(bspec, None, None, None)
        win_arr = window if isinstance(window, jax.Array) else jnp.array(window)
        o, kc, vc = shard_map(
            region,
            mesh=topo.mesh,
            in_specs=(rspec, rspec, rspec, cspec, cspec, P(), P()),
            out_specs=(rspec, cspec, cspec),
            check_vma=False,
        )(q, k, v, kc, vc, cache_len, win_arr)
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        return out, kc, vc
    return decode_attention(p, x, kc, vc, cache_len, cfg, window=window)


# ---------------------------------------------------------------------------
# Explicit-TP projections (perf flag: explicit_tp)
#
# The GSPMD partitioner on this backend places TP all-reduces on the f32
# accumulation value (float-normalization runs first), doubling wire bytes.
# Running the projections inside shard_map puts the psum under OUR control:
# payload is cast to the activation dtype before it touches the wire — the
# same move the paper makes by taking the collective out of the generic MPI
# stack into the NIC. Autodiff of the region places the dx boundary psum on
# the primal dtype as well.
# ---------------------------------------------------------------------------


def _tp_ready(topo, *dims):
    return (
        perf_flags.FLAGS.explicit_tp
        and topo.mesh is not None
        and topo.model_size > 1
        and all(d % topo.model_size == 0 for d in dims)
    )


def _batch_spec_entry(topo, batch_dim: int):
    """DP sharding entry for a batch dim, or None when it can't shard."""
    if batch_dim % max(topo.dp_size, 1) != 0 or batch_dim <= 1:
        return None
    dp = topo.batch_axes
    return dp[0] if len(dp) == 1 else dp


def explicit_tp_mlp(p: Params, x: jax.Array, act: str, topo) -> jax.Array:
    """Gated MLP with explicit ff-sharded compute + owned bf16 psum."""
    from jax.sharding import PartitionSpec as P

    axis = topo.model_axis
    dpspec = _batch_spec_entry(topo, x.shape[0])
    a = _ACT[act]
    gated = "w_gate" in p

    def region(x_l, w_in, w_gate, w_out):
        h = jnp.einsum("bsd,df->bsf", x_l, w_in)
        if w_gate is not None:
            h = a(jnp.einsum("bsd,df->bsf", x_l, w_gate)) * h
        else:
            h = a(h)
        out = jnp.einsum("bsf,fd->bsd", h, w_out)
        # barrier pins the bf16 value so the wire payload stays narrow
        out = lax.optimization_barrier(out.astype(x_l.dtype))
        return lax.psum(out, axis)

    xspec = P(dpspec, None, None)
    if gated:
        fn = shard_map(
            region, mesh=topo.mesh,
            in_specs=(xspec, P(None, axis), P(None, axis), P(axis, None)),
            out_specs=xspec, check_vma=False,
        )
        return fn(x, p["w_in"], p["w_gate"], p["w_out"])
    fn = shard_map(
        lambda x_l, wi, wo: region(x_l, wi, None, wo),
        mesh=topo.mesh,
        in_specs=(xspec, P(None, axis), P(axis, None)),
        out_specs=xspec, check_vma=False,
    )
    return fn(x, p["w_in"], p["w_out"])


def explicit_tp_qkv(p: Params, x: jax.Array, xkv: Optional[jax.Array], topo):
    """Head-sharded q/k/v projections inside shard_map (dx psum owned)."""
    from jax.sharding import PartitionSpec as P

    axis = topo.model_axis
    msize = topo.model_size
    dpspec = _batch_spec_entry(topo, x.shape[0])
    kv_sharded = p["wk"].shape[1] % msize == 0
    has_bias = "bq" in p

    def region(x_l, xkv_l, wq, wk, wv, bq, bk, bv):
        q = jnp.einsum("bsd,dhk->bshk", x_l, wq)
        k = jnp.einsum("bsd,dhk->bshk", xkv_l, wk)
        v = jnp.einsum("bsd,dhk->bshk", xkv_l, wv)
        if bq is not None:
            q = q + bq
            k = k + bk
            v = v + bv
        return q, k, v

    xspec = P(dpspec, None, None)
    hspec = P(None, axis, None)
    kvspec = hspec if kv_sharded else P(None, None, None)
    hbspec = P(axis, None)
    kvbspec = hbspec if kv_sharded else P(None, None)
    out_h = P(dpspec, None, axis, None)
    out_kv = out_h if kv_sharded else P(dpspec, None, None, None)

    if has_bias:
        fn = shard_map(
            region, mesh=topo.mesh,
            in_specs=(xspec, xspec, hspec, kvspec, kvspec, hbspec, kvbspec, kvbspec),
            out_specs=(out_h, out_kv, out_kv), check_vma=False,
        )
        return fn(x, xkv if xkv is not None else x, p["wq"], p["wk"], p["wv"],
                  p["bq"], p["bk"], p["bv"])
    fn = shard_map(
        lambda x_l, xkv_l, wq, wk, wv: region(x_l, xkv_l, wq, wk, wv, None, None, None),
        mesh=topo.mesh,
        in_specs=(xspec, xspec, hspec, kvspec, kvspec),
        out_specs=(out_h, out_kv, out_kv), check_vma=False,
    )
    return fn(x, xkv if xkv is not None else x, p["wq"], p["wk"], p["wv"])


def explicit_tp_wo(out_heads: jax.Array, wo: jax.Array, topo) -> jax.Array:
    """Out-projection contraction over sharded heads with owned bf16 psum."""
    from jax.sharding import PartitionSpec as P

    axis = topo.model_axis
    dpspec = _batch_spec_entry(topo, out_heads.shape[0])

    def region(o_l, w_l):
        r = jnp.einsum("bshk,hkd->bsd", o_l, w_l)
        r = lax.optimization_barrier(r.astype(o_l.dtype))
        return lax.psum(r, axis)

    fn = shard_map(
        region, mesh=topo.mesh,
        in_specs=(P(dpspec, None, axis, None), P(axis, None, None)),
        out_specs=P(dpspec, None, None), check_vma=False,
    )
    return fn(out_heads, wo)
