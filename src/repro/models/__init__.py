from repro.models.model import ModelApi, build_model, input_specs

__all__ = ["ModelApi", "build_model", "input_specs"]
